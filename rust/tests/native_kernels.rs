//! Integration tier for the native kernels + workspace subsystem:
//! blocked-GEMM parity through the public linalg path, the steady-state
//! no-allocation invariant across whole solver drives, the serving-level
//! rank-deficient-window regression, and the oversize-batch contract.

use std::sync::Arc;
use std::time::Duration;

use deq_anderson::infer;
use deq_anderson::native::kernels;
use deq_anderson::native::linalg;
use deq_anderson::runtime::{
    Backend, HostTensor, NativeConfig, NativeEngine, SolverMeta,
};
use deq_anderson::server::{Router, RouterConfig, SchedMode};
use deq_anderson::solver::{self, SolveOptions, SolverKind};
use deq_anderson::util::rng::Rng;

/// Blocked/parallel GEMM must agree with the naive oracle on shapes that
/// are non-square, not multiples of any block size, and larger than one
/// cache tile — through the public `linalg::gemm` everything in `native/`
/// actually calls.
#[test]
fn linalg_gemm_parity_on_non_block_shapes() {
    let mut rng = Rng::new(77);
    for &(m, k, n) in &[(13usize, 29usize, 7usize), (3, 300, 520), (65, 17, 9)] {
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut want = vec![0.0f32; m * n];
        kernels::gemm_reference(&a, &b, m, k, n, &mut want);
        let mut got = vec![0.0f32; m * n];
        linalg::gemm(&a, &b, m, k, n, &mut got);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert!(
                (x - y).abs() < 1e-2,
                "({m},{k},{n})[{i}]: {x} vs {y}"
            );
        }
    }
}

fn solve_opts(e: &NativeEngine, kind: SolverKind) -> SolveOptions {
    SolveOptions {
        tol: 1e-4,
        max_iter: 40,
        ..SolveOptions::from_manifest(e, kind)
    }
}

/// The acceptance invariant of the pooled hot path: after one warm-up
/// solve has stocked the workspace, a repeat solve of the same shape
/// performs **zero** fresh buffer allocations — every per-iteration
/// tensor (f, norms, mixed iterate, Gram scratch, α) is a pool hit.
#[test]
fn steady_state_solves_allocate_nothing() {
    for kind in [SolverKind::Anderson, SolverKind::Hybrid, SolverKind::Forward] {
        let e = NativeEngine::tiny();
        let p = e.init_params().unwrap();
        let batch = 8;
        let n = e.manifest().model.latent_dim();
        let mut rng = Rng::new(9);
        let x_feat = HostTensor::f32(
            e.manifest().model.latent_shape(batch),
            rng.normal_vec(batch * n, 0.5),
        )
        .unwrap();
        let opts = solve_opts(&e, kind);
        let warm_report = solver::solve(&e, &p.tensors, &x_feat, &opts).unwrap();
        assert!(warm_report.iters() > 0);
        let warm = e.workspace_stats();
        let report = solver::solve(&e, &p.tensors, &x_feat, &opts).unwrap();
        let after = e.workspace_stats();
        assert_eq!(
            after.allocs, warm.allocs,
            "{:?}: steady-state solve allocated ({} -> {})",
            kind, warm.allocs, after.allocs
        );
        assert!(after.hits > warm.hits, "{kind:?}: pool was not exercised");
        // And the repeat solve is bit-identical to the warm one.
        assert_eq!(report.iters(), warm_report.iters());
        assert_eq!(
            report.z_star.f32s().unwrap(),
            warm_report.z_star.f32s().unwrap(),
            "{kind:?}: pooled buffers leaked state between solves"
        );
    }
}

/// End-to-end regression for the rank-deficient Anderson window: with
/// λ = 0 the scheduler's replication-seeded lane windows make H = GGᵀ
/// exactly singular on a lane's first mixed iteration.  The solve used
/// to abort (error reply to every waiter); it must now degrade that
/// iteration to a forward step and serve the request normally.
#[test]
fn serving_survives_rank_deficient_window() {
    let cfg = NativeConfig {
        solver: SolverMeta { lam: 0.0, ..NativeConfig::default().solver },
        ..NativeConfig::default()
    };
    let engine = Arc::new(NativeEngine::new(cfg));
    let dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let solver_opts =
        SolveOptions::from_manifest(engine.as_ref(), SolverKind::Anderson);
    let router = Router::start(
        engine,
        params,
        RouterConfig {
            solver: solver_opts,
            mode: SchedMode::IterationLevel,
            max_wait: Duration::from_millis(5),
            queue_cap: 64,
        },
    )
    .unwrap();
    let mut rng = Rng::new(21);
    let resp = router
        .infer_blocking(rng.normal_vec(dim, 1.0))
        .expect("rank-deficient first window must not abort the solve");
    assert!(resp.class < 10);
    assert!(resp.solver_iters > 0);
    router.shutdown();
}

/// Oversize batches are rejected where they enter, with an explicit
/// error naming the largest bucket — not silently clamped into a bucket
/// that cannot hold them.
#[test]
fn oversize_batch_is_rejected_explicitly() {
    let e = NativeEngine::tiny();
    let p = e.init_params().unwrap();
    let max_bucket = *e.config().buckets.last().unwrap();
    let count = max_bucket + 8;
    let dim = e.manifest().model.image_dim();
    let images = vec![0.1f32; count * dim];
    let opts = SolveOptions::from_manifest(&e, SolverKind::Forward);
    let err = infer::infer(&e, &p, &images, count, &opts).unwrap_err();
    assert!(
        format!("{err:#}").contains("exceeds the largest compiled bucket"),
        "unexpected error: {err:#}"
    );
}

/// The serving schedulers keep their own per-solve/per-lane pools warm:
/// after a first burst, a second identical burst through the
/// iteration-level scheduler adds no engine allocations.
#[test]
fn scheduler_steady_state_allocates_nothing() {
    let engine = Arc::new(NativeEngine::tiny());
    let stats_handle = engine.clone();
    let dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let solver_opts =
        SolveOptions::from_manifest(engine.as_ref() as &dyn Backend, SolverKind::Anderson);
    let router = Router::start(
        engine,
        params,
        RouterConfig {
            solver: solver_opts,
            mode: SchedMode::IterationLevel,
            max_wait: Duration::from_millis(5),
            queue_cap: 64,
        },
    )
    .unwrap();
    let mut rng = Rng::new(33);
    let burst = |router: &Router, rng: &mut Rng| {
        let rxs: Vec<_> = (0..4)
            .map(|_| router.submit(rng.normal_vec(dim, 1.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().expect("reply").expect("response");
        }
    };
    burst(&router, &mut rng);
    burst(&router, &mut rng);
    let warm = stats_handle.workspace_stats();
    burst(&router, &mut rng);
    let after = stats_handle.workspace_stats();
    assert_eq!(
        after.allocs, warm.allocs,
        "steady-state scheduler allocated ({} -> {})",
        warm.allocs, after.allocs
    );
    router.shutdown();
}

//! Integration tier for the native kernels + pack + pool + workspace
//! subsystem: microkernel/blocked GEMM parity through the public paths,
//! SIMD-vs-scalar bit-identity and bf16-pack parity through the public
//! dispatch surface, the steady-state no-allocation / no-repack /
//! no-spawn invariants across whole solver drives, pack-cache
//! invalidation across a training step, pool shutdown on engine drop,
//! the serving-level rank-deficient-window regression, and the
//! oversize-batch contract.

use std::sync::Arc;
use std::time::Duration;

use deq_anderson::infer;
use deq_anderson::model::ParamSet;
use deq_anderson::native::kernels;
use deq_anderson::native::linalg;
use deq_anderson::native::pack;
use deq_anderson::native::{PackPrecision, SimdLevel, WorkerPool};
use deq_anderson::runtime::{
    Backend, HostTensor, NativeConfig, NativeEngine, SolverMeta,
};
use deq_anderson::server::{Router, RouterConfig, SchedMode};
use deq_anderson::solver::{self, SolveClamps, SolveSpec, SolverKind};
use deq_anderson::util::rng::Rng;

/// Blocked/parallel GEMM must agree with the naive oracle on shapes that
/// are non-square, not multiples of any block size, and larger than one
/// cache tile — through the public `linalg::gemm` everything in `native/`
/// actually calls.
#[test]
fn linalg_gemm_parity_on_non_block_shapes() {
    let mut rng = Rng::new(77);
    for &(m, k, n) in &[(13usize, 29usize, 7usize), (3, 300, 520), (65, 17, 9)] {
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut want = vec![0.0f32; m * n];
        kernels::gemm_reference(&a, &b, m, k, n, &mut want);
        let mut got = vec![0.0f32; m * n];
        linalg::gemm(&a, &b, m, k, n, &mut got);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert!(
                (x - y).abs() < 1e-2,
                "({m},{k},{n})[{i}]: {x} vs {y}"
            );
        }
    }
}

/// Property sweep: the packed microkernel GEMM must agree with the naive
/// oracle on every odd shape — tails in all three dimensions, shapes
/// straddling the MR/NR/KC tile boundaries — and must be *bit-identical*
/// across chunk counts 1/2/4 on pools of 1/2/4 workers (each C row's
/// k-summation order is fixed by construction, so the partition cannot
/// change the arithmetic).
#[test]
fn packed_microkernel_gemm_parity_odd_shapes_and_threads() {
    let dims = [1usize, 3, 7, 17, 64, 129];
    let pools: Vec<(usize, WorkerPool)> =
        [1usize, 2, 4].into_iter().map(|t| (t, WorkerPool::new(t))).collect();
    let mut rng = Rng::new(99);
    for &m in &dims {
        for &k in &dims {
            for &n in &dims {
                let a = rng.normal_vec(m * k, 1.0);
                let b = rng.normal_vec(k * n, 1.0);
                let mut want = vec![0.0f32; m * n];
                kernels::gemm_reference(&a, &b, m, k, n, &mut want);
                let mut serial = vec![0.0f32; m * n];
                pack::gemm_micro(&a, &b, m, k, n, &mut serial);
                let tol = 1e-5 * (k as f32).sqrt();
                for (i, (x, y)) in serial.iter().zip(&want).enumerate() {
                    assert!(
                        (x - y).abs() <= tol,
                        "({m},{k},{n})[{i}]: micro {x} vs reference {y}"
                    );
                }
                for (threads, pool) in &pools {
                    let mut par = vec![0.0f32; m * n];
                    pack::gemm_micro_with(
                        &a, &b, m, k, n, &mut par, *threads, Some(pool), SimdLevel::from_env(),
                    );
                    assert_eq!(
                        par, serial,
                        "({m},{k},{n}) chunks={threads}: parallel diverged"
                    );
                }
            }
        }
    }
}

/// The explicit SIMD microkernel must be **bit-identical** to the scalar
/// oracle for f32 packs across the odd-shape sweep: the AVX2 path does
/// the same per-k-step multiply then add (no FMA contraction), so the
/// dispatch level can never change a solve trace.
#[test]
fn simd_dispatch_is_bit_identical_to_scalar_for_f32() {
    let dims = [1usize, 7, 17, 64, 129];
    let pool = WorkerPool::new(2);
    let mut rng = Rng::new(101);
    for &m in &dims {
        for &k in &dims {
            for &n in &dims {
                let a = rng.normal_vec(m * k, 1.0);
                let b = rng.normal_vec(k * n, 1.0);
                let mut scalar = vec![0.0f32; m * n];
                pack::gemm_micro_with(
                    &a, &b, m, k, n, &mut scalar, 2, Some(&pool), SimdLevel::Scalar,
                );
                let mut simd = vec![0.0f32; m * n];
                pack::gemm_micro_with(
                    &a, &b, m, k, n, &mut simd, 2, Some(&pool), SimdLevel::detect(),
                );
                assert_eq!(simd, scalar, "({m},{k},{n}): simd diverged");
            }
        }
    }
}

/// bf16 packed panels through the public GEMM path: within the
/// documented relative tolerance of the f32 result (storage rounds to
/// bf16, accumulation stays f32), at exactly half the resident bytes,
/// and bit-identical across SIMD levels (the widening load rounds
/// nowhere).
#[test]
fn bf16_pack_gemm_parity_and_footprint() {
    let mut rng = Rng::new(103);
    for &(m, k, n) in &[(17usize, 33usize, 9usize), (64, 128, 65)] {
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let bp32 = pack::PackedB::pack(&b, k, n);
        let bp16 = pack::PackedB::pack_with(&b, k, n, PackPrecision::Bf16);
        assert_eq!(bp16.packed_bytes() * 2, bp32.packed_bytes());
        let mut apack = vec![0.0f32; pack::apack_len(m, k)];
        let mut c32 = vec![0.0f32; m * n];
        pack::gemm_packed(&a, &bp32, m, &mut c32, &mut apack, SimdLevel::from_env());
        let mut c16 = vec![0.0f32; m * n];
        pack::gemm_packed(&a, &bp16, m, &mut c16, &mut apack, SimdLevel::from_env());
        let tol = 0.02 * (k as f32).sqrt();
        for (i, (x, y)) in c16.iter().zip(&c32).enumerate() {
            assert!((x - y).abs() <= tol, "({m},{k},{n})[{i}]: bf16 {x} vs f32 {y}");
        }
        let mut c16_scalar = vec![0.0f32; m * n];
        pack::gemm_packed(&a, &bp16, m, &mut c16_scalar, &mut apack, SimdLevel::Scalar);
        let mut c16_simd = vec![0.0f32; m * n];
        pack::gemm_packed(&a, &bp16, m, &mut c16_simd, &mut apack, SimdLevel::detect());
        assert_eq!(c16_simd, c16_scalar, "({m},{k},{n}): bf16 simd diverged");
    }
}

/// The pool-driven GEMV path: parity with a host dot product and
/// bit-stability across explicit chunk counts (the injectable-threads
/// fix — no global OnceLock latching the first env read).
#[test]
fn pooled_gemv_parity_across_thread_counts() {
    let mut rng = Rng::new(98);
    for &(m, n) in &[(1usize, 7usize), (17, 129), (129, 64), (64, 1)] {
        let a = rng.normal_vec(m * n, 1.0);
        let x = rng.normal_vec(n, 1.0);
        let mut serial = vec![0.0f32; m];
        kernels::gemv_with_threads(&a, &x, m, n, &mut serial, 1);
        for i in 0..m {
            let want: f32 =
                a[i * n..(i + 1) * n].iter().zip(&x).map(|(p, q)| p * q).sum();
            assert!(
                (serial[i] - want).abs() < 1e-3,
                "gemv ({m},{n})[{i}]: {} vs {want}",
                serial[i]
            );
        }
        for threads in [2usize, 4] {
            let mut par = vec![0.0f32; m];
            kernels::gemv_with_threads(&a, &x, m, n, &mut par, threads);
            assert_eq!(par, serial, "gemv ({m},{n}) threads={threads}");
        }
    }
}

fn solve_opts(e: &NativeEngine, kind: SolverKind) -> SolveSpec {
    SolveSpec {
        tol: 1e-4,
        max_iter: 40,
        ..SolveSpec::from_manifest(e, kind)
    }
}

/// The acceptance invariant of the pooled + packed hot path: after one
/// warm-up solve has stocked the workspace and the pack cache, a repeat
/// solve of the same shape performs **zero** fresh buffer allocations,
/// **zero** weight packing (pack hits only — no misses, invalidations,
/// or uncached packs), and **zero** thread spawns (the engine pool's
/// `spawned` counter never moves after construction).
#[test]
fn steady_state_solves_allocate_pack_and_spawn_nothing() {
    for kind in [SolverKind::Anderson, SolverKind::Hybrid, SolverKind::Forward] {
        let e = NativeEngine::tiny();
        let p = e.init_params().unwrap();
        let batch = 8;
        let n = e.manifest().model.latent_dim();
        let mut rng = Rng::new(9);
        let x_feat = HostTensor::f32(
            e.manifest().model.latent_shape(batch),
            rng.normal_vec(batch * n, 0.5),
        )
        .unwrap();
        let opts = solve_opts(&e, kind);
        let warm_report = solver::solve_spec(&e, &p.tensors, &x_feat, &opts).unwrap();
        assert!(warm_report.iters() > 0);
        let warm = e.workspace_stats();
        let warm_pool = e.pool_stats();
        let report = solver::solve_spec(&e, &p.tensors, &x_feat, &opts).unwrap();
        let after = e.workspace_stats();
        let after_pool = e.pool_stats();
        assert_eq!(
            after.allocs, warm.allocs,
            "{:?}: steady-state solve allocated ({} -> {})",
            kind, warm.allocs, after.allocs
        );
        assert!(after.hits > warm.hits, "{kind:?}: pool was not exercised");
        // Zero weight packing: the cached packs serve every iteration.
        assert_eq!(
            (after.pack_misses, after.pack_invalidations, after.pack_uncached),
            (warm.pack_misses, warm.pack_invalidations, warm.pack_uncached),
            "{kind:?}: steady-state solve re-packed weights"
        );
        assert!(
            after.pack_hits > warm.pack_hits,
            "{kind:?}: pack cache was not exercised"
        );
        // Zero thread spawns: workers exist from construction, only.
        assert_eq!(
            after_pool.spawned, warm_pool.spawned,
            "{kind:?}: steady-state solve spawned threads"
        );
        assert_eq!(after_pool.workers, warm_pool.workers);
        // And the repeat solve is bit-identical to the warm one.
        assert_eq!(report.iters(), warm_report.iters());
        assert_eq!(
            report.z_star.f32s().unwrap(),
            warm_report.z_star.f32s().unwrap(),
            "{kind:?}: pooled buffers leaked state between solves"
        );
    }
}

/// Pack-cache invalidation across a training step: `train_update`
/// produces new parameter tensors; once they are re-stamped into a
/// `ParamSet` (as the training loop does), the next `cell_step`
/// re-packs the cell weight **exactly once** and then serves every
/// subsequent call from cache — with results identical to a fresh
/// engine that never saw the old parameters.
#[test]
fn pack_cache_invalidation_after_train_update_repacks_once() {
    let e = NativeEngine::tiny();
    let p = e.init_params().unwrap();
    let mom = ParamSet::zeros_like(e.manifest());
    let np = p.tensors.len();
    let batch = 8;
    let meta = e.manifest().model.clone();
    let n = meta.latent_dim();
    let mut rng = Rng::new(31);
    let z = HostTensor::f32(meta.latent_shape(batch), rng.normal_vec(batch * n, 0.5))
        .unwrap();
    let x = HostTensor::f32(meta.latent_shape(batch), rng.normal_vec(batch * n, 0.5))
        .unwrap();

    // Warm the cache with the current parameters.
    let mut cell_in = p.tensors.clone();
    cell_in.push(z.clone());
    cell_in.push(x.clone());
    e.execute("cell_step", batch, &cell_in).unwrap();
    let warm = e.workspace_stats();
    assert!(warm.pack_misses >= 1);

    // One training step → new parameter tensors, stamped exactly as the
    // training loop stamps them.
    let mut tr_in: Vec<HostTensor> = p.tensors.clone();
    tr_in.extend(mom.tensors.iter().cloned());
    tr_in.push(HostTensor::f32(
        meta.latent_shape(batch),
        rng.normal_vec(batch * n, 0.5),
    )
    .unwrap());
    tr_in.push(HostTensor::f32(
        meta.image_shape(batch),
        rng.normal_vec(batch * meta.image_dim(), 0.5),
    )
    .unwrap());
    tr_in.push(
        HostTensor::i32(vec![batch], vec![0; batch]).unwrap(),
    );
    let mut out = e.execute("train_update", batch, &tr_in).unwrap();
    out.truncate(np); // params'; drop momentum/loss/correct
    let p2 = ParamSet::from_tensors(out);

    let before = e.workspace_stats();
    let mut cell_in2 = p2.tensors.clone();
    cell_in2.push(z.clone());
    cell_in2.push(x.clone());
    let first = e.execute("cell_step", batch, &cell_in2).unwrap();
    let after_first = e.workspace_stats();
    assert_eq!(
        after_first.pack_invalidations,
        before.pack_invalidations + 1,
        "exactly one re-pack for the new cell weight"
    );
    assert_eq!(after_first.pack_misses, before.pack_misses);

    let second = e.execute("cell_step", batch, &cell_in2).unwrap();
    let after_second = e.workspace_stats();
    assert_eq!(
        after_second.pack_invalidations, after_first.pack_invalidations,
        "second call must be served from cache"
    );
    assert!(after_second.pack_hits > after_first.pack_hits);
    assert_eq!(first[0].f32s().unwrap(), second[0].f32s().unwrap());

    // Identical to a fresh engine that only ever saw the new params.
    let fresh = NativeEngine::tiny();
    let fresh_out = fresh.execute("cell_step", batch, &cell_in2).unwrap();
    assert_eq!(
        first[0].f32s().unwrap(),
        fresh_out[0].f32s().unwrap(),
        "stale pack served after invalidation"
    );
}

/// Engine drop must join the worker pool: no detached threads leak past
/// the engine's lifetime (the probe counts workers that exited their
/// loop, which only happens through the pool's Drop).
#[test]
fn engine_drop_joins_pool_workers() {
    let e = NativeEngine::new(NativeConfig { threads: 3, ..NativeConfig::default() });
    let probe = e.pool().exit_probe();
    // Exercise the engine once so the pool has seen real work.
    let p = e.init_params().unwrap();
    let mut inputs = p.tensors.clone();
    inputs.push(HostTensor::zeros(e.manifest().model.latent_shape(1)));
    inputs.push(HostTensor::zeros(e.manifest().model.latent_shape(1)));
    e.execute("cell_step", 1, &inputs).unwrap();
    assert_eq!(e.pool_stats().workers, 3);
    assert_eq!(probe.load(std::sync::atomic::Ordering::SeqCst), 0);
    drop(e);
    assert_eq!(
        probe.load(std::sync::atomic::Ordering::SeqCst),
        3,
        "engine drop left pool workers running"
    );
}

/// End-to-end regression for the rank-deficient Anderson window: with
/// λ = 0 the scheduler's replication-seeded lane windows make H = GGᵀ
/// exactly singular on a lane's first mixed iteration.  The solve used
/// to abort (error reply to every waiter); it must now degrade that
/// iteration to a forward step and serve the request normally.
#[test]
fn serving_survives_rank_deficient_window() {
    let cfg = NativeConfig {
        solver: SolverMeta { lam: 0.0, ..NativeConfig::default().solver },
        ..NativeConfig::default()
    };
    let engine = Arc::new(NativeEngine::new(cfg));
    let dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let solver_opts =
        SolveSpec::from_manifest(engine.as_ref(), SolverKind::Anderson);
    let router = Router::start(
        engine,
        params,
        RouterConfig {
            solver: solver_opts,
            clamps: SolveClamps::default(),
            mode: SchedMode::IterationLevel,
            max_wait: Duration::from_millis(5),
            queue_cap: 64,
            replicas: 1,
            default_deadline: None,
            redrive_budget: 1,
        },
    )
    .unwrap();
    let mut rng = Rng::new(21);
    let resp = router
        .infer_blocking(rng.normal_vec(dim, 1.0))
        .expect("rank-deficient first window must not abort the solve");
    assert!(resp.class < 10);
    assert!(resp.solver_iters > 0);
    router.shutdown();
}

/// Oversize batches are rejected where they enter, with an explicit
/// error naming the largest bucket — not silently clamped into a bucket
/// that cannot hold them.
#[test]
fn oversize_batch_is_rejected_explicitly() {
    let e = NativeEngine::tiny();
    let p = e.init_params().unwrap();
    let max_bucket = *e.config().buckets.last().unwrap();
    let count = max_bucket + 8;
    let dim = e.manifest().model.image_dim();
    let images = vec![0.1f32; count * dim];
    let opts = SolveSpec::from_manifest(&e, SolverKind::Forward);
    let err = infer::infer(&e, &p, &images, count, &opts).unwrap_err();
    assert!(
        format!("{err:#}").contains("exceeds the largest compiled bucket"),
        "unexpected error: {err:#}"
    );
}

/// The serving schedulers keep their own per-solve/per-lane pools warm:
/// after a first burst, a second identical burst through the
/// iteration-level scheduler adds no engine allocations.
#[test]
fn scheduler_steady_state_allocates_nothing() {
    let engine = Arc::new(NativeEngine::tiny());
    let stats_handle = engine.clone();
    let dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let solver_opts =
        SolveSpec::from_manifest(engine.as_ref() as &dyn Backend, SolverKind::Anderson);
    let router = Router::start(
        engine,
        params,
        RouterConfig {
            solver: solver_opts,
            clamps: SolveClamps::default(),
            mode: SchedMode::IterationLevel,
            max_wait: Duration::from_millis(5),
            queue_cap: 64,
            replicas: 1,
            default_deadline: None,
            redrive_budget: 1,
        },
    )
    .unwrap();
    let mut rng = Rng::new(33);
    let burst = |router: &Router, rng: &mut Rng| {
        let rxs: Vec<_> = (0..4)
            .map(|_| router.submit(rng.normal_vec(dim, 1.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().expect("reply").expect("response");
        }
    };
    burst(&router, &mut rng);
    burst(&router, &mut rng);
    let warm = stats_handle.workspace_stats();
    burst(&router, &mut rng);
    let after = stats_handle.workspace_stats();
    assert_eq!(
        after.allocs, warm.allocs,
        "steady-state scheduler allocated ({} -> {})",
        warm.allocs, after.allocs
    );
    router.shutdown();
}

//! Serving-stack integration tests: router, dynamic batcher, TCP protocol.
//! Hermetic: they run on whatever backend `backend_from_dir` selects (the
//! pure-Rust `NativeEngine` when AOT artifacts are absent), so nothing
//! here skips in CI.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use deq_anderson::data;
use deq_anderson::runtime::{backend_from_dir, Backend};
use deq_anderson::server::{tcp, Router, RouterConfig};
use deq_anderson::solver::{SolveOptions, SolverKind};
use deq_anderson::util::json::{self, Json};

fn make_router(max_wait_ms: u64) -> (Arc<Router>, usize) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = backend_from_dir(dir).expect("backend");
    let image_dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let cfg = RouterConfig {
        solver: SolveOptions::from_manifest(engine.as_ref(), SolverKind::Anderson),
        max_wait: Duration::from_millis(max_wait_ms),
        queue_cap: 256,
    };
    (Arc::new(Router::start(engine, params, cfg).unwrap()), image_dim)
}

#[test]
fn single_request_roundtrip() {
    let (router, dim) = make_router(5);
    let (data, _, _) = data::load_auto(8, 8, 1);
    let resp = router.infer_blocking(data.image(0).to_vec()).unwrap();
    assert!(resp.class < 10);
    assert_eq!(resp.batch_size, 1);
    assert!(resp.latency > Duration::ZERO);
    assert_eq!(dim, data.image_dim());
}

#[test]
fn concurrent_requests_get_batched() {
    let (router, _) = make_router(25);
    let (data, _, _) = data::load_auto(16, 8, 2);
    // Submit 8 requests quickly; with a 25ms window they should share
    // batches rather than each going out alone.
    let receivers: Vec<_> = (0..8)
        .map(|i| router.submit(data.image(i).to_vec()).unwrap())
        .collect();
    let responses: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("response"))
        .collect();
    assert_eq!(responses.len(), 8);
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch > 1, "no batching happened (all singletons)");
    // All served, metrics recorded.
    assert_eq!(
        router
            .metrics
            .served
            .load(std::sync::atomic::Ordering::Relaxed),
        8
    );
}

#[test]
fn queue_depth_visible_while_waiting() {
    let (router, dim) = make_router(1_000);
    let img = vec![0.0f32; dim];
    let _r1 = router.submit(img.clone()).unwrap();
    let _r2 = router.submit(img).unwrap();
    assert!(router.queue_depth() <= 2);
}

#[test]
fn tcp_protocol_end_to_end() {
    let (router, dim) = make_router(5);
    let addr = "127.0.0.1:17973";
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = tcp::serve_tcp(router, dim, addr);
        });
    }
    std::thread::sleep(Duration::from_millis(300));

    let mut stream = TcpStream::connect(addr).expect("connect");
    // ping
    stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));

    // malformed
    line.clear();
    stream.write_all(b"{nope}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    // wrong image size
    line.clear();
    stream.write_all(b"{\"image\":[1,2,3]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    // real request
    let (data, _, _) = data::load_auto(4, 4, 3);
    let img: Vec<String> =
        data.image(0).iter().map(|v| format!("{v:.4}")).collect();
    let req = format!("{{\"id\":7,\"image\":[{}]}}\n", img.join(","));
    line.clear();
    stream.write_all(req.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("id").and_then(Json::as_i64), Some(7));
    let class = v.get("class").and_then(Json::as_i64).expect("class");
    assert!((0..10).contains(&class));

    // stats
    line.clear();
    stream.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("served="));
}

#[test]
fn router_shutdown_is_clean() {
    let (router, _) = make_router(5);
    let router = Arc::try_unwrap(router).ok().expect("sole owner");
    router.shutdown();
}

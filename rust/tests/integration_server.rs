//! Serving-stack integration tests: router, iteration-level scheduler,
//! batch-granular baseline, TCP protocol.
//! Hermetic: they run on whatever backend `backend_from_dir` selects (the
//! pure-Rust `NativeEngine` when AOT artifacts are absent), so nothing
//! here skips in CI.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use deq_anderson::data;
use deq_anderson::infer;
use deq_anderson::runtime::{backend_from_dir, Backend};
use deq_anderson::server::{
    tcp, Router, RouterConfig, SchedMode, SubmitRejection, COLD_RETRY_PRIOR_MS,
};
use deq_anderson::solver::{SolveClamps, SolveOverrides, SolveSpec, SolverKind};
use deq_anderson::util::json::{self, Json};

fn engine() -> Arc<dyn Backend> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    backend_from_dir(dir).expect("backend")
}

fn make_router(max_wait_ms: u64, mode: SchedMode) -> (Arc<Router>, usize) {
    make_router_n(max_wait_ms, mode, 1, 256)
}

fn make_router_n(
    max_wait_ms: u64,
    mode: SchedMode,
    replicas: usize,
    queue_cap: usize,
) -> (Arc<Router>, usize) {
    let engine = engine();
    let image_dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let cfg = RouterConfig {
        solver: SolveSpec::from_manifest(engine.as_ref(), SolverKind::Anderson),
        clamps: SolveClamps::default(),
        mode,
        max_wait: Duration::from_millis(max_wait_ms),
        queue_cap,
        replicas,
        default_deadline: None,
        redrive_budget: 1,
    };
    (Arc::new(Router::start(engine, params, cfg).unwrap()), image_dim)
}

/// Scale an image to modulate solve difficulty on the tanh cell: large
/// amplitudes saturate it (fast convergence), small ones leave it near
/// its linear regime (slow, rate ≈ the cell's spectral radius).
fn scaled(image: &[f32], scale: f32) -> Vec<f32> {
    image.iter().map(|&v| v * scale).collect()
}

#[test]
fn single_request_roundtrip() {
    // Default mode: the iteration-level scheduler.
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 1);
    let resp = router.infer_blocking(data.image(0).to_vec()).unwrap();
    assert!(resp.class < 10);
    assert_eq!(resp.batch_size, 1);
    assert!(resp.latency > Duration::ZERO);
    assert!(resp.solver_iters > 0);
    assert!(resp.solver_fevals >= resp.solver_iters);
    assert!(resp.converged, "default-tol solve should converge");
    assert_eq!(dim, data.image_dim());
}

#[test]
fn concurrent_requests_get_batched() {
    // The batch-granular baseline still batches fire-and-wait style.
    let (router, _) = make_router(25, SchedMode::BatchGranular);
    let (data, _, _) = data::load_auto(16, 8, 2);
    // Submit 8 requests quickly; with a 25ms window they should share
    // batches rather than each going out alone.
    let receivers: Vec<_> = (0..8)
        .map(|i| router.submit(data.image(i).to_vec()).unwrap())
        .collect();
    let responses: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("reply").expect("response"))
        .collect();
    assert_eq!(responses.len(), 8);
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch > 1, "no batching happened (all singletons)");
    // All served, metrics recorded.
    assert_eq!(
        router
            .metrics
            .served
            .load(std::sync::atomic::Ordering::Relaxed),
        8
    );
}

#[test]
fn submit_rejects_wrong_image_size() {
    // Validated at submission, so a malformed request can never fail a
    // whole batch-granular batch (or waste a scheduler lane).
    let (router, dim) = make_router(5, SchedMode::BatchGranular);
    assert!(router.submit(vec![0.0; dim + 1]).is_err());
    assert!(router.submit(Vec::new()).is_err());
}

#[test]
fn queue_depth_visible_while_waiting() {
    let (router, dim) = make_router(1_000, SchedMode::BatchGranular);
    let img = vec![0.0f32; dim];
    let _r1 = router.submit(img.clone()).unwrap();
    let _r2 = router.submit(img).unwrap();
    assert!(router.queue_depth() <= 2);
}

#[test]
fn stiff_sample_does_not_delay_easy_sample() {
    // The point of iteration-level scheduling: an easy sample retires the
    // iteration it converges, even while a stiff co-rider keeps going.
    let (router, _) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 9);
    let rx_stiff = router.submit(scaled(data.image(0), 0.03)).unwrap();
    let rx_easy = router.submit(scaled(data.image(1), 3.0)).unwrap();
    let stiff = rx_stiff.recv().expect("reply").expect("stiff response");
    let easy = rx_easy.recv().expect("reply").expect("easy response");
    assert!(
        easy.solver_iters < stiff.solver_iters,
        "easy took {} iters, stiff {} — per-sample retirement broken",
        easy.solver_iters,
        stiff.solver_iters
    );
    assert!(
        easy.latency < stiff.latency,
        "easy latency {:?} not below stiff {:?}",
        easy.latency,
        stiff.latency
    );
    // Per-sample counters, not the batch max, ride the response.
    assert_eq!(easy.solver_fevals, easy.solver_iters);
    let occ = router.metrics.lane_occupancy.lock().unwrap().count();
    assert!(occ > 0, "scheduler recorded no iterations");
}

#[test]
fn per_sample_early_exit_matches_batch_granular_solve() {
    // Property-style sweep: a mixed-difficulty batch solved with
    // per-sample freezing must return the same logits (within tol-level
    // slack) as each sample solved alone to its own convergence — and
    // must charge strictly fewer fevals than lockstep accounting.
    let e = engine();
    let params = e.init_params().unwrap();
    let opts = SolveSpec {
        tol: 1e-4,
        max_iter: 80,
        ..SolveSpec::from_manifest(e.as_ref(), SolverKind::Anderson)
    };
    for seed in 0..4u64 {
        let (data, _, _) = data::load_auto(8, 8, seed + 20);
        let images: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                let s = if i % 2 == 0 { 3.0 } else { 0.03 };
                scaled(data.image(i), s)
            })
            .collect();
        let flat: Vec<f32> = images.concat();
        let batched = infer::infer(e.as_ref(), &params, &flat, 8, &opts).unwrap();
        assert_eq!(batched.sample_iters.len(), 8);
        for (i, image) in images.iter().enumerate() {
            let solo = infer::infer(e.as_ref(), &params, image, 1, &opts).unwrap();
            for (a, b) in batched.logits[i].iter().zip(&solo.logits[0]) {
                assert!(
                    (a - b).abs() < 1e-2,
                    "seed={seed} sample {i}: logits diverged ({a} vs {b})"
                );
            }
            // Argmax parity wherever the solo margin is decisive.
            let row = &solo.logits[0];
            let mut sorted = row.clone();
            sorted.sort_by(|x, y| y.partial_cmp(x).unwrap());
            if sorted[0] - sorted[1] > 0.05 {
                assert_eq!(
                    batched.predictions[i], solo.predictions[0],
                    "seed={seed} sample {i}: prediction flipped"
                );
            }
            // Early exit is per-sample: the lane's own count matches the
            // solo solve (both freeze at the same tol crossing).
            assert_eq!(
                batched.sample_iters[i], solo.sample_iters[0],
                "seed={seed} sample {i}: lane iters diverged from solo"
            );
        }
        // Strictly fewer fevals than every lane paying the slowest lane.
        let total: usize = batched.sample_fevals.iter().sum();
        assert!(
            total < batched.solver_fevals * 8,
            "seed={seed}: {total} fevals, lockstep would be {}",
            batched.solver_fevals * 8
        );
    }
}

#[test]
fn burst_larger_than_biggest_bucket_is_split_not_clamped() {
    // Satellite audit of the old `pick_bucket` clamp: a queue deeper than
    // the largest compiled bucket must be served as multiple batches
    // (each within a real bucket), never truncated or clamped into a
    // bucket that cannot hold it.  40 requests over max bucket 32 → at
    // least two batch-granular batches, every single one answered.
    let (router, _) = make_router(10, SchedMode::BatchGranular);
    let (data, _, _) = data::load_auto(8, 8, 5);
    let total = 40usize;
    let receivers: Vec<_> = (0..total)
        .map(|i| router.submit(data.image(i % 8).to_vec()).unwrap())
        .collect();
    let responses: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("reply").expect("response"))
        .collect();
    assert_eq!(responses.len(), total, "some requests were dropped");
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch <= 32, "a batch exceeded the biggest bucket");
    assert_eq!(
        router
            .metrics
            .served
            .load(std::sync::atomic::Ordering::Relaxed),
        total as u64
    );
}

#[test]
fn shutdown_drains_queue_with_error_replies() {
    // Long max_wait so the batch never fires: submissions are still
    // queued when shutdown lands, and must get an explicit error reply
    // instead of a silently dropped sender.
    let (router, dim) = make_router(60_000, SchedMode::BatchGranular);
    let rxs: Vec<_> = (0..4)
        .map(|_| router.submit(vec![0.0; dim]).unwrap())
        .collect();
    let router = Arc::try_unwrap(router).ok().expect("sole owner");
    router.shutdown();
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(_)) => {} // served before shutdown landed — also fine
            Ok(Err(fail)) => {
                let msg = fail.to_string();
                assert!(msg.contains("shutting down"), "unexpected error: {msg}")
            }
            Err(e) => panic!("request dropped without a reply: {e}"),
        }
    }
}

#[test]
fn tcp_protocol_end_to_end() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let addr = "127.0.0.1:17973";
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = tcp::serve_tcp(router, dim, addr);
        });
    }
    std::thread::sleep(Duration::from_millis(300));

    let mut stream = TcpStream::connect(addr).expect("connect");
    // ping
    stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));

    // malformed
    line.clear();
    stream.write_all(b"{nope}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    // wrong image size
    line.clear();
    stream.write_all(b"{\"image\":[1,2,3]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    // real request — the reply carries this sample's own solver counters.
    let (data, _, _) = data::load_auto(4, 4, 3);
    let img: Vec<String> =
        data.image(0).iter().map(|v| format!("{v:.4}")).collect();
    let req = format!("{{\"id\":7,\"image\":[{}]}}\n", img.join(","));
    line.clear();
    stream.write_all(req.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("id").and_then(Json::as_i64), Some(7));
    let class = v.get("class").and_then(Json::as_i64).expect("class");
    assert!((0..10).contains(&class));
    let iters = v
        .get("solver_iters")
        .and_then(Json::as_i64)
        .expect("solver_iters");
    assert!(iters > 0);
    assert!(v.get("solver_fevals").is_some());

    // stats
    line.clear();
    stream.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("served="));
}

#[test]
fn router_shutdown_is_clean() {
    let (router, _) = make_router(5, SchedMode::IterationLevel);
    let router = Arc::try_unwrap(router).ok().expect("sole owner");
    router.shutdown();
}

// ---------------------------------------------------------------------------
// Per-request solver control (SolveSpec/SolveOverrides end to end)
// ---------------------------------------------------------------------------

/// The tentpole acceptance test: one iteration-level batch mixing
/// different per-request tolerances.  Each lane must retire at *its own*
/// tol — with correct per-sample `solver_iters` and `converged` — and
/// the response must echo the effective spec the lane ran under.
#[test]
fn heterogeneous_tolerances_retire_each_lane_at_its_own_tol() {
    let (router, _) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 13);
    // One identical moderately-stiff image for every request, so lane
    // retirement order is driven purely by the per-request tolerances.
    let img = scaled(data.image(0), 0.2);
    let loose = SolveOverrides { tol: Some(0.3), ..Default::default() };
    let tight = SolveOverrides {
        tol: Some(1e-4),
        max_iter: Some(400),
        ..Default::default()
    };
    let rx_loose = router.submit_with(img.clone(), &loose).unwrap();
    let rx_mid = router.submit(img.clone()).unwrap(); // router default tol
    let rx_tight = router.submit_with(img, &tight).unwrap();
    let loose_r = rx_loose.recv().expect("reply").expect("loose response");
    let mid_r = rx_mid.recv().expect("reply").expect("mid response");
    let tight_r = rx_tight.recv().expect("reply").expect("tight response");

    // Every lane converged at its own tolerance...
    assert!(loose_r.converged, "loose lane did not converge");
    assert!(mid_r.converged, "default lane did not converge");
    assert!(tight_r.converged, "tight lane did not converge");
    // ...and the responses echo the effective per-lane specs.
    assert_eq!(loose_r.spec.tol, 0.3);
    assert_eq!(tight_r.spec.tol, 1e-4);
    assert_eq!(tight_r.spec.max_iter, 400);
    assert!(
        mid_r.spec.tol < loose_r.spec.tol && mid_r.spec.tol > tight_r.spec.tol,
        "router default tol {} not between the overrides",
        mid_r.spec.tol
    );
    // A lane retires the iteration it crosses ITS tol: looser lanes exit
    // earlier on the same input.
    assert!(
        loose_r.solver_iters < tight_r.solver_iters,
        "loose lane took {} iters, tight {} — per-lane tol retirement broken",
        loose_r.solver_iters,
        tight_r.solver_iters
    );
    assert!(loose_r.solver_iters <= mid_r.solver_iters);
    assert!(mid_r.solver_iters <= tight_r.solver_iters);
    // Per-sample accounting rides each lane's own counters.
    assert_eq!(loose_r.solver_fevals, loose_r.solver_iters);
    assert_eq!(tight_r.solver_fevals, tight_r.solver_iters);
}

/// A per-request `max_iter` override cuts a lane off at its own budget
/// with `converged: false` and the true iteration count.
#[test]
fn max_iter_override_cuts_off_lane_unconverged() {
    let (router, _) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 17);
    let img = scaled(data.image(0), 0.03); // stiff: cannot hit 1e-5 in 3 iters
    let ov = SolveOverrides {
        tol: Some(1e-5),
        max_iter: Some(3),
        ..Default::default()
    };
    let resp = router.infer_blocking_with(img, &ov).unwrap();
    assert_eq!(resp.solver_iters, 3, "lane ignored its max_iter budget");
    assert!(!resp.converged, "3 stiff iterations cannot reach 1e-5");
    assert_eq!(resp.spec.max_iter, 3);
    assert_eq!(resp.spec.tol, 1e-5);
}

/// A per-request solver-kind override runs inside a router whose default
/// is a different kind (heterogeneous policies in one lane set).
#[test]
fn solver_kind_override_serves_alongside_default_kind() {
    let (router, _) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 19);
    let img = scaled(data.image(0), 3.0);
    let fwd = SolveOverrides {
        kind: Some(SolverKind::Forward),
        ..Default::default()
    };
    let rx_fwd = router.submit_with(img.clone(), &fwd).unwrap();
    let rx_def = router.submit(img).unwrap();
    let fwd_r = rx_fwd.recv().expect("reply").expect("forward response");
    let def_r = rx_def.recv().expect("reply").expect("default response");
    assert_eq!(fwd_r.spec.kind, SolverKind::Forward);
    assert_eq!(def_r.spec.kind, SolverKind::Anderson);
    assert!(fwd_r.converged && def_r.converged);
    // Both policies converge to the same equilibrium: logits agree to
    // tol-level slack (argmax equality is skipped — an untrained model
    // can have sub-tol logit margins).
    for (a, b) in fwd_r.logits.iter().zip(&def_r.logits) {
        assert!((a - b).abs() < 5e-2, "logits diverged: {a} vs {b}");
    }
}

/// Malformed overrides error at submission — synchronously, before any
/// lane or batch is touched — and greedy ones are clamped, not rejected.
#[test]
fn overrides_validate_and_clamp_at_submission() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let bad_tol = SolveOverrides { tol: Some(-1.0), ..Default::default() };
    let err = router
        .submit_with(vec![0.0; dim], &bad_tol)
        .unwrap_err()
        .to_string();
    assert!(err.contains("override tol"), "unexpected error: {err}");
    let bad_iter = SolveOverrides { max_iter: Some(0), ..Default::default() };
    let err = router
        .submit_with(vec![0.0; dim], &bad_iter)
        .unwrap_err()
        .to_string();
    assert!(err.contains("override max_iter"), "unexpected error: {err}");

    // Greedy values clamp to the router's bounds (default clamps:
    // min_tol 1e-6, max_iter 500) and the echo shows the clamp.
    let (data, _, _) = data::load_auto(8, 8, 23);
    let greedy = SolveOverrides {
        tol: Some(1e-30),
        max_iter: Some(1_000_000),
        ..Default::default()
    };
    let resp = router
        .infer_blocking_with(scaled(data.image(0), 3.0), &greedy)
        .unwrap();
    assert_eq!(resp.spec.tol, SolveClamps::default().min_tol);
    assert_eq!(resp.spec.max_iter, SolveClamps::default().max_iter);
}

/// Per-request overrides also work through the batch-granular baseline:
/// requests with distinct effective specs are solved as separate
/// sub-batches, each billed by its own lockstep solve.
#[test]
fn batch_granular_mode_honors_per_request_specs() {
    let (router, _) = make_router(25, SchedMode::BatchGranular);
    let (data, _, _) = data::load_auto(8, 8, 29);
    let img = scaled(data.image(0), 0.2);
    let loose = SolveOverrides { tol: Some(0.3), ..Default::default() };
    let rx_loose = router.submit_with(img.clone(), &loose).unwrap();
    let rx_def = router.submit(img).unwrap();
    let loose_r = rx_loose.recv().expect("reply").expect("loose response");
    let def_r = rx_def.recv().expect("reply").expect("default response");
    assert_eq!(loose_r.spec.tol, 0.3);
    assert!(def_r.spec.tol < 0.3);
    assert!(loose_r.converged && def_r.converged);
    // The loose sub-batch stops at its looser tol.
    assert!(loose_r.solver_iters <= def_r.solver_iters);
}

// ---------------------------------------------------------------------------
// TCP protocol error paths: golden JSON replies
// ---------------------------------------------------------------------------

/// The exact JSON of every protocol error reply is part of the wire
/// format.  If one of these fails because of an intentional message
/// change, update the string here AND in the protocol docs — never relax
/// the comparison.
#[test]
fn tcp_error_replies_are_golden() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let reply = |line: &str| json::to_string(&tcp::process_line(&router, dim, line));

    // Malformed JSON (parser error, with byte offset).  The reply embeds
    // the parser's message, with its inner quote JSON-escaped.
    assert_eq!(
        reply("{nope}"),
        "{\"error\":\"malformed json: json parse error at byte 1: expected '\\\"', found Some('n')\"}"
    );
    // Missing image array.
    assert_eq!(reply("{\"id\":1}"), "{\"error\":\"missing 'image' array\"}");
    // Wrong image dimension.
    assert_eq!(
        reply("{\"image\":[1,2,3]}"),
        format!("{{\"error\":\"image has 3 values, model wants {dim}\"}}")
    );
    // Non-numeric image element: an explicit per-element error.  The old
    // `filter_map(Json::as_f64)` silently dropped the element and
    // misreported the image as short (or, worse, passed a shifted image
    // when the length happened to still match).
    assert_eq!(
        reply("{\"image\":[1,\"x\",3]}"),
        "{\"error\":\"image[1] is not a number\"}"
    );
    // ...including at the correct length, where the old code shifted
    // values instead of erroring.
    let mut vals = vec!["0"; dim];
    vals[1] = "\"x\"";
    assert_eq!(
        reply(&format!("{{\"image\":[{}]}}", vals.join(","))),
        "{\"error\":\"image[1] is not a number\"}"
    );
    // Unknown command.
    assert_eq!(
        reply("{\"cmd\":\"warp\"}"),
        "{\"error\":\"unknown cmd 'warp'\"}"
    );

    // Override shape/value errors ride a correctly-sized image.
    let zeros = vec!["0"; dim].join(",");
    let with = |extra: &str| format!("{{\"image\":[{zeros}],{extra}}}");
    assert_eq!(
        reply(&with("\"solver\":\"warp\"")),
        "{\"error\":\"unknown solver 'warp' (expected forward|anderson|hybrid|auto)\"}"
    );
    assert_eq!(
        reply(&with("\"solver\":7")),
        "{\"error\":\"override 'solver' must be a string\"}"
    );
    assert_eq!(
        reply(&with("\"tol\":\"tight\"")),
        "{\"error\":\"override 'tol' must be a number\"}"
    );
    assert_eq!(
        reply(&with("\"tol\":-0.5")),
        "{\"error\":\"override tol must be a positive finite number, got -0.5\"}"
    );
    assert_eq!(
        reply(&with("\"max_iter\":2.5")),
        "{\"error\":\"override 'max_iter' must be a positive integer\"}"
    );
    assert_eq!(
        reply(&with("\"max_iter\":0")),
        "{\"error\":\"override 'max_iter' must be a positive integer\"}"
    );
    assert_eq!(
        reply(&with("\"gram\":\"fast\"")),
        "{\"error\":\"override 'gram' must be \\\"exact\\\" or a positive integer\"}"
    );
    assert_eq!(
        reply(&with("\"gram\":0")),
        "{\"error\":\"override 'gram' must be \\\"exact\\\" or a positive integer\"}"
    );
    assert_eq!(
        reply(&with("\"gram\":2.5")),
        "{\"error\":\"override 'gram' must be \\\"exact\\\" or a positive integer\"}"
    );
    // The streaming opt-in flag must be a boolean.
    assert_eq!(
        reply(&with("\"stream\":\"yes\"")),
        "{\"error\":\"'stream' must be a boolean\"}"
    );
}

/// A successful TCP reply echoes the effective spec (dyadic override
/// values, so the float rendering is exact).
#[test]
fn tcp_reply_echoes_effective_spec() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(4, 4, 31);
    let img: Vec<String> =
        scaled(data.image(0), 3.0).iter().map(|v| format!("{v:.4}")).collect();
    let line = format!(
        "{{\"id\":9,\"image\":[{}],\"solver\":\"forward\",\"tol\":0.25,\"max_iter\":7}}",
        img.join(",")
    );
    let v = tcp::process_line(&router, dim, &line);
    assert_eq!(v.get("error"), None, "unexpected error: {v:?}");
    assert_eq!(v.get("id").and_then(Json::as_i64), Some(9));
    assert_eq!(v.get("solver").and_then(Json::as_str), Some("forward"));
    assert_eq!(v.get("tol").and_then(Json::as_f64), Some(0.25));
    assert_eq!(v.get("max_iter").and_then(Json::as_i64), Some(7));
    // No gram override → the effective spec echoes the exact default.
    assert_eq!(v.get("gram").and_then(Json::as_str), Some("exact"));
    assert!(v.get("converged").and_then(Json::as_bool).is_some());
    let iters = v.get("solver_iters").and_then(Json::as_i64).unwrap();
    assert!((1..=7).contains(&iters), "iters {iters} escaped the override");
}

/// A per-request sketched-Gram override rides the adaptive knobs through
/// TCP and is echoed back as the sketch dimension (the exact form echoes
/// as the string); afterwards the stats command reports the resident
/// pack-cache footprint gauges.
#[test]
fn tcp_gram_override_echo_and_stats_report_pack_footprint() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(4, 4, 37);
    let img: Vec<String> =
        scaled(data.image(0), 1.0).iter().map(|v| format!("{v:.4}")).collect();
    let line = format!(
        "{{\"id\":3,\"image\":[{}],\"adaptive\":true,\"gram\":32}}",
        img.join(",")
    );
    let v = tcp::process_line(&router, dim, &line);
    assert_eq!(v.get("error"), None, "unexpected error: {v:?}");
    assert_eq!(v.get("gram").and_then(Json::as_f64), Some(32.0));
    let line =
        format!("{{\"id\":4,\"image\":[{}],\"gram\":\"exact\"}}", img.join(","));
    let v = tcp::process_line(&router, dim, &line);
    assert_eq!(v.get("error"), None, "unexpected error: {v:?}");
    assert_eq!(v.get("gram").and_then(Json::as_str), Some("exact"));

    // The serving backend has packed weights by now: the footprint
    // gauges show resident f32 packs and (at the default precision) no
    // bf16 packs.
    let v = tcp::process_line(&router, dim, "{\"cmd\":\"stats\"}");
    let hot = v.get("hot_path").expect("hot_path stats");
    let f32b = hot.get("pack_bytes_f32").and_then(Json::as_f64).unwrap();
    let bf16b = hot.get("pack_bytes_bf16").and_then(Json::as_f64).unwrap();
    let entries = hot.get("pack_entries").and_then(Json::as_f64).unwrap();
    assert!(f32b > 0.0, "no f32 pack bytes resident after serving");
    assert_eq!(bf16b, 0.0, "default precision must never pack bf16");
    assert!(entries >= 1.0, "no resident pack entries after serving");
}

/// Adaptive-policy satellite: one iteration-level window mixes lanes
/// running the condition-monitored adaptive policy (randomized knobs)
/// with fixed-window lanes, all through the TCP request path.  Every
/// lane must retire inside its own budget and each reply must echo the
/// effective adaptivity fields that lane actually ran under — adaptive
/// lanes their overrides, fixed lanes the router defaults.
#[test]
fn tcp_mixes_adaptive_and_fixed_lanes_in_one_bucket() {
    let (router, dim) = make_router(25, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 41);

    // Small deterministic LCG: the adaptive/fixed split, the knob
    // values, and the per-lane stiffness vary across lanes but the test
    // stays reproducible.
    let mut state = 0x5EED_CAFEu64;
    let mut next = move |m: u32| -> u32 {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        ((state >> 33) as u32) % m
    };

    // Exactly-representable f32 knob values so the shortest-decimal echo
    // compares exactly after the f64 parse.
    const ERRORFACTORS: [f32; 3] = [10.0, 100.0, 1000.0];
    const COND_MAXES: [f32; 3] = [1e4, 1e6, 1e8];

    struct Lane {
        id: i64,
        adaptive: bool,
        safeguard: bool,
        errorfactor: Option<f32>,
        cond_max: Option<f32>,
        max_iter: usize,
        line: String,
    }

    let lanes: Vec<Lane> = (0..6)
        .map(|i| {
            // Force at least one lane of each flavor into the bucket.
            let adaptive = match i {
                0 => true,
                1 => false,
                _ => next(2) == 0,
            };
            let safeguard = adaptive && next(2) == 0;
            let errorfactor =
                adaptive.then(|| ERRORFACTORS[next(3) as usize]);
            let cond_max = adaptive.then(|| COND_MAXES[next(3) as usize]);
            let max_iter = 40 + 20 * next(4) as usize;
            let scale = [0.4f32, 1.0, 3.0][next(3) as usize];
            let img: Vec<String> = scaled(data.image(i as usize), scale)
                .iter()
                .map(|v| format!("{v:.4}"))
                .collect();
            let mut line = format!(
                "{{\"id\":{i},\"image\":[{}],\"tol\":0.05,\"max_iter\":{max_iter}",
                img.join(",")
            );
            if adaptive {
                line.push_str(&format!(
                    ",\"adaptive\":true,\"safeguard\":{safeguard},\
\"errorfactor\":{},\"cond_max\":{}",
                    errorfactor.unwrap(),
                    cond_max.unwrap()
                ));
            }
            line.push('}');
            Lane { id: i, adaptive, safeguard, errorfactor, cond_max, max_iter, line }
        })
        .collect();

    // Fire all six lanes concurrently so the 25ms window batches them
    // into shared buckets.
    let replies: Vec<(usize, Json)> = std::thread::scope(|s| {
        let handles: Vec<_> = lanes
            .iter()
            .enumerate()
            .map(|(i, lane)| {
                let router = router.clone();
                let line = lane.line.clone();
                s.spawn(move || (i, tcp::process_line(&router, dim, &line)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("lane thread")).collect()
    });

    let base = SolveSpec::from_manifest(engine().as_ref(), SolverKind::Anderson);
    for (i, v) in replies {
        let lane = &lanes[i];
        assert_eq!(v.get("error"), None, "lane {i} errored: {v:?}");
        assert_eq!(v.get("id").and_then(Json::as_i64), Some(lane.id));
        // Per-lane retirement: the lane stopped inside its own budget.
        let iters = v
            .get("solver_iters")
            .and_then(Json::as_i64)
            .expect("solver_iters") as usize;
        assert!(
            (1..=lane.max_iter).contains(&iters),
            "lane {i} ran {iters} iters past its max_iter {}",
            lane.max_iter
        );
        // Effective-spec echo: adaptive lanes see their overrides,
        // fixed lanes the router defaults.
        assert_eq!(
            v.get("adaptive").and_then(Json::as_bool),
            Some(lane.adaptive),
            "lane {i} adaptive echo"
        );
        assert_eq!(
            v.get("safeguard").and_then(Json::as_bool),
            Some(lane.safeguard),
            "lane {i} safeguard echo"
        );
        let want_ef = lane.errorfactor.unwrap_or(base.errorfactor) as f64;
        let want_cm = lane.cond_max.unwrap_or(base.cond_max) as f64;
        assert_eq!(
            v.get("errorfactor").and_then(Json::as_f64),
            Some(want_ef),
            "lane {i} errorfactor echo"
        );
        assert_eq!(
            v.get("cond_max").and_then(Json::as_f64),
            Some(want_cm),
            "lane {i} cond_max echo"
        );
        assert!(
            v.get("converged").and_then(Json::as_bool).is_some(),
            "lane {i} missing converged"
        );
    }
    // Sanity: the randomized split really did mix policies.
    assert!(lanes.iter().any(|l| l.adaptive) && lanes.iter().any(|l| !l.adaptive));
}

// ---------------------------------------------------------------------------
// Multiplexed wire protocol: ids, streaming, shedding, replicas
// ---------------------------------------------------------------------------

/// Spawn a TCP server for `router` on `addr` and connect one client.
fn serve_and_connect(
    router: &Arc<Router>,
    dim: usize,
    addr: &'static str,
    max_inflight: usize,
) -> (TcpStream, BufReader<TcpStream>) {
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = tcp::serve_tcp_with(router, dim, addr, max_inflight);
        });
    }
    std::thread::sleep(Duration::from_millis(300));
    let stream = TcpStream::connect(addr).expect("connect");
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn read_frame(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).expect("read frame");
    json::parse(line.trim()).expect("parse frame")
}

/// The heart of multiplexing: two requests pipelined on one connection,
/// stiff first — and the *easy* reply comes back first, matched by the
/// client-chosen string id, not by submission order.
#[test]
fn tcp_replies_are_matched_by_id_not_order() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (mut stream, mut reader) =
        serve_and_connect(&router, dim, "127.0.0.1:17974", 64);
    let (data, _, _) = data::load_auto(8, 8, 9);
    let fmt = |img: &[f32]| -> String {
        img.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(",")
    };
    let stiff = format!(
        "{{\"id\":\"stiff\",\"image\":[{}],\"tol\":1e-5,\"max_iter\":400}}\n",
        fmt(&scaled(data.image(0), 0.03))
    );
    let easy = format!(
        "{{\"id\":\"easy\",\"image\":[{}],\"tol\":0.3}}\n",
        fmt(&scaled(data.image(1), 3.0))
    );
    stream.write_all(stiff.as_bytes()).unwrap();
    stream.write_all(easy.as_bytes()).unwrap();

    let first = read_frame(&mut reader);
    let second = read_frame(&mut reader);
    assert_eq!(first.get("error"), None, "first reply errored: {first:?}");
    assert_eq!(second.get("error"), None, "second reply errored: {second:?}");
    assert_eq!(
        first.get("id").and_then(Json::as_str),
        Some("easy"),
        "easy solve did not overtake the stiff one: {first:?}"
    );
    assert_eq!(second.get("id").and_then(Json::as_str), Some("stiff"));
    let easy_iters = first.get("solver_iters").and_then(Json::as_i64).unwrap();
    let stiff_iters = second.get("solver_iters").and_then(Json::as_i64).unwrap();
    assert!(easy_iters < stiff_iters);
}

/// `"stream": true` subscribes a request to per-iteration progress
/// frames, all delivered before the final reply on the same connection.
#[test]
fn tcp_stream_emits_progress_frames_before_reply() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (mut stream, mut reader) =
        serve_and_connect(&router, dim, "127.0.0.1:17975", 64);
    let (data, _, _) = data::load_auto(8, 8, 11);
    let img: Vec<String> =
        scaled(data.image(0), 0.2).iter().map(|v| format!("{v:.4}")).collect();
    let req =
        format!("{{\"id\":5,\"image\":[{}],\"stream\":true}}\n", img.join(","));
    stream.write_all(req.as_bytes()).unwrap();

    let mut progress = Vec::new();
    let reply = loop {
        let v = read_frame(&mut reader);
        if v.get("event").and_then(Json::as_str) == Some("progress") {
            progress.push(v);
        } else {
            break v;
        }
    };
    assert!(
        !progress.is_empty(),
        "streaming request produced no progress frames"
    );
    let mut last_iter = 0;
    for (k, frame) in progress.iter().enumerate() {
        assert_eq!(frame.get("id").and_then(Json::as_i64), Some(5));
        let iter = frame
            .get("iter")
            .and_then(Json::as_i64)
            .expect("progress frame missing iter");
        assert!(iter > last_iter, "frame {k} iter {iter} not increasing");
        last_iter = iter;
        let residual = frame
            .get("residual")
            .and_then(Json::as_f64)
            .expect("progress frame missing residual");
        assert!(residual.is_finite() && residual >= 0.0);
    }
    // The final reply carries the answer, after every progress frame.
    assert_eq!(reply.get("error"), None, "unexpected error: {reply:?}");
    assert_eq!(reply.get("id").and_then(Json::as_i64), Some(5));
    let iters = reply.get("solver_iters").and_then(Json::as_i64).unwrap();
    assert!(
        iters >= last_iter,
        "final reply reports {iters} iters, saw a progress frame for {last_iter}"
    );
}

/// Queue at capacity → the extra request is shed on the wire with a
/// structured `overloaded` frame carrying a retry hint and the id.
#[test]
fn tcp_sheds_with_overloaded_frame_when_queue_full() {
    // Batch-granular with a long window: submissions pile up in the
    // queue (nothing fires before max_wait), so the third request finds
    // it at its cap of 2 deterministically.
    let (router, dim) =
        make_router_n(60_000, SchedMode::BatchGranular, 1, 2);
    let (mut stream, mut reader) =
        serve_and_connect(&router, dim, "127.0.0.1:17976", 64);
    let zeros = vec!["0"; dim].join(",");
    let mut lines = String::new();
    for id in 1..=3 {
        lines.push_str(&format!("{{\"id\":{id},\"image\":[{zeros}]}}\n"));
    }
    stream.write_all(lines.as_bytes()).unwrap();

    // Requests 1 and 2 are parked in the queue; the only frame on the
    // wire is request 3's shed reply.
    let v = read_frame(&mut reader);
    assert_eq!(v.get("error").and_then(Json::as_str), Some("overloaded"));
    assert_eq!(v.get("id").and_then(Json::as_i64), Some(3));
    let retry = v
        .get("retry_after_ms")
        .and_then(Json::as_i64)
        .expect("overloaded frame missing retry_after_ms");
    assert!(retry >= 1, "retry hint must be at least 1ms, got {retry}");
    assert_eq!(
        router
            .metrics
            .shed
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
}

/// The structured admission API itself: a full queue returns
/// `SubmitRejection::Overloaded` (not a stringly error) and bumps the
/// shed counter.
#[test]
fn try_submit_rejects_structured_overload() {
    let (router, dim) = make_router_n(60_000, SchedMode::BatchGranular, 1, 1);
    let _parked = router
        .try_submit(vec![0.0; dim], &SolveOverrides::default(), None, None)
        .expect("first request fits the queue");
    match router.try_submit(vec![0.0; dim], &SolveOverrides::default(), None, None)
    {
        Err(SubmitRejection::Overloaded { retry_after_ms }) => {
            assert!(retry_after_ms >= 1);
        }
        other => panic!(
            "expected Overloaded, got {:?}",
            other.map(|_| "Ok(receiver)")
        ),
    }
    assert_eq!(
        router
            .metrics
            .shed
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    // Bad requests are still structured as Invalid, not Overloaded.
    match router.try_submit(
        vec![0.0; dim + 1],
        &SolveOverrides::default(),
        None,
        None,
    ) {
        Err(SubmitRejection::Invalid(msg)) => {
            assert!(msg.contains("image has"), "unexpected message: {msg}")
        }
        _ => panic!("wrong-size image must reject as Invalid"),
    }
}

/// Golden pin for the cold-start shed hint: a router that has never
/// retired a request has no retire/latency percentiles, so its first
/// `Overloaded` rejection must carry exactly the documented
/// [`COLD_RETRY_PRIOR_MS`] prior — clients key their backoff off this
/// value, so it changes only with a doc + test update, never silently.
#[test]
fn cold_router_shed_hint_is_the_documented_prior() {
    // queue_cap 1 and a 60s window: the first request parks, the second
    // is shed before anything has ever been served or retired.
    let (router, dim) = make_router_n(60_000, SchedMode::BatchGranular, 1, 1);
    let _parked = router
        .try_submit(vec![0.0; dim], &SolveOverrides::default(), None, None)
        .expect("first request fits the queue");
    match router.try_submit(vec![0.0; dim], &SolveOverrides::default(), None, None)
    {
        Err(SubmitRejection::Overloaded { retry_after_ms }) => {
            assert_eq!(
                retry_after_ms, COLD_RETRY_PRIOR_MS,
                "cold-start retry hint drifted from the documented prior"
            );
        }
        other => panic!(
            "expected Overloaded, got {:?}",
            other.map(|_| "Ok(receiver)")
        ),
    }
    // The pre-queue hint (used by the connection in-flight cap) answers
    // the same prior on a cold router with an empty queue... almost: the
    // backlog above still counts as one wave, so it stays at the prior.
    assert_eq!(router.retry_after_hint(), COLD_RETRY_PRIOR_MS);
}

/// A client that vanishes mid-stream (with `"stream":true` progress
/// frames in flight) must not wedge its replica or leak its lane: the
/// in-flight solve finishes against a dead socket, the dropped progress
/// hook and reply sender are absorbed, and the server keeps serving new
/// connections.
#[test]
fn tcp_client_disconnect_mid_stream_does_not_wedge_server() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let addr = "127.0.0.1:17981";
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = tcp::serve_tcp(router, dim, addr);
        });
    }
    std::thread::sleep(Duration::from_millis(300));

    let (data, _, _) = data::load_auto(8, 8, 9);
    let fmt = |img: &[f32]| -> String {
        img.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(",")
    };
    {
        // Stiff streaming request: hundreds of iterations, progress
        // frames flowing.  Read one progress frame to prove the solve
        // is live, then drop the connection with the solve in flight.
        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let req = format!(
            "{{\"id\":1,\"image\":[{}],\"stream\":true,\"tol\":1e-5,\"max_iter\":400}}\n",
            fmt(&scaled(data.image(0), 0.03))
        );
        stream.write_all(req.as_bytes()).unwrap();
        let first = read_frame(&mut reader);
        assert_eq!(
            first.get("event").and_then(Json::as_str),
            Some("progress"),
            "expected a progress frame first: {first:?}"
        );
        drop(reader);
        drop(stream); // client gone, solve still running
    }

    // A fresh connection must be served normally while/after the
    // orphaned solve drains into the void.
    let mut stream = TcpStream::connect(addr).expect("reconnect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let req = format!(
        "{{\"id\":2,\"image\":[{}],\"tol\":0.3}}\n",
        fmt(&scaled(data.image(1), 3.0))
    );
    stream.write_all(req.as_bytes()).unwrap();
    let reply = read_frame(&mut reader);
    assert_eq!(reply.get("error"), None, "unexpected error: {reply:?}");
    assert_eq!(reply.get("id").and_then(Json::as_i64), Some(2));
    // The orphaned request still retires inside the router (its reply
    // lands in a dropped channel, which is fine) — wait for it so the
    // served counter proves no lane was leaked or wedged.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let served = router
            .metrics
            .served
            .load(std::sync::atomic::Ordering::Relaxed);
        if served >= 2 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "orphaned streaming solve never retired (served={served})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Back-compat pin: a legacy request (no `id`, no `stream`) gets a reply
/// with exactly the legacy key set — nothing multiplexing-related leaks
/// into old clients' replies.
#[test]
fn tcp_reply_without_id_keeps_legacy_key_set() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (mut stream, mut reader) =
        serve_and_connect(&router, dim, "127.0.0.1:17977", 64);
    let (data, _, _) = data::load_auto(4, 4, 3);
    let img: Vec<String> =
        data.image(0).iter().map(|v| format!("{v:.4}")).collect();
    let req = format!("{{\"image\":[{}]}}\n", img.join(","));
    stream.write_all(req.as_bytes()).unwrap();
    let v = read_frame(&mut reader);
    let Json::Obj(map) = &v else { panic!("reply is not an object: {v:?}") };
    let keys: Vec<&str> = map.keys().map(String::as_str).collect();
    assert_eq!(
        keys,
        vec![
            "adaptive",
            "batch",
            "class",
            "cond_max",
            "converged",
            "errorfactor",
            "gram",
            "latency_ms",
            "max_iter",
            "safeguard",
            "solver",
            "solver_fevals",
            "solver_iters",
            "tol",
        ],
        "legacy reply key set drifted"
    );
}

/// The per-connection in-flight cap sheds the pipelined excess while a
/// slow solve is still running.
#[test]
fn tcp_inflight_cap_sheds_pipelined_excess() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (mut stream, mut reader) =
        serve_and_connect(&router, dim, "127.0.0.1:17978", 1);
    let (data, _, _) = data::load_auto(8, 8, 9);
    let fmt = |img: &[f32]| -> String {
        img.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(",")
    };
    // A stiff request occupies the single in-flight slot for many
    // iterations; the immediately pipelined second request must be shed
    // at the connection door.
    let stiff = format!(
        "{{\"id\":1,\"image\":[{}],\"tol\":1e-5,\"max_iter\":400}}\n",
        fmt(&scaled(data.image(0), 0.03))
    );
    let easy = format!(
        "{{\"id\":2,\"image\":[{}],\"tol\":0.3}}\n",
        fmt(&scaled(data.image(1), 3.0))
    );
    stream.write_all(stiff.as_bytes()).unwrap();
    stream.write_all(easy.as_bytes()).unwrap();

    let shed = read_frame(&mut reader);
    assert_eq!(shed.get("error").and_then(Json::as_str), Some("overloaded"));
    assert_eq!(shed.get("id").and_then(Json::as_i64), Some(2));
    assert!(shed.get("retry_after_ms").and_then(Json::as_i64).unwrap() >= 1);
    // The in-flight request itself is unharmed.
    let reply = read_frame(&mut reader);
    assert_eq!(reply.get("id").and_then(Json::as_i64), Some(1));
    assert_eq!(reply.get("error"), None, "unexpected error: {reply:?}");
}

/// Two replicas drain one shared queue: every request is answered, both
/// replicas exist in the gauges, and per-replica served counts account
/// for exactly the offered traffic.
#[test]
fn multi_replica_router_serves_all_and_tracks_gauges() {
    let (router, _) = make_router_n(5, SchedMode::IterationLevel, 2, 256);
    let (data, _, _) = data::load_auto(16, 8, 2);
    let receivers: Vec<_> = (0..8)
        .map(|i| router.submit(data.image(i).to_vec()).unwrap())
        .collect();
    for rx in receivers {
        rx.recv().expect("reply").expect("response");
    }
    assert_eq!(router.metrics.replicas.len(), 2);
    let per_replica: Vec<u64> = router
        .metrics
        .replicas
        .iter()
        .map(|g| g.served.load(std::sync::atomic::Ordering::Relaxed))
        .collect();
    assert_eq!(
        per_replica.iter().sum::<u64>(),
        8,
        "per-replica served {per_replica:?} does not sum to the traffic"
    );
    // Queue-depth observations: one per successful submission.
    assert_eq!(router.metrics.queue_depth.lock().unwrap().count(), 8);
}

/// `stats` is structured now: counters and percentiles as JSON fields,
/// a per-replica gauge array, and the legacy summary blob riding along.
#[test]
fn stats_reply_is_structured_json() {
    let (router, dim) = make_router_n(5, SchedMode::IterationLevel, 2, 256);
    let (data, _, _) = data::load_auto(4, 4, 3);
    router.infer_blocking(data.image(0).to_vec()).unwrap();
    let v = tcp::process_line(&router, dim, "{\"cmd\":\"stats\"}");
    assert_eq!(v.get("served").and_then(Json::as_f64), Some(1.0));
    assert_eq!(v.get("shed").and_then(Json::as_f64), Some(0.0));
    for key in [
        "batches",
        "latency_p50_ms",
        "latency_p95_ms",
        "latency_p99_ms",
        "mean_fill",
        "occupancy",
        "retire_p50_ms",
        "retire_p95_ms",
        "fevals_saved",
        "queue_depth_p50",
        "queue_depth_max",
        "queue_now",
    ] {
        assert!(
            v.get(key).and_then(Json::as_f64).is_some(),
            "stats missing numeric field {key}: {v:?}"
        );
    }
    let replicas = v.get("replicas").and_then(Json::as_arr).expect("replicas");
    assert_eq!(replicas.len(), 2);
    let served_total: f64 = replicas
        .iter()
        .map(|g| g.get("served").and_then(Json::as_f64).unwrap())
        .sum();
    assert_eq!(served_total, 1.0);
    // Auto-selection observability: the switch counter, the per-kind
    // retirement histogram (this one request retired under the router's
    // default anderson spec), and the learned-profile array.
    assert_eq!(v.get("auto_switches").and_then(Json::as_f64), Some(0.0));
    let retired = v.get("retired_by_kind").expect("retired_by_kind");
    assert_eq!(retired.get("anderson").and_then(Json::as_f64), Some(1.0));
    for kind in ["forward", "hybrid", "auto"] {
        assert_eq!(retired.get(kind).and_then(Json::as_f64), Some(0.0));
    }
    let profiles =
        v.get("workload_profiles").and_then(Json::as_arr).expect("profiles");
    assert!(!profiles.is_empty(), "retired lane recorded no profile");
    let p = &profiles[0];
    assert!(p.get("bucket").and_then(Json::as_f64).is_some());
    assert_eq!(p.get("lanes").and_then(Json::as_f64), Some(1.0));
    assert!(p.get("mean_iters").and_then(Json::as_f64).unwrap() > 0.0);
    // The legacy blob survives for old scrapers.
    let summary = v.get("summary").and_then(Json::as_str).expect("summary");
    assert!(summary.contains("served="), "summary blob drifted: {summary}");
}

/// End-to-end auto-selection: a `"solver":"auto"` override is accepted
/// at the door, solved by the per-lane crossover controller, echoed back
/// as `auto`, and its learning shows up in `stats` — switch decisions
/// (a stiff near-linear input forces the forward→Anderson crossover)
/// and the per-bucket learned prior fields.
#[test]
fn auto_solver_end_to_end_switches_and_learns() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 5);
    let auto = SolveOverrides {
        kind: Some(SolverKind::Auto),
        tol: Some(1e-5),
        max_iter: Some(300),
        ..SolveOverrides::default()
    };
    // Stiff sample: small amplitude keeps the tanh cell near its linear
    // regime, so plain forward iteration crawls at the cell's spectral
    // radius and the controller must cross over to Anderson.
    let stiff = router
        .infer_blocking_with(scaled(data.image(0), 0.03), &auto)
        .unwrap();
    assert_eq!(stiff.spec.kind, SolverKind::Auto, "spec echo lost the kind");
    assert!(stiff.converged, "auto failed to converge a stiff lane");
    // Easy sample: saturated cell, converges in a handful of forward
    // steps — no reason to ever pay the mixing penalty.
    let easy = router
        .infer_blocking_with(scaled(data.image(1), 3.0), &auto)
        .unwrap();
    assert!(easy.converged);
    assert!(
        easy.solver_iters < stiff.solver_iters,
        "easy lane ({} iters) should retire before stiff ({} iters)",
        easy.solver_iters,
        stiff.solver_iters
    );

    let v = tcp::process_line(&router, dim, "{\"cmd\":\"stats\"}");
    let switches = v.get("auto_switches").and_then(Json::as_f64).unwrap();
    assert!(switches >= 1.0, "stiff auto lane never crossed over: {v:?}");
    let retired = v.get("retired_by_kind").expect("retired_by_kind");
    assert_eq!(retired.get("auto").and_then(Json::as_f64), Some(2.0));
    let profiles =
        v.get("workload_profiles").and_then(Json::as_arr).expect("profiles");
    let learned = profiles.iter().find(|p| {
        p.get("switches").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0
    });
    let p = learned.expect("no bucket profile recorded the switch");
    // Auto retirements feed the prior: a fitted decay rate in (0, 1)
    // (the probe saw a contraction) and a positive mean-iters estimate.
    let rate = p.get("decay_rate").and_then(Json::as_f64).unwrap();
    assert!(rate > 0.0 && rate < 1.0, "learned decay rate {rate} not in (0,1)");
    assert!(p.get("mean_iters").and_then(Json::as_f64).unwrap() > 0.0);
}

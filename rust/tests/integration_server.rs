//! Serving-stack integration tests: router, iteration-level scheduler,
//! batch-granular baseline, TCP protocol.
//! Hermetic: they run on whatever backend `backend_from_dir` selects (the
//! pure-Rust `NativeEngine` when AOT artifacts are absent), so nothing
//! here skips in CI.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use deq_anderson::data;
use deq_anderson::infer;
use deq_anderson::runtime::{backend_from_dir, Backend};
use deq_anderson::server::{tcp, Router, RouterConfig, SchedMode};
use deq_anderson::solver::{SolveOptions, SolverKind};
use deq_anderson::util::json::{self, Json};

fn engine() -> Arc<dyn Backend> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    backend_from_dir(dir).expect("backend")
}

fn make_router(max_wait_ms: u64, mode: SchedMode) -> (Arc<Router>, usize) {
    let engine = engine();
    let image_dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let cfg = RouterConfig {
        solver: SolveOptions::from_manifest(engine.as_ref(), SolverKind::Anderson),
        mode,
        max_wait: Duration::from_millis(max_wait_ms),
        queue_cap: 256,
    };
    (Arc::new(Router::start(engine, params, cfg).unwrap()), image_dim)
}

/// Scale an image to modulate solve difficulty on the tanh cell: large
/// amplitudes saturate it (fast convergence), small ones leave it near
/// its linear regime (slow, rate ≈ the cell's spectral radius).
fn scaled(image: &[f32], scale: f32) -> Vec<f32> {
    image.iter().map(|&v| v * scale).collect()
}

#[test]
fn single_request_roundtrip() {
    // Default mode: the iteration-level scheduler.
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 1);
    let resp = router.infer_blocking(data.image(0).to_vec()).unwrap();
    assert!(resp.class < 10);
    assert_eq!(resp.batch_size, 1);
    assert!(resp.latency > Duration::ZERO);
    assert!(resp.solver_iters > 0);
    assert!(resp.solver_fevals >= resp.solver_iters);
    assert!(resp.converged, "default-tol solve should converge");
    assert_eq!(dim, data.image_dim());
}

#[test]
fn concurrent_requests_get_batched() {
    // The batch-granular baseline still batches fire-and-wait style.
    let (router, _) = make_router(25, SchedMode::BatchGranular);
    let (data, _, _) = data::load_auto(16, 8, 2);
    // Submit 8 requests quickly; with a 25ms window they should share
    // batches rather than each going out alone.
    let receivers: Vec<_> = (0..8)
        .map(|i| router.submit(data.image(i).to_vec()).unwrap())
        .collect();
    let responses: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("reply").expect("response"))
        .collect();
    assert_eq!(responses.len(), 8);
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch > 1, "no batching happened (all singletons)");
    // All served, metrics recorded.
    assert_eq!(
        router
            .metrics
            .served
            .load(std::sync::atomic::Ordering::Relaxed),
        8
    );
}

#[test]
fn submit_rejects_wrong_image_size() {
    // Validated at submission, so a malformed request can never fail a
    // whole batch-granular batch (or waste a scheduler lane).
    let (router, dim) = make_router(5, SchedMode::BatchGranular);
    assert!(router.submit(vec![0.0; dim + 1]).is_err());
    assert!(router.submit(Vec::new()).is_err());
}

#[test]
fn queue_depth_visible_while_waiting() {
    let (router, dim) = make_router(1_000, SchedMode::BatchGranular);
    let img = vec![0.0f32; dim];
    let _r1 = router.submit(img.clone()).unwrap();
    let _r2 = router.submit(img).unwrap();
    assert!(router.queue_depth() <= 2);
}

#[test]
fn stiff_sample_does_not_delay_easy_sample() {
    // The point of iteration-level scheduling: an easy sample retires the
    // iteration it converges, even while a stiff co-rider keeps going.
    let (router, _) = make_router(5, SchedMode::IterationLevel);
    let (data, _, _) = data::load_auto(8, 8, 9);
    let rx_stiff = router.submit(scaled(data.image(0), 0.03)).unwrap();
    let rx_easy = router.submit(scaled(data.image(1), 3.0)).unwrap();
    let stiff = rx_stiff.recv().expect("reply").expect("stiff response");
    let easy = rx_easy.recv().expect("reply").expect("easy response");
    assert!(
        easy.solver_iters < stiff.solver_iters,
        "easy took {} iters, stiff {} — per-sample retirement broken",
        easy.solver_iters,
        stiff.solver_iters
    );
    assert!(
        easy.latency < stiff.latency,
        "easy latency {:?} not below stiff {:?}",
        easy.latency,
        stiff.latency
    );
    // Per-sample counters, not the batch max, ride the response.
    assert_eq!(easy.solver_fevals, easy.solver_iters);
    let occ = router.metrics.lane_occupancy.lock().unwrap().count();
    assert!(occ > 0, "scheduler recorded no iterations");
}

#[test]
fn per_sample_early_exit_matches_batch_granular_solve() {
    // Property-style sweep: a mixed-difficulty batch solved with
    // per-sample freezing must return the same logits (within tol-level
    // slack) as each sample solved alone to its own convergence — and
    // must charge strictly fewer fevals than lockstep accounting.
    let e = engine();
    let params = e.init_params().unwrap();
    let opts = SolveOptions {
        tol: 1e-4,
        max_iter: 80,
        ..SolveOptions::from_manifest(e.as_ref(), SolverKind::Anderson)
    };
    for seed in 0..4u64 {
        let (data, _, _) = data::load_auto(8, 8, seed + 20);
        let images: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                let s = if i % 2 == 0 { 3.0 } else { 0.03 };
                scaled(data.image(i), s)
            })
            .collect();
        let flat: Vec<f32> = images.concat();
        let batched = infer::infer(e.as_ref(), &params, &flat, 8, &opts).unwrap();
        assert_eq!(batched.sample_iters.len(), 8);
        for (i, image) in images.iter().enumerate() {
            let solo = infer::infer(e.as_ref(), &params, image, 1, &opts).unwrap();
            for (a, b) in batched.logits[i].iter().zip(&solo.logits[0]) {
                assert!(
                    (a - b).abs() < 1e-2,
                    "seed={seed} sample {i}: logits diverged ({a} vs {b})"
                );
            }
            // Argmax parity wherever the solo margin is decisive.
            let row = &solo.logits[0];
            let mut sorted = row.clone();
            sorted.sort_by(|x, y| y.partial_cmp(x).unwrap());
            if sorted[0] - sorted[1] > 0.05 {
                assert_eq!(
                    batched.predictions[i], solo.predictions[0],
                    "seed={seed} sample {i}: prediction flipped"
                );
            }
            // Early exit is per-sample: the lane's own count matches the
            // solo solve (both freeze at the same tol crossing).
            assert_eq!(
                batched.sample_iters[i], solo.sample_iters[0],
                "seed={seed} sample {i}: lane iters diverged from solo"
            );
        }
        // Strictly fewer fevals than every lane paying the slowest lane.
        let total: usize = batched.sample_fevals.iter().sum();
        assert!(
            total < batched.solver_fevals * 8,
            "seed={seed}: {total} fevals, lockstep would be {}",
            batched.solver_fevals * 8
        );
    }
}

#[test]
fn burst_larger_than_biggest_bucket_is_split_not_clamped() {
    // Satellite audit of the old `pick_bucket` clamp: a queue deeper than
    // the largest compiled bucket must be served as multiple batches
    // (each within a real bucket), never truncated or clamped into a
    // bucket that cannot hold it.  40 requests over max bucket 32 → at
    // least two batch-granular batches, every single one answered.
    let (router, _) = make_router(10, SchedMode::BatchGranular);
    let (data, _, _) = data::load_auto(8, 8, 5);
    let total = 40usize;
    let receivers: Vec<_> = (0..total)
        .map(|i| router.submit(data.image(i % 8).to_vec()).unwrap())
        .collect();
    let responses: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("reply").expect("response"))
        .collect();
    assert_eq!(responses.len(), total, "some requests were dropped");
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch <= 32, "a batch exceeded the biggest bucket");
    assert_eq!(
        router
            .metrics
            .served
            .load(std::sync::atomic::Ordering::Relaxed),
        total as u64
    );
}

#[test]
fn shutdown_drains_queue_with_error_replies() {
    // Long max_wait so the batch never fires: submissions are still
    // queued when shutdown lands, and must get an explicit error reply
    // instead of a silently dropped sender.
    let (router, dim) = make_router(60_000, SchedMode::BatchGranular);
    let rxs: Vec<_> = (0..4)
        .map(|_| router.submit(vec![0.0; dim]).unwrap())
        .collect();
    let router = Arc::try_unwrap(router).ok().expect("sole owner");
    router.shutdown();
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(_)) => {} // served before shutdown landed — also fine
            Ok(Err(msg)) => {
                assert!(msg.contains("shutting down"), "unexpected error: {msg}")
            }
            Err(e) => panic!("request dropped without a reply: {e}"),
        }
    }
}

#[test]
fn tcp_protocol_end_to_end() {
    let (router, dim) = make_router(5, SchedMode::IterationLevel);
    let addr = "127.0.0.1:17973";
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = tcp::serve_tcp(router, dim, addr);
        });
    }
    std::thread::sleep(Duration::from_millis(300));

    let mut stream = TcpStream::connect(addr).expect("connect");
    // ping
    stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));

    // malformed
    line.clear();
    stream.write_all(b"{nope}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    // wrong image size
    line.clear();
    stream.write_all(b"{\"image\":[1,2,3]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    // real request — the reply carries this sample's own solver counters.
    let (data, _, _) = data::load_auto(4, 4, 3);
    let img: Vec<String> =
        data.image(0).iter().map(|v| format!("{v:.4}")).collect();
    let req = format!("{{\"id\":7,\"image\":[{}]}}\n", img.join(","));
    line.clear();
    stream.write_all(req.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("id").and_then(Json::as_i64), Some(7));
    let class = v.get("class").and_then(Json::as_i64).expect("class");
    assert!((0..10).contains(&class));
    let iters = v
        .get("solver_iters")
        .and_then(Json::as_i64)
        .expect("solver_iters");
    assert!(iters > 0);
    assert!(v.get("solver_fevals").is_some());

    // stats
    line.clear();
    stream.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("served="));
}

#[test]
fn router_shutdown_is_clean() {
    let (router, _) = make_router(5, SchedMode::IterationLevel);
    let router = Arc::try_unwrap(router).ok().expect("sole owner");
    router.shutdown();
}

//! Chaos suite: deterministic fault injection ([`FaultPlan`]) driven
//! through every robustness layer — solver-level non-finite quarantine,
//! replica supervision with request redrive, per-request deadlines, and
//! the TCP wire shapes of each failure.
//!
//! Every test here builds its *own* injector over a bare
//! `NativeEngine::tiny()` rather than going through `backend_from_dir`:
//! that path wraps the `DEQ_FAULTS` env plan, and the CI chaos job sets
//! the var — these tests must stay deterministic regardless.  The one
//! exception is the liveness test at the bottom, which deliberately
//! rides the env plan when one is set.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use deq_anderson::data;
use deq_anderson::infer;
use deq_anderson::runtime::{
    backend_from_dir, Backend, FaultInjector, FaultPlan, NativeEngine,
};
use deq_anderson::server::{
    tcp, FailureKind, Router, RouterConfig, SchedMode,
};
use deq_anderson::solver::{SolveClamps, SolveOverrides, SolveSpec, SolverKind};
use deq_anderson::util::json::{self, Json};

/// Bare engine, immune to any `DEQ_FAULTS` the process carries.
fn bare_engine() -> Arc<dyn Backend> {
    Arc::new(NativeEngine::tiny())
}

/// Bare engine wrapped with an explicit, test-owned fault plan.
fn faulted_engine(plan: &str) -> Arc<dyn Backend> {
    let plan = FaultPlan::parse(plan).expect("fault plan");
    Arc::new(FaultInjector::new(bare_engine(), plan))
}

fn start_router(
    engine: Arc<dyn Backend>,
    mode: SchedMode,
    redrive_budget: u32,
) -> (Arc<Router>, usize) {
    let image_dim = engine.manifest().model.image_dim();
    let params = Arc::new(engine.init_params().unwrap());
    let cfg = RouterConfig {
        solver: SolveSpec::from_manifest(engine.as_ref(), SolverKind::Anderson),
        clamps: SolveClamps::default(),
        mode,
        max_wait: Duration::from_millis(10),
        queue_cap: 256,
        replicas: 1,
        default_deadline: None,
        redrive_budget,
    };
    (Arc::new(Router::start(engine, params, cfg).unwrap()), image_dim)
}

/// Scale an image to modulate solve difficulty (see integration_server).
fn scaled(image: &[f32], scale: f32) -> Vec<f32> {
    image.iter().map(|&v| v * scale).collect()
}

/// Overrides for a request stiff enough to still be in flight when a
/// mid-solve fault fires.
fn stiff() -> SolveOverrides {
    SolveOverrides {
        tol: Some(1e-5),
        max_iter: Some(400),
        ..Default::default()
    }
}

fn load(v: &std::sync::atomic::AtomicU64) -> u64 {
    v.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Non-finite lane quarantine
// ---------------------------------------------------------------------------

/// The containment acceptance test: poisoning one lane of a batched
/// solve quarantines that lane *alone* — every non-faulted bucket-mate's
/// logits, prediction and per-sample counters are bit-identical to a
/// fault-free run of the same batch (all kernels are row-wise, so a NaN
/// row cannot bleed sideways).
#[test]
fn nan_fault_quarantines_one_lane_bucket_mates_bit_identical() {
    let engine = bare_engine();
    let params = engine.init_params().unwrap();
    let spec = SolveSpec {
        tol: 1e-4,
        max_iter: 80,
        ..SolveSpec::from_manifest(engine.as_ref(), SolverKind::Anderson)
    };
    let (data, _, _) = data::load_auto(8, 8, 21);
    // Lane 0 stiff so it is still active when the fault fires at call 3;
    // the bucket-mates span easy to moderate.
    let scales = [0.03f32, 3.0, 1.0, 0.4];
    let images: Vec<Vec<f32>> = (0..4)
        .map(|i| scaled(data.image(i), scales[i]))
        .collect();
    let flat: Vec<f32> = images.concat();

    let clean = infer::infer(engine.as_ref(), &params, &flat, 4, &spec).unwrap();
    assert!(
        clean.sample_faulted.iter().all(|&f| !f),
        "fault-free run reported a quarantine"
    );

    let inj =
        FaultInjector::new(engine.clone(), FaultPlan::parse("nan@cell_step#3").unwrap());
    let faulted = infer::infer(&inj, &params, &flat, 4, &spec).unwrap();
    assert_eq!(inj.injected(), 1, "the plan must fire exactly once");
    assert!(
        faulted.sample_faulted[0],
        "poisoned lane 0 not flagged: {:?}",
        faulted.sample_faulted
    );
    for i in 1..4 {
        assert!(!faulted.sample_faulted[i], "lane {i} wrongly quarantined");
        assert_eq!(
            faulted.logits[i], clean.logits[i],
            "lane {i} logits not bit-identical to the fault-free run"
        );
        assert_eq!(faulted.predictions[i], clean.predictions[i]);
        assert_eq!(faulted.sample_iters[i], clean.sample_iters[i]);
        assert_eq!(faulted.sample_converged[i], clean.sample_converged[i]);
    }
}

/// Serving-side quarantine: a lane that goes non-finite mid-solve gets a
/// terminal `Numerical` reply with its partial stats, the `quarantined`
/// counter moves, and the freed (wiped) lane serves the next request.
#[test]
fn scheduler_quarantines_nan_lane_and_keeps_serving() {
    let (router, _) = start_router(
        faulted_engine("nan@cell_step#5"),
        SchedMode::IterationLevel,
        1,
    );
    let (data, _, _) = data::load_auto(8, 8, 17);
    let rx = router
        .submit_with(scaled(data.image(0), 0.03), &stiff())
        .unwrap();
    let fail = rx
        .recv()
        .expect("terminal reply")
        .expect_err("a poisoned lane must fail, not answer");
    assert_eq!(fail.kind, FailureKind::Numerical);
    // The lane was admitted at the first boundary, so its iteration
    // count is exactly the faulting call index.
    assert_eq!(fail.iters, 5, "partial stats drifted");
    assert_eq!(fail.fevals, 5);
    assert!(
        fail.detail.contains("non-finite residual"),
        "unexpected detail: {}",
        fail.detail
    );
    assert_eq!(load(&router.metrics.quarantined), 1);
    assert_eq!(load(&router.metrics.served), 0);
    assert_eq!(router.backend_faults_injected(), 1);

    // Exact-count plans fire once: the quarantined lane was wiped and
    // the router serves normally afterwards.
    let resp = router.infer_blocking(scaled(data.image(1), 3.0)).unwrap();
    assert!(resp.converged);
    assert_eq!(load(&router.metrics.served), 1);
}

// ---------------------------------------------------------------------------
// Replica supervision + redrive
// ---------------------------------------------------------------------------

/// A replica panic mid-solve is not the end of the requests it carried:
/// the supervisor recovers them from the lanes, redrives them onto the
/// queue, respawns the replica, and every waiter still gets its answer.
#[test]
fn replica_crash_redrives_inflight_requests_to_completion() {
    let (router, _) = start_router(
        faulted_engine("panic@cell_step#3"),
        SchedMode::IterationLevel,
        1,
    );
    let (data, _, _) = data::load_auto(8, 8, 13);
    let rx1 = router
        .submit_with(scaled(data.image(0), 0.03), &stiff())
        .unwrap();
    let rx2 = router
        .submit_with(scaled(data.image(1), 0.03), &stiff())
        .unwrap();
    let r1 = rx1
        .recv()
        .expect("reply 1")
        .expect("request 1 must survive the crash via redrive");
    let r2 = rx2
        .recv()
        .expect("reply 2")
        .expect("request 2 must survive the crash via redrive");
    assert!(r1.converged && r2.converged);
    assert_eq!(load(&router.metrics.replica_restarts), 1);
    let redrives = load(&router.metrics.redrives);
    // At least request 1 was in flight at the crash; request 2 may have
    // still been queued (untouched) or share the lane set.
    assert!(
        (1..=2).contains(&redrives),
        "unexpected redrive count {redrives}"
    );
    assert_eq!(router.backend_faults_injected(), 1);
    assert_eq!(load(&router.metrics.served), 2);
}

/// With the redrive budget at zero a crash becomes a terminal
/// `internal` (retryable) reply carrying the panic text — and the
/// respawned replica keeps the router alive for new work.
#[test]
fn exhausted_redrive_budget_is_a_retryable_internal_reply() {
    let (router, _) = start_router(
        faulted_engine("panic@cell_step#2"),
        SchedMode::IterationLevel,
        0,
    );
    let (data, _, _) = data::load_auto(8, 8, 19);
    let rx = router
        .submit_with(scaled(data.image(0), 0.03), &stiff())
        .unwrap();
    let fail = rx
        .recv()
        .expect("terminal reply")
        .expect_err("budget 0 must turn the crash into a failure reply");
    assert_eq!(fail.kind, FailureKind::Internal);
    assert!(fail.retryable(), "internal replies must be retryable");
    assert!(
        fail.detail.contains("crashed while serving"),
        "unexpected detail: {}",
        fail.detail
    );
    assert!(
        fail.detail.contains("injected fault"),
        "panic text missing from detail: {}",
        fail.detail
    );
    assert_eq!(load(&router.metrics.replica_restarts), 1);
    assert_eq!(load(&router.metrics.redrives), 0);

    // The respawned replica serves fresh requests.
    let resp = router.infer_blocking(scaled(data.image(1), 3.0)).unwrap();
    assert!(resp.converged);
}

/// The batch-granular baseline rides the same supervision: a panic
/// inside a fired batch recovers the whole group for redrive and the
/// respawned batcher answers everyone.
#[test]
fn batcher_crash_redrives_batch_and_respawns() {
    let (router, _) = start_router(
        faulted_engine("panic@cell_step#1"),
        SchedMode::BatchGranular,
        1,
    );
    let (data, _, _) = data::load_auto(8, 8, 23);
    let rx1 = router.submit(scaled(data.image(0), 3.0)).unwrap();
    let rx2 = router.submit(scaled(data.image(1), 3.0)).unwrap();
    let r1 = rx1.recv().expect("reply 1").expect("request 1 answered");
    let r2 = rx2.recv().expect("reply 2").expect("request 2 answered");
    assert!(r1.converged && r2.converged);
    assert_eq!(load(&router.metrics.replica_restarts), 1);
    assert!(load(&router.metrics.redrives) >= 1);
    assert_eq!(load(&router.metrics.served), 2);
}

// ---------------------------------------------------------------------------
// Per-request deadlines
// ---------------------------------------------------------------------------

/// A stalled backend (injected latency on every cell step) trips the
/// per-request deadline at an iteration boundary: the reply is
/// `DeadlineExceeded` with the partial stats the lane accrued.
#[test]
fn stalled_backend_trips_deadline_with_partial_stats() {
    let (router, _) = start_router(
        faulted_engine("stall@cell_step%1:25ms"),
        SchedMode::IterationLevel,
        1,
    );
    let (data, _, _) = data::load_auto(8, 8, 29);
    let ov = SolveOverrides {
        tol: Some(1e-6),
        max_iter: Some(400),
        ..Default::default()
    };
    let rx = router
        .try_submit(
            scaled(data.image(0), 0.03),
            &ov,
            None,
            Some(Duration::from_millis(150)),
        )
        .unwrap();
    let fail = rx
        .recv()
        .expect("terminal reply")
        .expect_err("a stalled solve must miss a 150ms deadline");
    assert_eq!(fail.kind, FailureKind::DeadlineExceeded);
    assert!(fail.iters >= 1, "partial stats missing: {} iters", fail.iters);
    assert_eq!(fail.fevals, fail.iters);
    assert_eq!(load(&router.metrics.deadline_exceeded), 1);
    assert_eq!(load(&router.metrics.served), 0);
    assert!(router.backend_faults_injected() >= 1, "stalls never fired");
}

/// A request whose deadline passed while it queued is shed at the
/// admission boundary — before paying its encode — with zeroed stats.
#[test]
fn requests_expired_in_queue_are_shed_before_encode() {
    let (router, dim) = start_router(bare_engine(), SchedMode::IterationLevel, 1);
    let rx = router
        .try_submit(
            vec![0.0; dim],
            &SolveOverrides::default(),
            None,
            Some(Duration::ZERO),
        )
        .unwrap();
    let fail = rx
        .recv()
        .expect("terminal reply")
        .expect_err("an already-expired request must be shed");
    assert_eq!(fail.kind, FailureKind::DeadlineExceeded);
    assert_eq!((fail.iters, fail.fevals), (0, 0), "shed before any solve work");
    assert_eq!(load(&router.metrics.deadline_exceeded), 1);
    assert_eq!(load(&router.metrics.served), 0);
}

// ---------------------------------------------------------------------------
// Wire shapes + counters over TCP
// ---------------------------------------------------------------------------

fn read_frame(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).expect("read frame");
    json::parse(line.trim()).expect("parse frame")
}

/// End to end: `deadline_ms` on the wire, a stall-heavy plan underneath,
/// the structured `deadline_exceeded` frame back, and the chaos counters
/// visible through the `stats` command.
#[test]
fn tcp_deadline_reply_and_chaos_counters_end_to_end() {
    let (router, dim) = start_router(
        faulted_engine("stall@cell_step%1:25ms"),
        SchedMode::IterationLevel,
        1,
    );
    let addr = "127.0.0.1:17982";
    {
        let router = router.clone();
        std::thread::spawn(move || {
            let _ = tcp::serve_tcp(router, dim, addr);
        });
    }
    std::thread::sleep(Duration::from_millis(300));

    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let (data, _, _) = data::load_auto(8, 8, 31);
    let img: Vec<String> = scaled(data.image(0), 0.03)
        .iter()
        .map(|v| format!("{v:.4}"))
        .collect();
    let req = format!(
        "{{\"id\":1,\"image\":[{}],\"tol\":1e-6,\"max_iter\":400,\"deadline_ms\":120}}\n",
        img.join(",")
    );
    stream.write_all(req.as_bytes()).unwrap();
    let v = read_frame(&mut reader);
    assert_eq!(
        v.get("error").and_then(Json::as_str),
        Some("deadline_exceeded"),
        "unexpected frame: {v:?}"
    );
    assert_eq!(v.get("id").and_then(Json::as_i64), Some(1));
    let iters = v
        .get("solver_iters")
        .and_then(Json::as_i64)
        .expect("deadline frame missing solver_iters");
    assert!(iters >= 1, "partial stats missing from the wire frame");

    stream.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    let stats = read_frame(&mut reader);
    assert!(
        stats.get("deadline_exceeded").and_then(Json::as_f64).unwrap() >= 1.0,
        "stats missing the deadline counter: {stats:?}"
    );
    assert!(
        stats.get("faults_injected").and_then(Json::as_f64).unwrap() >= 1.0,
        "stats missing injected-fault count: {stats:?}"
    );
    for key in ["replica_restarts", "redrives", "quarantined"] {
        assert!(
            stats.get(key).and_then(Json::as_f64).is_some(),
            "stats missing counter {key}: {stats:?}"
        );
    }

    // A malformed deadline is rejected at parse time, before admission
    // (the image validates first, so it must be well-formed here).
    let zeros = vec!["0"; dim].join(",");
    stream
        .write_all(
            format!("{{\"id\":2,\"image\":[{zeros}],\"deadline_ms\":0}}\n")
                .as_bytes(),
        )
        .unwrap();
    let bad = read_frame(&mut reader);
    assert_eq!(
        bad.get("error").and_then(Json::as_str),
        Some("'deadline_ms' must be a positive integer")
    );
}

// ---------------------------------------------------------------------------
// Env-plan liveness (the CI chaos job's entry point)
// ---------------------------------------------------------------------------

/// The one property every failure mode above feeds: **exactly one
/// terminal reply per request, no waiter ever hangs**.  This test rides
/// whatever `DEQ_FAULTS` plan the process carries (the CI chaos job runs
/// it under a panic-heavy and a NaN-heavy plan, single replica); with
/// the var unset it exercises the same liveness on a bare backend.
#[test]
fn every_request_gets_exactly_one_terminal_reply_under_env_plan() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = backend_from_dir(dir).expect("backend");
    let params = Arc::new(engine.init_params().unwrap());
    let cfg = RouterConfig {
        solver: SolveSpec::from_manifest(engine.as_ref(), SolverKind::Anderson),
        clamps: SolveClamps::default(),
        mode: SchedMode::IterationLevel,
        max_wait: Duration::from_millis(5),
        queue_cap: 256,
        replicas: 1,
        default_deadline: Some(Duration::from_secs(30)),
        redrive_budget: 2,
    };
    let router = Arc::new(Router::start(engine, params, cfg).unwrap());
    let (data, _, _) = data::load_auto(8, 8, 3);
    let receivers: Vec<_> = (0..8)
        .map(|i| router.submit(data.image(i).to_vec()))
        .collect();
    for (i, submitted) in receivers.into_iter().enumerate() {
        let rx = match submitted {
            Ok(rx) => rx,
            // A rejection at the door is itself a terminal answer.
            Err(_) => continue,
        };
        match rx.recv_timeout(Duration::from_secs(60)) {
            // Ok response or structured failure — both are terminal.
            Ok(_reply) => {}
            Err(e) => panic!("request {i} hung without a terminal reply: {e}"),
        }
    }
}

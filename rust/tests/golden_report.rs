//! Golden-trace regression tests for the solver report JSON format.
//!
//! Experiment outputs (residual traces, solve summaries) serialize through
//! `SolveReport::to_json` / `util::json::to_string`.  These tests pin the
//! exact byte-level format — key order (sorted), number rendering, nesting,
//! and the per-sample trace fields introduced by iteration-level
//! scheduling (`sample_residuals`/`active` per step; `sample_iters`/
//! `sample_fevals`/`sample_converged` per report) — so downstream tooling
//! that parses result files can't silently break.  Fixture values are
//! dyadic (0.25, 0.5, 1.5 …) so f32→f64→text→f64→f32 round-trips are
//! exact.

use std::time::Duration;

use deq_anderson::runtime::HostTensor;
use deq_anderson::solver::{
    SolveReport, SolveSpec, SolveStep, SolverKind, DEFAULT_COND_MAX,
    DEFAULT_ERRORFACTOR,
};
use deq_anderson::util::json;

/// A two-lane solve where lane 0 froze at step 0 and lane 1 at step 1.
fn fixture() -> SolveReport {
    SolveReport {
        kind: SolverKind::Anderson,
        converged: true,
        steps: vec![
            SolveStep {
                iter: 0,
                rel_residual: 1.0,
                sample_residuals: vec![0.25, 1.0],
                active: 1,
                elapsed: Duration::from_secs_f64(0.25),
                fevals: 1,
                mixed: true,
            },
            SolveStep {
                iter: 1,
                rel_residual: 0.25,
                sample_residuals: vec![0.25, 0.125],
                active: 0,
                elapsed: Duration::from_secs_f64(0.5),
                fevals: 2,
                mixed: false,
            },
        ],
        z_star: HostTensor::f32(vec![2], vec![1.5, -2.0]).unwrap(),
        sample_iters: vec![1, 2],
        sample_fevals: vec![1, 2],
        sample_converged: vec![true, true],
        sample_faulted: vec![false, false],
    }
}

/// The pinned wire format.  If this test fails because of an intentional
/// format change, bump the experiment docs and update the string — never
/// regenerate it blindly.
const GOLDEN: &str = "{\"converged\":true,\"kind\":\"anderson\",\
\"sample_converged\":[true,true],\"sample_fevals\":[1,2],\"sample_iters\":[1,2],\
\"steps\":[\
{\"active\":1,\"elapsed_s\":0.25,\"fevals\":1,\"iter\":0,\"mixed\":true,\
\"rel_residual\":1,\"sample_residuals\":[0.25,1]},\
{\"active\":0,\"elapsed_s\":0.5,\"fevals\":2,\"iter\":1,\"mixed\":false,\
\"rel_residual\":0.25,\"sample_residuals\":[0.25,0.125]}\
],\"z_star\":{\"data\":[1.5,-2],\"shape\":[2]}}";

#[test]
fn report_serializes_to_golden_string() {
    let text = json::to_string(&fixture().to_json());
    assert_eq!(text, GOLDEN);
}

#[test]
fn golden_string_parses_back_to_report() {
    let v = json::parse(GOLDEN).unwrap();
    let rep = SolveReport::from_json(&v).unwrap();
    assert_eq!(rep.kind, SolverKind::Anderson);
    assert!(rep.converged);
    assert_eq!(rep.iters(), 2);
    assert_eq!(rep.steps[0].iter, 0);
    assert_eq!(rep.steps[0].rel_residual, 1.0);
    assert_eq!(rep.steps[0].sample_residuals, vec![0.25, 1.0]);
    assert_eq!(rep.steps[0].active, 1);
    assert_eq!(rep.steps[0].elapsed, Duration::from_secs_f64(0.25));
    assert_eq!(rep.steps[0].fevals, 1);
    assert!(rep.steps[0].mixed);
    assert!(!rep.steps[1].mixed);
    assert_eq!(rep.steps[1].sample_residuals, vec![0.25, 0.125]);
    assert_eq!(rep.sample_iters, vec![1, 2]);
    assert_eq!(rep.sample_fevals, vec![1, 2]);
    assert_eq!(rep.sample_converged, vec![true, true]);
    assert_eq!(rep.fevals_total(), 3);
    assert_eq!(rep.z_star.shape, vec![2]);
    assert_eq!(rep.z_star.f32s().unwrap(), &[1.5, -2.0]);
}

#[test]
fn roundtrip_is_identity_on_the_wire() {
    // serialize → parse → serialize must be byte-stable.
    let once = json::to_string(&fixture().to_json());
    let rep = SolveReport::from_json(&json::parse(&once).unwrap()).unwrap();
    let twice = json::to_string(&rep.to_json());
    assert_eq!(once, twice);
}

#[test]
fn empty_report_roundtrips() {
    let rep = SolveReport {
        kind: SolverKind::Forward,
        converged: false,
        steps: vec![],
        z_star: HostTensor::f32(vec![0], vec![]).unwrap(),
        sample_iters: vec![],
        sample_fevals: vec![],
        sample_converged: vec![],
        sample_faulted: vec![],
    };
    let text = json::to_string(&rep.to_json());
    let back = SolveReport::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.kind, SolverKind::Forward);
    assert!(!back.converged);
    assert_eq!(back.iters(), 0);
    assert!(back.z_star.is_empty());
    assert!(back.sample_iters.is_empty());
}

#[test]
fn quarantined_report_emits_sample_faulted_and_roundtrips() {
    // sample_faulted rides the wire only when a lane actually faulted —
    // the fault-free GOLDEN above must never grow the key.
    let mut rep = fixture();
    rep.converged = false;
    rep.sample_converged = vec![true, false];
    rep.sample_faulted = vec![false, true];
    rep.steps[1].sample_residuals = vec![0.25, f32::NAN];
    let wire = json::to_string(&rep.to_json());
    assert!(wire.contains("\"sample_faulted\":[false,true]"), "{wire}");
    // The NaN residual of the quarantined lane serializes as null...
    assert!(wire.contains("\"sample_residuals\":[0.25,null]"), "{wire}");
    // ...and parses back as NaN, with the flags intact and byte-stable.
    let back = SolveReport::from_json(&json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back.sample_faulted, vec![false, true]);
    assert_eq!(back.quarantined(), 1);
    assert!(back.steps[1].sample_residuals[1].is_nan());
    assert_eq!(json::to_string(&back.to_json()), wire);
}

#[test]
fn legacy_report_without_sample_fields_parses() {
    // Reports written before iteration-level scheduling carry no
    // per-sample arrays; they must keep parsing (as empty traces).
    let legacy = "{\"converged\":true,\"kind\":\"anderson\",\"steps\":[\
{\"elapsed_s\":0.25,\"fevals\":1,\"iter\":0,\"mixed\":true,\"rel_residual\":1}\
],\"z_star\":{\"data\":[1.5,-2],\"shape\":[2]}}";
    let rep = SolveReport::from_json(&json::parse(legacy).unwrap()).unwrap();
    assert_eq!(rep.iters(), 1);
    assert!(rep.sample_iters.is_empty());
    assert!(rep.sample_converged.is_empty());
    assert!(rep.steps[0].sample_residuals.is_empty());
    // fevals_total falls back to the lockstep estimate: fevals × batch.
    assert_eq!(rep.fevals_total(), 2);
}

#[test]
fn pr5_era_solve_spec_without_adaptivity_fields_parses_to_fixed_defaults() {
    // A spec serialized before the adaptive policies existed carries no
    // adaptive_window/errorfactor/cond_max/safeguard keys.  It must keep
    // parsing, and it must come back as a *fixed-window* spec: adaptivity
    // off, CDLS21/DFTK default bounds.  Values are dyadic so the float
    // round-trips are exact.
    let legacy = "{\"damping\":{\"mode\":\"full\"},\"fused_forward\":true,\
\"kind\":\"anderson\",\"lam\":0.5,\"max_fevals\":0,\"max_iter\":64,\
\"restart_on_breakdown\":false,\"stagnation\":{\"eps\":0.25,\"window\":4},\
\"tol\":0.125,\"window\":5}";
    let spec = SolveSpec::from_json(&json::parse(legacy).unwrap()).unwrap();
    assert_eq!(spec.kind, SolverKind::Anderson);
    assert_eq!(spec.window, 5);
    assert_eq!(spec.tol, 0.125);
    assert_eq!(spec.lam, 0.5);
    assert!(!spec.adaptive_window);
    assert!(!spec.safeguard);
    assert_eq!(spec.errorfactor, DEFAULT_ERRORFACTOR);
    assert_eq!(spec.cond_max, DEFAULT_COND_MAX);
    // Parsing a legacy spec and a default-built spec of the same shape
    // agree on every adaptivity knob.
    let built = SolveSpec::builder(SolverKind::Anderson)
        .window(5)
        .tol(0.125)
        .lam(0.5)
        .max_iter(64)
        .build()
        .unwrap();
    assert_eq!(spec.adaptive_window, built.adaptive_window);
    assert_eq!(spec.errorfactor, built.errorfactor);
    assert_eq!(spec.cond_max, built.cond_max);
    assert_eq!(spec.safeguard, built.safeguard);
}

#[test]
fn solve_spec_adaptivity_fields_roundtrip_byte_stable() {
    // Non-default adaptivity knobs survive serialize → parse → serialize
    // with byte-identical output (sorted keys, shortest-decimal floats),
    // and the parsed spec compares equal field-for-field.
    let spec = SolveSpec::builder(SolverKind::Hybrid)
        .window(7)
        .tol(0.0625)
        .adaptive_window(true)
        .errorfactor(1024.0)
        .cond_max(65536.0)
        .safeguard(true)
        .build()
        .unwrap();
    let wire = json::to_string(&spec.to_json());
    // The adaptivity keys are present on the wire once set.
    for key in [
        "\"adaptive_window\":true",
        "\"safeguard\":true",
        "\"errorfactor\":",
        "\"cond_max\":",
    ] {
        assert!(wire.contains(key), "missing {key} in {wire}");
    }
    let back = SolveSpec::from_json(&json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back, spec);
    assert_eq!(json::to_string(&back.to_json()), wire);
}

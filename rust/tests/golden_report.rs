//! Golden-trace regression tests for the solver report JSON format.
//!
//! Experiment outputs (residual traces, solve summaries) serialize through
//! `SolveReport::to_json` / `util::json::to_string`.  These tests pin the
//! exact byte-level format — key order (sorted), number rendering, nesting,
//! and the per-sample trace fields introduced by iteration-level
//! scheduling (`sample_residuals`/`active` per step; `sample_iters`/
//! `sample_fevals`/`sample_converged` per report) — so downstream tooling
//! that parses result files can't silently break.  Fixture values are
//! dyadic (0.25, 0.5, 1.5 …) so f32→f64→text→f64→f32 round-trips are
//! exact.

use std::time::Duration;

use deq_anderson::runtime::HostTensor;
use deq_anderson::solver::{SolveReport, SolveStep, SolverKind};
use deq_anderson::util::json;

/// A two-lane solve where lane 0 froze at step 0 and lane 1 at step 1.
fn fixture() -> SolveReport {
    SolveReport {
        kind: SolverKind::Anderson,
        converged: true,
        steps: vec![
            SolveStep {
                iter: 0,
                rel_residual: 1.0,
                sample_residuals: vec![0.25, 1.0],
                active: 1,
                elapsed: Duration::from_secs_f64(0.25),
                fevals: 1,
                mixed: true,
            },
            SolveStep {
                iter: 1,
                rel_residual: 0.25,
                sample_residuals: vec![0.25, 0.125],
                active: 0,
                elapsed: Duration::from_secs_f64(0.5),
                fevals: 2,
                mixed: false,
            },
        ],
        z_star: HostTensor::f32(vec![2], vec![1.5, -2.0]).unwrap(),
        sample_iters: vec![1, 2],
        sample_fevals: vec![1, 2],
        sample_converged: vec![true, true],
    }
}

/// The pinned wire format.  If this test fails because of an intentional
/// format change, bump the experiment docs and update the string — never
/// regenerate it blindly.
const GOLDEN: &str = "{\"converged\":true,\"kind\":\"anderson\",\
\"sample_converged\":[true,true],\"sample_fevals\":[1,2],\"sample_iters\":[1,2],\
\"steps\":[\
{\"active\":1,\"elapsed_s\":0.25,\"fevals\":1,\"iter\":0,\"mixed\":true,\
\"rel_residual\":1,\"sample_residuals\":[0.25,1]},\
{\"active\":0,\"elapsed_s\":0.5,\"fevals\":2,\"iter\":1,\"mixed\":false,\
\"rel_residual\":0.25,\"sample_residuals\":[0.25,0.125]}\
],\"z_star\":{\"data\":[1.5,-2],\"shape\":[2]}}";

#[test]
fn report_serializes_to_golden_string() {
    let text = json::to_string(&fixture().to_json());
    assert_eq!(text, GOLDEN);
}

#[test]
fn golden_string_parses_back_to_report() {
    let v = json::parse(GOLDEN).unwrap();
    let rep = SolveReport::from_json(&v).unwrap();
    assert_eq!(rep.kind, SolverKind::Anderson);
    assert!(rep.converged);
    assert_eq!(rep.iters(), 2);
    assert_eq!(rep.steps[0].iter, 0);
    assert_eq!(rep.steps[0].rel_residual, 1.0);
    assert_eq!(rep.steps[0].sample_residuals, vec![0.25, 1.0]);
    assert_eq!(rep.steps[0].active, 1);
    assert_eq!(rep.steps[0].elapsed, Duration::from_secs_f64(0.25));
    assert_eq!(rep.steps[0].fevals, 1);
    assert!(rep.steps[0].mixed);
    assert!(!rep.steps[1].mixed);
    assert_eq!(rep.steps[1].sample_residuals, vec![0.25, 0.125]);
    assert_eq!(rep.sample_iters, vec![1, 2]);
    assert_eq!(rep.sample_fevals, vec![1, 2]);
    assert_eq!(rep.sample_converged, vec![true, true]);
    assert_eq!(rep.fevals_total(), 3);
    assert_eq!(rep.z_star.shape, vec![2]);
    assert_eq!(rep.z_star.f32s().unwrap(), &[1.5, -2.0]);
}

#[test]
fn roundtrip_is_identity_on_the_wire() {
    // serialize → parse → serialize must be byte-stable.
    let once = json::to_string(&fixture().to_json());
    let rep = SolveReport::from_json(&json::parse(&once).unwrap()).unwrap();
    let twice = json::to_string(&rep.to_json());
    assert_eq!(once, twice);
}

#[test]
fn empty_report_roundtrips() {
    let rep = SolveReport {
        kind: SolverKind::Forward,
        converged: false,
        steps: vec![],
        z_star: HostTensor::f32(vec![0], vec![]).unwrap(),
        sample_iters: vec![],
        sample_fevals: vec![],
        sample_converged: vec![],
    };
    let text = json::to_string(&rep.to_json());
    let back = SolveReport::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.kind, SolverKind::Forward);
    assert!(!back.converged);
    assert_eq!(back.iters(), 0);
    assert!(back.z_star.is_empty());
    assert!(back.sample_iters.is_empty());
}

#[test]
fn legacy_report_without_sample_fields_parses() {
    // Reports written before iteration-level scheduling carry no
    // per-sample arrays; they must keep parsing (as empty traces).
    let legacy = "{\"converged\":true,\"kind\":\"anderson\",\"steps\":[\
{\"elapsed_s\":0.25,\"fevals\":1,\"iter\":0,\"mixed\":true,\"rel_residual\":1}\
],\"z_star\":{\"data\":[1.5,-2],\"shape\":[2]}}";
    let rep = SolveReport::from_json(&json::parse(legacy).unwrap()).unwrap();
    assert_eq!(rep.iters(), 1);
    assert!(rep.sample_iters.is_empty());
    assert!(rep.sample_converged.is_empty());
    assert!(rep.steps[0].sample_residuals.is_empty());
    // fevals_total falls back to the lockstep estimate: fevals × batch.
    assert_eq!(rep.fevals_total(), 2);
}

//! End-to-end training + inference integration tests, hermetic: they run
//! on whatever backend `backend_from_dir` selects (the pure-Rust
//! `NativeEngine` when AOT artifacts are absent), so nothing here skips.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use deq_anderson::data;
use deq_anderson::infer;
use deq_anderson::runtime::{backend_from_dir, Backend};
use deq_anderson::solver::{SolveSpec, SolverKind};
use deq_anderson::train::{default_config, Backward, Trainer};

fn backend() -> &'static Arc<dyn Backend> {
    static B: OnceLock<Arc<dyn Backend>> = OnceLock::new();
    B.get_or_init(|| {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        backend_from_dir(dir).expect("backend")
    })
}

#[test]
fn one_epoch_reduces_loss_and_updates_params() {
    let e = backend().as_ref();
    let (train, test, _) = data::load_auto(128, 32, 1);
    let init = e.init_params().unwrap();
    let mut cfg = default_config(e, SolverKind::Anderson, 2);
    cfg.eval_every = 0;
    let rep = Trainer::new(e, cfg)
        .unwrap()
        .train(&init, &train, &test)
        .unwrap();
    assert_eq!(rep.epochs.len(), 2);
    assert!(!rep.diverged);
    assert!(
        rep.epochs[1].train_loss < rep.epochs[0].train_loss,
        "loss did not decrease: {:?}",
        rep.epochs.iter().map(|e| e.train_loss).collect::<Vec<_>>()
    );
    // Params actually moved.
    let d: f32 = rep
        .params
        .to_flat()
        .iter()
        .zip(init.to_flat())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(d > 1e-4, "params unchanged");
    assert!(rep.params.all_finite());
}

#[test]
fn neumann_backward_also_trains() {
    let e = backend().as_ref();
    let (train, test, _) = data::load_auto(64, 32, 2);
    let init = e.init_params().unwrap();
    let mut cfg = default_config(e, SolverKind::Anderson, 2);
    cfg.backward = Backward::Neumann;
    cfg.eval_every = 0;
    let rep = Trainer::new(e, cfg)
        .unwrap()
        .train(&init, &train, &test)
        .unwrap();
    assert!(rep.epochs[1].train_loss < rep.epochs[0].train_loss + 0.05);
    assert!(rep.params.all_finite());
}

#[test]
fn explicit_baseline_trains() {
    let e = backend().as_ref();
    let (train, test, _) = data::load_auto(64, 32, 3);
    let init = e.init_params().unwrap();
    let mut cfg = default_config(e, SolverKind::Anderson, 2);
    cfg.eval_every = 2;
    let rep = Trainer::new(e, cfg)
        .unwrap()
        .train_explicit(&init, &train, &test)
        .unwrap();
    assert_eq!(rep.epochs.len(), 2);
    assert!(rep.epochs[1].train_loss < rep.epochs[0].train_loss + 0.05);
    assert!(rep.epochs[1].test_acc.is_some());
}

#[test]
fn inference_pads_to_buckets() {
    let e = backend().as_ref();
    let params = e.init_params().unwrap();
    let (data, _, _) = data::load_auto(40, 8, 4);
    let opts = SolveSpec::from_manifest(e, SolverKind::Anderson);
    // Sizes that are NOT compiled buckets must still work via padding.
    for n in [1usize, 3, 5, 8, 17, 32] {
        let idx: Vec<usize> = (0..n).collect();
        let (imgs, _) = data.gather(&idx);
        let r = infer::infer(e, &params, &imgs, n, &opts).unwrap();
        assert_eq!(r.predictions.len(), n);
        assert_eq!(r.logits.len(), n);
        assert!(r.logits.iter().all(|row| row.len() == 10));
    }
    // Oversized request is rejected.
    let idx: Vec<usize> = (0..33).collect();
    let (imgs, _) = data.gather(&idx);
    assert!(infer::infer(e, &params, &imgs, 33, &opts).is_err());
}

#[test]
fn padding_does_not_change_predictions() {
    // The same sample must classify identically at batch 1 and inside a
    // padded bucket (guards against cross-sample leakage; both the native
    // cell and GroupNorm are per-sample so this must hold up to fp noise).
    let e = backend().as_ref();
    let params = e.init_params().unwrap();
    let (data, _, _) = data::load_auto(8, 8, 5);
    let opts = SolveSpec::from_manifest(e, SolverKind::Forward);
    let (img1, _) = data.gather(&[0]);
    let r1 = infer::infer(e, &params, &img1, 1, &opts).unwrap();
    let (img3, _) = data.gather(&[0, 1, 2]);
    let r3 = infer::infer(e, &params, &img3, 3, &opts).unwrap();
    for (a, b) in r1.logits[0].iter().zip(&r3.logits[0]) {
        assert!((a - b).abs() < 1e-2, "{a} vs {b}");
    }
}

#[test]
fn evaluate_runs_on_test_set() {
    let e = backend().as_ref();
    let params = e.init_params().unwrap();
    let (_, test, _) = data::load_auto(32, 64, 6);
    let opts = SolveSpec::from_manifest(e, SolverKind::Anderson);
    let acc = infer::evaluate(e, &params, &test, 32, &opts).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    let acc_e = infer::evaluate_explicit(e, &params, &test, 32).unwrap();
    assert!((0.0..=1.0).contains(&acc_e));
}

#[test]
fn evaluate_covers_tail_remainder() {
    // 40 samples at batch 32 leaves a remainder of 8; it used to be
    // silently dropped (`len / batch` truncation).  Both inference paths
    // are per-sample deterministic, so accuracy over the same 40 samples
    // must not depend on how they are chunked into batches.
    let e = backend().as_ref();
    let params = e.init_params().unwrap();
    let (_, test, _) = data::load_auto(16, 40, 7);
    assert_eq!(test.len(), 40);
    let opts = SolveSpec::from_manifest(e, SolverKind::Anderson);
    let acc32 = infer::evaluate(e, &params, &test, 32, &opts).unwrap();
    let acc8 = infer::evaluate(e, &params, &test, 8, &opts).unwrap();
    assert_eq!(acc32, acc8, "DEQ accuracy depends on batch chunking");
    let acc_e32 = infer::evaluate_explicit(e, &params, &test, 32).unwrap();
    let acc_e8 = infer::evaluate_explicit(e, &params, &test, 8).unwrap();
    assert_eq!(acc_e32, acc_e8, "explicit accuracy depends on chunking");
}

//! Property-based tests of the solver invariants (DESIGN.md §4).
//!
//! No `proptest` in the offline crate set, so this is a seeded-case
//! harness over the deterministic PRNG: each property runs across a sweep
//! of random seeds/shapes and shrinks failures by reporting the seed.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use deq_anderson::native::{
    self, maps::AffineMap, maps::TanhMap, AndersonOpts, AndersonState,
    FixedPointMap,
};
use deq_anderson::runtime::{backend_from_dir, Backend, HostTensor};
use deq_anderson::solver::anderson::{History, LaneHistory};
use deq_anderson::solver::driver::{damp_in_place, solve_spec};
use deq_anderson::solver::{
    crossover, AdaptiveAndersonPolicy, GramMode, LaneStep, SolvePolicy,
    SolveSpec, SolverKind, WindowRule,
};
use deq_anderson::util::rng::Rng;

fn backend() -> &'static Arc<dyn Backend> {
    static B: OnceLock<Arc<dyn Backend>> = OnceLock::new();
    B.get_or_init(|| {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        backend_from_dir(dir).expect("backend selection never fails in auto mode")
    })
}

/// Run `prop` over `cases` seeds; panic with the failing seed.
///
/// The case count is the per-property default; the `DEQ_PROP_CASES`
/// environment variable overrides it with an absolute count for every
/// property (proptest's `PROPTEST_CASES` convention) — the CI deep-test
/// job sets it to 256+, local runs keep the cheap defaults.  Seeds are
/// always `0..cases`, so any failure reproduces by seed without the
/// env var.
fn for_seeds(cases: u64, prop: impl Fn(u64)) {
    let cases = std::env::var("DEQ_PROP_CASES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(|v| v.max(1))
        .unwrap_or(cases);
    for seed in 0..cases {
        // Catch nothing — a panic inside already names the seed via the
        // assert messages below.
        prop(seed);
    }
}

#[test]
fn prop_alpha_sums_to_one_any_window_fill() {
    for_seeds(30, |seed| {
        let mut rng = Rng::new(seed);
        let m = 1 + (seed as usize % 8);
        let n = 4 + (seed as usize % 60);
        let mut st = AndersonState::new(m, n, 1.0, 1e-5);
        let pushes = 1 + (seed as usize % (2 * m));
        for _ in 0..pushes {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            st.push(&z, &f);
        }
        let (z, alpha) = st.mix().unwrap();
        let s: f32 = alpha.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "seed={seed} m={m} n={n} sum={s}");
        assert!(z.iter().all(|v| v.is_finite()), "seed={seed}: non-finite z");
        assert_eq!(alpha.len(), st.valid());
    });
}

#[test]
fn prop_anderson_never_slower_on_affine_maps() {
    // On smooth affine contractions Anderson (m>=2, small λ) must need at
    // most as many iterations as forward to the same tolerance.
    for_seeds(12, |seed| {
        let n = 10 + (seed as usize % 40);
        let rho = 0.7 + 0.02 * (seed % 10) as f32; // 0.7 .. 0.88
        let map = AffineMap::random(n, rho, seed + 100);
        let z0 = vec![0.0; n];
        let opts = AndersonOpts {
            window: 4,
            lam: 1e-8,
            tol: 1e-4,
            max_iter: 800,
            ..Default::default()
        };
        let fw = native::solve_forward(&map, &z0, opts);
        let an = native::solve_anderson(&map, &z0, opts).unwrap();
        assert!(an.converged, "seed={seed}: anderson failed to converge");
        assert!(
            an.iters() <= fw.iters(),
            "seed={seed} rho={rho}: anderson {} > forward {}",
            an.iters(),
            fw.iters()
        );
    });
}

#[test]
fn prop_converged_point_is_fixed_point() {
    for_seeds(10, |seed| {
        let n = 8 + (seed as usize % 24);
        let map = TanhMap::random(n, 0.8, seed + 7);
        let opts = AndersonOpts {
            tol: 1e-5,
            max_iter: 500,
            ..Default::default()
        };
        let tr = native::solve_anderson(&map, &vec![0.0; n], opts).unwrap();
        assert!(tr.converged, "seed={seed}");
        let mut out = vec![0.0; n];
        map.apply(&tr.z, &mut out);
        let rel = native::rel_residual(&out, &tr.z, opts.lam);
        assert!(rel < 10.0 * opts.tol, "seed={seed}: residual {rel}");
    });
}

#[test]
fn prop_beta_zero_keeps_iterate_in_x_span() {
    // β=0 mixes only past iterates: starting from identical X rows, the
    // mixed iterate equals that row regardless of F.
    for_seeds(20, |seed| {
        let mut rng = Rng::new(seed);
        let (m, n) = (3usize, 12usize);
        let mut st = AndersonState::new(m, n, 0.0, 1e-6);
        let x = rng.normal_vec(n, 1.0);
        for _ in 0..m {
            let f = rng.normal_vec(n, 1.0);
            st.push(&x, &f);
        }
        let (z, _) = st.mix().unwrap();
        for (a, b) in z.iter().zip(&x) {
            // Relative to the coordinate's magnitude: at deep-test case
            // counts (DEQ_PROP_CASES >= 256) the seed sweep reaches
            // multi-sigma draws where a flat absolute bound flakes.
            let tol = 1e-3 * b.abs().max(1.0);
            assert!((a - b).abs() < tol, "seed={seed}: {a} vs {b}");
        }
    });
}

#[test]
fn prop_residual_scale_invariance() {
    // rel_residual(c·f, c·z) is invariant for λ→0 (homogeneity check).
    for_seeds(20, |seed| {
        let mut rng = Rng::new(seed);
        let n = 16;
        let f = rng.normal_vec(n, 1.0);
        let z = rng.normal_vec(n, 1.0);
        let r1 = native::rel_residual(&f, &z, 0.0);
        let c = 7.5f32;
        let fc: Vec<f32> = f.iter().map(|v| c * v).collect();
        let zc: Vec<f32> = z.iter().map(|v| c * v).collect();
        let r2 = native::rel_residual(&fc, &zc, 0.0);
        // Relative bound: residuals grow with the draw's magnitude, so a
        // flat 1e-4 flakes on the tail seeds of a deep-test sweep.
        assert!(
            (r1 - r2).abs() < 1e-4 * r1.max(1.0),
            "seed={seed}: {r1} vs {r2}"
        );
    });
}

#[test]
fn prop_solver_determinism() {
    // Identical seeds → bitwise identical traces.
    for_seeds(5, |seed| {
        let map = AffineMap::random(20, 0.9, seed);
        let opts = AndersonOpts { tol: 1e-5, max_iter: 200, ..Default::default() };
        let a = native::solve_anderson(&map, &vec![0.0; 20], opts).unwrap();
        let b = native::solve_anderson(&map, &vec![0.0; 20], opts).unwrap();
        assert_eq!(a.iters(), b.iters());
        assert_eq!(a.z, b.z);
    });
}

#[test]
fn prop_crossover_consistency() {
    // For any pair of solve traces, time_to_target is monotone in target
    // and the mixing penalty is positive.
    for_seeds(8, |seed| {
        let n = 24;
        let map = AffineMap::random(n, 0.9, seed + 50);
        let opts = AndersonOpts {
            tol: 1e-5,
            lam: 1e-8,
            max_iter: 500,
            ..Default::default()
        };
        let _fw = native::solve_forward(&map, &vec![0.0; n], opts);
        let an = native::solve_anderson(&map, &vec![0.0; n], opts).unwrap();
        let trace: Vec<crossover::TracePoint> = an
            .records
            .iter()
            .enumerate()
            .map(|(k, r)| crossover::TracePoint {
                t: std::time::Duration::from_micros(k as u64 + 1),
                residual: r.rel_residual,
            })
            .collect();
        let mut last = None;
        for target in [1e-1f32, 1e-2, 1e-3, 1e-4] {
            let t = crossover::time_to_target(&trace, target);
            if let (Some(prev), Some(cur)) = (last, t) {
                assert!(cur >= prev, "seed={seed}: non-monotone time-to-target");
            }
            if t.is_some() {
                last = t;
            }
        }
    });
}

#[test]
fn prop_history_and_native_state_agree_on_ring_layout() {
    // The coordinator's batched History and the native AndersonState must
    // place identical push sequences into identical ring slots (slot =
    // push_count mod m) and agree on the valid count / mask — including
    // under wraparound, where the oldest slot is overwritten first.
    for_seeds(25, |seed| {
        let mut rng = Rng::new(seed);
        let m = 1 + (seed as usize % 6);
        let n = 3 + (seed as usize % 10);
        let pushes = 1 + (seed as usize % (3 * m));
        let mut hist = History::new(1, m, n);
        let mut st = AndersonState::new(m, n, 1.0, 1e-6);
        for _ in 0..pushes {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            hist.push(&z, &f);
            st.push(&z, &f);
        }
        assert_eq!(hist.valid(), st.valid(), "seed={seed}");
        let (xh, fh, mask) = hist.tensors().unwrap();
        assert_eq!(
            xh.f32s().unwrap(),
            st.xs_raw(),
            "seed={seed} m={m} n={n} pushes={pushes}: x ring diverged"
        );
        assert_eq!(fh.f32s().unwrap(), st.fs_raw(), "seed={seed}: f ring diverged");
        // Mask is a 1-prefix of length valid().
        let mv = mask.f32s().unwrap();
        for (i, &v) in mv.iter().enumerate() {
            let want = if i < st.valid() { 1.0 } else { 0.0 };
            assert_eq!(v, want, "seed={seed} slot {i}");
        }
    });
}

#[test]
fn prop_padded_history_matches_native_window_prefix() {
    // A runtime window m padded into `slots` > m compiled slots must hold
    // exactly the native m-ring in its first m slots, zeros elsewhere.
    for_seeds(15, |seed| {
        let mut rng = Rng::new(seed ^ 0xA11CE);
        let m = 1 + (seed as usize % 4);
        let slots = m + 1 + (seed as usize % 4);
        let n = 4 + (seed as usize % 6);
        let mut hist = History::with_padded_slots(1, m, slots, n);
        let mut st = AndersonState::new(m, n, 1.0, 1e-6);
        for _ in 0..(2 * m + 1) {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            hist.push(&z, &f);
            st.push(&z, &f);
        }
        let (xh, _, mask) = hist.tensors().unwrap();
        let x = xh.f32s().unwrap();
        assert_eq!(&x[..m * n], st.xs_raw(), "seed={seed}: ring prefix diverged");
        assert!(
            x[m * n..].iter().all(|&v| v == 0.0),
            "seed={seed}: padded slots not zero"
        );
        let mv = mask.f32s().unwrap();
        assert_eq!(mv.len(), slots);
        assert!(mv[..m].iter().all(|&v| v == 1.0), "seed={seed}");
        assert!(mv[m..].iter().all(|&v| v == 0.0), "seed={seed}");
    });
}

#[test]
fn prop_krylov_exactness_on_affine_maps() {
    // With window ≥ dim + 1 and tiny regularization, Anderson on an
    // affine map is GMRES in disguise: it must converge in at most
    // dim + O(1) iterations (f32 rounding allows a small slack).
    for_seeds(10, |seed| {
        let n = 3 + (seed as usize % 6);
        let rho = 0.75 + 0.05 * (seed % 3) as f32;
        let map = AffineMap::random(n, rho, seed + 31);
        let opts = AndersonOpts {
            window: n + 2,
            lam: 1e-8,
            tol: 1e-4,
            max_iter: 60,
            ..Default::default()
        };
        let tr = native::solve_anderson(&map, &vec![0.0; n], opts).unwrap();
        assert!(tr.converged, "seed={seed} n={n}: did not converge");
        assert!(
            tr.iters() <= n + 6,
            "seed={seed} n={n}: {} iters breaks Krylov exactness",
            tr.iters()
        );
        let sol = map.solution().expect("small affine maps have solutions");
        let err: f32 = tr
            .z
            .iter()
            .zip(&sol)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-2, "seed={seed}: err={err}");
    });
}

#[test]
fn prop_window_monotonicity_on_hard_affine() {
    // Bigger windows shouldn't catastrophically hurt on smooth problems:
    // m=5 converges within 2x the iterations of the best of {1,2,5}.
    for_seeds(6, |seed| {
        let n = 30;
        let map = AffineMap::random(n, 0.95, seed + 11);
        let iters = |m: usize| {
            let o = AndersonOpts {
                window: m,
                lam: 1e-8,
                tol: 1e-4,
                max_iter: 1500,
                ..Default::default()
            };
            native::solve_anderson(&map, &vec![0.0; n], o)
                .unwrap()
                .iters()
        };
        let (i1, i2, i5) = (iters(1), iters(2), iters(5));
        let best = i1.min(i2).min(i5);
        assert!(
            i5 <= 2 * best,
            "seed={seed}: m=5 took {i5}, best {best} (m1={i1} m2={i2})"
        );
    });
}

// ---------- adaptive-window / safeguard properties ----------------------

#[test]
fn prop_effective_window_never_exceeds_spec_window() {
    // Whatever the knobs, adaptation can only *shrink* the window: the
    // mask never has more live slots than min(spec.window, pushes), never
    // fewer than one, and every hole it punches sits inside the valid
    // prefix.
    for_seeds(25, |seed| {
        let mut rng = Rng::new(seed ^ 0x5EED);
        let m = 1 + (seed as usize % 6);
        let slots = m + (seed as usize % 3);
        let n = 3 + (seed as usize % 8);
        let batch = 1 + (seed as usize % 3);
        let mut hist = History::with_padded_slots(batch, m, slots, n);
        let pushes = 1 + (seed as usize % (2 * m + 1));
        for _ in 0..pushes {
            let z = rng.normal_vec(batch * n, 1.0);
            let f = rng.normal_vec(batch * n, 2.0);
            hist.push(&z, &f);
        }
        let rule = WindowRule {
            errorfactor: 1.0 + rng.range(0.1, 30.0),
            cond_max: rng.range(1.0, 1e6),
            // Both probe flavors must uphold the structural invariants.
            gram: if seed % 2 == 0 {
                GramMode::Exact
            } else {
                GramMode::Sketched { dim: 1 + (seed as usize % 8) }
            },
        };
        let out = hist.adapt(rule, 1e-3);
        let mask = hist.mask();
        let live = mask.iter().filter(|&&v| v == 1.0).count();
        let nv = pushes.min(m);
        assert_eq!(live, out.kept, "seed={seed}: mask disagrees with outcome");
        assert!(
            (1..=nv).contains(&live),
            "seed={seed} m={m} pushes={pushes}: {live} live slots escape [1, {nv}]"
        );
        assert!(
            mask[nv..].iter().all(|&v| v == 0.0),
            "seed={seed}: adaptation marked an invalid slot live"
        );
        assert_eq!(
            out.kept + out.dropped(),
            nv,
            "seed={seed}: kept + dropped must cover the valid window"
        );
    });
}

#[test]
fn prop_safeguard_step_is_exactly_the_plain_damped_step() {
    // Drive the adaptive policy's state machine over random residual
    // trajectories: after any mixed step whose residual *rose*, the
    // safeguarded policy must emit a Forward step whose β sits exactly
    // where the damping schedule points — and applying that step is
    // bitwise the plain damped update z + β(f−z), so the fallback can
    // never do worse than the damped step it falls back to (it *is*
    // that step).
    for_seeds(25, |seed| {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37) + 1);
        let spec = SolveSpec {
            safeguard: true,
            adaptive_window: seed % 2 == 0,
            restart_on_breakdown: seed % 3 == 0,
            ..SolveSpec::new(SolverKind::Anderson)
        };
        let mut p = AdaptiveAndersonPolicy::new(&spec);
        let mut prev: Option<f32> = None;
        let mut last_was_mix = false;
        let mut safeguards = 0usize;
        for step in 0..40 {
            let rel = rng.range(1e-4, 2.0);
            let rose = prev.map(|q| rel > q).unwrap_or(false);
            let action = p.observe(rel);
            if last_was_mix && rose {
                // Post-mix breakdown: the safeguard must catch it with a
                // plain damped step — never a Restart (window survives),
                // never another Mix.
                let LaneStep::Forward { beta } = action else {
                    panic!("seed={seed} step={step}: breakdown not safeguarded, got {action:?}");
                };
                // Default damping schedule is Full: β = 1 exactly.
                assert_eq!(beta, 1.0, "seed={seed}: safeguard β off-schedule");
                safeguards += 1;
                // The emitted step applied through the driver's blend is
                // bitwise the plain damped update.
                let n = 6;
                let z = rng.normal_vec(n, 1.0);
                let f = rng.normal_vec(n, 1.0);
                let mut via_driver = f.clone();
                damp_in_place(&mut via_driver, &z, beta);
                let plain: Vec<f32> = z
                    .iter()
                    .zip(&f)
                    .map(|(zv, fv)| zv + beta * (fv - zv))
                    .collect();
                assert_eq!(via_driver, plain, "seed={seed}: blend diverged");
            } else {
                assert_eq!(
                    action,
                    LaneStep::Mix,
                    "seed={seed} step={step}: lane stopped mixing without breakdown"
                );
            }
            last_was_mix = action == LaneStep::Mix;
            prev = Some(rel);
        }
        assert_eq!(
            p.safeguard_steps(),
            safeguards,
            "seed={seed}: safeguard counter out of sync"
        );
    });
}

#[test]
fn prop_dropped_iterates_violate_errorfactor_bound() {
    // With the condition ceiling disabled, the residual rule is the only
    // dropper — and it must be exact both ways: every dropped slot
    // violates `errorfactor × min` on the cohort norms, every kept
    // non-newest slot does not, and the newest slot survives always.
    for_seeds(25, |seed| {
        let mut rng = Rng::new(seed ^ 0xD0D0);
        let m = 2 + (seed as usize % 5);
        let n = 3 + (seed as usize % 6);
        let batch = 1 + (seed as usize % 2);
        let mut h = History::new(batch, m, n);
        let pushes = m + (seed as usize % (m + 1));
        // Track the latest (z, f) pair landing in each ring slot so the
        // test recomputes cohort norms independently of the bookkeeping.
        let mut slot_rows: Vec<Option<(Vec<f32>, Vec<f32>)>> = vec![None; m];
        for t in 0..pushes {
            let z = rng.normal_vec(batch * n, 1.0);
            // Inflate some pushes so drops actually happen.
            let scale = if t % 3 == 1 { rng.range(5.0, 40.0) } else { 1.0 };
            let f: Vec<f32> =
                z.iter().map(|v| v + scale * rng.normal()).collect();
            h.push(&z, &f);
            slot_rows[t % m] = Some((z, f));
        }
        let ef = 1.0 + rng.range(0.5, 20.0);
        // cond_max = ∞ disables the ceiling outright (even a failed
        // factorization's INFINITY estimate satisfies `cond ≤ ∞`), so
        // the residual rule is provably the only dropper here.
        let rule = WindowRule {
            errorfactor: ef,
            cond_max: f32::INFINITY,
            gram: GramMode::Exact,
        };
        let out = h.adapt(rule, 1e-3);
        assert!(out.dropped_cond.is_empty(), "seed={seed}: cond ceiling was disabled");
        let nv = pushes.min(m);
        let newest = (pushes - 1) % m;
        // Independent cohort norms: max over the batch per slot.
        let cohort: Vec<f32> = (0..nv)
            .map(|s| {
                let (z, f) = slot_rows[s].as_ref().expect("slot filled");
                (0..batch)
                    .map(|b| {
                        z[b * n..(b + 1) * n]
                            .iter()
                            .zip(&f[b * n..(b + 1) * n])
                            .map(|(zv, fv)| (fv - zv) * (fv - zv))
                            .sum::<f32>()
                            .sqrt()
                    })
                    .fold(0.0f32, f32::max)
            })
            .collect();
        let min = cohort.iter().cloned().fold(f32::INFINITY, f32::min);
        let mask = h.mask();
        assert_eq!(mask[newest], 1.0, "seed={seed}: newest slot dropped");
        for s in 0..nv {
            let dropped = out.dropped_resid.contains(&s);
            assert_eq!(
                mask[s] == 0.0,
                dropped,
                "seed={seed} slot={s}: mask and outcome disagree"
            );
            if dropped {
                assert!(
                    cohort[s] > ef * min,
                    "seed={seed} slot={s}: dropped but within bound \
                     ({} <= {ef} × {min})",
                    cohort[s]
                );
            } else if s != newest {
                assert!(
                    cohort[s] <= ef * min,
                    "seed={seed} slot={s}: kept but violates bound \
                     ({} > {ef} × {min})",
                    cohort[s]
                );
            }
        }
    });
}

#[test]
fn prop_cond_truncation_never_leaves_empty_window() {
    // Nearly-parallel history rows force the condition ceiling to
    // truncate; however hostile the cap, both ring flavors must keep the
    // newest iterate and at least one slot.
    for_seeds(25, |seed| {
        let mut rng = Rng::new(seed ^ 0xC04D);
        let m = 2 + (seed as usize % 5);
        let n = 4 + (seed as usize % 6);
        let base = rng.normal_vec(n, 1.0);
        let rule = WindowRule {
            errorfactor: f32::MAX,
            cond_max: rng.range(1.0, 100.0),
            // Half the seeds truncate through the sketched condition
            // probe, so the hostile-cap invariants cover both flavors.
            gram: if seed % 2 == 0 {
                GramMode::Exact
            } else {
                GramMode::Sketched { dim: 4 + (seed as usize % 16) }
            },
        };
        let lam = if seed % 2 == 0 { 1e-6 } else { 1e-3 };

        let mut h = History::new(1, m, n);
        let pushes = m + (seed as usize % m);
        for _ in 0..pushes {
            let z = rng.normal_vec(n, 0.1);
            // f − z ≈ base + tiny noise: rows are close to rank one.
            let f: Vec<f32> = z
                .iter()
                .zip(&base)
                .map(|(zv, bv)| zv + bv + 1e-3 * rng.normal())
                .collect();
            h.push(&z, &f);
        }
        let out = h.adapt(rule, lam);
        let newest = (pushes - 1) % m;
        let mask = h.mask();
        assert!(out.kept >= 1, "seed={seed}: window emptied");
        assert_eq!(
            mask.iter().filter(|&&v| v == 1.0).count(),
            out.kept,
            "seed={seed}: mask/outcome mismatch"
        );
        assert_eq!(mask[newest], 1.0, "seed={seed}: newest truncated");
        assert!(
            !out.dropped_cond.contains(&newest)
                && !out.dropped_resid.contains(&newest),
            "seed={seed}: outcome claims the newest slot was dropped"
        );

        // Same invariants for the scheduler's per-lane ring, where drops
        // overwrite with the newest pair instead of masking.
        let mut lh = LaneHistory::new(2, m, m, n);
        for _ in 0..pushes {
            let z = rng.normal_vec(n, 0.1);
            let f: Vec<f32> = z
                .iter()
                .zip(&base)
                .map(|(zv, bv)| zv + bv + 1e-3 * rng.normal())
                .collect();
            lh.push_lane(1, &z, &f);
        }
        let out = lh.adapt_lane(1, rule, lam);
        assert!(out.kept >= 1, "seed={seed}: lane lost every live slot");
        let live = lh.live_slots(1);
        assert_eq!(live.len(), out.kept, "seed={seed}: live/outcome mismatch");
        assert!(
            live.contains(&lh.newest_slot(1)),
            "seed={seed}: lane's newest slot went dead"
        );
        // Lane 0 (never touched) stays empty.
        assert!(lh.live_slots(0).is_empty(), "seed={seed}: cross-lane leak");
    });
}

#[test]
fn prop_sketched_gram_solves_reach_the_exact_fixed_point() {
    // GramMode changes only the *condition probe* driving adaptive window
    // truncation, never the mixing algebra: an adaptive Anderson solve
    // under a sketched Gram must still converge, and to the same fixed
    // point as the exact-Gram solve (the equilibrium is unique, so both
    // approximate it to within solver tolerance).
    for_seeds(4, |seed| {
        let e = backend();
        let p = e.init_params().unwrap();
        let meta = e.manifest().model.clone();
        let batch = 2;
        let mut rng = Rng::new(seed.wrapping_mul(0x5E7C) + 3);
        let img = HostTensor::f32(
            meta.image_shape(batch),
            rng.normal_vec(batch * meta.image_dim(), 1.0),
        )
        .unwrap();
        let mut enc_in = p.tensors.clone();
        enc_in.push(img);
        let xf = e.execute("encode", batch, &enc_in).unwrap().remove(0);
        let tol = 1e-3f32;
        let solve = |gram: GramMode| {
            let spec = SolveSpec {
                tol,
                max_iter: 120,
                adaptive_window: true,
                gram,
                ..SolveSpec::from_manifest(e.as_ref(), SolverKind::Anderson)
            };
            solve_spec(e.as_ref(), &p.tensors, &xf, &spec).unwrap()
        };
        let exact = solve(GramMode::Exact);
        let dim = 4 + (seed as usize % 29);
        let sketched = solve(GramMode::Sketched { dim });
        assert!(exact.converged, "seed={seed}: exact-gram solve diverged");
        assert!(
            sketched.converged,
            "seed={seed} dim={dim}: sketched-gram solve diverged"
        );
        let ze = exact.z_star.f32s().unwrap();
        let zs = sketched.z_star.f32s().unwrap();
        let scale = ze.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        let maxerr = ze
            .iter()
            .zip(zs)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            maxerr <= 100.0 * tol * scale,
            "seed={seed} dim={dim}: fixed points diverge by {maxerr} (scale {scale})"
        );
    });
}

//! Property-based tests of the solver invariants (DESIGN.md §4).
//!
//! No `proptest` in the offline crate set, so this is a seeded-case
//! harness over the deterministic PRNG: each property runs across a sweep
//! of random seeds/shapes and shrinks failures by reporting the seed.

use deq_anderson::native::{
    self, maps::AffineMap, maps::TanhMap, AndersonOpts, AndersonState,
    FixedPointMap,
};
use deq_anderson::solver::anderson::History;
use deq_anderson::solver::crossover;
use deq_anderson::util::rng::Rng;

/// Run `prop` over `cases` seeds; panic with the failing seed.
fn for_seeds(cases: u64, prop: impl Fn(u64)) {
    for seed in 0..cases {
        // Catch nothing — a panic inside already names the seed via the
        // assert messages below.
        prop(seed);
    }
}

#[test]
fn prop_alpha_sums_to_one_any_window_fill() {
    for_seeds(30, |seed| {
        let mut rng = Rng::new(seed);
        let m = 1 + (seed as usize % 8);
        let n = 4 + (seed as usize % 60);
        let mut st = AndersonState::new(m, n, 1.0, 1e-5);
        let pushes = 1 + (seed as usize % (2 * m));
        for _ in 0..pushes {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            st.push(&z, &f);
        }
        let (z, alpha) = st.mix().unwrap();
        let s: f32 = alpha.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "seed={seed} m={m} n={n} sum={s}");
        assert!(z.iter().all(|v| v.is_finite()), "seed={seed}: non-finite z");
        assert_eq!(alpha.len(), st.valid());
    });
}

#[test]
fn prop_anderson_never_slower_on_affine_maps() {
    // On smooth affine contractions Anderson (m>=2, small λ) must need at
    // most as many iterations as forward to the same tolerance.
    for_seeds(12, |seed| {
        let n = 10 + (seed as usize % 40);
        let rho = 0.7 + 0.02 * (seed % 10) as f32; // 0.7 .. 0.88
        let map = AffineMap::random(n, rho, seed + 100);
        let z0 = vec![0.0; n];
        let opts = AndersonOpts {
            window: 4,
            lam: 1e-8,
            tol: 1e-4,
            max_iter: 800,
            ..Default::default()
        };
        let fw = native::solve_forward(&map, &z0, opts);
        let an = native::solve_anderson(&map, &z0, opts).unwrap();
        assert!(an.converged, "seed={seed}: anderson failed to converge");
        assert!(
            an.iters() <= fw.iters(),
            "seed={seed} rho={rho}: anderson {} > forward {}",
            an.iters(),
            fw.iters()
        );
    });
}

#[test]
fn prop_converged_point_is_fixed_point() {
    for_seeds(10, |seed| {
        let n = 8 + (seed as usize % 24);
        let map = TanhMap::random(n, 0.8, seed + 7);
        let opts = AndersonOpts {
            tol: 1e-5,
            max_iter: 500,
            ..Default::default()
        };
        let tr = native::solve_anderson(&map, &vec![0.0; n], opts).unwrap();
        assert!(tr.converged, "seed={seed}");
        let mut out = vec![0.0; n];
        map.apply(&tr.z, &mut out);
        let rel = native::rel_residual(&out, &tr.z, opts.lam);
        assert!(rel < 10.0 * opts.tol, "seed={seed}: residual {rel}");
    });
}

#[test]
fn prop_beta_zero_keeps_iterate_in_x_span() {
    // β=0 mixes only past iterates: starting from identical X rows, the
    // mixed iterate equals that row regardless of F.
    for_seeds(20, |seed| {
        let mut rng = Rng::new(seed);
        let (m, n) = (3usize, 12usize);
        let mut st = AndersonState::new(m, n, 0.0, 1e-6);
        let x = rng.normal_vec(n, 1.0);
        for _ in 0..m {
            let f = rng.normal_vec(n, 1.0);
            st.push(&x, &f);
        }
        let (z, _) = st.mix().unwrap();
        for (a, b) in z.iter().zip(&x) {
            assert!((a - b).abs() < 1e-3, "seed={seed}: {a} vs {b}");
        }
    });
}

#[test]
fn prop_residual_scale_invariance() {
    // rel_residual(c·f, c·z) is invariant for λ→0 (homogeneity check).
    for_seeds(20, |seed| {
        let mut rng = Rng::new(seed);
        let n = 16;
        let f = rng.normal_vec(n, 1.0);
        let z = rng.normal_vec(n, 1.0);
        let r1 = native::rel_residual(&f, &z, 0.0);
        let c = 7.5f32;
        let fc: Vec<f32> = f.iter().map(|v| c * v).collect();
        let zc: Vec<f32> = z.iter().map(|v| c * v).collect();
        let r2 = native::rel_residual(&fc, &zc, 0.0);
        assert!((r1 - r2).abs() < 1e-4, "seed={seed}: {r1} vs {r2}");
    });
}

#[test]
fn prop_solver_determinism() {
    // Identical seeds → bitwise identical traces.
    for_seeds(5, |seed| {
        let map = AffineMap::random(20, 0.9, seed);
        let opts = AndersonOpts { tol: 1e-5, max_iter: 200, ..Default::default() };
        let a = native::solve_anderson(&map, &vec![0.0; 20], opts).unwrap();
        let b = native::solve_anderson(&map, &vec![0.0; 20], opts).unwrap();
        assert_eq!(a.iters(), b.iters());
        assert_eq!(a.z, b.z);
    });
}

#[test]
fn prop_crossover_consistency() {
    // For any pair of solve traces, time_to_target is monotone in target
    // and the mixing penalty is positive.
    for_seeds(8, |seed| {
        let n = 24;
        let map = AffineMap::random(n, 0.9, seed + 50);
        let opts = AndersonOpts {
            tol: 1e-5,
            lam: 1e-8,
            max_iter: 500,
            ..Default::default()
        };
        let _fw = native::solve_forward(&map, &vec![0.0; n], opts);
        let an = native::solve_anderson(&map, &vec![0.0; n], opts).unwrap();
        let trace: Vec<crossover::TracePoint> = an
            .records
            .iter()
            .enumerate()
            .map(|(k, r)| crossover::TracePoint {
                t: std::time::Duration::from_micros(k as u64 + 1),
                residual: r.rel_residual,
            })
            .collect();
        let mut last = None;
        for target in [1e-1f32, 1e-2, 1e-3, 1e-4] {
            let t = crossover::time_to_target(&trace, target);
            if let (Some(prev), Some(cur)) = (last, t) {
                assert!(cur >= prev, "seed={seed}: non-monotone time-to-target");
            }
            if t.is_some() {
                last = t;
            }
        }
    });
}

#[test]
fn prop_history_and_native_state_agree_on_ring_layout() {
    // The coordinator's batched History and the native AndersonState must
    // place identical push sequences into identical ring slots (slot =
    // push_count mod m) and agree on the valid count / mask — including
    // under wraparound, where the oldest slot is overwritten first.
    for_seeds(25, |seed| {
        let mut rng = Rng::new(seed);
        let m = 1 + (seed as usize % 6);
        let n = 3 + (seed as usize % 10);
        let pushes = 1 + (seed as usize % (3 * m));
        let mut hist = History::new(1, m, n);
        let mut st = AndersonState::new(m, n, 1.0, 1e-6);
        for _ in 0..pushes {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            hist.push(&z, &f);
            st.push(&z, &f);
        }
        assert_eq!(hist.valid(), st.valid(), "seed={seed}");
        let (xh, fh, mask) = hist.tensors().unwrap();
        assert_eq!(
            xh.f32s().unwrap(),
            st.xs_raw(),
            "seed={seed} m={m} n={n} pushes={pushes}: x ring diverged"
        );
        assert_eq!(fh.f32s().unwrap(), st.fs_raw(), "seed={seed}: f ring diverged");
        // Mask is a 1-prefix of length valid().
        let mv = mask.f32s().unwrap();
        for (i, &v) in mv.iter().enumerate() {
            let want = if i < st.valid() { 1.0 } else { 0.0 };
            assert_eq!(v, want, "seed={seed} slot {i}");
        }
    });
}

#[test]
fn prop_padded_history_matches_native_window_prefix() {
    // A runtime window m padded into `slots` > m compiled slots must hold
    // exactly the native m-ring in its first m slots, zeros elsewhere.
    for_seeds(15, |seed| {
        let mut rng = Rng::new(seed ^ 0xA11CE);
        let m = 1 + (seed as usize % 4);
        let slots = m + 1 + (seed as usize % 4);
        let n = 4 + (seed as usize % 6);
        let mut hist = History::with_padded_slots(1, m, slots, n);
        let mut st = AndersonState::new(m, n, 1.0, 1e-6);
        for _ in 0..(2 * m + 1) {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            hist.push(&z, &f);
            st.push(&z, &f);
        }
        let (xh, _, mask) = hist.tensors().unwrap();
        let x = xh.f32s().unwrap();
        assert_eq!(&x[..m * n], st.xs_raw(), "seed={seed}: ring prefix diverged");
        assert!(
            x[m * n..].iter().all(|&v| v == 0.0),
            "seed={seed}: padded slots not zero"
        );
        let mv = mask.f32s().unwrap();
        assert_eq!(mv.len(), slots);
        assert!(mv[..m].iter().all(|&v| v == 1.0), "seed={seed}");
        assert!(mv[m..].iter().all(|&v| v == 0.0), "seed={seed}");
    });
}

#[test]
fn prop_krylov_exactness_on_affine_maps() {
    // With window ≥ dim + 1 and tiny regularization, Anderson on an
    // affine map is GMRES in disguise: it must converge in at most
    // dim + O(1) iterations (f32 rounding allows a small slack).
    for_seeds(10, |seed| {
        let n = 3 + (seed as usize % 6);
        let rho = 0.75 + 0.05 * (seed % 3) as f32;
        let map = AffineMap::random(n, rho, seed + 31);
        let opts = AndersonOpts {
            window: n + 2,
            lam: 1e-8,
            tol: 1e-4,
            max_iter: 60,
            ..Default::default()
        };
        let tr = native::solve_anderson(&map, &vec![0.0; n], opts).unwrap();
        assert!(tr.converged, "seed={seed} n={n}: did not converge");
        assert!(
            tr.iters() <= n + 6,
            "seed={seed} n={n}: {} iters breaks Krylov exactness",
            tr.iters()
        );
        let sol = map.solution().expect("small affine maps have solutions");
        let err: f32 = tr
            .z
            .iter()
            .zip(&sol)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-2, "seed={seed}: err={err}");
    });
}

#[test]
fn prop_window_monotonicity_on_hard_affine() {
    // Bigger windows shouldn't catastrophically hurt on smooth problems:
    // m=5 converges within 2x the iterations of the best of {1,2,5}.
    for_seeds(6, |seed| {
        let n = 30;
        let map = AffineMap::random(n, 0.95, seed + 11);
        let iters = |m: usize| {
            let o = AndersonOpts {
                window: m,
                lam: 1e-8,
                tol: 1e-4,
                max_iter: 1500,
                ..Default::default()
            };
            native::solve_anderson(&map, &vec![0.0; n], o)
                .unwrap()
                .iters()
        };
        let (i1, i2, i5) = (iters(1), iters(2), iters(5));
        let best = i1.min(i2).min(i5);
        assert!(
            i5 <= 2 * best,
            "seed={seed}: m=5 took {i5}, best {best} (m1={i1} m2={i2})"
        );
    });
}

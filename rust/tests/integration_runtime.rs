//! Integration tests over the runtime layer, hermetic by construction:
//! they run against whatever [`Backend`] `backend_from_dir` selects — the
//! PJRT engine when AOT artifacts are present (and the `pjrt` feature is
//! on), the pure-Rust `NativeEngine` otherwise.  Nothing here skips.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use deq_anderson::model::ParamSet;
use deq_anderson::native;
use deq_anderson::runtime::{backend_from_dir, Backend, HostTensor};
use deq_anderson::solver::{self, SolveSpec, SolverKind};
use deq_anderson::util::rng::Rng;

fn backend() -> &'static Arc<dyn Backend> {
    static B: OnceLock<Arc<dyn Backend>> = OnceLock::new();
    B.get_or_init(|| {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        backend_from_dir(dir).expect("backend selection never fails in auto mode")
    })
}

#[cfg(feature = "pjrt")]
#[test]
fn literal_roundtrip_f32_i32() {
    // Tensor ↔ literal conversion (vendored stub or real bindings).
    let t = HostTensor::f32(vec![2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
    let lit = t.to_literal().unwrap();
    let back = HostTensor::from_literal(&lit).unwrap();
    assert_eq!(t, back);

    let ti = HostTensor::i32(vec![4], vec![1, -2, 3, -4]).unwrap();
    let lit = ti.to_literal().unwrap();
    let back = HostTensor::from_literal(&lit).unwrap();
    assert_eq!(ti, back);
}

#[test]
fn backend_selection_is_hermetic() {
    let b = backend();
    assert!(!b.platform().is_empty());
    assert!(!b.manifest().entries.is_empty());
    // The serving entry points every coordinator path relies on exist.
    for name in ["encode", "cell_step", "anderson_update", "classify"] {
        assert!(
            !b.manifest().batches_for(name).is_empty(),
            "missing entry '{name}'"
        );
    }
}

#[test]
fn manifest_and_params_load() {
    let e = backend();
    let m = e.manifest();
    assert!(m.model.param_count > 1000);
    let p = e.init_params().unwrap();
    assert_eq!(p.tensors.len(), m.params.len());
    assert!(p.all_finite());
    assert!(p.max_abs() > 0.0);
    // Round-trip through the checkpoint format.
    let path = std::env::temp_dir().join("deqa_ckpt_test.bin");
    p.save(&path).unwrap();
    let p2 = ParamSet::load(m, &path).unwrap();
    assert_eq!(p.to_flat(), p2.to_flat());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn engine_validates_shapes() {
    let e = backend();
    // Wrong input count.
    let err = e.execute("anderson_update", 1, &[]).unwrap_err();
    assert!(format!("{err}").contains("expected 3 inputs"), "{err}");
    // Wrong shape.
    let m = e.manifest().solver.window;
    let n = e.manifest().model.latent_dim();
    let bad = [
        HostTensor::zeros(vec![1, m, n + 1]),
        HostTensor::zeros(vec![1, m, n + 1]),
        HostTensor::zeros(vec![m]),
    ];
    assert!(e.execute("anderson_update", 1, &bad).is_err());
    // Unknown entry.
    assert!(e.execute("nope", 1, &[]).is_err());
}

#[test]
fn anderson_update_matches_native_reference() {
    // THE parity contract: whatever backend serves `anderson_update`, its
    // output must match the reference math in native::AndersonState on
    // identical windows, per batch element.
    let e = backend();
    let m = e.manifest().solver.window;
    let n = e.manifest().model.latent_dim();
    let (beta, lam) = (e.manifest().solver.beta, e.manifest().solver.lam);
    let batch = 8;
    let mut rng = Rng::new(42);
    let xh = rng.normal_vec(batch * m * n, 1.0);
    let fh: Vec<f32> = xh.iter().map(|v| v + 0.05 * rng.normal()).collect();
    let out = e
        .execute(
            "anderson_update",
            batch,
            &[
                HostTensor::f32(vec![batch, m, n], xh.clone()).unwrap(),
                HostTensor::f32(vec![batch, m, n], fh.clone()).unwrap(),
                HostTensor::f32(vec![m], vec![1.0; m]).unwrap(),
            ],
        )
        .unwrap();
    let z_art = out[0].f32s().unwrap();
    let a_art = out[1].f32s().unwrap();
    for b in 0..batch {
        let mut st = native::AndersonState::new(m, n, beta, lam);
        for i in 0..m {
            let off = (b * m + i) * n;
            st.push(&xh[off..off + n], &fh[off..off + n]);
        }
        let (z_nat, a_nat) = st.mix().unwrap();
        for (x, y) in z_art[b * n..(b + 1) * n].iter().zip(&z_nat) {
            assert!((x - y).abs() < 2e-2, "b={b}: {x} vs {y}");
        }
        let asum: f32 = a_art[b * m..(b + 1) * m].iter().sum();
        assert!((asum - 1.0).abs() < 1e-3, "alpha sum {asum}");
        for (x, y) in a_art[b * m..(b + 1) * m].iter().zip(&a_nat) {
            assert!((x - y).abs() < 2e-2, "b={b} alpha: {x} vs {y}");
        }
    }
}

#[test]
fn anderson_warmup_mask_single_slot_is_forward() {
    // mask = [1,0,...] with beta=1 must return exactly fhist[0].
    let e = backend();
    let m = e.manifest().solver.window;
    let n = e.manifest().model.latent_dim();
    let mut rng = Rng::new(3);
    let xh = rng.normal_vec(m * n, 1.0);
    let fh = rng.normal_vec(m * n, 1.0);
    let mut mask = vec![0.0f32; m];
    mask[0] = 1.0;
    let out = e
        .execute(
            "anderson_update",
            1,
            &[
                HostTensor::f32(vec![1, m, n], xh.clone()).unwrap(),
                HostTensor::f32(vec![1, m, n], fh.clone()).unwrap(),
                HostTensor::f32(vec![m], mask).unwrap(),
            ],
        )
        .unwrap();
    let z = out[0].f32s().unwrap();
    for (a, b) in z.iter().zip(&fh[0..n]) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn cell_step_residual_consistency() {
    // The fused residual outputs must match norms recomputed on the host.
    let e = backend();
    let p = e.init_params().unwrap();
    let meta = e.manifest().model.clone();
    let batch = 1;
    let mut rng = Rng::new(9);
    let z = HostTensor::f32(
        meta.latent_shape(batch),
        rng.normal_vec(meta.latent_dim(), 1.0),
    )
    .unwrap();
    let xf = HostTensor::f32(
        meta.latent_shape(batch),
        rng.normal_vec(meta.latent_dim(), 1.0),
    )
    .unwrap();
    let mut inputs = p.tensors.clone();
    inputs.push(z.clone());
    inputs.push(xf);
    let out = e.execute("cell_step", batch, &inputs).unwrap();
    let f = out[0].f32s().unwrap();
    let zv = z.f32s().unwrap();
    let want_num: f32 = f
        .iter()
        .zip(zv)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt();
    let want_fn: f32 = f.iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!((out[1].f32s().unwrap()[0] - want_num).abs() / want_num < 1e-3);
    assert!((out[2].f32s().unwrap()[0] - want_fn).abs() / want_fn < 1e-3);
}

#[test]
fn forward_solve_k_consistent_with_cell_steps() {
    // K fused steps == K sequential cell_step calls (same final iterate).
    let e = backend();
    let p = e.init_params().unwrap();
    let meta = e.manifest().model.clone();
    let k = e.manifest().solver.fused_steps;
    let batch = 1;
    let mut rng = Rng::new(17);
    let xf = HostTensor::f32(
        meta.latent_shape(batch),
        rng.normal_vec(meta.latent_dim(), 0.5),
    )
    .unwrap();
    // Sequential.
    let mut z = HostTensor::zeros(meta.latent_shape(batch));
    for _ in 0..k {
        let mut inputs = p.tensors.clone();
        inputs.push(z.clone());
        inputs.push(xf.clone());
        let out = e.execute("cell_step", batch, &inputs).unwrap();
        z = out[0].clone();
    }
    // Fused: k evaluations total, returning z_k.
    let mut inputs = p.tensors.clone();
    inputs.push(HostTensor::zeros(meta.latent_shape(batch)));
    inputs.push(xf);
    let fused = e.execute("forward_solve_k", batch, &inputs).unwrap();
    let zf = fused[0].f32s().unwrap();
    let zs = z.f32s().unwrap();
    let maxerr = zf
        .iter()
        .zip(zs)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxerr < 1e-3, "fused vs sequential maxerr={maxerr}");
}

#[test]
fn solvers_reach_tolerance_on_init_params() {
    let e = backend();
    let p = e.init_params().unwrap();
    let meta = e.manifest().model.clone();
    let batch = 8;
    // Encode a random image batch.
    let mut rng = Rng::new(5);
    let img = HostTensor::f32(
        meta.image_shape(batch),
        rng.normal_vec(batch * meta.image_dim(), 1.0),
    )
    .unwrap();
    let mut enc_in = p.tensors.clone();
    enc_in.push(img);
    let xf = e.execute("encode", batch, &enc_in).unwrap().remove(0);

    for kind in [SolverKind::Forward, SolverKind::Anderson, SolverKind::Hybrid] {
        let opts = SolveSpec {
            tol: 1e-2,
            max_iter: 80,
            ..SolveSpec::from_manifest(e.as_ref(), kind)
        };
        let rep = solver::solve_spec(e.as_ref(), &p.tensors, &xf, &opts).unwrap();
        assert!(
            rep.converged,
            "{}: residual {:.2e} after {} iters",
            kind.name(),
            rep.final_residual(),
            rep.iters()
        );
        assert_eq!(rep.z_star.shape, meta.latent_shape(batch));
        // Residual trace is recorded and timestamps are monotone.
        assert!(rep.steps.len() >= 2);
        for w in rep.steps.windows(2) {
            assert!(w[0].elapsed <= w[1].elapsed);
        }
        // `mixed` flag semantics: the terminal (converged) step takes f
        // directly, so it is never mixed; for Anderson every earlier step
        // is (including step 0, whose output rides the one-slot window).
        assert!(!rep.steps.last().unwrap().mixed);
        if kind == SolverKind::Anderson {
            for s in &rep.steps[..rep.steps.len() - 1] {
                assert!(s.mixed, "anderson step {} not marked mixed", s.iter);
            }
        }
        if kind == SolverKind::Forward {
            assert!(rep.steps.iter().all(|s| !s.mixed));
        }
    }
}

/// The deprecated `SolveOptions`/`solve` shim must reproduce the
/// `SolveSpec`/`solve_spec` path bit-identically — same step traces,
/// per-sample counters and terminal iterate for all three kinds — so
/// pre-redesign callers see unchanged results.
#[test]
#[allow(deprecated)]
fn deprecated_solve_shim_is_bit_identical_to_solve_spec() {
    use deq_anderson::solver::SolveOptions;
    let e = backend();
    let p = e.init_params().unwrap();
    let meta = e.manifest().model.clone();
    let batch = 4;
    let mut rng = Rng::new(11);
    let img = HostTensor::f32(
        meta.image_shape(batch),
        rng.normal_vec(batch * meta.image_dim(), 1.0),
    )
    .unwrap();
    let mut enc_in = p.tensors.clone();
    enc_in.push(img);
    let xf = e.execute("encode", batch, &enc_in).unwrap().remove(0);

    for kind in [SolverKind::Forward, SolverKind::Anderson, SolverKind::Hybrid] {
        let opts = SolveOptions {
            tol: 1e-3,
            max_iter: 40,
            ..SolveOptions::from_manifest(e.as_ref(), kind)
        };
        let old = solver::solve(e.as_ref(), &p.tensors, &xf, &opts).unwrap();
        let spec = SolveSpec {
            tol: 1e-3,
            max_iter: 40,
            ..SolveSpec::from_manifest(e.as_ref(), kind)
        };
        let new = solver::solve_spec(e.as_ref(), &p.tensors, &xf, &spec).unwrap();
        assert_eq!(old.kind, new.kind);
        assert_eq!(old.converged, new.converged);
        assert_eq!(old.steps.len(), new.steps.len(), "{kind:?} step counts");
        for (a, b) in old.steps.iter().zip(&new.steps) {
            assert_eq!(a.sample_residuals, b.sample_residuals, "{kind:?}");
            assert_eq!(a.mixed, b.mixed, "{kind:?}");
            assert_eq!(a.fevals, b.fevals, "{kind:?}");
            assert_eq!(a.active, b.active, "{kind:?}");
        }
        assert_eq!(old.sample_iters, new.sample_iters);
        assert_eq!(old.sample_fevals, new.sample_fevals);
        assert_eq!(old.sample_converged, new.sample_converged);
        assert_eq!(
            old.z_star.f32s().unwrap(),
            new.z_star.f32s().unwrap(),
            "{kind:?} terminal iterates diverge"
        );
    }
}

#[test]
fn anderson_uses_fewer_fevals_than_forward() {
    // The paper's core claim, measured on the selected backend at init.
    let e = backend();
    let p = e.init_params().unwrap();
    let meta = e.manifest().model.clone();
    let batch = 8;
    let mut rng = Rng::new(23);
    let img = HostTensor::f32(
        meta.image_shape(batch),
        rng.normal_vec(batch * meta.image_dim(), 1.0),
    )
    .unwrap();
    let mut enc_in = p.tensors.clone();
    enc_in.push(img);
    let xf = e.execute("encode", batch, &enc_in).unwrap().remove(0);

    let solve = |kind| {
        let opts = SolveSpec {
            tol: 2e-3,
            max_iter: 120,
            fused_forward: false,
            ..SolveSpec::from_manifest(e.as_ref(), kind)
        };
        solver::solve_spec(e.as_ref(), &p.tensors, &xf, &opts).unwrap()
    };
    let fw = solve(SolverKind::Forward);
    let an = solve(SolverKind::Anderson);
    assert!(
        an.best_residual() <= fw.best_residual() * 1.5,
        "anderson best {:.2e} vs forward best {:.2e}",
        an.best_residual(),
        fw.best_residual()
    );
    // To the residual forward ends at, anderson should need no more evals.
    let target = fw.final_residual() * 1.05;
    let a_fevals = an
        .steps
        .iter()
        .find(|s| s.rel_residual <= target)
        .map(|s| s.fevals)
        .unwrap_or(usize::MAX);
    assert!(
        a_fevals <= fw.fevals(),
        "anderson {a_fevals} fevals vs forward {}",
        fw.fevals()
    );
}

#[test]
fn backend_records_execution_stats() {
    let e = backend();
    let m = e.manifest().solver.window;
    let n = e.manifest().model.latent_dim();
    let inputs = [
        HostTensor::zeros(vec![1, m, n]),
        HostTensor::zeros(vec![1, m, n]),
        HostTensor::f32(vec![m], vec![1.0; m]).unwrap(),
    ];
    e.execute("anderson_update", 1, &inputs).unwrap();
    let stats = e.stats();
    assert!(stats
        .iter()
        .any(|((name, batch), s)| name == "anderson_update" && *batch == 1 && s.calls >= 1));
    assert!(e.stats_report().contains("anderson_update"));
}

//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and an auto-generated usage line.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const FLAG_SET: &str = "<set>";

impl Args {
    /// Parse an argv slice (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), FLAG_SET.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects a number, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    /// Comma-separated list: `--values 1,2,5`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        panic!("--{key} expects integers, got '{s}'")
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["train", "--epochs", "5", "--fast", "--lr=0.1"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("epochs", 0), 5);
        assert!(a.has("fast"));
        assert_eq!(a.f32_or("lr", 0.0), 0.1);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("missing", "x"), "x");
        assert!(!a.has("missing"));
    }

    #[test]
    fn bare_flag_before_positional_grabs_nothing_when_next_is_flag() {
        let a = parse(&["--verbose", "--n", "3"]);
        assert_eq!(a.get("verbose"), Some(FLAG_SET));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn lists() {
        let a = parse(&["--values", "1,2,8"]);
        assert_eq!(a.usize_list_or("values", &[]), vec![1, 2, 8]);
        assert_eq!(a.usize_list_or("other", &[4]), vec![4]);
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        parse(&["--epochs", "abc"]).usize_or("epochs", 0);
    }
}

//! Minimal JSON parser + serializer.
//!
//! The offline build environment vendors no `serde_json`, so the
//! coordinator carries its own recursive-descent JSON implementation.
//! Scope: everything `artifacts/manifest.json` and the serving protocol
//! need — objects, arrays, strings (with escapes), numbers, bools, null.
//! Not supported (not needed): `\u` surrogate pairs beyond the BMP are
//! passed through unvalidated; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access: `v.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj["a"]["b"]` style path access.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { msg: msg.into(), offset: self.pos })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!(
                "expected '{}', found {:?}",
                b as char,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("invalid literal, expected '{word}'"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => self.err(format!("unexpected {:?}", other.map(|c| c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => {
                    return self.err(format!(
                        "expected ',' or '}}', found {:?}",
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => {
                    return self.err(format!(
                        "expected ',' or ']', found {:?}",
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or(JsonError {
                                    msg: "bad \\u escape".into(),
                                    offset: self.pos,
                                })?;
                            code = code * 16 + c;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => {
                        return self.err(format!(
                            "bad escape {:?}",
                            other.map(|c| c as char)
                        ))
                    }
                },
                Some(c) => out.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{text}'")),
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a value to compact JSON text.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no NaN/Inf tokens; emitting them would make
                // the document unparseable.  Non-finite numbers (e.g. a
                // quarantined lane's residual) serialize as null.
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building responses.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(text: &str) -> Json {
    Json::Str(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(to_string(&Json::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Json::Num(f64::INFINITY)), "null");
        assert_eq!(to_string(&Json::Num(f64::NEG_INFINITY)), "null");
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.path(&["d", "e"]), Some(&Json::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,"x"],"n":null,"ok":true}"#;
        let v = parse(text).unwrap();
        assert_eq!(to_string(&v), text);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" \n\t{ \"a\" :\r[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn escape_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n".into());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }
}

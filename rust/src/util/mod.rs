//! Shared utilities the offline crate set forces us to own:
//! JSON, PRNG, CLI parsing and the micro-bench harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;

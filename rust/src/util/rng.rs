//! Deterministic PRNG (SplitMix64 + xoshiro256**) and samplers.
//!
//! The vendored crate set has no `rand`, so the data generator, the
//! property-test harness and the server load generator share this
//! implementation.  xoshiro256** is the same generator family used by
//! `rand_xoshiro`; SplitMix64 seeds it per Blackman & Vigna.

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> uniform f32 in [0, 1)
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style multiply-shift reduction (bias negligible for our n).
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-10 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a vector with i.i.d. N(0, sigma^2).
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| sigma * self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f32 = (0..n).map(|_| r.uniform()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(42);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}

//! Micro-bench harness (no `criterion` in the offline crate set).
//!
//! Provides warmup + timed iterations with mean / std / min / percentile
//! reporting, plus a throughput mode.  All `cargo bench` targets
//! (`rust/benches/*.rs`, `harness = false`) use this.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>6} iters  mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  min {:>10.3?}  ±{:>8.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min, self.std_dev
        )
    }
}

/// Run `f` repeatedly: `warmup` untimed runs, then timed runs until either
/// `max_iters` or `budget` wallclock is exhausted (min 5 timed runs).
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    max_iters: usize,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < max_iters
        && (samples.len() < 5 || start.elapsed() < budget)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, &mut samples)
}

/// Summarize raw duration samples into a BenchResult.
pub fn summarize(name: &str, samples: &mut [Duration]) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| (d.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean,
        std_dev: Duration::from_secs_f64(var.sqrt()),
        min: samples[0],
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
    }
}

/// Simple throughput formatter.
pub fn throughput(items: usize, elapsed: Duration) -> String {
    format!("{:.1} items/s", items as f64 / elapsed.as_secs_f64())
}

/// Standard bench-binary header so `cargo bench` output is greppable.
pub fn header(title: &str) {
    println!("\n=== bench: {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0usize;
        let r = bench("noop", 2, 50, Duration::from_millis(50), || {
            count += 1;
        });
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters + 2);
        assert!(r.min <= r.mean || r.iters == 1);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn summarize_percentiles_ordered() {
        let mut samples: Vec<Duration> =
            (1..=100).map(Duration::from_micros).collect();
        let r = summarize("s", &mut samples);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert_eq!(r.iters, 100);
    }

    #[test]
    fn throughput_format() {
        let s = throughput(500, Duration::from_secs(2));
        assert!(s.starts_with("250.0"));
    }
}

//! **Serving**: iteration-level continuous batching vs the batch-granular
//! baseline on mixed-difficulty synthetic traffic.
//!
//! The paper trades fewer, heavier iterations for convergence; the
//! batch-granular server throws part of that win away by making every
//! request in a batch wait for the slowest sample.  This scenario sweeps
//! easy/stiff sample mixes (difficulty modulated through the input scale:
//! saturated tanh cells converge in a few steps, near-linear ones crawl
//! at the cell's spectral radius) through both [`SchedMode`]s and reports
//! the crossover: per-request billed fevals, latency percentiles,
//! throughput, lane occupancy, and prediction parity.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::synthetic;
use crate::experiments::ExpOptions;
use crate::metrics::{Csv, Stats};
use crate::model::ParamSet;
use crate::runtime::Backend;
use crate::server::{Router, RouterConfig, SchedMode, SubmitRejection};
use crate::solver::{SolveClamps, SolveOverrides, SolveSpec, SolverKind};

/// Deterministic mixed-difficulty workload: synthetic images scaled so a
/// `stiff_frac` share of them drive the cell near its slow linear regime
/// (small amplitude → Jacobian ≈ W_cell) and the rest saturate it (fast).
/// Stiff samples are interleaved, not front-loaded, so both schedulers
/// see the same arrival pattern.
pub fn mixed_traffic(total: usize, stiff_frac: f32, seed: u64) -> Vec<Vec<f32>> {
    let data = synthetic::generate(total.max(1), seed);
    let threshold = (stiff_frac * 100.0) as usize;
    (0..total)
        .map(|i| {
            let stiff = (i * 37) % 100 < threshold;
            let scale = if stiff { 0.03 } else { 3.0 };
            data.image(i).iter().map(|&v| v * scale).collect()
        })
        .collect()
}

/// One mode's measured outcome over a workload.
pub struct ModeOutcome {
    pub served: usize,
    /// Σ over responses of `solver_iters` (what each request waited for).
    pub total_iters: usize,
    /// Σ over responses of `solver_fevals` (same accounting).
    pub total_fevals: usize,
    pub p50: Duration,
    pub p95: Duration,
    pub wall: Duration,
    pub predictions: Vec<usize>,
    /// Mean occupied-lane fraction (iteration-level mode only, else 0).
    pub occupancy: f64,
    /// Fevals saved vs a lockstep solve over the same lanes (iteration-
    /// level mode only, else 0).
    pub fevals_saved: u64,
    /// Forward↔Anderson switches taken by auto-selection lanes (0 for
    /// static solver kinds).
    pub auto_switches: u64,
}

impl ModeOutcome {
    pub fn throughput(&self) -> f64 {
        self.served as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Drive one router mode over the workload: submit everything, wait for
/// every reply, tear the router down.
pub fn drive(
    engine: &Arc<dyn Backend>,
    params: &Arc<ParamSet>,
    images: &[Vec<f32>],
    mode: SchedMode,
    solver: &SolveSpec,
    replicas: usize,
) -> Result<ModeOutcome> {
    let cfg = RouterConfig {
        solver: solver.clone(),
        clamps: SolveClamps::default(),
        mode,
        max_wait: Duration::from_millis(2),
        queue_cap: images.len() + 16,
        replicas,
        default_deadline: None,
        redrive_budget: 1,
    };
    let router = Router::start(engine.clone(), params.clone(), cfg)?;
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = images
        .iter()
        .map(|img| router.submit(img.clone()))
        .collect::<Result<Vec<_>>>()?;
    let mut lat = Stats::default();
    let mut total_iters = 0usize;
    let mut total_fevals = 0usize;
    let mut predictions = Vec::with_capacity(images.len());
    for rx in receivers {
        let resp = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("router dropped request"))?
            .map_err(|msg| anyhow::anyhow!(msg))?;
        lat.push_duration(resp.latency);
        total_iters += resp.solver_iters;
        total_fevals += resp.solver_fevals;
        predictions.push(resp.class);
    }
    let wall = t0.elapsed();
    let occupancy = {
        let occ = router
            .metrics
            .lane_occupancy
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        occ.mean()
    };
    let fevals_saved = router.metrics.fevals_saved();
    let auto_switches = router
        .metrics
        .auto_switches
        .load(std::sync::atomic::Ordering::Relaxed);
    router.shutdown();
    Ok(ModeOutcome {
        served: predictions.len(),
        total_iters,
        total_fevals,
        p50: Duration::from_secs_f64(lat.percentile(50.0)),
        p95: Duration::from_secs_f64(lat.percentile(95.0)),
        wall,
        predictions,
        occupancy: if mode == SchedMode::IterationLevel {
            occupancy
        } else {
            0.0
        },
        fevals_saved: if mode == SchedMode::IterationLevel {
            fevals_saved
        } else {
            0
        },
        auto_switches,
    })
}

/// Outcome of one open-loop saturation run (see [`saturate`]).
pub struct SaturationOutcome {
    pub replicas: usize,
    /// Offered load as a multiple of measured single-replica capacity.
    pub load_multiplier: f64,
    /// Requests offered (admitted + shed).
    pub offered: usize,
    /// Requests admitted past the backpressure door.
    pub accepted: usize,
    /// Requests refused with an explicit `overloaded`/`retry_after_ms`.
    pub shed: usize,
    /// Accepted requests that came back as errors (should be zero — any
    /// non-zero value means the server failed under load rather than
    /// shedding gracefully).
    pub errors: usize,
    /// Latency percentiles over *accepted, answered* requests.
    pub p50: Duration,
    pub p99: Duration,
    pub wall: Duration,
}

impl SaturationOutcome {
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.offered.max(1) as f64
    }

    pub fn throughput(&self) -> f64 {
        (self.accepted - self.errors) as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Graceful-degradation gate: under overload the server must keep
    /// answering — some requests accepted, none of them errored, and
    /// the accepted-request p99 finite and under `p99_bound` (shedding
    /// keeps the queue — and therefore waiting time — bounded).
    pub fn graceful(&self, p99_bound: Duration) -> bool {
        self.accepted > 0
            && self.errors == 0
            && self.p99.as_secs_f64().is_finite()
            && self.p99 <= p99_bound
    }
}

/// Open-loop saturation probe: offer `offered` requests at a fixed
/// arrival rate (`rate_rps`), independent of how the server is coping —
/// the regime where a closed-loop driver would self-throttle and hide
/// the overload.  Shed requests are counted, accepted ones awaited to
/// completion; tears the router down before returning.
#[allow(clippy::too_many_arguments)] // a bench harness, not an API
pub fn saturate(
    engine: &Arc<dyn Backend>,
    params: &Arc<ParamSet>,
    images: &[Vec<f32>],
    replicas: usize,
    offered: usize,
    rate_rps: f64,
    queue_cap: usize,
    solver: &SolveSpec,
) -> Result<SaturationOutcome> {
    let cfg = RouterConfig {
        solver: solver.clone(),
        clamps: SolveClamps::default(),
        mode: SchedMode::IterationLevel,
        max_wait: Duration::from_millis(2),
        queue_cap,
        replicas,
        default_deadline: None,
        redrive_budget: 1,
    };
    let router = Router::start(engine.clone(), params.clone(), cfg)?;
    let interarrival = Duration::from_secs_f64(1.0 / rate_rps.max(1e-9));
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(offered);
    let mut shed = 0usize;
    for i in 0..offered {
        // Pace against the schedule, not the previous send, so a slow
        // admission doesn't quietly lower the offered rate.
        let due = t0 + interarrival * (i as u32);
        let pause = due.saturating_duration_since(Instant::now());
        if !pause.is_zero() {
            std::thread::sleep(pause);
        }
        let image = images[i % images.len()].clone();
        match router.try_submit(image, &SolveOverrides::default(), None, None) {
            Ok(rx) => receivers.push(rx),
            Err(SubmitRejection::Overloaded { retry_after_ms }) => {
                debug_assert!(retry_after_ms >= 1);
                shed += 1;
            }
            Err(other) => return Err(anyhow::anyhow!(other.to_string())),
        }
    }
    let accepted = receivers.len();
    let mut lat = Stats::default();
    let mut errors = 0usize;
    for rx in receivers {
        match rx.recv() {
            Ok(Ok(resp)) => lat.push_duration(resp.latency),
            Ok(Err(_)) | Err(_) => errors += 1,
        }
    }
    let wall = t0.elapsed();
    router.shutdown();
    let pct = |p: f64| {
        if lat.count() == 0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(lat.percentile(p))
        }
    };
    Ok(SaturationOutcome {
        replicas,
        load_multiplier: 0.0, // stamped by the caller, which measured capacity
        offered,
        accepted,
        shed,
        errors,
        p50: pct(50.0),
        p99: pct(99.0),
        wall,
    })
}

pub fn run(engine: &Arc<dyn Backend>, opts: &ExpOptions) -> Result<()> {
    let params = Arc::new(engine.init_params()?);
    let total = opts.test_size.clamp(32, 96);
    // Tight tolerance so both schedules land within argmax-stable reach
    // of the same equilibria (the prediction-parity check below).
    let solver = SolveSpec {
        tol: 1e-4,
        max_iter: 80,
        ..SolveSpec::from_manifest(engine.as_ref(), SolverKind::Anderson)
    };
    println!(
        "[serving] backend={} requests={total} solver={} tol={:.0e}",
        engine.platform(),
        solver.kind.name(),
        solver.tol
    );

    let mut csv = Csv::new(&[
        "stiff_frac",
        "mode",
        "served",
        "total_iters",
        "total_fevals",
        "p50_ms",
        "p95_ms",
        "throughput_rps",
        "occupancy",
        "fevals_saved",
        "prediction_mismatches",
    ]);
    let mut all_better = true;
    for &frac in &[0.0f32, 0.25, 0.5, 0.75] {
        let images = mixed_traffic(total, frac, opts.seed);
        let base = drive(
            engine,
            &params,
            &images,
            SchedMode::BatchGranular,
            &solver,
            1,
        )?;
        let sched = drive(
            engine,
            &params,
            &images,
            SchedMode::IterationLevel,
            &solver,
            1,
        )?;
        let mismatches = base
            .predictions
            .iter()
            .zip(&sched.predictions)
            .filter(|(a, b)| a != b)
            .count();
        // The acceptance claim is over *mixed* traffic: with a uniform
        // workload (frac 0) every lane retires near-simultaneously and
        // the two schedules can tie on billed fevals.
        if frac > 0.0 {
            all_better &= sched.total_fevals < base.total_fevals
                && sched.p50 <= base.p50
                && mismatches == 0;
        }
        println!(
            "[serving] stiff={frac:.2}  batch-granular: fevals={} p50={:.1}ms p95={:.1}ms {:.0} req/s",
            base.total_fevals,
            base.p50.as_secs_f64() * 1e3,
            base.p95.as_secs_f64() * 1e3,
            base.throughput()
        );
        println!(
            "[serving] stiff={frac:.2}  iteration-level: fevals={} p50={:.1}ms p95={:.1}ms {:.0} req/s \
             (occupancy {:.2}, saved {} fevals, {} prediction mismatches)",
            sched.total_fevals,
            sched.p50.as_secs_f64() * 1e3,
            sched.p95.as_secs_f64() * 1e3,
            sched.throughput(),
            sched.occupancy,
            sched.fevals_saved,
            mismatches
        );
        for (mode, o) in [("batch-granular", &base), ("iteration-level", &sched)]
        {
            csv.row(&[
                format!("{frac:.2}"),
                mode.to_string(),
                o.served.to_string(),
                o.total_iters.to_string(),
                o.total_fevals.to_string(),
                format!("{:.3}", o.p50.as_secs_f64() * 1e3),
                format!("{:.3}", o.p95.as_secs_f64() * 1e3),
                format!("{:.1}", o.throughput()),
                format!("{:.3}", o.occupancy),
                o.fevals_saved.to_string(),
                mismatches.to_string(),
            ]);
        }
    }
    csv.save(opts.out_dir.join("serving_continuous_batching.csv"))?;
    println!(
        "[serving] wrote {}",
        opts.out_dir.join("serving_continuous_batching.csv").display()
    );
    println!(
        "[serving] iteration-level strictly better on every mixed-difficulty mix: {}",
        if all_better { "YES" } else { "NO" }
    );

    auto_vs_static(engine, &params, total, opts)?;
    Ok(())
}

/// A/B the online auto-selection controller against every static solver
/// kind, per mix ratio, on the iteration-level scheduler.  The claim
/// under test is Fig. 1 made operational: no single static kind wins
/// every mix (forward wins pure-easy, Anderson wins pure-stiff), and the
/// per-lane crossover controller should track the winner across the
/// sweep without being told the workload.  Each run gets a fresh router
/// (cold priors — the controller earns its keep from the probe window
/// alone here; prior learning is exercised by the serving bench and the
/// unit tests).  Writes `auto_vs_static.csv`.
fn auto_vs_static(
    engine: &Arc<dyn Backend>,
    params: &Arc<ParamSet>,
    total: usize,
    opts: &ExpOptions,
) -> Result<()> {
    let kinds = [
        SolverKind::Forward,
        SolverKind::Anderson,
        SolverKind::Hybrid,
        SolverKind::Auto,
    ];
    let mut csv = Csv::new(&[
        "stiff_frac",
        "solver",
        "served",
        "mean_fevals",
        "p50_ms",
        "p95_ms",
        "throughput_rps",
        "auto_switches",
    ]);
    for &frac in &[0.0f32, 0.5, 1.0] {
        let images = mixed_traffic(total, frac, opts.seed);
        let mut best_static = f64::NEG_INFINITY;
        let mut worst_static = f64::INFINITY;
        let mut auto_tp = 0.0f64;
        for kind in kinds {
            let solver = SolveSpec {
                tol: 1e-4,
                max_iter: 80,
                ..SolveSpec::from_manifest(engine.as_ref(), kind)
            };
            let o = drive(
                engine,
                params,
                &images,
                SchedMode::IterationLevel,
                &solver,
                1,
            )?;
            let tp = o.throughput();
            if kind == SolverKind::Auto {
                auto_tp = tp;
            } else {
                best_static = best_static.max(tp);
                worst_static = worst_static.min(tp);
            }
            let mean_fevals = o.total_fevals as f64 / o.served.max(1) as f64;
            println!(
                "[serving] stiff={frac:.2}  {:>8}: mean_fevals={mean_fevals:.1} \
                 p50={:.1}ms {tp:.0} req/s switches={}",
                kind.name(),
                o.p50.as_secs_f64() * 1e3,
                o.auto_switches,
            );
            csv.row(&[
                format!("{frac:.2}"),
                kind.name().to_string(),
                o.served.to_string(),
                format!("{mean_fevals:.2}"),
                format!("{:.3}", o.p50.as_secs_f64() * 1e3),
                format!("{:.3}", o.p95.as_secs_f64() * 1e3),
                format!("{tp:.1}"),
                o.auto_switches.to_string(),
            ]);
        }
        println!(
            "[serving] stiff={frac:.2}  auto vs static: {:.2}x best, {:.2}x worst",
            auto_tp / best_static.max(1e-9),
            auto_tp / worst_static.max(1e-9),
        );
    }
    csv.save(opts.out_dir.join("auto_vs_static.csv"))?;
    println!(
        "[serving] wrote {}",
        opts.out_dir.join("auto_vs_static.csv").display()
    );
    Ok(())
}

//! **Fig. 7**: time to stable convergence — the DEQ trains ~10x faster to a
//! given accuracy with Anderson than with forward iteration.
//!
//! We train both solvers from the same init and sweep accuracy targets,
//! reporting wallclock-to-target for each (the paper's bar/line view),
//! plus the measured speedup band (paper Table 1: 2-8.6x / "up to an order
//! of magnitude").

use anyhow::Result;

use crate::data;
use crate::experiments::ExpOptions;
use crate::metrics::Csv;
use crate::runtime::Backend;
use crate::solver::SolverKind;
use crate::train::{default_config, Trainer};

pub fn run(engine: &dyn Backend, opts: &ExpOptions) -> Result<()> {
    let (train_data, test_data, ds) =
        data::load_auto(opts.train_size, opts.test_size, opts.seed);
    let init = engine.init_params()?;
    println!(
        "[fig7] dataset={ds} train={} epochs={}",
        train_data.len(),
        opts.epochs
    );

    let mut cfg_a = default_config(engine, SolverKind::Anderson, opts.epochs);
    cfg_a.verbose = opts.verbose;
    let rep_a =
        Trainer::new(engine, cfg_a)?.train(&init, &train_data, &test_data)?;
    let mut cfg_f = default_config(engine, SolverKind::Forward, opts.epochs);
    cfg_f.verbose = opts.verbose;
    let rep_f =
        Trainer::new(engine, cfg_f)?.train(&init, &train_data, &test_data)?;

    // Sweep accuracy targets between chance and the best either run hit.
    let best = rep_a
        .final_train_acc()
        .max(rep_f.final_train_acc())
        .max(0.15);
    let targets: Vec<f32> =
        (1..=10).map(|i| 0.1 + (best - 0.1) * i as f32 / 10.0).collect();

    let mut csv = Csv::new(&[
        "train_acc_target", "anderson_time_s", "forward_time_s", "speedup",
    ]);
    println!(
        "{:>10} {:>16} {:>16} {:>9}",
        "target", "anderson_time", "forward_time", "speedup"
    );
    let mut speedups = Vec::new();
    for t in targets {
        let ta = rep_a.time_to_train_acc(t);
        let tf = rep_f.time_to_train_acc(t);
        let sp = match (ta, tf) {
            (Some(a), Some(f)) => Some(f.as_secs_f64() / a.as_secs_f64().max(1e-9)),
            _ => None,
        };
        if let Some(s) = sp {
            speedups.push(s);
        }
        println!(
            "{:>9.1}% {:>16} {:>16} {:>9}",
            100.0 * t,
            ta.map(|d| format!("{:.2}s", d.as_secs_f64()))
                .unwrap_or_else(|| "-".into()),
            tf.map(|d| format!("{:.2}s", d.as_secs_f64()))
                .unwrap_or_else(|| "-".into()),
            sp.map(|s| format!("{s:.1}x")).unwrap_or_else(|| "-".into()),
        );
        csv.row(&[
            format!("{t:.3}"),
            ta.map(|d| format!("{:.3}", d.as_secs_f64())).unwrap_or_default(),
            tf.map(|d| format!("{:.3}", d.as_secs_f64())).unwrap_or_default(),
            sp.map(|s| format!("{s:.2}")).unwrap_or_default(),
        ]);
    }
    if !speedups.is_empty() {
        let (lo, hi) = speedups.iter().fold((f64::MAX, f64::MIN), |(l, h), &s| {
            (l.min(s), h.max(s))
        });
        println!(
            "[fig7] speedup band: {lo:.1}x – {hi:.1}x (paper: 2-8.6x, 'up to ~10x')"
        );
    } else {
        println!("[fig7] no common accuracy target reached by both solvers");
    }
    csv.save(opts.out_dir.join("fig7_convergence.csv"))?;
    println!(
        "[fig7] wrote {}",
        opts.out_dir.join("fig7_convergence.csv").display()
    );
    Ok(())
}

//! **Table 1**: Summary of algorithmic improvements to training and
//! inference (without augmentation).
//!
//! Rows: parameter count, train accuracy, test accuracy, training time,
//! inference time, speedup — for Standard (forward-iteration) vs
//! Accelerated (Anderson) DEQ, plus the explicit unrolled baseline.
//!
//! Paper reference values (V100, full CIFAR10, long training):
//!   params 64,842 | train 64.7% → 96.3% | test 64.2% → 79.1%
//!   train time 1.2e4s → 1.4e3s | infer 1s → 0.5s | speedup 2–8.6x,
//!   compute saved 50–88%.
//! We reproduce the *structure* at reduced scale and report both measured
//! values and the device-model projection to V100.

use anyhow::Result;

use crate::data;
use crate::experiments::ExpOptions;
use crate::infer;
use crate::metrics::{fmt_duration, fmt_pct, Csv};
use crate::runtime::Backend;
use crate::simulate::{Workload, V100, XEON};
use crate::solver::{SolveSpec, SolverKind};
use crate::train::{default_config, Trainer};

pub fn run(engine: &dyn Backend, opts: &ExpOptions) -> Result<()> {
    let manifest = engine.manifest();
    let (train_data, test_data, ds_name) =
        data::load_auto(opts.train_size, opts.test_size, opts.seed);
    println!(
        "[table1] dataset={ds_name} train={} test={} epochs={} params={}",
        train_data.len(),
        test_data.len(),
        opts.epochs,
        manifest.model.param_count
    );

    let init = engine.init_params()?;

    // --- Standard DEQ: forward iteration ---
    let mut cfg_f = default_config(engine, SolverKind::Forward, opts.epochs);
    cfg_f.verbose = opts.verbose;
    let trainer_f = Trainer::new(engine, cfg_f.clone())?;
    println!("[table1] training standard DEQ (forward iteration)...");
    let rep_f = trainer_f.train(&init, &train_data, &test_data)?;

    // --- Accelerated DEQ: Anderson ---
    let mut cfg_a = default_config(engine, SolverKind::Anderson, opts.epochs);
    cfg_a.verbose = opts.verbose;
    let trainer_a = Trainer::new(engine, cfg_a.clone())?;
    println!("[table1] training accelerated DEQ (Anderson)...");
    let rep_a = trainer_a.train(&init, &train_data, &test_data)?;

    // --- Explicit baseline ---
    println!("[table1] training explicit baseline...");
    let rep_e = trainer_a.train_explicit(&init, &train_data, &test_data)?;

    // --- Inference timing (batch of 1, like the paper's "inference time") ---
    let so_f = SolveSpec::from_manifest(engine, SolverKind::Forward);
    let so_a = SolveSpec::from_manifest(engine, SolverKind::Anderson);
    let one = train_data.gather(&[0]).0;
    let inf_f = infer::infer(engine, &rep_f.params, &one, 1, &so_f)?;
    let inf_a = infer::infer(engine, &rep_a.params, &one, 1, &so_a)?;

    // --- Speedup metrics ---
    // Time-to-accuracy: wallclock for Anderson to reach the *forward* run's
    // final train accuracy (the paper's "reach a given high accuracy in
    // less time").
    let target = rep_f.final_train_acc();
    let t_f = rep_f.total_time;
    let t_a_to_target = rep_a.time_to_train_acc(target).unwrap_or(rep_a.total_time);
    let speedup = t_f.as_secs_f64() / t_a_to_target.as_secs_f64().max(1e-9);
    // Compute saved: cell evaluations per epoch, anderson vs forward.
    let fevals_f: f32 = rep_f.epochs.iter().map(|e| e.solver_fevals).sum();
    let fevals_a: f32 = rep_a.epochs.iter().map(|e| e.solver_fevals).sum();
    let compute_saved = 1.0 - fevals_a / fevals_f.max(1e-9);

    // Device-model projection of training time to the paper's hardware.
    let w = Workload {
        batch: 32,
        latent_hw: manifest.model.latent_hw,
        channels: manifest.model.channels,
        window: manifest.solver.window,
    };
    let proj = |fevals: f32, anderson: bool| {
        let per_iter_v100 = V100.iter_time(&w, anderson).as_secs_f64();
        let per_iter_xeon = XEON.iter_time(&w, anderson).as_secs_f64();
        (fevals as f64 * per_iter_v100, fevals as f64 * per_iter_xeon)
    };
    let batches = (opts.train_size / 32) as f32;
    let (v100_f, xeon_f) = proj(fevals_f * batches, false);
    let (v100_a, xeon_a) = proj(fevals_a * batches, true);

    // --- Report ---
    let row = |name: &str, std_v: String, acc_v: String, exp_v: String| {
        println!("{name:<28} {std_v:>16} {acc_v:>16} {exp_v:>16}");
    };
    println!("\nTable 1 (measured at reduced scale; see EXPERIMENTS.md)");
    row("", "Standard".into(), "Accelerated".into(), "Explicit".into());
    row(
        "Parameters",
        manifest.model.param_count.to_string(),
        manifest.model.param_count.to_string(),
        manifest.model.param_count.to_string(),
    );
    row(
        "Training accuracy",
        fmt_pct(rep_f.final_train_acc()),
        fmt_pct(rep_a.final_train_acc()),
        fmt_pct(rep_e.final_train_acc()),
    );
    row(
        "Testing accuracy",
        fmt_pct(rep_f.best_test_acc().unwrap_or(0.0)),
        fmt_pct(rep_a.best_test_acc().unwrap_or(0.0)),
        fmt_pct(rep_e.best_test_acc().unwrap_or(0.0)),
    );
    row(
        "Training time",
        fmt_duration(rep_f.total_time),
        fmt_duration(rep_a.total_time),
        fmt_duration(rep_e.total_time),
    );
    row(
        "Inference time (b=1)",
        fmt_duration(inf_f.latency),
        fmt_duration(inf_a.latency),
        "-".into(),
    );
    row(
        "Speedup to std accuracy",
        "1.0x".into(),
        format!("{speedup:.1}x"),
        "-".into(),
    );
    row(
        "Compute saved (fevals)",
        "-".into(),
        fmt_pct(compute_saved),
        "-".into(),
    );
    println!(
        "\nDevice-model projection of solver compute (same fevals):\n\
         forward : V100 {:.2}s | Xeon {:.2}s\n\
         anderson: V100 {:.2}s | Xeon {:.2}s",
        v100_f, xeon_f, v100_a, xeon_a
    );

    // --- CSV ---
    let mut csv = Csv::new(&[
        "metric", "standard", "accelerated", "explicit", "paper_standard",
        "paper_accelerated",
    ]);
    let r = |m: &str, s: String, a: String, e: String, ps: &str, pa: &str| {
        [m.to_string(), s, a, e, ps.to_string(), pa.to_string()]
    };
    csv.row(&r(
        "params",
        manifest.model.param_count.to_string(),
        manifest.model.param_count.to_string(),
        manifest.model.param_count.to_string(),
        "64842",
        "64842",
    ));
    csv.row(&r(
        "train_acc",
        format!("{:.4}", rep_f.final_train_acc()),
        format!("{:.4}", rep_a.final_train_acc()),
        format!("{:.4}", rep_e.final_train_acc()),
        "0.647",
        "0.963",
    ));
    csv.row(&r(
        "test_acc",
        format!("{:.4}", rep_f.best_test_acc().unwrap_or(0.0)),
        format!("{:.4}", rep_a.best_test_acc().unwrap_or(0.0)),
        format!("{:.4}", rep_e.best_test_acc().unwrap_or(0.0)),
        "0.642",
        "0.791",
    ));
    csv.row(&r(
        "train_time_s",
        format!("{:.2}", rep_f.total_time.as_secs_f64()),
        format!("{:.2}", rep_a.total_time.as_secs_f64()),
        format!("{:.2}", rep_e.total_time.as_secs_f64()),
        "12000",
        "1400",
    ));
    csv.row(&r(
        "infer_time_s",
        format!("{:.4}", inf_f.latency.as_secs_f64()),
        format!("{:.4}", inf_a.latency.as_secs_f64()),
        String::new(),
        "1",
        "0.5",
    ));
    csv.row(&r(
        "speedup",
        "1.0".into(),
        format!("{speedup:.2}"),
        String::new(),
        "1.0",
        "2-8.6",
    ));
    csv.row(&r(
        "compute_saved",
        String::new(),
        format!("{compute_saved:.3}"),
        String::new(),
        "",
        "0.50-0.88",
    ));
    csv.save(opts.out_dir.join("table1.csv"))?;
    println!("[table1] wrote {}", opts.out_dir.join("table1.csv").display());
    Ok(())
}

//! **Fig. 2**: AI carbon-footprint / electricity-demand projection to 2030
//! with the Anderson+GPU savings overlay.  Pure model (no artifacts).

use anyhow::Result;

use crate::experiments::ExpOptions;
use crate::metrics::Csv;
use crate::simulate::EnergyModel;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let model = EnergyModel::default();
    let series = model.series();

    println!("[fig2] AI electricity projection (model assumptions in simulate::energy)");
    println!(
        "{:<6} {:>12} {:>10} {:>9} {:>10} {:>11} {:>12}",
        "year", "global TWh", "DC TWh", "AI TWh", "AI share", "saved TWh", "saved MtCO2"
    );
    let mut csv = Csv::new(&[
        "year",
        "global_twh",
        "dc_twh",
        "ai_twh",
        "ai_share_of_global",
        "saved_twh",
        "saved_mt_co2",
    ]);
    for p in &series {
        println!(
            "{:<6} {:>12.0} {:>10.0} {:>9.0} {:>9.2}% {:>11.0} {:>12.0}",
            p.year,
            p.global_twh,
            p.dc_twh,
            p.ai_twh,
            100.0 * p.ai_share_of_global,
            p.saved_twh,
            p.saved_mt_co2
        );
        csv.row(&[
            p.year.to_string(),
            format!("{:.1}", p.global_twh),
            format!("{:.1}", p.dc_twh),
            format!("{:.1}", p.ai_twh),
            format!("{:.4}", p.ai_share_of_global),
            format!("{:.1}", p.saved_twh),
            format!("{:.1}", p.saved_mt_co2),
        ]);
    }
    let last = series.last().unwrap();
    println!(
        "[fig2] 2030: AI = {:.1}% of global demand (paper: >2%); \
         Anderson savings = {:.0} TWh/yr (paper: ~160 TWh/yr)",
        100.0 * last.ai_share_of_global,
        last.saved_twh
    );
    csv.save(opts.out_dir.join("fig2_energy.csv"))?;
    println!("[fig2] wrote {}", opts.out_dir.join("fig2_energy.csv").display());
    Ok(())
}

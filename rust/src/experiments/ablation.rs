//! **Ablations** — the design-choice studies DESIGN.md calls out, covering
//! the hyperparameter space the paper's §6 explicitly leaves unexplored:
//!
//!   A. window m ∈ {1, 2, 3, 5}: runtime-masked against the compiled m=5
//!      artifact (real PJRT solves on an encoded batch).
//!   B. damping β ∈ {0.5, 0.8, 1.0} and stochastic sketch sizes on the
//!      native solver (stiff affine map) — including the paper's cited
//!      future-work stochastic Anderson variant [Wei et al. 2021].
//!   C. backward mode JFB vs truncated-Neumann: short training runs from
//!      the same init, loss trajectories compared.
//!   D. adaptive (condition-monitored window + safeguarded step) vs
//!      fixed-window Anderson on easy and stiff input mixes at equal
//!      tolerance — fevals to convergence head-to-head, written to
//!      `adaptive_vs_fixed.csv` (the CI deep-test job uploads it).

use anyhow::Result;

use crate::data;
use crate::experiments::ExpOptions;
use crate::metrics::Csv;
use crate::native::{
    self, maps::AffineMap, AndersonOpts, StochasticOpts,
};
use crate::runtime::{Backend, HostTensor};
use crate::solver::{self, SolveSpec, SolverKind};
use crate::train::{default_config, Backward, Trainer};

pub fn run(engine: &dyn Backend, opts: &ExpOptions) -> Result<()> {
    let mut csv = Csv::new(&["study", "setting", "metric", "value"]);

    // ---- A. window ablation on the real artifacts -------------------
    println!("[ablation] A: Anderson window (PJRT artifacts, masked)");
    let params = engine.init_params()?;
    let meta = engine.manifest().model.clone();
    let batch = *engine
        .manifest()
        .batches_for("encode")
        .get(1)
        .unwrap_or(&1); // second-smallest compiled bucket (8 by default)
    let (train_data, _, _) = data::load_auto(batch.max(32), 8, opts.seed);
    let idx: Vec<usize> = (0..batch).collect();
    let (imgs, _) = train_data.gather(&idx);
    let x_img = HostTensor::f32(meta.image_shape(batch), imgs)?;
    let mut enc_in = params.tensors.clone();
    enc_in.push(x_img);
    let x_feat = engine.execute("encode", batch, &enc_in)?.remove(0);

    let compiled_m = engine.manifest().solver.window;
    println!(
        "{:>8} {:>8} {:>8} {:>14}",
        "window", "iters", "fevals", "final_res"
    );
    for m in [1usize, 2, 3, compiled_m] {
        // Window ablation through the validating builder: each runtime
        // window rides the same compiled artifact via the mask.
        let so = SolveSpec::from_manifest(engine, SolverKind::Anderson)
            .to_builder()
            .window(m)
            .tol(2e-3)
            .max_iter(80)
            .build()?;
        let rep = solver::solve_spec(engine, &params.tensors, &x_feat, &so)?;
        println!(
            "{:>8} {:>8} {:>8} {:>14.3e}",
            m,
            rep.iters(),
            rep.fevals(),
            rep.final_residual()
        );
        csv.row(&[
            "window".into(),
            m.to_string(),
            "fevals".into(),
            rep.fevals().to_string(),
        ]);
        csv.row(&[
            "window".into(),
            m.to_string(),
            "final_res".into(),
            format!("{:.6e}", rep.final_residual()),
        ]);
    }

    // ---- B. damping + stochastic sketch (native, stiff map) ---------
    println!("\n[ablation] B: damping β and stochastic sketch (native, ρ=0.97)");
    let n = 256;
    let map = AffineMap::random(n, 0.97, opts.seed + 1);
    let z0 = vec![0.0f32; n];
    println!("{:>16} {:>8} {:>14}", "setting", "iters", "final_res");
    for beta in [0.5f32, 0.8, 1.0] {
        let o = AndersonOpts {
            window: 5,
            beta,
            lam: 1e-8,
            tol: 1e-5,
            max_iter: 2000,
        };
        let tr = native::solve_anderson(&map, &z0, o)?;
        println!("{:>16} {:>8} {:>14.3e}", format!("beta={beta}"), tr.iters(), tr.final_residual());
        csv.row(&[
            "beta".into(),
            format!("{beta}"),
            "iters".into(),
            tr.iters().to_string(),
        ]);
    }
    for sketch in [16usize, 64, 0] {
        let o = StochasticOpts {
            base: AndersonOpts {
                window: 5,
                lam: 1e-8,
                tol: 1e-5,
                max_iter: 2000,
                ..Default::default()
            },
            sketch,
            beta_lo: 0.9,
            beta_hi: 1.0,
            seed: opts.seed,
        };
        let tr = native::solve_stochastic(&map, &z0, o)?;
        let label = if sketch == 0 { "sketch=exact".to_string() } else { format!("sketch={sketch}") };
        println!("{:>16} {:>8} {:>14.3e}", label, tr.iters(), tr.final_residual());
        csv.row(&[
            "stochastic".into(),
            label,
            "iters".into(),
            tr.iters().to_string(),
        ]);
    }
    let fw = native::solve_forward(
        &map,
        &z0,
        AndersonOpts { tol: 1e-5, max_iter: 4000, ..Default::default() },
    );
    println!("{:>16} {:>8} {:>14.3e}", "forward", fw.iters(), fw.final_residual());
    csv.row(&[
        "baseline".into(),
        "forward".into(),
        "iters".into(),
        fw.iters().to_string(),
    ]);

    // ---- C. backward mode: JFB vs truncated Neumann ------------------
    println!("\n[ablation] C: backward mode (JFB vs Neumann-K), {} epochs", opts.epochs.min(3));
    let (train_d, test_d, _) = data::load_auto(
        opts.train_size.min(256),
        opts.test_size.min(96),
        opts.seed,
    );
    let init = engine.init_params()?;
    for (label, bw) in [("jfb", Backward::Jfb), ("neumann", Backward::Neumann)] {
        let mut cfg = default_config(engine, SolverKind::Anderson, opts.epochs.min(3));
        cfg.backward = bw;
        cfg.verbose = false;
        let rep = Trainer::new(engine, cfg)?.train(&init, &train_d, &test_d)?;
        let last = rep.epochs.last().unwrap();
        println!(
            "  {label:<8} final loss {:.4} train_acc {:.1}% test_acc {:.1}% ({:.1?})",
            last.train_loss,
            100.0 * last.train_acc,
            100.0 * rep.best_test_acc().unwrap_or(0.0),
            rep.total_time
        );
        csv.row(&[
            "backward".into(),
            label.into(),
            "final_loss".into(),
            format!("{:.4}", last.train_loss),
        ]);
        csv.row(&[
            "backward".into(),
            label.into(),
            "train_acc".into(),
            format!("{:.4}", last.train_acc),
        ]);
    }

    csv.save(opts.out_dir.join("ablation.csv"))?;
    println!("[ablation] wrote {}", opts.out_dir.join("ablation.csv").display());

    // ---- D. adaptive vs fixed Anderson on easy/stiff mixes -----------
    // Stiffness is modulated the way the serving tests do: scaling the
    // input image inflates the latent residuals and stretches the solve.
    // Both policies run at the same tolerance on the same encoded
    // features; the comparison is fevals to convergence.
    println!("\n[ablation] D: adaptive vs fixed Anderson (easy/stiff inputs)");
    let mut avf = Csv::new(&["policy", "load", "metric", "value"]);
    let fixed = SolveSpec::from_manifest(engine, SolverKind::Anderson)
        .to_builder()
        .window(compiled_m)
        .tol(2e-3)
        .max_iter(120)
        .build()?;
    let adaptive = fixed
        .clone()
        .to_builder()
        .adaptive_window(true)
        .safeguard(true)
        .errorfactor(1e3)
        .cond_max(1e6)
        .build()?;
    println!(
        "{:>10} {:>8} {:>8} {:>8} {:>14}",
        "policy", "load", "iters", "fevals", "final_res"
    );
    for (load, scale) in [("easy", 1.0f32), ("stiff", 3.0)] {
        let scaled: Vec<f32> = {
            let (imgs, _) = train_data.gather(&idx);
            imgs.iter().map(|v| v * scale).collect()
        };
        let x_img = HostTensor::f32(meta.image_shape(batch), scaled)?;
        let mut enc_in = params.tensors.clone();
        enc_in.push(x_img);
        let feat = engine.execute("encode", batch, &enc_in)?.remove(0);
        for (policy, spec) in [("fixed", &fixed), ("adaptive", &adaptive)] {
            let rep = solver::solve_spec(engine, &params.tensors, &feat, spec)?;
            println!(
                "{:>10} {:>8} {:>8} {:>8} {:>14.3e}",
                policy,
                load,
                rep.iters(),
                rep.fevals(),
                rep.final_residual()
            );
            for (metric, value) in [
                ("iters", rep.iters().to_string()),
                ("fevals", rep.fevals().to_string()),
                ("final_res", format!("{:.6e}", rep.final_residual())),
            ] {
                avf.row(&[
                    policy.into(),
                    load.into(),
                    metric.into(),
                    value,
                ]);
            }
        }
    }
    avf.save(opts.out_dir.join("adaptive_vs_fixed.csv"))?;
    println!(
        "[ablation] wrote {}",
        opts.out_dir.join("adaptive_vs_fixed.csv").display()
    );
    Ok(())
}

//! **Fig. 5**: CIFAR10 training curves — train/test accuracy per epoch for
//! Anderson vs forward iteration, from identical initialization.
//!
//! Paper claims reproduced in shape: Anderson reaches a higher accuracy
//! plateau (×~1.2 at stable convergence), with visibly lower epoch-to-
//! epoch fluctuation than forward iteration.

use anyhow::Result;

use crate::data;
use crate::experiments::ExpOptions;
use crate::metrics::Csv;
use crate::runtime::Backend;
use crate::solver::SolverKind;
use crate::train::{default_config, TrainReport, Trainer};

/// Std-dev of the last-half test accuracies — the "fluctuation" metric.
pub fn fluctuation(rep: &TrainReport) -> f32 {
    let accs: Vec<f32> = rep.epochs.iter().filter_map(|e| e.test_acc).collect();
    if accs.len() < 2 {
        return 0.0;
    }
    let tail = &accs[accs.len() / 2..];
    let mean = tail.iter().sum::<f32>() / tail.len() as f32;
    (tail.iter().map(|a| (a - mean).powi(2)).sum::<f32>() / tail.len() as f32)
        .sqrt()
}

pub fn run(engine: &dyn Backend, opts: &ExpOptions) -> Result<()> {
    let (train_data, test_data, ds) =
        data::load_auto(opts.train_size, opts.test_size, opts.seed);
    let init = engine.init_params()?;
    println!(
        "[fig5] dataset={ds} train={} test={} epochs={}",
        train_data.len(),
        test_data.len(),
        opts.epochs
    );

    let mut reports: Vec<(SolverKind, TrainReport)> = Vec::new();
    for kind in [SolverKind::Anderson, SolverKind::Forward] {
        let mut cfg = default_config(engine, kind, opts.epochs);
        cfg.verbose = opts.verbose;
        println!("[fig5] training with {} ...", kind.name());
        let rep = Trainer::new(engine, cfg)?.train(&init, &train_data, &test_data)?;
        reports.push((kind, rep));
    }

    let mut csv = Csv::new(&[
        "solver", "epoch", "train_acc", "test_acc", "train_loss",
        "solver_iters", "cumulative_time_s",
    ]);
    for (kind, rep) in &reports {
        for e in &rep.epochs {
            csv.row(&[
                kind.name().to_string(),
                e.epoch.to_string(),
                format!("{:.4}", e.train_acc),
                e.test_acc.map(|a| format!("{a:.4}")).unwrap_or_default(),
                format!("{:.4}", e.train_loss),
                format!("{:.2}", e.solver_iters),
                format!("{:.3}", e.cumulative_time.as_secs_f64()),
            ]);
        }
    }
    csv.save(opts.out_dir.join("fig5_accuracy.csv"))?;

    let (a, f) = (&reports[0].1, &reports[1].1);
    let ratio = a.best_test_acc().unwrap_or(0.0)
        / f.best_test_acc().unwrap_or(1e-9).max(1e-9);
    println!(
        "[fig5] best test acc: anderson {:.1}% vs forward {:.1}% (ratio {:.2}x; paper: ~1.2x)",
        100.0 * a.best_test_acc().unwrap_or(0.0),
        100.0 * f.best_test_acc().unwrap_or(0.0),
        ratio
    );
    println!(
        "[fig5] late-epoch test-acc fluctuation: anderson {:.4} vs forward {:.4}",
        fluctuation(a),
        fluctuation(f)
    );
    println!("[fig5] wrote {}", opts.out_dir.join("fig5_accuracy.csv").display());
    Ok(())
}

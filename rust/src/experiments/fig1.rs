//! **Fig. 1**: Crossover and mixing penalty — relative residual vs time
//! for Anderson vs forward iteration on one equilibrium solve.
//!
//! Measured on the real AOT artifacts (CPU wallclock), then re-timed with
//! the V100/Xeon roofline models so the plot carries the paper's four
//! curves.  The crossover detector reports the residual level where
//! Anderson's wallclock advantage begins, and the per-iteration mixing
//! penalty.

use anyhow::Result;

use crate::data;
use crate::experiments::ExpOptions;
use crate::metrics::Csv;
use crate::runtime::{Backend, HostTensor};
use crate::simulate::{simulate_timestamps, Workload, V100, XEON};
use crate::solver::{self, crossover, SolveSpec, SolverKind};

pub fn run(engine: &dyn Backend, opts: &ExpOptions) -> Result<()> {
    let manifest = engine.manifest();
    let batch = 32usize;
    let (train_data, _, ds) = data::load_auto(batch.max(64), 8, opts.seed);
    let params = engine.init_params()?;
    println!("[fig1] dataset={ds} batch={batch} solving to tol=1e-4 ...");

    // Encode one batch.
    let idx: Vec<usize> = (0..batch).collect();
    let (imgs, _) = train_data.gather(&idx);
    let x_img = HostTensor::f32(manifest.model.image_shape(batch), imgs)?;
    let mut enc_in: Vec<HostTensor> = params.tensors.clone();
    enc_in.push(x_img);
    let x_feat = engine.execute("encode", batch, &enc_in)?.remove(0);

    // Deep solves with both methods (per-step dispatch so the trace has
    // full resolution).
    let mk_spec = |kind| SolveSpec {
        tol: 1e-4,
        max_iter: 60,
        fused_forward: false,
        ..SolveSpec::from_manifest(engine, kind)
    };
    let rep_a = solver::solve_spec(
        engine,
        &params.tensors,
        &x_feat,
        &mk_spec(SolverKind::Anderson),
    )?;
    let rep_f = solver::solve_spec(
        engine,
        &params.tensors,
        &x_feat,
        &mk_spec(SolverKind::Forward),
    )?;

    let cx = crossover::analyze(&rep_a, &rep_f);
    println!(
        "[fig1] measured: anderson {} iters (res {:.2e}) | forward {} iters (res {:.2e})",
        rep_a.iters(),
        rep_a.final_residual(),
        rep_f.iters(),
        rep_f.final_residual()
    );
    println!(
        "[fig1] mixing penalty (cost/iter ratio): {:.2}x | crossover residual: {}",
        cx.mixing_penalty,
        cx.crossover_residual
            .map(|r| format!("{r:.2e}"))
            .unwrap_or_else(|| "none within horizon".into()),
    );

    // CSV: measured + device-model curves.
    let w = Workload {
        batch,
        latent_hw: manifest.model.latent_hw,
        channels: manifest.model.channels,
        window: manifest.solver.window,
    };
    let mut csv = Csv::new(&["series", "iter", "time_s", "rel_residual"]);
    for (series, rep, anderson) in
        [("anderson_cpu_measured", &rep_a, true), ("forward_cpu_measured", &rep_f, false)]
    {
        for s in &rep.steps {
            csv.row(&[
                series.to_string(),
                s.iter.to_string(),
                format!("{:.6}", s.elapsed.as_secs_f64()),
                format!("{:.6e}", s.rel_residual),
            ]);
        }
        let residuals: Vec<f32> =
            rep.steps.iter().map(|s| s.rel_residual).collect();
        for (dev, tag) in [(&V100, "v100_model"), (&XEON, "xeon_model")] {
            for (k, (t, r)) in
                simulate_timestamps(&residuals, dev, &w, anderson)
                    .into_iter()
                    .enumerate()
            {
                csv.row(&[
                    format!(
                        "{}_{}",
                        if anderson { "anderson" } else { "forward" },
                        tag
                    ),
                    k.to_string(),
                    format!("{:.6e}", t.as_secs_f64()),
                    format!("{:.6e}", r),
                ]);
            }
        }
    }
    csv.save(opts.out_dir.join("fig1_crossover.csv"))?;
    println!(
        "[fig1] wrote {}",
        opts.out_dir.join("fig1_crossover.csv").display()
    );
    Ok(())
}

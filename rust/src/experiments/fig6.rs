//! **Fig. 6**: relative residual vs time for a random input x — the
//! GPU-vs-CPU × Anderson-vs-forward four-way comparison.
//!
//! Paper claims: a typical GPU is ~100-150x faster than a typical CPU to a
//! target relative residual with Anderson, with a mixing penalty of
//! ~10⁻¹–10⁻² (Anderson's deeper plateau).  Residual *trajectories* are
//! computed exactly with the native solver at paper scale (channels=48,
//! 16x16 latent ⇒ n=12288); *timestamps* come from the V100/Xeon roofline
//! models (DESIGN.md §6 substitution).

use anyhow::Result;

use crate::experiments::ExpOptions;
use crate::metrics::Csv;
use crate::native::{self, maps::AffineMap, AndersonOpts};
use crate::simulate::{simulate_timestamps, DeviceModel, Workload, V100, XEON};

pub fn run(opts: &ExpOptions) -> Result<()> {
    // Paper-scale workload for the cost model; the native map uses a
    // reduced state (cost model scales analytically, trajectories are
    // map-specific anyway).  The map is the *stiff* regime the paper's
    // comparison lives in: spectral radius 0.98, where forward iteration
    // crawls at rate 0.98/iter and Anderson's Krylov acceleration shines.
    let w = Workload { batch: 1, latent_hw: 16, channels: 48, window: 5 };
    let n_map = 512; // native map dimension (dense n² matvec)
    let map = AffineMap::random(n_map, 0.98, opts.seed ^ 0xF16);
    let z0 = vec![0.0f32; n_map];

    let solver_opts = AndersonOpts {
        window: 5,
        beta: 1.0,
        lam: 1e-8,
        tol: 1e-6,
        max_iter: 1000,
    };
    println!("[fig6] solving random-input fixed point (n={n_map}) ...");
    let tr_a = native::solve_anderson(&map, &z0, solver_opts)?;
    let tr_f = native::solve_forward(&map, &z0, solver_opts);

    let res_a: Vec<f32> = tr_a.records.iter().map(|r| r.rel_residual).collect();
    let res_f: Vec<f32> = tr_f.records.iter().map(|r| r.rel_residual).collect();

    let mut csv = Csv::new(&["series", "iter", "time_s", "rel_residual"]);
    let mut emit = |dev: &DeviceModel, anderson: bool, res: &[f32]| {
        let tag = format!(
            "{}_{}",
            if anderson { "anderson" } else { "forward" },
            dev.name.to_lowercase()
        );
        for (k, (t, r)) in
            simulate_timestamps(res, dev, &w, anderson).into_iter().enumerate()
        {
            csv.row(&[
                tag.clone(),
                k.to_string(),
                format!("{:.6e}", t.as_secs_f64()),
                format!("{:.6e}", r),
            ]);
        }
    };
    emit(&V100, true, &res_a);
    emit(&V100, false, &res_f);
    emit(&XEON, true, &res_a);
    emit(&XEON, false, &res_f);
    csv.save(opts.out_dir.join("fig6_residual.csv"))?;

    // Headline numbers.  Plateau comparison at an equal-iteration budget
    // (forward's trajectory length may exceed anderson's).
    let budget = tr_a.iters().min(tr_f.iters()).saturating_sub(1);
    let res_at = |tr: &native::SolveTrace| tr.records[budget].rel_residual;
    let target = 10.0 * tr_a.final_residual().max(1e-7);
    let t = |res: &[f32], dev: &DeviceModel, anderson: bool| -> Option<f64> {
        simulate_timestamps(res, dev, &w, anderson)
            .iter()
            .find(|(_, r)| *r <= target)
            .map(|(t, _)| t.as_secs_f64())
    };
    if let (Some(gpu), Some(cpu)) =
        (t(&res_a, &V100, true), t(&res_a, &XEON, true))
    {
        println!(
            "[fig6] time to residual {:.1e} with Anderson: V100 {:.2e}s vs Xeon {:.2e}s \
             → {:.0}x (paper: ~100-150x)",
            target,
            gpu,
            cpu,
            cpu / gpu
        );
    }
    let gap = res_at(&tr_f) / res_at(&tr_a).max(1e-12);
    println!(
        "[fig6] residual at equal iteration budget ({budget}): \
         anderson {:.2e} vs forward {:.2e} → anderson {:.1e}x deeper \
         (paper: mixing penalty '10⁻¹-10⁻² lower')",
        res_at(&tr_a),
        res_at(&tr_f),
        gap
    );
    println!("[fig6] anderson iters {} vs forward iters {} (to their plateaus)",
        tr_a.iters(), tr_f.iters());
    println!("[fig6] wrote {}", opts.out_dir.join("fig6_residual.csv").display());
    Ok(())
}

//! Experiment harness: one module per table/figure in the paper's
//! evaluation (see DESIGN.md §3 for the index).
//!
//! Every experiment
//!   * regenerates the same rows/series the paper reports,
//!   * prints a human-readable table to stdout,
//!   * writes machine-readable CSV under `--out` (default `results/`),
//! and is invoked either through `deq-anderson experiment <id>` or its
//! `cargo bench` wrapper.
//!
//! Scale note: the paper trains on a V100 for hours; these default sizes
//! are chosen so the full suite runs on CPU in minutes while preserving
//! the comparisons' *shape* (who wins, by what factor, where crossovers
//! fall).  Paper-scale projections come from the device model.

pub mod ablation;
pub mod fig1;
pub mod fig2;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod serving;
pub mod table1;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::Backend;

/// Shared experiment options.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub out_dir: PathBuf,
    pub train_size: usize,
    pub test_size: usize,
    pub epochs: usize,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            out_dir: PathBuf::from("results"),
            train_size: 960,
            test_size: 320,
            epochs: 6,
            seed: 0,
            verbose: true,
        }
    }
}

impl ExpOptions {
    /// Reduced sizes for bench wrappers / CI smoke.
    pub fn smoke() -> Self {
        Self {
            train_size: 128,
            test_size: 64,
            epochs: 2,
            verbose: false,
            ..Self::default()
        }
    }
}

/// All experiment ids, in paper order (plus the serving scenario).
pub const ALL: &[&str] =
    &["table1", "fig1", "fig2", "fig5", "fig6", "fig7", "ablation", "serving"];

/// Dispatch by id. `engine` may be None only for fig2/fig6 (native-only).
/// The engine rides in an `Arc` because the serving scenario spawns the
/// router's worker thread over it.
pub fn run(
    id: &str,
    engine: Option<&Arc<dyn Backend>>,
    opts: &ExpOptions,
) -> Result<()> {
    match id {
        "table1" => table1::run(need(engine)?.as_ref(), opts),
        "fig1" => fig1::run(need(engine)?.as_ref(), opts),
        "fig2" => fig2::run(opts),
        "fig5" => fig5::run(need(engine)?.as_ref(), opts),
        "fig6" => fig6::run(opts),
        "fig7" => fig7::run(need(engine)?.as_ref(), opts),
        "ablation" => ablation::run(need(engine)?.as_ref(), opts),
        "serving" => serving::run(need(engine)?, opts),
        other => bail!("unknown experiment '{other}' (have {ALL:?})"),
    }
}

fn need<'a>(
    engine: Option<&'a Arc<dyn Backend>>,
) -> Result<&'a Arc<dyn Backend>> {
    engine.ok_or_else(|| {
        anyhow::anyhow!("this experiment needs an execution backend")
    })
}

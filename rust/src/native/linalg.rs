//! Small dense linear algebra for the native (pure-Rust) solver twin.
//!
//! Sized for Anderson's needs: Gram matrices up to m=8, batched solves,
//! plus general gemm/gemv for the synthetic fixed-point test maps.  All
//! row-major `&[f32]`.

use anyhow::{bail, Result};

/// y = A x, A is (m, n) row-major.  Delegates to the kernels gemv (row
/// panels go parallel above the size threshold; per-row dot order is
/// unchanged, so results are identical at any thread count).
pub fn gemv(a: &[f32], x: &[f32], m: usize, n: usize, y: &mut [f32]) {
    crate::native::kernels::gemv(a, x, m, n, y);
}

/// C = A B, A (m, k), B (k, n), C (m, n), all row-major.  Delegates to
/// the blocked (and, for large problems, multi-threaded) kernel in
/// [`crate::native::kernels`]; the old naive loop survives there as
/// `gemm_reference`, the parity oracle.
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    crate::native::kernels::gemm(a, b, m, k, n, c);
}

/// One upper-triangle row of the Gram matrix: `hrow[j] = ⟨g_i, g_j⟩`
/// for `j in i..m`; entries below the diagonal are left untouched.
///
/// This is the single dot-product kernel behind both [`gram`] and the
/// pool-parallel Anderson Gram build (`AndersonState::mix_into`), so
/// the serial and parallel paths stay bit-identical by construction.
pub fn gram_row_upper(g: &[f32], m: usize, n: usize, i: usize, hrow: &mut [f32]) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(hrow.len(), m);
    let ri = &g[i * n..(i + 1) * n];
    for j in i..m {
        let rj = &g[j * n..(j + 1) * n];
        let mut acc = 0.0f32;
        for (p, q) in ri.iter().zip(rj) {
            acc += p * q;
        }
        hrow[j] = acc;
    }
}

/// Gram matrix H = G Gᵀ for G (m, n) row-major → H (m, m): upper
/// triangle via [`gram_row_upper`], then mirrored.
pub fn gram(g: &[f32], m: usize, n: usize, h: &mut [f32]) {
    assert_eq!(g.len(), m * n);
    assert_eq!(h.len(), m * m);
    for (i, hrow) in h.chunks_mut(m).enumerate() {
        gram_row_upper(g, m, n, i, hrow);
    }
    for i in 1..m {
        for j in 0..i {
            h[i * m + j] = h[j * m + i];
        }
    }
}

/// In-place Cholesky factorization of an SPD matrix (m, m): A = L Lᵀ,
/// L stored in the lower triangle. Errors on a non-positive pivot.
pub fn cholesky(a: &mut [f32], m: usize) -> Result<()> {
    assert_eq!(a.len(), m * m);
    for j in 0..m {
        let mut d = a[j * m + j];
        for k in 0..j {
            d -= a[j * m + k] * a[j * m + k];
        }
        if d <= 0.0 {
            bail!("cholesky: non-positive pivot {d} at {j}");
        }
        let d = d.sqrt();
        a[j * m + j] = d;
        for i in (j + 1)..m {
            let mut s = a[i * m + j];
            for k in 0..j {
                s -= a[i * m + k] * a[j * m + k];
            }
            a[i * m + j] = s / d;
        }
    }
    Ok(())
}

/// Solve A x = b given the Cholesky factor from [`cholesky`] (in `a`).
pub fn cholesky_solve(a: &[f32], m: usize, b: &mut [f32]) {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m);
    // Forward: L y = b
    for i in 0..m {
        let mut s = b[i];
        for k in 0..i {
            s -= a[i * m + k] * b[k];
        }
        b[i] = s / a[i * m + i];
    }
    // Backward: Lᵀ x = y
    for i in (0..m).rev() {
        let mut s = b[i];
        for k in (i + 1)..m {
            s -= a[k * m + i] * b[k];
        }
        b[i] = s / a[i * m + i];
    }
}

/// Solve SPD A x = b in place: `a` is destroyed (replaced by its
/// Cholesky factor) and `b` is overwritten with the solution.  The
/// allocation-free core of [`solve_spd`], used by the pooled hot paths.
pub fn solve_spd_in_place(a: &mut [f32], m: usize, b: &mut [f32]) -> Result<()> {
    cholesky(a, m)?;
    cholesky_solve(a, m, b);
    Ok(())
}

/// Cheap 2-norm condition estimate for an SPD matrix, via its Cholesky
/// factor: `(max_i L_ii / min_i L_ii)²`.  This is a lower bound on the
/// true `cond₂(A)` (the diagonal of L brackets the extreme eigenvalues
/// from inside), computed with the same factorization the Anderson mix
/// already performs — which is what makes per-iteration condition
/// monitoring affordable.  `a` is destroyed (replaced by its factor).
/// A failed factorization (numerically indefinite) reports `INFINITY`:
/// for monitoring purposes a system Cholesky rejects is as bad as a
/// singular one.
pub fn spd_cond_estimate(a: &mut [f32], m: usize) -> f32 {
    if m == 0 {
        return 1.0;
    }
    if cholesky(a, m).is_err() {
        return f32::INFINITY;
    }
    let (mut lo, mut hi) = (f32::INFINITY, 0.0f32);
    for i in 0..m {
        let d = a[i * m + i];
        lo = lo.min(d);
        hi = hi.max(d);
    }
    if lo <= 0.0 {
        return f32::INFINITY;
    }
    let r = hi / lo;
    r * r
}

/// Solve SPD A x = b (copies A; convenience wrapper).
pub fn solve_spd(a: &[f32], m: usize, b: &[f32]) -> Result<Vec<f32>> {
    let mut fac = a.to_vec();
    let mut x = b.to_vec();
    solve_spd_in_place(&mut fac, m, &mut x)?;
    Ok(x)
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// a ← a + s·b
pub fn axpy(s: f32, b: &[f32], a: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    for (ai, bi) in a.iter_mut().zip(b) {
        *ai += s * bi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gemv_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let x = vec![3.0, 4.0];
        let mut y = vec![0.0; 2];
        gemv(&a, &x, 2, 2, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn gemm_known() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0; 4];
        let mut c = vec![0.0; 4];
        gemm(&a, &b, 2, 2, 2, &mut c);
        assert_eq!(c, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gram_is_gg_t() {
        let mut r = Rng::new(1);
        let (m, n) = (4, 17);
        let g = r.normal_vec(m * n, 1.0);
        let mut h = vec![0.0; m * m];
        gram(&g, m, n, &mut h);
        // Check against gemm with explicit transpose.
        let mut gt = vec![0.0; n * m];
        for i in 0..m {
            for j in 0..n {
                gt[j * m + i] = g[i * n + j];
            }
        }
        let mut h2 = vec![0.0; m * m];
        gemm(&g, &gt, m, n, m, &mut h2);
        for (x, y) in h.iter().zip(&h2) {
            assert!((x - y).abs() < 1e-4);
        }
        // Symmetry
        for i in 0..m {
            for j in 0..m {
                assert!((h[i * m + j] - h[j * m + i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn cholesky_solves_spd() {
        let mut r = Rng::new(2);
        for m in [1usize, 2, 3, 5, 8] {
            let g = r.normal_vec(m * (3 * m), 1.0);
            let mut h = vec![0.0; m * m];
            gram(&g, m, 3 * m, &mut h);
            for i in 0..m {
                h[i * m + i] += 1e-3;
            }
            let b = r.normal_vec(m, 1.0);
            let x = solve_spd(&h, m, &b).unwrap();
            let mut ax = vec![0.0; m];
            gemv(&h, &x, m, m, &mut ax);
            for (l, r_) in ax.iter().zip(&b) {
                assert!((l - r_).abs() < 1e-2, "m={m}: {l} vs {r_}");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&mut a, 2).is_err());
    }

    #[test]
    fn cond_estimate_tracks_spread_and_flags_indefinite() {
        // Identity: perfectly conditioned.
        let mut eye = vec![1.0f32, 0.0, 0.0, 1.0];
        assert!((spd_cond_estimate(&mut eye, 2) - 1.0).abs() < 1e-6);
        // diag(100, 1): cond = 100, the Cholesky-diag estimate is exact
        // for diagonal matrices.
        let mut d = vec![100.0f32, 0.0, 0.0, 1.0];
        assert!((spd_cond_estimate(&mut d, 2) - 100.0).abs() < 1e-3);
        // Indefinite input reports INFINITY instead of erroring.
        let mut bad = vec![1.0f32, 2.0, 2.0, 1.0];
        assert!(spd_cond_estimate(&mut bad, 2).is_infinite());
        // The estimate never exceeds the true condition number on random
        // SPD systems (lower-bound property).
        let mut r = Rng::new(9);
        for m in [2usize, 4, 6] {
            let g = r.normal_vec(m * (2 * m), 1.0);
            let mut h = vec![0.0; m * m];
            gram(&g, m, 2 * m, &mut h);
            for i in 0..m {
                h[i * m + i] += 1e-3;
            }
            // Rayleigh-quotient bracket via a few power iterations gives
            // a (loose) reference; the estimate must stay finite and ≥ 1.
            let est = spd_cond_estimate(&mut h.clone(), m);
            assert!(est.is_finite() && est >= 1.0, "m={m}: est={est}");
        }
    }

    #[test]
    fn norm_and_axpy() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        let mut a = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut a);
        assert_eq!(a, vec![3.0, 5.0]);
    }
}

//! Packed-panel, register-blocked microkernel GEMM — the compute core of
//! the paper's "fewer, more compute-intensive but generally *cacheable*
//! iterations" thesis.
//!
//! Every Anderson iteration re-applies the **same** weight matrices, so
//! the dominant GEMM cost splits into two very different halves:
//!
//!   * **B (weights)**: identical across iterations (and across lanes in
//!     continuous batching).  [`PackedB`] reorders a weight matrix once
//!     into microkernel-ready [`NR`]-wide column strips, padded and
//!     contiguous, so the inner loop streams it with unit stride and no
//!     edge branches.  The engine caches one `PackedB` per weight matrix
//!     (see `NativeEngine`'s pack cache), keyed by the parameter version
//!     counter from [`crate::model::params`] — steady-state iterations do
//!     **zero** weight packing.  Panels come in two precisions
//!     ([`PackPrecision`]): full `f32`, or `bf16` storage (truncated
//!     8-bit-mantissa floats, round-to-nearest-even) that **halves the
//!     pack-cache footprint** — the paper's speed-vs-memory axis — while
//!     the kernel still accumulates in `f32`.
//!   * **A (activations)**: fresh every iteration.  `pack_a` repacks
//!     the current panel into [`MR`]-tall column-major strips in caller
//!     scratch (workspace-pooled on the engine path), an O(m·k) copy that
//!     buys the O(m·k·n) loop perfect access patterns.
//!
//! The inner loop is an [`MR`]×[`NR`] (8×8) register tile, in two
//! implementations behind runtime CPU-feature dispatch ([`SimdLevel`]):
//!
//!   * the **scalar microkernel** — 64 scalar accumulators in portable
//!     safe Rust, fixed-trip loops the compiler auto-vectorizes; kept
//!     verbatim as the **parity oracle** (and the only kernel off
//!     x86-64);
//!   * the **explicit AVX2 microkernel** (`std::arch`) — each of the 8
//!     accumulator rows is exactly one `__m256`, updated by broadcast +
//!     separate multiply and add (deliberately *not* FMA: contraction
//!     would change rounding, and the AVX2 path is **bit-identical** to
//!     the scalar oracle — per C element both sum the same k terms in the
//!     same ascending order with one rounding per multiply and add).
//!
//! Dispatch is resolved **once** per engine/pool construction (the env
//! knob `DEQ_NATIVE_SIMD=off|scalar|avx2` forces a level; unset
//! auto-detects), then threaded through the entry points as an explicit
//! [`SimdLevel`] argument — no per-call feature detection.
//!
//! Accumulation order over k is ascending for every C element, exactly
//! like `kernels::gemm_reference`, so results are independent of the
//! row-chunking used for parallelism *and* of the dispatched SIMD level.
//!
//! Parallelism comes from a [`WorkerPool`] (no per-call thread spawns):
//! rows of C are split into contiguous chunks, one job per chunk, each
//! with its own A-pack scratch and a disjoint `&mut` slice of C.

use crate::native::pool::WorkerPool;

/// Microkernel tile height (rows of C per register tile).
pub const MR: usize = 8;
/// Microkernel tile width (columns of C per register tile).
pub const NR: usize = 8;
/// k-dimension cache block: one `KC`×[`NR`] B strip plus an `MR`×`KC`
/// A strip stay cache-resident through a full tile update.
pub const KC: usize = 256;
/// n-dimension cache block (must be a multiple of [`NR`]): bounds the
/// set of B strips walked per A panel so they stay L2-resident.
pub const NC: usize = 512;

// The AVX2 microkernels hold one __m256 per accumulator row and load
// NR-wide B strips as one vector; they are written for exactly this tile.
const _: () = assert!(MR == 8 && NR == 8, "AVX2 microkernels assume 8x8 tiles");

/// Which microkernel implementation the packed GEMM entry points run.
///
/// Resolved **once** at engine/pool construction via [`SimdLevel::from_env`]
/// (the `DEQ_NATIVE_SIMD` knob) and passed down explicitly — the hot path
/// never re-detects CPU features.  `Avx2` is only ever constructed after a
/// successful runtime `avx2` feature detection, which is what makes the
/// `unsafe` kernel calls sound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// The portable safe-Rust microkernel (also the parity oracle).
    Scalar,
    /// The explicit `std::arch` AVX2 microkernel (x86-64 only;
    /// bit-identical to [`SimdLevel::Scalar`] for f32 packs).
    Avx2,
}

impl SimdLevel {
    /// Best level the running CPU supports.
    pub fn detect() -> Self {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return SimdLevel::Avx2;
            }
        }
        SimdLevel::Scalar
    }

    /// Resolve the `DEQ_NATIVE_SIMD` override against runtime detection:
    /// `off` / `scalar` force the scalar oracle, `avx2` asks for AVX2
    /// (silently capped at what the CPU supports), anything else (or
    /// unset) auto-detects.  Call once at construction, not per kernel.
    pub fn from_env() -> Self {
        Self::resolve(std::env::var("DEQ_NATIVE_SIMD").ok().as_deref(), Self::detect())
    }

    /// Pure resolution core of [`Self::from_env`] (unit-testable without
    /// touching process environment).
    fn resolve(knob: Option<&str>, detected: SimdLevel) -> SimdLevel {
        match knob.map(|s| s.trim().to_ascii_lowercase()) {
            Some(ref s) if s == "off" || s == "scalar" => SimdLevel::Scalar,
            // "avx2" (or any unknown value) can never exceed detection.
            _ => detected,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// Storage precision of a [`PackedB`] weight panel.  The microkernel
/// always accumulates in `f32`; `Bf16` only changes how the packed B
/// elements are *stored* (upper 16 bits of the f32, round-to-nearest-
/// even), halving resident pack-cache bytes at ~3 decimal digits of
/// weight precision.  Resolved once at engine construction via the
/// `DEQ_NATIVE_PRECISION=f32|bf16` knob (default `f32`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackPrecision {
    F32,
    Bf16,
}

impl PackPrecision {
    /// Resolve the `DEQ_NATIVE_PRECISION` knob (default [`Self::F32`]).
    pub fn from_env() -> Self {
        Self::resolve(std::env::var("DEQ_NATIVE_PRECISION").ok().as_deref())
    }

    /// Pure resolution core of [`Self::from_env`].
    fn resolve(knob: Option<&str>) -> Self {
        match knob.map(|s| s.trim().to_ascii_lowercase()) {
            Some(ref s) if s == "bf16" => PackPrecision::Bf16,
            _ => PackPrecision::F32,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PackPrecision::F32 => "f32",
            PackPrecision::Bf16 => "bf16",
        }
    }
}

/// Convert one f32 to bf16 storage (upper 16 bits), rounding to nearest
/// even; NaNs truncate with a forced quiet bit so they stay NaN.
#[inline]
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFFu32 + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// Widen bf16 storage back to f32 — exact (bf16 is a prefix of f32).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Packed panel storage: one precision per pack (the engine's cache
/// keeps both per weight slot, invalidated together by version).
#[derive(Debug, Clone)]
enum PanelData {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

/// A weight matrix (k, n) repacked for the microkernel: for each k-tile
/// of height ≤ [`KC`], the columns are laid out in [`NR`]-wide strips,
/// row-major *within* the strip (`strip[p * NR + c] = B[p0 + p][j0 + c]`),
/// zero-padded in the tail strip.  Pack once, stream forever.
#[derive(Debug, Clone)]
pub struct PackedB {
    /// Rows of the original matrix (the GEMM k dimension).
    pub k: usize,
    /// Columns of the original matrix (the GEMM n dimension).
    pub n: usize,
    data: PanelData,
}

impl PackedB {
    /// Pack a row-major (k, n) matrix at full f32 precision.  O(k·n)
    /// copy; the engine amortizes it across every subsequent iteration
    /// via its pack cache.
    pub fn pack(b: &[f32], k: usize, n: usize) -> Self {
        Self::pack_with(b, k, n, PackPrecision::F32)
    }

    /// [`Self::pack`] with an explicit storage precision.  `Bf16` rounds
    /// each element to nearest-even bf16 at pack time (the one-time
    /// quantization); the kernels widen back to f32 on load and
    /// accumulate in f32.
    pub fn pack_with(b: &[f32], k: usize, n: usize, precision: PackPrecision) -> Self {
        assert_eq!(b.len(), k * n, "PackedB::pack: data/shape mismatch");
        let nstrips = n.div_ceil(NR);
        let mut data = vec![0.0f32; k * nstrips * NR];
        let mut off = 0;
        for p0 in (0..k).step_by(KC) {
            let kc = (p0 + KC).min(k) - p0;
            for s in 0..nstrips {
                let j0 = s * NR;
                let jw = NR.min(n - j0);
                for p in 0..kc {
                    let src = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jw];
                    data[off + p * NR..off + p * NR + jw].copy_from_slice(src);
                }
                off += kc * NR;
            }
        }
        let data = match precision {
            PackPrecision::F32 => PanelData::F32(data),
            PackPrecision::Bf16 => {
                PanelData::Bf16(data.iter().map(|&v| f32_to_bf16(v)).collect())
            }
        };
        Self { k, n, data }
    }

    /// The storage precision this panel was packed at.
    pub fn precision(&self) -> PackPrecision {
        match self.data {
            PanelData::F32(_) => PackPrecision::F32,
            PanelData::Bf16(_) => PackPrecision::Bf16,
        }
    }

    /// Packed element count (padding included) — precision-independent.
    pub fn packed_len(&self) -> usize {
        match &self.data {
            PanelData::F32(d) => d.len(),
            PanelData::Bf16(d) => d.len(),
        }
    }

    /// Resident bytes of this pack (the stats/bench footprint gauge):
    /// bf16 panels cost exactly half the f32 bytes for the same shape.
    pub fn packed_bytes(&self) -> usize {
        match &self.data {
            PanelData::F32(d) => d.len() * std::mem::size_of::<f32>(),
            PanelData::Bf16(d) => d.len() * std::mem::size_of::<u16>(),
        }
    }

    /// Start of the [`NR`]-wide strip `s` of the k-tile at row `p0`
    /// (height `kc`): tiles before `p0` hold `p0` full rows of
    /// `n.div_ceil(NR)` strips.
    #[inline]
    fn strip_base(&self, p0: usize, kc: usize, s: usize) -> usize {
        p0 * self.n.div_ceil(NR) * NR + s * kc * NR
    }

    /// Run the dispatched microkernel over one packed A block and this
    /// panel's strip `s` of the k-tile at `p0`.
    #[inline]
    fn microkernel_at(
        &self,
        p0: usize,
        kc: usize,
        s: usize,
        ap: &[f32],
        acc: &mut [f32; MR * NR],
        simd: SimdLevel,
    ) {
        let base = self.strip_base(p0, kc, s);
        match &self.data {
            PanelData::F32(d) => {
                let bstrip = &d[base..base + kc * NR];
                match simd {
                    SimdLevel::Scalar => microkernel(kc, ap, bstrip, acc),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: Avx2 is only constructed after runtime
                    // detection succeeded (SimdLevel::detect/resolve).
                    SimdLevel::Avx2 => unsafe {
                        microkernel_avx2(kc, ap, bstrip, acc)
                    },
                    #[cfg(not(target_arch = "x86_64"))]
                    SimdLevel::Avx2 => microkernel(kc, ap, bstrip, acc),
                }
            }
            PanelData::Bf16(d) => {
                let bstrip = &d[base..base + kc * NR];
                match simd {
                    SimdLevel::Scalar => microkernel_bf16(kc, ap, bstrip, acc),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: as above.
                    SimdLevel::Avx2 => unsafe {
                        microkernel_bf16_avx2(kc, ap, bstrip, acc)
                    },
                    #[cfg(not(target_arch = "x86_64"))]
                    SimdLevel::Avx2 => microkernel_bf16(kc, ap, bstrip, acc),
                }
            }
        }
    }
}

/// Length of the A-pack scratch [`gemm_packed`] needs for an `m`-row
/// panel against a k-dimension of `k`.  Never zero, so workspace pools
/// can serve it unconditionally.
pub fn apack_len(m: usize, k: usize) -> usize {
    (m.div_ceil(MR) * MR * KC.min(k)).max(1)
}

/// Repack rows `0..rows` of row-major A (leading dimension `lda`),
/// k-columns `p0..p0+kc`, into [`MR`]-tall column-major strips:
/// `block[p * MR + r] = A[r0 + r][p0 + p]`, tail rows zero-padded.
fn pack_a(a: &[f32], lda: usize, rows: usize, p0: usize, kc: usize, apack: &mut [f32]) {
    let nblocks = rows.div_ceil(MR);
    debug_assert!(apack.len() >= nblocks * kc * MR);
    for ib in 0..nblocks {
        let r0 = ib * MR;
        let rh = MR.min(rows - r0);
        let dst = &mut apack[ib * kc * MR..(ib + 1) * kc * MR];
        if rh < MR {
            dst.fill(0.0); // zero-pad the tail block once
        }
        for r in 0..rh {
            let arow = &a[(r0 + r) * lda + p0..(r0 + r) * lda + p0 + kc];
            for (p, &v) in arow.iter().enumerate() {
                dst[p * MR + r] = v;
            }
        }
    }
}

/// The scalar 8×8 register tile — the **parity oracle**: 64 accumulators
/// updated by unrolled multiply-adds over one packed A block and one
/// packed B strip.  The two inner loops are fixed-trip (`MR`, `NR`) over
/// contiguous slices, which auto-vectorizes well on any target; the
/// explicit [`microkernel_avx2`] twin must stay bit-identical to this
/// exact loop (same k order, separate multiply and add per term).
#[inline]
fn microkernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    for (arow, brow) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(kc) {
        for (&ar, accrow) in arow.iter().zip(acc.chunks_exact_mut(NR)) {
            for (av, bv) in accrow.iter_mut().zip(brow) {
                *av += ar * bv;
            }
        }
    }
}

/// Scalar bf16-panel microkernel: widen each stored element to f32
/// (exact — bf16 is an f32 prefix) and accumulate in f32.  The parity
/// oracle for [`microkernel_bf16_avx2`]; vs the f32 kernels the only
/// difference is the one-time pack rounding of B.
#[inline]
fn microkernel_bf16(kc: usize, ap: &[f32], bp: &[u16], acc: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    for (arow, brow) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(kc) {
        for (&ar, accrow) in arow.iter().zip(acc.chunks_exact_mut(NR)) {
            for (av, &bv) in accrow.iter_mut().zip(brow) {
                *av += ar * bf16_to_f32(bv);
            }
        }
    }
}

/// Explicit AVX2 8×8 tile: one `__m256` per accumulator row, broadcast
/// A element, **separate** `_mm256_mul_ps` + `_mm256_add_ps` per k term.
/// Not FMA on purpose: the scalar oracle rounds after the multiply and
/// after the add, so a fused multiply-add would change low bits — this
/// way the AVX2 path is bit-identical to [`microkernel`] and default-knob
/// solve traces don't depend on the dispatched level.
///
/// # Safety
/// Caller must ensure the running CPU supports AVX2 (guaranteed by
/// [`SimdLevel::Avx2`] construction).  Slices must hold at least
/// `kc * MR` / `kc * NR` elements (packed panels are tile-padded).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn microkernel_avx2(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32; MR * NR]) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    let mut rows = [_mm256_setzero_ps(); MR];
    for (r, row) in rows.iter_mut().enumerate() {
        *row = _mm256_loadu_ps(acc.as_ptr().add(r * NR));
    }
    for p in 0..kc {
        let bv = _mm256_loadu_ps(bp.as_ptr().add(p * NR));
        let apk = ap.as_ptr().add(p * MR);
        for (r, row) in rows.iter_mut().enumerate() {
            let ar = _mm256_set1_ps(*apk.add(r));
            *row = _mm256_add_ps(*row, _mm256_mul_ps(ar, bv));
        }
    }
    for (r, row) in rows.iter().enumerate() {
        _mm256_storeu_ps(acc.as_mut_ptr().add(r * NR), *row);
    }
}

/// AVX2 bf16-panel tile: load 8 stored u16, zero-extend to 32 bits and
/// shift into the f32 high half (the exact widening), then the same
/// mul+add accumulation as [`microkernel_avx2`] — bit-identical to the
/// scalar [`microkernel_bf16`].
///
/// # Safety
/// As [`microkernel_avx2`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn microkernel_bf16_avx2(
    kc: usize,
    ap: &[f32],
    bp: &[u16],
    acc: &mut [f32; MR * NR],
) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    let mut rows = [_mm256_setzero_ps(); MR];
    for (r, row) in rows.iter_mut().enumerate() {
        *row = _mm256_loadu_ps(acc.as_ptr().add(r * NR));
    }
    for p in 0..kc {
        let raw = _mm_loadu_si128(bp.as_ptr().add(p * NR) as *const __m128i);
        let bv = _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_cvtepu16_epi32(raw), 16));
        let apk = ap.as_ptr().add(p * MR);
        for (r, row) in rows.iter_mut().enumerate() {
            let ar = _mm256_set1_ps(*apk.add(r));
            *row = _mm256_add_ps(*row, _mm256_mul_ps(ar, bv));
        }
    }
    for (r, row) in rows.iter().enumerate() {
        _mm256_storeu_ps(acc.as_mut_ptr().add(r * NR), *row);
    }
}

/// C = A · B over a pre-packed B, serial, through the dispatched
/// microkernel.  `apack` is caller scratch of at least
/// [`apack_len`]`(m, bp.k)` elements (pooled on the hot path); `simd` is
/// the level resolved once at engine/pool construction.
///
/// Per C element the k-summation is ascending regardless of tiling, so
/// the result is identical for any row chunking *and any f32 SIMD level*
/// (and bit-stable across repeat calls — the property the pooled solve
/// tests assert).
pub fn gemm_packed(
    a: &[f32],
    bp: &PackedB,
    m: usize,
    c: &mut [f32],
    apack: &mut [f32],
    simd: SimdLevel,
) {
    let (k, n) = (bp.k, bp.n);
    assert_eq!(a.len(), m * k, "gemm_packed: A len");
    assert_eq!(c.len(), m * n, "gemm_packed: C len");
    if m == 0 || n == 0 {
        return;
    }
    c.fill(0.0);
    if k == 0 {
        return;
    }
    assert!(apack.len() >= apack_len(m, k), "gemm_packed: apack scratch too small");
    let nstrips = n.div_ceil(NR);
    let strips_per_group = NC / NR;
    let nblocks = m.div_ceil(MR);
    let mut acc = [0.0f32; MR * NR];
    for p0 in (0..k).step_by(KC) {
        let kc = (p0 + KC).min(k) - p0;
        pack_a(a, k, m, p0, kc, apack);
        for sg0 in (0..nstrips).step_by(strips_per_group) {
            let sg1 = (sg0 + strips_per_group).min(nstrips);
            for ib in 0..nblocks {
                let i0 = ib * MR;
                let rh = MR.min(m - i0);
                let ap = &apack[ib * kc * MR..(ib + 1) * kc * MR];
                for s in sg0..sg1 {
                    let j0 = s * NR;
                    let jw = NR.min(n - j0);
                    acc.fill(0.0);
                    bp.microkernel_at(p0, kc, s, ap, &mut acc, simd);
                    for r in 0..rh {
                        let crow = &mut c[(i0 + r) * n + j0..(i0 + r) * n + j0 + jw];
                        for (cv, av) in crow.iter_mut().zip(&acc[r * NR..r * NR + jw]) {
                            *cv += av;
                        }
                    }
                }
            }
        }
    }
}

/// [`gemm_packed`] parallelized over contiguous row chunks of C through a
/// persistent [`WorkerPool`] — one job per chunk, each with its own
/// A-pack scratch from `apacks` (at least `ceil(m / ceil(m/chunks))`
/// buffers, each of [`apack_len`]`(rows_per_chunk, bp.k)` elements).
/// Results are identical to the serial call for any chunk count.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn gemm_packed_chunked(
    a: &[f32],
    bp: &PackedB,
    m: usize,
    c: &mut [f32],
    chunks: usize,
    pool: &WorkerPool,
    apacks: &mut [Vec<f32>],
    simd: SimdLevel,
) {
    let (k, n) = (bp.k, bp.n);
    assert_eq!(a.len(), m * k, "gemm_packed_chunked: A len");
    assert_eq!(c.len(), m * n, "gemm_packed_chunked: C len");
    if m == 0 || n == 0 {
        return;
    }
    let chunks = chunks.clamp(1, m);
    let rows_per = m.div_ceil(chunks);
    let nchunks = m.div_ceil(rows_per);
    assert!(apacks.len() >= nchunks, "gemm_packed_chunked: scratch count");
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nchunks);
    for ((ti, c_chunk), apack) in
        c.chunks_mut(rows_per * n).enumerate().zip(apacks.iter_mut())
    {
        let rows = c_chunk.len() / n;
        let a_chunk = &a[ti * rows_per * k..ti * rows_per * k + rows * k];
        tasks.push(Box::new(move || {
            gemm_packed(a_chunk, bp, rows, c_chunk, apack, simd)
        }));
    }
    pool.run(tasks);
}

/// The whole DEQ cell over a packed weight matrix, for a contiguous
/// panel of `rows` samples:
///
///   f = tanh(Z Wᵖ + b + X),  res[s] = ‖f_s − z_s‖₂,  fnorm[s] = ‖f_s‖₂
///
/// — the packed twin of `kernels::cell_batch`, with the GEMM epilogue
/// (bias + skip + tanh + both norms) fused into one pass over f.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn cell_rows_packed(
    bp: &PackedB,
    bias: &[f32],
    z: &[f32],
    x: &[f32],
    rows: usize,
    n: usize,
    f: &mut [f32],
    res: &mut [f32],
    fnorm: &mut [f32],
    apack: &mut [f32],
    simd: SimdLevel,
) {
    debug_assert_eq!(bp.k, n);
    debug_assert_eq!(bp.n, n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(z.len(), rows * n);
    debug_assert_eq!(x.len(), rows * n);
    debug_assert_eq!(f.len(), rows * n);
    debug_assert_eq!(res.len(), rows);
    debug_assert_eq!(fnorm.len(), rows);
    gemm_packed(z, bp, rows, f, apack, simd);
    for s in 0..rows {
        let zs = &z[s * n..(s + 1) * n];
        let xs = &x[s * n..(s + 1) * n];
        let fs = &mut f[s * n..(s + 1) * n];
        let mut num = 0.0f32;
        let mut den = 0.0f32;
        for j in 0..n {
            let v = (fs[j] + bias[j] + xs[j]).tanh();
            fs[j] = v;
            let d = v - zs[j];
            num += d * d;
            den += v * v;
        }
        res[s] = num.sqrt();
        fnorm[s] = den.sqrt();
    }
}

/// [`cell_rows_packed`] parallelized over sample chunks through the
/// pool; `apacks` as in [`gemm_packed_chunked`] (sized for
/// `rows_per_chunk`).  Chunk boundaries never change any sample's
/// arithmetic, so results match the serial call exactly.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn cell_batch_packed(
    bp: &PackedB,
    bias: &[f32],
    z: &[f32],
    x: &[f32],
    batch: usize,
    n: usize,
    f: &mut [f32],
    res: &mut [f32],
    fnorm: &mut [f32],
    chunks: usize,
    pool: Option<&WorkerPool>,
    apacks: &mut [Vec<f32>],
    simd: SimdLevel,
) {
    if batch == 0 || n == 0 {
        return;
    }
    let chunks = chunks.clamp(1, batch);
    let (pool, chunks) = match pool {
        Some(p) if chunks > 1 => (p, chunks),
        _ => {
            assert!(
                !apacks.is_empty()
                    && apacks[0].len() >= apack_len(batch, n),
                "cell_batch_packed: serial fallback needs one apack of \
                 apack_len(batch, n)"
            );
            cell_rows_packed(
                bp, bias, z, x, batch, n, f, res, fnorm, &mut apacks[0], simd,
            );
            return;
        }
    };
    let rows_per = batch.div_ceil(chunks);
    let nchunks = batch.div_ceil(rows_per);
    assert!(apacks.len() >= nchunks, "cell_batch_packed: scratch count");
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nchunks);
    let iter = f
        .chunks_mut(rows_per * n)
        .zip(res.chunks_mut(rows_per))
        .zip(fnorm.chunks_mut(rows_per))
        .zip(apacks.iter_mut())
        .enumerate();
    for (ti, (((f_c, res_c), fn_c), apack)) in iter {
        let rows = res_c.len();
        let z_c = &z[ti * rows_per * n..ti * rows_per * n + rows * n];
        let x_c = &x[ti * rows_per * n..ti * rows_per * n + rows * n];
        tasks.push(Box::new(move || {
            cell_rows_packed(
                bp, bias, z_c, x_c, rows, n, f_c, res_c, fn_c, apack, simd,
            )
        }));
    }
    pool.run(tasks);
}

/// Standalone microkernel GEMM: packs B fresh (no cache), allocates its
/// own scratch and resolves the SIMD level from the environment — the
/// un-cached convenience entry for tests, benches and callers outside
/// the engine's pack cache.  Hot paths latch a [`SimdLevel`] once and
/// call [`gemm_packed`]/[`gemm_packed_chunked`] instead.
pub fn gemm_micro(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_micro_with(a, b, m, k, n, c, 1, None, SimdLevel::from_env());
}

/// [`gemm_micro`] with an explicit chunk count, pool and SIMD level —
/// the deterministic serial-vs-parallel and scalar-vs-SIMD test surface
/// (chunking, not worker count, fixes the partition, so any pool size
/// gives the same split).
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn gemm_micro_with(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    chunks: usize,
    pool: Option<&WorkerPool>,
    simd: SimdLevel,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let bp = PackedB::pack(b, k, n);
    match pool {
        Some(p) if chunks > 1 && m > 1 => {
            let chunks = chunks.clamp(1, m);
            let rows_per = m.div_ceil(chunks);
            let nchunks = m.div_ceil(rows_per);
            let mut apacks: Vec<Vec<f32>> =
                (0..nchunks).map(|_| vec![0.0; apack_len(rows_per, k)]).collect();
            gemm_packed_chunked(a, &bp, m, c, chunks, p, &mut apacks, simd);
        }
        _ => {
            let mut apack = vec![0.0; apack_len(m, k)];
            gemm_packed(a, &bp, m, c, &mut apack, simd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::kernels::gemm_reference;
    use crate::util::rng::Rng;

    fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn packed_matches_reference_on_tile_straddling_shapes() {
        let mut rng = Rng::new(50);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (MR - 1, 5, NR - 1),
            (MR + 1, 7, NR + 1),
            (17, KC + 3, 2 * NR + 3),
            (2 * MR, 31, NC + NR + 1),
            (64, 64, 64),
        ] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_reference(&a, &b, m, k, n, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm_micro(&a, &b, m, k, n, &mut got);
            // Same ascending-k accumulation order as the reference: only
            // codegen-level rounding (if any) separates them.
            close(&got, &want, 1e-5 * (k as f32).sqrt(), "gemm_micro");
        }
    }

    #[test]
    fn simd_levels_are_bit_identical_for_f32() {
        // The whole point of the mul+add (non-FMA) AVX2 kernel: both
        // levels sum the same k terms in the same order with the same
        // roundings, so f32 results match *bitwise* on every shape —
        // including ragged tiles that exercise the padded-edge loads.
        let mut rng = Rng::new(53);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (MR - 1, 5, NR - 1),
            (MR + 1, 7, NR + 1),
            (17, KC + 3, 2 * NR + 3),
            (64, 64, 64),
        ] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut scalar = vec![0.0f32; m * n];
            gemm_micro_with(&a, &b, m, k, n, &mut scalar, 1, None, SimdLevel::Scalar);
            let mut simd = vec![0.0f32; m * n];
            gemm_micro_with(
                &a, &b, m, k, n, &mut simd, 1, None, SimdLevel::detect(),
            );
            assert_eq!(simd, scalar, "({m},{k},{n}) diverged across SIMD levels");
        }
    }

    #[test]
    fn chunked_is_identical_to_serial() {
        let mut rng = Rng::new(51);
        let (m, k, n) = (29usize, 37usize, 23usize);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut serial = vec![0.0f32; m * n];
        gemm_micro(&a, &b, m, k, n, &mut serial);
        let pool = WorkerPool::new(3);
        for chunks in [2usize, 3, 5, 29] {
            let mut par = vec![0.0f32; m * n];
            gemm_micro_with(
                &a, &b, m, k, n, &mut par, chunks, Some(&pool),
                SimdLevel::from_env(),
            );
            assert_eq!(par, serial, "chunks={chunks} diverged bitwise");
        }
    }

    #[test]
    fn degenerate_dims() {
        let mut c = vec![9.0f32; 6];
        gemm_micro(&[], &[], 2, 0, 3, &mut c);
        assert_eq!(c, vec![0.0; 6], "k = 0 must zero C");
        gemm_micro(&[], &[1.0, 2.0], 0, 1, 2, &mut []);
        gemm_micro(&[1.0, 2.0], &[], 2, 1, 0, &mut []);
    }

    #[test]
    fn bf16_conversion_rounds_to_nearest_even_and_keeps_nan() {
        // Exactly representable values survive the round-trip.
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.15625] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v, "{v} not preserved");
        }
        // 1.0 + 2^-9 sits exactly halfway between bf16(1.0) and the next
        // step 1.0 + 2^-8: nearest-even rounds *down* to 1.0.
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 2f32.powi(-9))), 1.0);
        // 1.0 + 3·2^-9 is halfway to the odd side: rounds *up* to
        // 1.0 + 2^-7.
        assert_eq!(
            bf16_to_f32(f32_to_bf16(1.0 + 3.0 * 2f32.powi(-9))),
            1.0 + 2f32.powi(-7)
        );
        // Anything above the halfway point rounds up.
        assert_eq!(
            bf16_to_f32(f32_to_bf16(1.0 + 2f32.powi(-9) + 2f32.powi(-12))),
            1.0 + 2f32.powi(-8)
        );
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn bf16_pack_halves_bytes_and_stays_close_to_f32() {
        let mut rng = Rng::new(54);
        let (m, k, n) = (17usize, 33usize, NR * 2 + 3);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let bp32 = PackedB::pack(&b, k, n);
        let bp16 = PackedB::pack_with(&b, k, n, PackPrecision::Bf16);
        assert_eq!(bp32.precision(), PackPrecision::F32);
        assert_eq!(bp16.precision(), PackPrecision::Bf16);
        assert_eq!(bp16.packed_len(), bp32.packed_len());
        assert_eq!(bp16.packed_bytes() * 2, bp32.packed_bytes());

        let mut apack = vec![0.0f32; apack_len(m, k)];
        let mut c32 = vec![0.0f32; m * n];
        gemm_packed(&a, &bp32, m, &mut c32, &mut apack, SimdLevel::Scalar);
        for simd in [SimdLevel::Scalar, SimdLevel::detect()] {
            let mut c16 = vec![0.0f32; m * n];
            gemm_packed(&a, &bp16, m, &mut c16, &mut apack, simd);
            // bf16 keeps 8 mantissa bits ⇒ each B element moves by at
            // most a 2^-8 relative step; k random-sign terms accumulate
            // ~sqrt(k) of that (documented tolerance, same as the
            // integration sweep in tests/native_kernels.rs).
            close(&c16, &c32, 0.02 * (k as f32).sqrt(), "bf16 gemm");
        }
        // And the two bf16 kernels agree bitwise (widening is exact).
        let mut scalar16 = vec![0.0f32; m * n];
        gemm_packed(&a, &bp16, m, &mut scalar16, &mut apack, SimdLevel::Scalar);
        let mut simd16 = vec![0.0f32; m * n];
        gemm_packed(&a, &bp16, m, &mut simd16, &mut apack, SimdLevel::detect());
        assert_eq!(simd16, scalar16);
    }

    #[test]
    fn simd_knob_resolution_is_pure_and_capped_by_detection() {
        use SimdLevel::*;
        for detected in [Scalar, Avx2] {
            assert_eq!(SimdLevel::resolve(Some("off"), detected), Scalar);
            assert_eq!(SimdLevel::resolve(Some("scalar"), detected), Scalar);
            assert_eq!(SimdLevel::resolve(Some(" OFF "), detected), Scalar);
            // Forcing avx2 can never exceed what the CPU reports.
            assert_eq!(SimdLevel::resolve(Some("avx2"), detected), detected);
            assert_eq!(SimdLevel::resolve(None, detected), detected);
            assert_eq!(SimdLevel::resolve(Some("???"), detected), detected);
        }
        assert_eq!(PackPrecision::resolve(Some("bf16")), PackPrecision::Bf16);
        assert_eq!(PackPrecision::resolve(Some(" BF16 ")), PackPrecision::Bf16);
        assert_eq!(PackPrecision::resolve(Some("f32")), PackPrecision::F32);
        assert_eq!(PackPrecision::resolve(None), PackPrecision::F32);
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
        assert_eq!(PackPrecision::Bf16.name(), "bf16");
    }

    #[test]
    fn cell_rows_packed_matches_cell_batch() {
        let mut rng = Rng::new(52);
        let (batch, n) = (5usize, 19usize);
        let w = rng.normal_vec(n * n, 0.3);
        let bias = rng.normal_vec(n, 0.1);
        let z = rng.normal_vec(batch * n, 1.0);
        let x = rng.normal_vec(batch * n, 1.0);
        let mut f_want = vec![0.0f32; batch * n];
        let mut res_want = vec![0.0f32; batch];
        let mut fn_want = vec![0.0f32; batch];
        crate::native::kernels::cell_batch(
            &w, &bias, &z, &x, batch, n, &mut f_want, &mut res_want, &mut fn_want,
        );
        let simd = SimdLevel::from_env();
        let bp = PackedB::pack(&w, n, n);
        let mut apack = vec![0.0f32; apack_len(batch, n)];
        let mut f = vec![0.0f32; batch * n];
        let mut res = vec![0.0f32; batch];
        let mut fnorm = vec![0.0f32; batch];
        cell_rows_packed(
            &bp, &bias, &z, &x, batch, n, &mut f, &mut res, &mut fnorm,
            &mut apack, simd,
        );
        close(&f, &f_want, 1e-5, "cell f");
        close(&res, &res_want, 1e-5, "cell res");
        close(&fnorm, &fn_want, 1e-5, "cell fnorm");

        // The pool-chunked variant is bit-identical to the serial one.
        let pool = WorkerPool::new(2);
        let mut apacks: Vec<Vec<f32>> =
            (0..3).map(|_| vec![0.0f32; apack_len(2, n)]).collect();
        let mut f2 = vec![0.0f32; batch * n];
        let mut res2 = vec![0.0f32; batch];
        let mut fn2 = vec![0.0f32; batch];
        cell_batch_packed(
            &bp, &bias, &z, &x, batch, n, &mut f2, &mut res2, &mut fn2, 3,
            Some(&pool), &mut apacks, simd,
        );
        assert_eq!(f2, f);
        assert_eq!(res2, res);
        assert_eq!(fn2, fnorm);
    }

    #[test]
    fn packed_b_layout_roundtrips() {
        // A recognizable matrix: B[p][j] = p * 100 + j, shapes that leave
        // both a ragged strip and (with a tiny KC this test can't change)
        // at least full coverage of the padding path.
        let (k, n) = (5usize, NR + 3);
        let b: Vec<f32> =
            (0..k * n).map(|i| ((i / n) * 100 + i % n) as f32).collect();
        let bp = PackedB::pack(&b, k, n);
        assert_eq!(bp.packed_len(), k * n.div_ceil(NR) * NR);
        // An identity A of m = k rows reproduces B through the kernel.
        let mut a = vec![0.0f32; k * k];
        for i in 0..k {
            a[i * k + i] = 1.0;
        }
        let mut c = vec![0.0f32; k * n];
        let mut apack = vec![0.0f32; apack_len(k, k)];
        gemm_packed(&a, &bp, k, &mut c, &mut apack, SimdLevel::from_env());
        assert_eq!(c, b, "identity × B must reproduce B exactly");
    }
}

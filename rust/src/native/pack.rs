//! Packed-panel, register-blocked microkernel GEMM — the compute core of
//! the paper's "fewer, more compute-intensive but generally *cacheable*
//! iterations" thesis.
//!
//! Every Anderson iteration re-applies the **same** weight matrices, so
//! the dominant GEMM cost splits into two very different halves:
//!
//!   * **B (weights)**: identical across iterations (and across lanes in
//!     continuous batching).  [`PackedB`] reorders a weight matrix once
//!     into microkernel-ready [`NR`]-wide column strips, padded and
//!     contiguous, so the inner loop streams it with unit stride and no
//!     edge branches.  The engine caches one `PackedB` per weight matrix
//!     (see `NativeEngine`'s pack cache), keyed by the parameter version
//!     counter from [`crate::model::params`] — steady-state iterations do
//!     **zero** weight packing.
//!   * **A (activations)**: fresh every iteration.  `pack_a` repacks
//!     the current panel into [`MR`]-tall column-major strips in caller
//!     scratch (workspace-pooled on the engine path), an O(m·k) copy that
//!     buys the O(m·k·n) loop perfect access patterns.
//!
//! The inner loop is an [`MR`]×[`NR`] (8×8) register tile: 64 scalar
//! accumulators the compiler keeps in vector registers, updated by
//! unrolled multiply-adds over the packed panels — a portable, safe-Rust
//! microkernel that vectorizes on any target without `std::simd` (the
//! scalar code *is* the fallback; on AVX the 8-wide rows map directly to
//! one register each).  Accumulation order over k is ascending for every
//! C element, exactly like `kernels::gemm_reference`, so results are
//! independent of the row-chunking used for parallelism.
//!
//! Parallelism comes from a [`WorkerPool`] (no per-call thread spawns):
//! rows of C are split into contiguous chunks, one job per chunk, each
//! with its own A-pack scratch and a disjoint `&mut` slice of C.

use crate::native::pool::WorkerPool;

/// Microkernel tile height (rows of C per register tile).
pub const MR: usize = 8;
/// Microkernel tile width (columns of C per register tile).
pub const NR: usize = 8;
/// k-dimension cache block: one `KC`×[`NR`] B strip plus an `MR`×`KC`
/// A strip stay cache-resident through a full tile update.
pub const KC: usize = 256;
/// n-dimension cache block (must be a multiple of [`NR`]): bounds the
/// set of B strips walked per A panel so they stay L2-resident.
pub const NC: usize = 512;

/// A weight matrix (k, n) repacked for the microkernel: for each k-tile
/// of height ≤ [`KC`], the columns are laid out in [`NR`]-wide strips,
/// row-major *within* the strip (`strip[p * NR + c] = B[p0 + p][j0 + c]`),
/// zero-padded in the tail strip.  Pack once, stream forever.
#[derive(Debug, Clone)]
pub struct PackedB {
    /// Rows of the original matrix (the GEMM k dimension).
    pub k: usize,
    /// Columns of the original matrix (the GEMM n dimension).
    pub n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack a row-major (k, n) matrix.  O(k·n) copy; the engine amortizes
    /// it across every subsequent iteration via its pack cache.
    pub fn pack(b: &[f32], k: usize, n: usize) -> Self {
        assert_eq!(b.len(), k * n, "PackedB::pack: data/shape mismatch");
        let nstrips = n.div_ceil(NR);
        let mut data = vec![0.0f32; k * nstrips * NR];
        let mut off = 0;
        for p0 in (0..k).step_by(KC) {
            let kc = (p0 + KC).min(k) - p0;
            for s in 0..nstrips {
                let j0 = s * NR;
                let jw = NR.min(n - j0);
                for p in 0..kc {
                    let src = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jw];
                    data[off + p * NR..off + p * NR + jw].copy_from_slice(src);
                }
                off += kc * NR;
            }
        }
        Self { k, n, data }
    }

    /// Packed bytes (for stats / bench reporting).
    pub fn packed_len(&self) -> usize {
        self.data.len()
    }

    /// The [`NR`]-wide strip `s` of the k-tile starting at row `p0`
    /// (which has height `kc`).
    #[inline]
    fn strip(&self, p0: usize, kc: usize, s: usize) -> &[f32] {
        // Tiles before p0 hold p0 full rows of n.div_ceil(NR) strips.
        let base = p0 * self.n.div_ceil(NR) * NR + s * kc * NR;
        &self.data[base..base + kc * NR]
    }
}

/// Length of the A-pack scratch [`gemm_packed`] needs for an `m`-row
/// panel against a k-dimension of `k`.  Never zero, so workspace pools
/// can serve it unconditionally.
pub fn apack_len(m: usize, k: usize) -> usize {
    (m.div_ceil(MR) * MR * KC.min(k)).max(1)
}

/// Repack rows `0..rows` of row-major A (leading dimension `lda`),
/// k-columns `p0..p0+kc`, into [`MR`]-tall column-major strips:
/// `block[p * MR + r] = A[r0 + r][p0 + p]`, tail rows zero-padded.
fn pack_a(a: &[f32], lda: usize, rows: usize, p0: usize, kc: usize, apack: &mut [f32]) {
    let nblocks = rows.div_ceil(MR);
    debug_assert!(apack.len() >= nblocks * kc * MR);
    for ib in 0..nblocks {
        let r0 = ib * MR;
        let rh = MR.min(rows - r0);
        let dst = &mut apack[ib * kc * MR..(ib + 1) * kc * MR];
        if rh < MR {
            dst.fill(0.0); // zero-pad the tail block once
        }
        for r in 0..rh {
            let arow = &a[(r0 + r) * lda + p0..(r0 + r) * lda + p0 + kc];
            for (p, &v) in arow.iter().enumerate() {
                dst[p * MR + r] = v;
            }
        }
    }
}

/// The 8×8 register tile: 64 accumulators updated by unrolled
/// multiply-adds over one packed A block and one packed B strip.  The
/// two inner loops are fixed-trip (`MR`, `NR`) over contiguous slices,
/// which is exactly the shape LLVM turns into broadcast+FMA vector code;
/// on targets without SIMD the same loop *is* the scalar fallback.
#[inline]
fn microkernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    for (arow, brow) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(kc) {
        for (&ar, accrow) in arow.iter().zip(acc.chunks_exact_mut(NR)) {
            for (av, bv) in accrow.iter_mut().zip(brow) {
                *av += ar * bv;
            }
        }
    }
}

/// C = A · B over a pre-packed B, serial.  `apack` is caller scratch of
/// at least [`apack_len`]`(m, bp.k)` elements (pooled on the hot path).
///
/// Per C element the k-summation is ascending regardless of tiling, so
/// the result is identical for any row chunking (and bit-stable across
/// repeat calls — the property the pooled solve tests assert).
pub fn gemm_packed(a: &[f32], bp: &PackedB, m: usize, c: &mut [f32], apack: &mut [f32]) {
    let (k, n) = (bp.k, bp.n);
    assert_eq!(a.len(), m * k, "gemm_packed: A len");
    assert_eq!(c.len(), m * n, "gemm_packed: C len");
    if m == 0 || n == 0 {
        return;
    }
    c.fill(0.0);
    if k == 0 {
        return;
    }
    assert!(apack.len() >= apack_len(m, k), "gemm_packed: apack scratch too small");
    let nstrips = n.div_ceil(NR);
    let strips_per_group = NC / NR;
    let nblocks = m.div_ceil(MR);
    let mut acc = [0.0f32; MR * NR];
    for p0 in (0..k).step_by(KC) {
        let kc = (p0 + KC).min(k) - p0;
        pack_a(a, k, m, p0, kc, apack);
        for sg0 in (0..nstrips).step_by(strips_per_group) {
            let sg1 = (sg0 + strips_per_group).min(nstrips);
            for ib in 0..nblocks {
                let i0 = ib * MR;
                let rh = MR.min(m - i0);
                let ap = &apack[ib * kc * MR..(ib + 1) * kc * MR];
                for s in sg0..sg1 {
                    let bstrip = bp.strip(p0, kc, s);
                    let j0 = s * NR;
                    let jw = NR.min(n - j0);
                    acc.fill(0.0);
                    microkernel(kc, ap, bstrip, &mut acc);
                    for r in 0..rh {
                        let crow = &mut c[(i0 + r) * n + j0..(i0 + r) * n + j0 + jw];
                        for (cv, av) in crow.iter_mut().zip(&acc[r * NR..r * NR + jw]) {
                            *cv += av;
                        }
                    }
                }
            }
        }
    }
}

/// [`gemm_packed`] parallelized over contiguous row chunks of C through a
/// persistent [`WorkerPool`] — one job per chunk, each with its own
/// A-pack scratch from `apacks` (at least `ceil(m / ceil(m/chunks))`
/// buffers, each of [`apack_len`]`(rows_per_chunk, bp.k)` elements).
/// Results are identical to the serial call for any chunk count.
pub fn gemm_packed_chunked(
    a: &[f32],
    bp: &PackedB,
    m: usize,
    c: &mut [f32],
    chunks: usize,
    pool: &WorkerPool,
    apacks: &mut [Vec<f32>],
) {
    let (k, n) = (bp.k, bp.n);
    assert_eq!(a.len(), m * k, "gemm_packed_chunked: A len");
    assert_eq!(c.len(), m * n, "gemm_packed_chunked: C len");
    if m == 0 || n == 0 {
        return;
    }
    let chunks = chunks.clamp(1, m);
    let rows_per = m.div_ceil(chunks);
    let nchunks = m.div_ceil(rows_per);
    assert!(apacks.len() >= nchunks, "gemm_packed_chunked: scratch count");
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nchunks);
    for ((ti, c_chunk), apack) in
        c.chunks_mut(rows_per * n).enumerate().zip(apacks.iter_mut())
    {
        let rows = c_chunk.len() / n;
        let a_chunk = &a[ti * rows_per * k..ti * rows_per * k + rows * k];
        tasks.push(Box::new(move || {
            gemm_packed(a_chunk, bp, rows, c_chunk, apack)
        }));
    }
    pool.run(tasks);
}

/// The whole DEQ cell over a packed weight matrix, for a contiguous
/// panel of `rows` samples:
///
///   f = tanh(Z Wᵖ + b + X),  res[s] = ‖f_s − z_s‖₂,  fnorm[s] = ‖f_s‖₂
///
/// — the packed twin of `kernels::cell_batch`, with the GEMM epilogue
/// (bias + skip + tanh + both norms) fused into one pass over f.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn cell_rows_packed(
    bp: &PackedB,
    bias: &[f32],
    z: &[f32],
    x: &[f32],
    rows: usize,
    n: usize,
    f: &mut [f32],
    res: &mut [f32],
    fnorm: &mut [f32],
    apack: &mut [f32],
) {
    debug_assert_eq!(bp.k, n);
    debug_assert_eq!(bp.n, n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(z.len(), rows * n);
    debug_assert_eq!(x.len(), rows * n);
    debug_assert_eq!(f.len(), rows * n);
    debug_assert_eq!(res.len(), rows);
    debug_assert_eq!(fnorm.len(), rows);
    gemm_packed(z, bp, rows, f, apack);
    for s in 0..rows {
        let zs = &z[s * n..(s + 1) * n];
        let xs = &x[s * n..(s + 1) * n];
        let fs = &mut f[s * n..(s + 1) * n];
        let mut num = 0.0f32;
        let mut den = 0.0f32;
        for j in 0..n {
            let v = (fs[j] + bias[j] + xs[j]).tanh();
            fs[j] = v;
            let d = v - zs[j];
            num += d * d;
            den += v * v;
        }
        res[s] = num.sqrt();
        fnorm[s] = den.sqrt();
    }
}

/// [`cell_rows_packed`] parallelized over sample chunks through the
/// pool; `apacks` as in [`gemm_packed_chunked`] (sized for
/// `rows_per_chunk`).  Chunk boundaries never change any sample's
/// arithmetic, so results match the serial call exactly.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn cell_batch_packed(
    bp: &PackedB,
    bias: &[f32],
    z: &[f32],
    x: &[f32],
    batch: usize,
    n: usize,
    f: &mut [f32],
    res: &mut [f32],
    fnorm: &mut [f32],
    chunks: usize,
    pool: Option<&WorkerPool>,
    apacks: &mut [Vec<f32>],
) {
    if batch == 0 || n == 0 {
        return;
    }
    let chunks = chunks.clamp(1, batch);
    let (pool, chunks) = match pool {
        Some(p) if chunks > 1 => (p, chunks),
        _ => {
            assert!(
                !apacks.is_empty()
                    && apacks[0].len() >= apack_len(batch, n),
                "cell_batch_packed: serial fallback needs one apack of \
                 apack_len(batch, n)"
            );
            cell_rows_packed(bp, bias, z, x, batch, n, f, res, fnorm, &mut apacks[0]);
            return;
        }
    };
    let rows_per = batch.div_ceil(chunks);
    let nchunks = batch.div_ceil(rows_per);
    assert!(apacks.len() >= nchunks, "cell_batch_packed: scratch count");
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nchunks);
    let iter = f
        .chunks_mut(rows_per * n)
        .zip(res.chunks_mut(rows_per))
        .zip(fnorm.chunks_mut(rows_per))
        .zip(apacks.iter_mut())
        .enumerate();
    for (ti, (((f_c, res_c), fn_c), apack)) in iter {
        let rows = res_c.len();
        let z_c = &z[ti * rows_per * n..ti * rows_per * n + rows * n];
        let x_c = &x[ti * rows_per * n..ti * rows_per * n + rows * n];
        tasks.push(Box::new(move || {
            cell_rows_packed(bp, bias, z_c, x_c, rows, n, f_c, res_c, fn_c, apack)
        }));
    }
    pool.run(tasks);
}

/// Standalone microkernel GEMM: packs B fresh (no cache) and allocates
/// its own scratch — the un-cached entry for tests, benches and callers
/// outside the engine's pack cache.
pub fn gemm_micro(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_micro_with(a, b, m, k, n, c, 1, None);
}

/// [`gemm_micro`] with an explicit chunk count and pool — the
/// deterministic serial-vs-parallel test surface (chunking, not worker
/// count, fixes the partition, so any pool size gives the same split).
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn gemm_micro_with(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    chunks: usize,
    pool: Option<&WorkerPool>,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let bp = PackedB::pack(b, k, n);
    match pool {
        Some(p) if chunks > 1 && m > 1 => {
            let chunks = chunks.clamp(1, m);
            let rows_per = m.div_ceil(chunks);
            let nchunks = m.div_ceil(rows_per);
            let mut apacks: Vec<Vec<f32>> =
                (0..nchunks).map(|_| vec![0.0; apack_len(rows_per, k)]).collect();
            gemm_packed_chunked(a, &bp, m, c, chunks, p, &mut apacks);
        }
        _ => {
            let mut apack = vec![0.0; apack_len(m, k)];
            gemm_packed(a, &bp, m, c, &mut apack);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::kernels::gemm_reference;
    use crate::util::rng::Rng;

    fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn packed_matches_reference_on_tile_straddling_shapes() {
        let mut rng = Rng::new(50);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (MR - 1, 5, NR - 1),
            (MR + 1, 7, NR + 1),
            (17, KC + 3, 2 * NR + 3),
            (2 * MR, 31, NC + NR + 1),
            (64, 64, 64),
        ] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_reference(&a, &b, m, k, n, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm_micro(&a, &b, m, k, n, &mut got);
            // Same ascending-k accumulation order as the reference: only
            // codegen-level rounding (if any) separates them.
            close(&got, &want, 1e-5 * (k as f32).sqrt(), "gemm_micro");
        }
    }

    #[test]
    fn chunked_is_identical_to_serial() {
        let mut rng = Rng::new(51);
        let (m, k, n) = (29usize, 37usize, 23usize);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut serial = vec![0.0f32; m * n];
        gemm_micro(&a, &b, m, k, n, &mut serial);
        let pool = WorkerPool::new(3);
        for chunks in [2usize, 3, 5, 29] {
            let mut par = vec![0.0f32; m * n];
            gemm_micro_with(&a, &b, m, k, n, &mut par, chunks, Some(&pool));
            assert_eq!(par, serial, "chunks={chunks} diverged bitwise");
        }
    }

    #[test]
    fn degenerate_dims() {
        let mut c = vec![9.0f32; 6];
        gemm_micro(&[], &[], 2, 0, 3, &mut c);
        assert_eq!(c, vec![0.0; 6], "k = 0 must zero C");
        gemm_micro(&[], &[1.0, 2.0], 0, 1, 2, &mut []);
        gemm_micro(&[1.0, 2.0], &[], 2, 1, 0, &mut []);
    }

    #[test]
    fn cell_rows_packed_matches_cell_batch() {
        let mut rng = Rng::new(52);
        let (batch, n) = (5usize, 19usize);
        let w = rng.normal_vec(n * n, 0.3);
        let bias = rng.normal_vec(n, 0.1);
        let z = rng.normal_vec(batch * n, 1.0);
        let x = rng.normal_vec(batch * n, 1.0);
        let mut f_want = vec![0.0f32; batch * n];
        let mut res_want = vec![0.0f32; batch];
        let mut fn_want = vec![0.0f32; batch];
        crate::native::kernels::cell_batch(
            &w, &bias, &z, &x, batch, n, &mut f_want, &mut res_want, &mut fn_want,
        );
        let bp = PackedB::pack(&w, n, n);
        let mut apack = vec![0.0f32; apack_len(batch, n)];
        let mut f = vec![0.0f32; batch * n];
        let mut res = vec![0.0f32; batch];
        let mut fnorm = vec![0.0f32; batch];
        cell_rows_packed(
            &bp, &bias, &z, &x, batch, n, &mut f, &mut res, &mut fnorm, &mut apack,
        );
        close(&f, &f_want, 1e-5, "cell f");
        close(&res, &res_want, 1e-5, "cell res");
        close(&fnorm, &fn_want, 1e-5, "cell fnorm");

        // The pool-chunked variant is bit-identical to the serial one.
        let pool = WorkerPool::new(2);
        let mut apacks: Vec<Vec<f32>> =
            (0..3).map(|_| vec![0.0f32; apack_len(2, n)]).collect();
        let mut f2 = vec![0.0f32; batch * n];
        let mut res2 = vec![0.0f32; batch];
        let mut fn2 = vec![0.0f32; batch];
        cell_batch_packed(
            &bp, &bias, &z, &x, batch, n, &mut f2, &mut res2, &mut fn2, 3,
            Some(&pool), &mut apacks,
        );
        assert_eq!(f2, f);
        assert_eq!(res2, res);
        assert_eq!(fn2, fnorm);
    }

    #[test]
    fn packed_b_layout_roundtrips() {
        // A recognizable matrix: B[p][j] = p * 100 + j, shapes that leave
        // both a ragged strip and (with a tiny KC this test can't change)
        // at least full coverage of the padding path.
        let (k, n) = (5usize, NR + 3);
        let b: Vec<f32> =
            (0..k * n).map(|i| ((i / n) * 100 + i % n) as f32).collect();
        let bp = PackedB::pack(&b, k, n);
        assert_eq!(bp.packed_len(), k * n.div_ceil(NR) * NR);
        // An identity A of m = k rows reproduces B through the kernel.
        let mut a = vec![0.0f32; k * k];
        for i in 0..k {
            a[i * k + i] = 1.0;
        }
        let mut c = vec![0.0f32; k * n];
        let mut apack = vec![0.0f32; apack_len(k, k)];
        gemm_packed(&a, &bp, k, &mut c, &mut apack);
        assert_eq!(c, b, "identity × B must reproduce B exactly");
    }
}

//! Synthetic fixed-point maps for the native solver: the workloads behind
//! the paper's "random input" residual studies (Fig. 6) and the property
//! tests.

use crate::native::anderson::FixedPointMap;
use crate::native::linalg;
use crate::util::rng::Rng;

/// Affine map f(z) = A z + b with controlled spectral radius.
///
/// `A = rho * Q / |λ_max(Q)|`: we draw a random matrix and scale by a
/// power-iteration estimate of its dominant eigenvalue magnitude, so the
/// spectral radius is ≈ `rho`.  Forward iteration then converges linearly
/// at asymptotic rate `rho`; Anderson accelerates like GMRES on (I - A).
pub struct AffineMap {
    n: usize,
    a: Vec<f32>, // (n, n)
    b: Vec<f32>,
}

impl AffineMap {
    pub fn random(n: usize, rho: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut a = rng.normal_vec(n * n, 1.0 / (n as f32).sqrt());
        // Power iteration on A itself → |λ_max| (the spectral radius for
        // a generic random matrix, whose dominant eigenvalue is simple).
        let mut v = rng.normal_vec(n, 1.0);
        let mut av = vec![0.0; n];
        let mut lam = 1.0f32;
        for _ in 0..200 {
            linalg::gemv(&a, &v, n, n, &mut av);
            lam = linalg::norm2(&av).max(1e-12);
            for (vi, ai) in v.iter_mut().zip(&av) {
                *vi = ai / lam;
            }
        }
        let scale = rho / lam;
        for x in a.iter_mut() {
            *x *= scale;
        }
        let b = rng.normal_vec(n, 1.0);
        Self { n, a, b }
    }
}

impl FixedPointMap for AffineMap {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&self, z: &[f32], out: &mut [f32]) {
        linalg::gemv(&self.a, z, self.n, self.n, out);
        linalg::axpy(1.0, &self.b, out);
    }

    /// z* = (I - A)⁻¹ b via dense Gaussian elimination (small n only).
    fn solution(&self) -> Option<Vec<f32>> {
        let n = self.n;
        if n > 256 {
            return None;
        }
        // Build I - A and solve with partial-pivot elimination.
        let mut m = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                m[i * n + j] = (i == j) as i32 as f32 - self.a[i * n + j];
            }
        }
        let mut rhs = self.b.clone();
        for col in 0..n {
            // pivot
            let mut piv = col;
            for r in (col + 1)..n {
                if m[r * n + col].abs() > m[piv * n + col].abs() {
                    piv = r;
                }
            }
            if m[piv * n + col].abs() < 1e-12 {
                return None;
            }
            if piv != col {
                for j in 0..n {
                    m.swap(col * n + j, piv * n + j);
                }
                rhs.swap(col, piv);
            }
            let d = m[col * n + col];
            for r in (col + 1)..n {
                let f = m[r * n + col] / d;
                if f == 0.0 {
                    continue;
                }
                for j in col..n {
                    m[r * n + j] -= f * m[col * n + j];
                }
                rhs[r] -= f * rhs[col];
            }
        }
        let mut x = vec![0.0f32; n];
        for i in (0..n).rev() {
            let mut s = rhs[i];
            for j in (i + 1)..n {
                s -= m[i * n + j] * x[j];
            }
            x[i] = s / m[i * n + i];
        }
        Some(x)
    }
}

/// Nonlinear contactive map f(z) = tanh(A z + b): smooth, contraction for
/// spectral radius < 1, exercises the solvers off the affine fast path.
pub struct TanhMap {
    inner: AffineMap,
}

impl TanhMap {
    pub fn random(n: usize, rho: f32, seed: u64) -> Self {
        Self { inner: AffineMap::random(n, rho, seed) }
    }
}

impl FixedPointMap for TanhMap {
    fn dim(&self) -> usize {
        self.inner.n
    }

    fn apply(&self, z: &[f32], out: &mut [f32]) {
        self.inner.apply(z, out);
        for v in out.iter_mut() {
            *v = v.tanh();
        }
    }
}

/// A "DEQ-like" map mimicking the cell's structure on the cheap:
/// f(z) = normalize(relu(W1 z) * W2-ish + x), with the normalization giving
/// the near-unit spectral radius behaviour of GroupNorm cells.  Used by the
/// device-model experiments at paper scale without paying XLA dispatch.
pub struct DeqLikeMap {
    n: usize,
    w1: Vec<f32>,
    w2: Vec<f32>,
    x: Vec<f32>,
    mix: f32,
}

impl DeqLikeMap {
    pub fn random(n: usize, mix: f32, seed: u64) -> Self {
        Self::with_gain(n, mix, 1.0, seed)
    }

    /// `gain` scales the second weight matrix: larger gain pushes the
    /// effective contraction factor toward 1, slowing forward iteration —
    /// the stiff regime where the paper's Fig. 6 comparison lives.
    pub fn with_gain(n: usize, mix: f32, gain: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let s = 1.0 / (n as f32).sqrt();
        Self {
            n,
            w1: rng.normal_vec(n * n, s),
            w2: rng.normal_vec(n * n, gain * s),
            x: rng.normal_vec(n, 1.0),
            mix,
        }
    }
}

impl FixedPointMap for DeqLikeMap {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&self, z: &[f32], out: &mut [f32]) {
        let n = self.n;
        let mut h = vec![0.0f32; n];
        linalg::gemv(&self.w1, z, n, n, &mut h);
        for v in h.iter_mut() {
            *v = v.max(0.0); // relu
        }
        linalg::gemv(&self.w2, &h, n, n, out);
        // inject input + soft normalization (keeps iterates bounded, like
        // the cell's GroupNorm)
        for i in 0..n {
            out[i] += self.x[i];
        }
        let nrm = linalg::norm2(out).max(1e-6);
        let target = (n as f32).sqrt();
        let g = self.mix * target / nrm + (1.0 - self.mix);
        for v in out.iter_mut() {
            *v *= g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::anderson::{solve_forward, AndersonOpts};

    #[test]
    fn affine_solution_is_fixed_point() {
        let map = AffineMap::random(20, 0.8, 11);
        let sol = map.solution().unwrap();
        let mut out = vec![0.0; 20];
        map.apply(&sol, &mut out);
        for (a, b) in out.iter().zip(&sol) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn affine_spectral_radius_bounded() {
        // Forward iteration must converge for rho < 1.
        let map = AffineMap::random(30, 0.6, 2);
        let tr = solve_forward(
            &map,
            &vec![0.0; 30],
            AndersonOpts { tol: 1e-5, max_iter: 200, ..Default::default() },
        );
        assert!(tr.converged, "residual={}", tr.final_residual());
    }

    #[test]
    fn tanh_map_contracts() {
        let map = TanhMap::random(16, 0.7, 9);
        let tr = solve_forward(
            &map,
            &vec![0.1; 16],
            AndersonOpts { tol: 1e-5, max_iter: 300, ..Default::default() },
        );
        assert!(tr.converged);
    }

    #[test]
    fn deq_like_stays_bounded() {
        let map = DeqLikeMap::random(32, 0.9, 4);
        let mut z = vec![0.0; 32];
        let mut out = vec![0.0; 32];
        for _ in 0..50 {
            map.apply(&z, &mut out);
            std::mem::swap(&mut z, &mut out);
        }
        let n = linalg::norm2(&z);
        assert!(n.is_finite() && n < 100.0, "norm={n}");
    }
}

//! Blocked, cache-tiled, multi-threaded compute kernels for the native
//! substrate — the "fast as the hardware allows" half of the hot path.
//!
//! The naive loops these replace (see [`gemm_reference`]) stream the
//! whole B matrix through cache for every row of A and run on one core.
//! Here the batch×latent matmuls that dominate `cell_step`, `encode` and
//! `classify` are:
//!
//!   * **tiled**: the k/j loops are blocked so a `KC`×`NC` panel of B
//!     stays cache-resident while a row panel of A streams through it;
//!   * **parallel**: above [`PAR_MIN_MACS`] multiply-accumulates, rows of
//!     C are partitioned into contiguous panels, one *pool job* per
//!     panel (disjoint `&mut` chunks — no locks; the persistent
//!     [`crate::native::pool::WorkerPool`] replaced the scoped-thread
//!     fan-out, so no thread is ever spawned per call);
//!   * **fused**: [`cell_batch`] runs the whole DEQ cell
//!     `f = tanh(z·W + b + x)` plus the per-sample residual norms in one
//!     pass over the output, so `cell_step` touches `f` exactly once.
//!
//! The blocked kernel here is the *uncached* path (and the bench
//! baseline); the engine's steady-state GEMMs run the packed microkernel
//! in [`crate::native::pack`] over cached weight packs instead.  That is
//! also where SIMD lives: the kernels below stay portable scalar loops
//! for the autovectorizer, while `pack` carries the explicit AVX2
//! microkernel behind runtime dispatch (`DEQ_NATIVE_SIMD`) plus the bf16
//! packed-panel precision mode (`DEQ_NATIVE_PRECISION`).
//!
//! Thread count comes from the `DEQ_NATIVE_THREADS` env knob (unset or
//! `0` → `available_parallelism`, capped at 8), read **at pool
//! construction** — the engine's pool at engine construction, the
//! process-wide [`crate::native::pool::shared_pool`] on its first
//! parallel call.  Small problems always run serial so the tiny CI
//! model never pays even a pool wakeup.

use crate::native::pool::shared_pool;

/// k-dimension tile: a KC-row slab of B is reused across a whole row
/// panel of A before moving on.
const KC: usize = 256;
/// n-dimension tile: KC×NC f32 of B ≈ 512 KiB upper bound, typically
/// L2-resident; the inner j loop stays contiguous over B and C.
const NC: usize = 512;
/// Below this many multiply-accumulates a parallel fan-out costs more
/// than it saves; run serial.  (The default test model's bucket-32
/// cell_step is 32·64·64 = 131k MACs — deliberately under this bound.)
pub const PAR_MIN_MACS: usize = 1 << 18;

/// Worker threads a freshly built pool should use.  `DEQ_NATIVE_THREADS=N`
/// pins it; unset or `0` means `available_parallelism` capped at 8.
///
/// Read from the environment on **every call** (the former process-wide
/// `OnceLock` memoization is gone): thread count is now injectable — the
/// engine reads this once when it constructs its own pool, and tests
/// build [`crate::native::pool::WorkerPool`]s of explicit sizes instead
/// of racing on the env knob.  The one remaining process-wide latch is
/// [`crate::native::pool::shared_pool`], whose *size* is fixed by the
/// env value at its first parallel use — engine pools and explicit
/// pools are unaffected.
pub fn max_threads() -> usize {
    match std::env::var("DEQ_NATIVE_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(0) | Err(_) => default_threads(),
            Ok(t) => t.min(64),
        },
        Err(_) => default_threads(),
    }
}

/// The number of parallel row chunks worth using for an (m, k, n) GEMM
/// given at most `max` workers: 1 below [`PAR_MIN_MACS`]
/// multiply-accumulates, else `max` clamped to the row count.  Pure
/// shape arithmetic — callers pass their pool's size, so the split (and
/// therefore the result's reduction tree) never depends on ambient env.
pub fn parallel_chunks(m: usize, k: usize, n: usize, max: usize) -> usize {
    let macs = m.saturating_mul(k).saturating_mul(n);
    if macs < PAR_MIN_MACS {
        1
    } else {
        max.min(m).max(1)
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1)
        .min(8)
}

fn threads_for(m: usize, k: usize, n: usize) -> usize {
    if m.saturating_mul(k).saturating_mul(n) < PAR_MIN_MACS {
        1
    } else {
        parallel_chunks(m, k, n, shared_pool().size())
    }
}

/// C = A B, A (m, k), B (k, n), C (m, n), all row-major.  Blocked and,
/// for large problems, parallel over row panels of C.
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_with_threads(a, b, m, k, n, c, threads_for(m, k, n));
}

/// [`gemm`] with an explicit chunk count — the parallel path is
/// deterministic (each job owns a disjoint row panel, and the panel
/// split depends only on `threads`, not on how many pool workers happen
/// to exist), so tests pin `threads` directly instead of racing on the
/// env knob.  Parallel chunks run as jobs on the persistent
/// [`shared_pool`] — no per-call thread spawns.
pub fn gemm_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let threads = threads.clamp(1, m);
    if threads == 1 {
        gemm_block(a, b, m, k, n, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (ti, c_panel) in c.chunks_mut(rows_per * n).enumerate() {
        let rows = c_panel.len() / n;
        let a_panel = &a[ti * rows_per * k..ti * rows_per * k + rows * k];
        tasks.push(Box::new(move || gemm_block(a_panel, b, rows, k, n, c_panel)));
    }
    shared_pool().run(tasks);
}

/// Serial cache-tiled macro-kernel: for each (k-tile, n-tile) of B, every
/// row of the A panel streams through the resident tile; the inner j loop
/// is contiguous over B and C, so it vectorizes.
fn gemm_block(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    c.fill(0.0);
    for p0 in (0..k).step_by(KC) {
        let pe = (p0 + KC).min(k);
        for j0 in (0..n).step_by(NC) {
            let je = (j0 + NC).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + je];
                for p in p0..pe {
                    let aip = arow[p];
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n + j0..p * n + je];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aip * bv;
                    }
                }
            }
        }
    }
}

/// The naive single-threaded ikj GEMM the blocked path replaced — kept
/// as the parity oracle for tests and the baseline for
/// `benches/native_kernels.rs`.
pub fn gemm_reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
}

/// y = A x, A (m, n) row-major; parallel over row panels for large A.
pub fn gemv(a: &[f32], x: &[f32], m: usize, n: usize, y: &mut [f32]) {
    gemv_with_threads(a, x, m, n, y, threads_for(m, n, 1));
}

/// [`gemv`] with an explicit thread count (see [`gemm_with_threads`]).
pub fn gemv_with_threads(
    a: &[f32],
    x: &[f32],
    m: usize,
    n: usize,
    y: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    if m == 0 {
        return;
    }
    let threads = threads.clamp(1, m);
    if threads == 1 {
        gemv_rows(a, x, n, y);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (ti, y_panel) in y.chunks_mut(rows_per).enumerate() {
        let a_panel = &a[ti * rows_per * n..ti * rows_per * n + y_panel.len() * n];
        tasks.push(Box::new(move || gemv_rows(a_panel, x, n, y_panel)));
    }
    shared_pool().run(tasks);
}

fn gemv_rows(a: &[f32], x: &[f32], n: usize, y: &mut [f32]) {
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (r, v) in row.iter().zip(x) {
            acc += r * v;
        }
        *yi = acc;
    }
}

/// The whole DEQ cell at batch width, fused with the residual norms the
/// `cell_step` entry returns:
///
///   f = tanh(Z W + b + X),  res[s] = ‖f_s − z_s‖₂,  fnorm[s] = ‖f_s‖₂.
///
/// Z, X, f are (batch, n); W is (n, n) in the `affine` (in, out) layout.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn cell_batch(
    w: &[f32],
    bias: &[f32],
    z: &[f32],
    x: &[f32],
    batch: usize,
    n: usize,
    f: &mut [f32],
    res: &mut [f32],
    fnorm: &mut [f32],
) {
    assert_eq!(w.len(), n * n);
    assert_eq!(bias.len(), n);
    assert_eq!(z.len(), batch * n);
    assert_eq!(x.len(), batch * n);
    assert_eq!(f.len(), batch * n);
    assert_eq!(res.len(), batch);
    assert_eq!(fnorm.len(), batch);
    gemm(z, w, batch, n, n, f);
    for s in 0..batch {
        let zs = &z[s * n..(s + 1) * n];
        let xs = &x[s * n..(s + 1) * n];
        let fs = &mut f[s * n..(s + 1) * n];
        let mut num = 0.0f32;
        let mut den = 0.0f32;
        for j in 0..n {
            let v = (fs[j] + bias[j] + xs[j]).tanh();
            fs[j] = v;
            let d = v - zs[j];
            num += d * d;
            den += v * v;
        }
        res[s] = num.sqrt();
        fnorm[s] = den.sqrt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn blocked_matches_reference_on_awkward_shapes() {
        // Non-square, non-multiple-of-block shapes, including tiles that
        // straddle the KC/NC boundaries and degenerate dims.
        let mut rng = Rng::new(40);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (17, 31, 13),
            (2, KC + 3, NC + 5),
            (5, 2 * KC + 1, 9),
            (64, 64, 64),
        ] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_reference(&a, &b, m, k, n, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm(&a, &b, m, k, n, &mut got);
            // f32 sums reassociate across tiles: tolerance scales with k.
            close(&got, &want, 1e-3 * (k as f32).sqrt(), "gemm");
        }
    }

    #[test]
    fn parallel_panels_match_reference() {
        // Pin the thread count (instead of env) so panel splitting with a
        // ragged final panel is exercised deterministically.
        let mut rng = Rng::new(41);
        for &(m, k, n, threads) in
            &[(7usize, 11usize, 5usize, 3usize), (8, 16, 16, 8), (5, 9, 3, 16)]
        {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_reference(&a, &b, m, k, n, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm_with_threads(&a, &b, m, k, n, &mut got, threads);
            close(&got, &want, 1e-3, "parallel gemm");
        }
    }

    #[test]
    fn degenerate_dims() {
        // k = 0 must zero C; m = 0 and n = 0 are no-ops.
        let mut c = vec![9.0f32; 6];
        gemm(&[], &[], 2, 0, 3, &mut c);
        assert_eq!(c, vec![0.0; 6]);
        gemm(&[], &[1.0, 2.0], 0, 1, 2, &mut []);
        gemv_with_threads(&[], &[], 0, 0, &mut [], 4);
    }

    #[test]
    fn gemv_matches_rowwise_dot() {
        let mut rng = Rng::new(42);
        let (m, n) = (23usize, 17usize);
        let a = rng.normal_vec(m * n, 1.0);
        let x = rng.normal_vec(n, 1.0);
        let mut serial = vec![0.0f32; m];
        gemv(&a, &x, m, n, &mut serial);
        let mut par = vec![0.0f32; m];
        gemv_with_threads(&a, &x, m, n, &mut par, 4);
        for i in 0..m {
            let want: f32 =
                a[i * n..(i + 1) * n].iter().zip(&x).map(|(p, q)| p * q).sum();
            assert!((serial[i] - want).abs() < 1e-4);
        }
        close(&par, &serial, 1e-6, "gemv threads");
    }

    #[test]
    fn cell_batch_matches_per_sample_math() {
        let mut rng = Rng::new(43);
        let (batch, n) = (4usize, 9usize);
        let w = rng.normal_vec(n * n, 0.3);
        let bias = rng.normal_vec(n, 0.1);
        let z = rng.normal_vec(batch * n, 1.0);
        let x = rng.normal_vec(batch * n, 1.0);
        let mut f = vec![0.0f32; batch * n];
        let mut res = vec![0.0f32; batch];
        let mut fnorm = vec![0.0f32; batch];
        cell_batch(&w, &bias, &z, &x, batch, n, &mut f, &mut res, &mut fnorm);
        for s in 0..batch {
            let mut num = 0.0f32;
            let mut den = 0.0f32;
            for j in 0..n {
                let mut acc = bias[j];
                for i in 0..n {
                    acc += z[s * n + i] * w[i * n + j];
                }
                let want = (acc + x[s * n + j]).tanh();
                let got = f[s * n + j];
                assert!((got - want).abs() < 1e-5, "f[{s},{j}]: {got} vs {want}");
                num += (want - z[s * n + j]).powi(2);
                den += want * want;
            }
            assert!((res[s] - num.sqrt()).abs() < 1e-4);
            assert!((fnorm[s] - den.sqrt()).abs() < 1e-4);
        }
    }

    #[test]
    fn thread_knob_is_sane() {
        let t = max_threads();
        assert!((1..=64).contains(&t));
    }

    #[test]
    fn parallel_chunks_is_pure_shape_arithmetic() {
        // Tiny problems stay serial whatever the worker budget; big ones
        // take the budget, clamped to the row count — no env involved,
        // so the split is injectable and deterministic in one process.
        assert_eq!(parallel_chunks(4, 4, 4, 8), 1);
        assert_eq!(parallel_chunks(1024, 512, 512, 4), 4);
        assert_eq!(parallel_chunks(2, 1024, 1024, 8), 2);
        assert_eq!(parallel_chunks(0, 1024, 1024, 8), 1);
    }
}

//! Stochastic Anderson mixing — the paper's named future-work direction
//! (§5, citing Wei, Bao & Liu, *Stochastic Anderson Mixing for Nonconvex
//! Stochastic Optimization*, NeurIPS 2021).
//!
//! Two stochastic ingredients over the deterministic state:
//!
//!  * **sketched Gram**: the m×m Gram matrix is estimated from a random
//!    coordinate subsample of the residual rows (a column sketch of G),
//!    cutting the O(m²·n) mixing cost to O(m²·s), s ≪ n — the "low-memory
//!    acceleration" knob at the cost of a noisy α;
//!  * **damped updates**: β is drawn per-iteration from [β_lo, β_hi],
//!    which the SAM paper shows stabilizes nonconvex trajectories.
//!
//! Exposed through `solve_stochastic` with the same trace type as the
//! deterministic drivers, so the ablation bench can compare all three.

use anyhow::Result;

use crate::native::anderson::{
    rel_residual, AndersonOpts, AndersonState, FixedPointMap, IterRecord,
    SolveTrace,
};
use crate::native::linalg;
use crate::util::rng::Rng;

/// Stochastic-mixing options.
#[derive(Debug, Clone, Copy)]
pub struct StochasticOpts {
    pub base: AndersonOpts,
    /// Coordinates sampled for the Gram sketch (0 = use all, i.e. exact).
    pub sketch: usize,
    /// Per-iteration mixing draw range.
    pub beta_lo: f32,
    pub beta_hi: f32,
    pub seed: u64,
}

impl Default for StochasticOpts {
    fn default() -> Self {
        Self {
            base: AndersonOpts::default(),
            sketch: 64,
            beta_lo: 0.7,
            beta_hi: 1.0,
            seed: 0,
        }
    }
}

/// Draw the coordinate subsample for a sketched Gram build.
///
/// Returns `None` when the sketch is a no-op (`sketch == 0` or
/// `sketch >= n`: use every coordinate, exactly), otherwise the sampled
/// coordinate indices (with replacement, uniform over `0..n`) plus the
/// `sqrt(n / s)` scale that makes the sketched Gram an unbiased estimate
/// of GᵀG.  Shared by [`sketched_alpha`] and the adaptive-window
/// condition probes in `native::anderson`, so both paths sketch the same
/// way.
pub fn sketch_coords(n: usize, sketch: usize, rng: &mut Rng) -> Option<(Vec<usize>, f32)> {
    if sketch == 0 || sketch >= n {
        return None;
    }
    let coords: Vec<usize> = (0..sketch).map(|_| rng.below(n)).collect();
    let scale = (n as f32 / sketch as f32).sqrt();
    Some((coords, scale))
}

/// Sketched constrained Anderson solve over an explicit window.
///
/// Returns (alpha, used_coords). Exact when `sketch == 0 || sketch >= n`.
/// `newest` names the ring slot holding the most recent (z, f) pair
/// (`AndersonState::newest_slot()`): a degenerate or rank-deficient
/// sketched Gram falls back to a forward step from *that* slot — under
/// ring wraparound, slot `nv − 1` can be up to m−1 iterations stale.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn sketched_alpha(
    xs: &[f32],
    fs: &[f32],
    nv: usize,
    n: usize,
    lam: f32,
    sketch: usize,
    newest: usize,
    rng: &mut Rng,
) -> Result<(Vec<f32>, usize)> {
    assert!(newest < nv, "newest slot {newest} outside valid window {nv}");
    let drawn = sketch_coords(n, sketch, rng);
    let s = drawn.as_ref().map_or(n, |(c, _)| c.len());

    // Residual rows restricted to the sampled coordinates, scaled so the
    // sketched Gram is an unbiased estimate of GᵀG (scale 1 when exact).
    let mut g = vec![0.0f32; nv * s];
    match &drawn {
        None => {
            for i in 0..nv {
                for c in 0..n {
                    g[i * s + c] = fs[i * n + c] - xs[i * n + c];
                }
            }
        }
        Some((coords, scale)) => {
            for i in 0..nv {
                for (t, &c) in coords.iter().enumerate() {
                    g[i * s + t] = scale * (fs[i * n + c] - xs[i * n + c]);
                }
            }
        }
    }

    let mut h = vec![0.0f32; nv * nv];
    linalg::gram(&g, nv, s, &mut h);
    for i in 0..nv {
        h[i * nv + i] += lam;
    }
    let ones = vec![1.0f32; nv];
    // Like AndersonState::mix_into, a rank-deficient (sketched) Gram is a
    // recoverable condition, not a solve-aborting error: fall back to a
    // plain forward step from the newest pair.
    let fallback = || {
        let mut e = vec![0.0; nv];
        e[newest] = 1.0;
        e
    };
    let alpha: Vec<f32> = match linalg::solve_spd(&h, nv, &ones) {
        Ok(a) => {
            let sum: f32 = a.iter().sum();
            if sum.is_finite() && sum.abs() >= 1e-30 {
                a.iter().map(|v| v / sum).collect()
            } else {
                fallback()
            }
        }
        Err(_) => fallback(),
    };
    Ok((alpha, s))
}

/// Solve with stochastic Anderson mixing.
pub fn solve_stochastic(
    map: &dyn FixedPointMap,
    z0: &[f32],
    opts: StochasticOpts,
) -> Result<SolveTrace> {
    let n = map.dim();
    let o = opts.base;
    let mut rng = Rng::new(opts.seed ^ 0x5A3D);
    // Reuse AndersonState purely as the ring buffer; mixing happens here
    // with the sketched alpha.
    let mut state = AndersonState::new(o.window, n, 1.0, o.lam);
    let mut z = z0.to_vec();
    let mut fz = vec![0.0f32; n];
    let mut records = Vec::new();
    let mut converged = false;

    for k in 0..o.max_iter {
        map.apply(&z, &mut fz);
        let rel = rel_residual(&fz, &z, o.lam);
        records.push(IterRecord { iter: k, rel_residual: rel, fevals: k + 1 });
        if rel < o.tol {
            converged = true;
            z = fz.clone();
            break;
        }
        state.push(&z, &fz);
        let nv = state.valid();
        let (alpha, _s) = sketched_alpha(
            state.xs_raw(),
            state.fs_raw(),
            nv,
            n,
            o.lam,
            opts.sketch,
            state.newest_slot(),
            &mut rng,
        )?;
        let beta = rng.range(opts.beta_lo, opts.beta_hi);
        let (xs, fs) = (state.xs_raw(), state.fs_raw());
        for t in 0..n {
            let mut acc = 0.0f32;
            for i in 0..nv {
                acc += alpha[i]
                    * ((1.0 - beta) * xs[i * n + t] + beta * fs[i * n + t]);
            }
            z[t] = acc;
        }
    }
    Ok(SolveTrace { z, records, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::maps::AffineMap;
    use crate::native::solve_forward;

    fn base(tol: f32) -> AndersonOpts {
        AndersonOpts { window: 5, lam: 1e-6, tol, max_iter: 2000, ..Default::default() }
    }

    #[test]
    fn exact_sketch_matches_deterministic_alpha() {
        let mut rng = Rng::new(1);
        let (m, n) = (4usize, 32usize);
        let mut st = AndersonState::new(m, n, 1.0, 1e-5);
        for _ in 0..m {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            st.push(&z, &f);
        }
        let (_, alpha_det) = st.mix().unwrap();
        let (alpha_sk, s) = sketched_alpha(
            st.xs_raw(),
            st.fs_raw(),
            m,
            n,
            1e-5,
            0, // exact
            st.newest_slot(),
            &mut rng,
        )
        .unwrap();
        assert_eq!(s, n);
        for (a, b) in alpha_sk.iter().zip(&alpha_det) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn stochastic_converges_on_affine() {
        let map = AffineMap::random(48, 0.95, 5);
        let z0 = vec![0.0; 48];
        let o = StochasticOpts {
            base: base(1e-4),
            sketch: 24,
            beta_lo: 0.9,
            beta_hi: 1.0,
            seed: 3,
        };
        let tr = solve_stochastic(&map, &z0, o).unwrap();
        assert!(tr.converged, "res={}", tr.final_residual());
        // Still beats forward despite the sketch noise.
        let fw = solve_forward(&map, &z0, base(1e-4));
        assert!(tr.iters() < fw.iters(), "{} vs {}", tr.iters(), fw.iters());
    }

    #[test]
    fn alpha_sums_to_one_under_sketch() {
        let mut rng = Rng::new(7);
        let (m, n) = (5usize, 100usize);
        let mut st = AndersonState::new(m, n, 1.0, 1e-5);
        for _ in 0..m {
            let z = rng.normal_vec(n, 1.0);
            let f = rng.normal_vec(n, 1.0);
            st.push(&z, &f);
        }
        for sketch in [8usize, 32, 64] {
            let (alpha, _) = sketched_alpha(
                st.xs_raw(),
                st.fs_raw(),
                m,
                n,
                1e-5,
                sketch,
                st.newest_slot(),
                &mut rng,
            )
            .unwrap();
            let s: f32 = alpha.iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "sketch={sketch} sum={s}");
        }
    }

    #[test]
    fn degenerate_sketch_falls_back_to_newest_slot_under_wraparound() {
        // Four pushes into a window of 3 wrap the ring: the newest pair
        // lives in slot 0, not slot nv−1.  Identical residual rows with
        // λ = 0 break Cholesky deterministically (H is the all-ones
        // matrix at n = 1), so the fallback fires — and it must name the
        // newest slot, not the stale slot nv−1 (regression: the old
        // fallback stepped up to m−1 iterations backward in time).
        let m = 3;
        let mut st = AndersonState::new(m, 1, 1.0, 0.0);
        for k in 0..4 {
            let x = [k as f32];
            let f = [k as f32 + 1.0]; // residual 1 in every slot
            st.push(&x, &f);
        }
        assert_eq!(st.newest_slot(), 0, "4 pushes into m=3 wrap to slot 0");
        let mut rng = Rng::new(2);
        let (alpha, _) = sketched_alpha(
            st.xs_raw(),
            st.fs_raw(),
            st.valid(),
            1,
            0.0, // λ = 0 ⇒ rank-1 H ⇒ Cholesky breakdown
            0,   // exact sketch
            st.newest_slot(),
            &mut rng,
        )
        .unwrap();
        assert_eq!(alpha, vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn deterministic_given_seed() {
        let map = AffineMap::random(32, 0.9, 9);
        let z0 = vec![0.0; 32];
        let o = StochasticOpts { seed: 11, ..Default::default() };
        let a = solve_stochastic(&map, &z0, o).unwrap();
        let b = solve_stochastic(&map, &z0, o).unwrap();
        assert_eq!(a.iters(), b.iters());
        assert_eq!(a.z, b.z);
    }
}

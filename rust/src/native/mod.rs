//! Native (pure-Rust) solver substrate: small linear algebra, the Anderson
//! twin of the AOT kernel, and synthetic fixed-point maps.  Powers the
//! device-model simulations, property tests and hyperparameter sweeps
//! without touching PJRT.

pub mod anderson;
pub mod linalg;
pub mod maps;
pub mod stochastic;

pub use stochastic::{solve_stochastic, StochasticOpts};
pub use anderson::{
    rel_residual, solve_anderson, solve_forward, AndersonOpts, AndersonState,
    FixedPointMap, IterRecord, SolveTrace,
};

//! Native (pure-Rust) solver substrate: small linear algebra, blocked
//! multi-threaded compute kernels, a packed-panel microkernel GEMM with
//! weight packing, runtime SIMD dispatch and bf16 panel storage
//! ([`pack`]), a persistent worker pool ([`pool`]), a
//! reusable scratch-buffer workspace, the Anderson twin of the AOT
//! kernel, and synthetic fixed-point maps.  Powers the device-model
//! simulations, property tests and hyperparameter sweeps without
//! touching PJRT — and, through [`pack`] + [`pool`] + [`workspace`], the
//! allocation-free, spawn-free, repack-free hot path of the
//! `NativeEngine` backend.

pub mod anderson;
pub mod kernels;
pub mod linalg;
pub mod maps;
pub mod pack;
pub mod pool;
pub mod stochastic;
pub mod workspace;

pub use stochastic::{sketch_coords, solve_stochastic, StochasticOpts};
pub use anderson::{
    rel_residual, solve_anderson, solve_forward, window_cond_estimate,
    AndersonOpts, AndersonState, FixedPointMap, IterRecord, SolveTrace,
};
pub use pack::{PackPrecision, PackedB, SimdLevel};
pub use pool::{PoolStats, WorkerPool};
pub use workspace::{Workspace, WorkspaceStats};

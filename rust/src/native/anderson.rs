//! Pure-Rust Anderson extrapolation over arbitrary fixed-point maps.
//!
//! This is the *native twin* of the AOT Anderson kernel: the same math
//! (paper Alg. 1, Eqs. 1-5) implemented directly in Rust over a
//! [`FixedPointMap`] trait.  It exists because the coordinator needs an
//! XLA-independent solver for
//!
//!   * the device cost-model simulations behind Figs. 1 & 6 (arbitrary
//!     problem sizes, no artifact compilation),
//!   * property tests of the solver invariants (window masking, Σα = 1,
//!     Krylov exactness on affine maps), cross-checked against the Pallas
//!     kernel through the runtime integration tests,
//!   * hyperparameter sweeps (window m, damping β, λ) that would be
//!     wasteful through PJRT dispatch.

use anyhow::Result;

use crate::native::kernels::PAR_MIN_MACS;
use crate::native::linalg;
use crate::native::pool::shared_pool;

/// One sample's masked-window Anderson mix (paper Eqs. 4–5): residual
/// rows over the `valid` slots, Gram system H = GGᵀ + λI, Ha = 1,
/// α = a/Σa, and z⁺ = Σ αᵢ((1−β)xᵢ + βfᵢ), with the rank-deficient
/// fallback to a forward step from the last valid slot.
///
/// This is the shared per-sample core of the engine's *batched*
/// `anderson_update` entry — extracted so the batch loop can fan samples
/// out across a worker pool (each job with its own `g`/`h`/`a` scratch
/// and disjoint `z_row`/`alpha_row` output slices) while the serial path
/// runs the identical arithmetic.  `z_row` and `alpha_row` are fully
/// overwritten.
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
pub fn mix_masked_window(
    xh: &[f32],
    fh: &[f32],
    valid: &[usize],
    m: usize,
    n: usize,
    beta: f32,
    lam: f32,
    g: &mut [f32],
    h: &mut [f32],
    a: &mut [f32],
    z_row: &mut [f32],
    alpha_row: &mut [f32],
) {
    let nv = valid.len();
    debug_assert!(nv >= 1);
    debug_assert_eq!(xh.len(), m * n);
    debug_assert_eq!(fh.len(), m * n);
    debug_assert_eq!(z_row.len(), n);
    debug_assert_eq!(alpha_row.len(), m);
    // Residual rows G_i = f_i − x_i over the valid slots.
    for (r, &i) in valid.iter().enumerate() {
        let off = i * n;
        for t in 0..n {
            g[r * n + t] = fh[off + t] - xh[off + t];
        }
    }
    // H = G Gᵀ + λI;  H a = 1;  α = a / Σa.
    linalg::gram(&g[..nv * n], nv, n, &mut h[..nv * nv]);
    for i in 0..nv {
        h[i * nv + i] += lam;
    }
    for v in a[..nv].iter_mut() {
        *v = 1.0;
    }
    // λ > 0 keeps H SPD on finite inputs, but λ = 0 configs and
    // duplicated lanes (e.g. a freshly replicated LaneHistory window)
    // make H rank-deficient.  That is a recoverable condition, not a
    // batch-aborting error: degrade this sample to a plain forward step
    // from the last valid slot (the kernel only sees the masked window,
    // not push order, so "last valid" is the best newest-pair proxy it
    // has), exactly like the reference AndersonState::mix_into fallback.
    let solved = linalg::solve_spd_in_place(&mut h[..nv * nv], nv, &mut a[..nv]).is_ok();
    let sum: f32 = a[..nv].iter().sum();
    if solved && sum.is_finite() && sum.abs() >= 1e-30 {
        for v in a[..nv].iter_mut() {
            *v /= sum;
        }
    } else {
        for v in a[..nv].iter_mut() {
            *v = 0.0;
        }
        a[nv - 1] = 1.0;
    }
    // z⁺ = Σ αᵢ ((1−β)·xᵢ + β·fᵢ)   (Eq. 5)
    z_row.fill(0.0);
    alpha_row.fill(0.0);
    for (r, &i) in valid.iter().enumerate() {
        let off = i * n;
        let (ax, af) = ((1.0 - beta) * a[r], beta * a[r]);
        for t in 0..n {
            z_row[t] += ax * xh[off + t] + af * fh[off + t];
        }
        alpha_row[i] = a[r];
    }
}

/// Condition estimate of the Tikhonov-regularized Anderson system
/// `H + λI` over residual rows `g` ((k, n), row-major): the same
/// Gram-then-Cholesky sequence [`mix_masked_window`] performs for the
/// solve, reused by the adaptive-window monitors in
/// `crate::solver::anderson` to decide when to truncate history (drop
/// largest-residual iterates while the estimate exceeds the spec's
/// `cond_max`).  Returns `INFINITY` when Cholesky rejects the system.
pub fn window_cond_estimate(g: &[f32], k: usize, n: usize, lam: f32) -> f32 {
    if k == 0 {
        return 1.0;
    }
    debug_assert_eq!(g.len(), k * n);
    let mut h = vec![0.0f32; k * k];
    linalg::gram(g, k, n, &mut h);
    for i in 0..k {
        h[i * k + i] += lam;
    }
    linalg::spd_cond_estimate(&mut h, k)
}

/// A vector-valued fixed-point problem z = f(z).
pub trait FixedPointMap {
    fn dim(&self) -> usize;
    /// Evaluate `out = f(z)`.
    fn apply(&self, z: &[f32], out: &mut [f32]);
    /// Optional known solution (for tests / error tracking).
    fn solution(&self) -> Option<Vec<f32>> {
        None
    }
}

/// Solver configuration (paper Alg. 1 defaults).
#[derive(Debug, Clone, Copy)]
pub struct AndersonOpts {
    pub window: usize, // m
    pub beta: f32,
    pub lam: f32,
    pub tol: f32,
    pub max_iter: usize,
}

impl Default for AndersonOpts {
    fn default() -> Self {
        Self { window: 5, beta: 1.0, lam: 1e-4, tol: 1e-2, max_iter: 1000 }
    }
}

/// Per-iteration record of a solve.
#[derive(Debug, Clone)]
pub struct IterRecord {
    pub iter: usize,
    /// Paper residual: ‖f(z)−z‖₂ / (‖f(z)‖₂ + λ)
    pub rel_residual: f32,
    /// Function evaluations consumed so far (1 per iteration here).
    pub fevals: usize,
}

/// Result of a native solve.
#[derive(Debug, Clone)]
pub struct SolveTrace {
    pub z: Vec<f32>,
    pub records: Vec<IterRecord>,
    pub converged: bool,
}

impl SolveTrace {
    pub fn iters(&self) -> usize {
        self.records.len()
    }
    pub fn final_residual(&self) -> f32 {
        self.records.last().map(|r| r.rel_residual).unwrap_or(f32::NAN)
    }
    /// First iteration index whose residual ≤ target, if reached.
    pub fn iters_to(&self, target: f32) -> Option<usize> {
        self.records.iter().find(|r| r.rel_residual <= target).map(|r| r.iter)
    }
}

/// Ring-buffer window of (iterate, image) pairs + the Anderson solve.
///
/// Memory: 2·m·n floats — the "memory for speed" trade the paper discusses
/// (§1.2).  The mixing step costs O(m·n + m³) per iteration on top of the
/// function evaluation; that is the *mixing penalty* of Fig. 1.
pub struct AndersonState {
    m: usize,
    n: usize,
    beta: f32,
    lam: f32,
    xs: Vec<f32>, // (m, n) ring
    fs: Vec<f32>, // (m, n) ring
    count: usize, // total pushes
    // Reusable mixing scratch, sized for the full window at construction
    // so the per-iteration O(m·n + m³) work of Eqs. 4–5 runs
    // allocation-free (see mix_into).
    g: Vec<f32>,     // (m, n) residual rows
    h: Vec<f32>,     // (m, m) Gram
    rhs: Vec<f32>,   // (m) ones → solution
    alpha: Vec<f32>, // (m) normalized weights
}

impl AndersonState {
    pub fn new(m: usize, n: usize, beta: f32, lam: f32) -> Self {
        assert!(m >= 1 && m <= 64);
        Self {
            m,
            n,
            beta,
            lam,
            xs: vec![0.0; m * n],
            fs: vec![0.0; m * n],
            count: 0,
            g: vec![0.0; m * n],
            h: vec![0.0; m * m],
            rhs: vec![0.0; m],
            alpha: vec![0.0; m],
        }
    }

    /// Number of valid history slots (min(count, m)).
    pub fn valid(&self) -> usize {
        self.count.min(self.m)
    }

    /// Ring slot holding the newest pushed pair: `(count − 1) mod m`.
    /// Because the ring fills slots 0..m in order before wrapping, this
    /// index is always `< valid()`, so it is safe to address α by it.
    pub fn newest_slot(&self) -> usize {
        assert!(self.count >= 1, "newest_slot() before any push()");
        (self.count - 1) % self.m
    }

    /// Raw (m, n) iterate window — consumed by the stochastic variant.
    pub fn xs_raw(&self) -> &[f32] {
        &self.xs
    }

    /// Raw (m, n) image window.
    pub fn fs_raw(&self) -> &[f32] {
        &self.fs
    }

    /// Record a new (z, f(z)) pair.
    pub fn push(&mut self, z: &[f32], fz: &[f32]) {
        assert_eq!(z.len(), self.n);
        assert_eq!(fz.len(), self.n);
        let slot = self.count % self.m;
        self.xs[slot * self.n..(slot + 1) * self.n].copy_from_slice(z);
        self.fs[slot * self.n..(slot + 1) * self.n].copy_from_slice(fz);
        self.count += 1;
    }

    /// Anderson-mix the current window into `z_next` (length n), reusing
    /// the state's internal scratch: steady-state mixing performs no heap
    /// allocation.  Returns the α weights over the valid slots.
    ///
    /// A **rank-deficient window** (Cholesky breakdown on H = GGᵀ + λI —
    /// duplicated iterates with λ = 0, or an exactly-converged pair)
    /// falls back to a β-damped forward step from the newest pair instead
    /// of erroring: aborting a whole solve because one window went
    /// degenerate is exactly the instability *Stable Anderson
    /// Acceleration* warns against.  The same fallback covers the
    /// Σa ≈ 0 degeneracy.  The `newest_slot()` index states the ring
    /// invariant directly (the previous `(count − 1) % min(m, nv)` form
    /// only named the right slot through the side condition
    /// nv == min(count, m)); the regression tests pin both paths.
    pub fn mix_into(&mut self, z_next: &mut [f32]) -> Result<&[f32]> {
        let nv = self.valid();
        assert!(nv >= 1, "mix() before any push()");
        assert_eq!(z_next.len(), self.n);
        let n = self.n;

        // The Gram build is the O(m·n + m²·n) half of the mixing penalty
        // (Fig. 1); above the kernel parallel threshold it fans out over
        // the persistent shared pool — residual rows, then Gram rows, are
        // disjoint `&mut` chunks, so the arithmetic (and the result) is
        // identical to the serial path.
        // G rows: residuals f_i - x_i over valid slots.  Always serial —
        // O(m·n) is far below the Gram cost the parallel gate measures,
        // so fanning tiny row jobs out would cost more than the work.
        for i in 0..nv {
            for t in 0..n {
                self.g[i * n + t] = self.fs[i * n + t] - self.xs[i * n + t];
            }
        }
        let parallel = nv * nv * n >= PAR_MIN_MACS;
        if parallel {
            // H = G Gᵀ fanned over the persistent shared pool: one job
            // per row runs the *same* upper-triangle kernel
            // ([`linalg::gram_row_upper`]) the serial [`linalg::gram`]
            // uses, then a serial O(m²) pass mirrors the lower triangle
            // — serial and parallel results are bit-identical by
            // construction.
            let pool = shared_pool();
            let g = &self.g[..nv * n];
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nv);
            for (i, hrow) in self.h[..nv * nv].chunks_mut(nv).enumerate() {
                tasks.push(Box::new(move || {
                    linalg::gram_row_upper(g, nv, n, i, hrow);
                }));
            }
            pool.run(tasks);
            for i in 1..nv {
                for j in 0..i {
                    self.h[i * nv + j] = self.h[j * nv + i];
                }
            }
        } else {
            // H = G Gᵀ + λI, solve H a = 1, α = a / Σa  (the unconstrained
            // reduction of the paper's bordered system Eq. 4).
            linalg::gram(&self.g[..nv * n], nv, n, &mut self.h[..nv * nv]);
        }
        for i in 0..nv {
            self.h[i * nv + i] += self.lam;
        }
        for v in self.rhs[..nv].iter_mut() {
            *v = 1.0;
        }
        let solved =
            linalg::solve_spd_in_place(&mut self.h[..nv * nv], nv, &mut self.rhs[..nv])
                .is_ok();
        let sum: f32 = self.rhs[..nv].iter().sum();
        if solved && sum.is_finite() && sum.abs() >= 1e-30 {
            for i in 0..nv {
                self.alpha[i] = self.rhs[i] / sum;
            }
        } else {
            // Rank-deficient or degenerate window: damped forward step
            // from the newest pair (α = e_newest).
            for v in self.alpha[..nv].iter_mut() {
                *v = 0.0;
            }
            let newest = self.newest_slot();
            self.alpha[newest] = 1.0;
        }

        // z⁺ = (1-β)·Σ αᵢ xᵢ + β·Σ αᵢ fᵢ   (Eq. 5)
        z_next.fill(0.0);
        for i in 0..nv {
            let (ax, af) = ((1.0 - self.beta) * self.alpha[i], self.beta * self.alpha[i]);
            if ax == 0.0 && af == 0.0 {
                continue;
            }
            let xrow = &self.xs[i * n..(i + 1) * n];
            let frow = &self.fs[i * n..(i + 1) * n];
            for t in 0..n {
                z_next[t] += ax * xrow[t] + af * frow[t];
            }
        }
        Ok(&self.alpha[..nv])
    }

    /// Compute the Anderson-mixed next iterate from the current window.
    /// Returns (z_next, alpha) with Σα = 1 over the valid slots.
    /// Allocating convenience wrapper over [`Self::mix_into`].
    pub fn mix(&mut self) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut z = vec![0.0f32; self.n];
        let alpha = self.mix_into(&mut z)?.to_vec();
        Ok((z, alpha))
    }
}

/// Relative residual per the paper.
pub fn rel_residual(fz: &[f32], z: &[f32], lam: f32) -> f32 {
    let mut num = 0.0f32;
    let mut den = 0.0f32;
    for (f, x) in fz.iter().zip(z) {
        num += (f - x) * (f - x);
        den += f * f;
    }
    num.sqrt() / (den.sqrt() + lam)
}

/// Solve with Anderson extrapolation; records the residual trajectory.
pub fn solve_anderson(
    map: &dyn FixedPointMap,
    z0: &[f32],
    opts: AndersonOpts,
) -> Result<SolveTrace> {
    let n = map.dim();
    let mut state = AndersonState::new(opts.window, n, opts.beta, opts.lam);
    let mut z = z0.to_vec();
    let mut fz = vec![0.0f32; n];
    let mut z_next = vec![0.0f32; n];
    let mut records = Vec::new();
    let mut converged = false;

    for k in 0..opts.max_iter {
        map.apply(&z, &mut fz);
        let rel = rel_residual(&fz, &z, opts.lam);
        records.push(IterRecord { iter: k, rel_residual: rel, fevals: k + 1 });
        if rel < opts.tol {
            converged = true;
            z.copy_from_slice(&fz);
            break;
        }
        state.push(&z, &fz);
        // mix_into reuses the state's scratch and the loop's z_next
        // buffer: the steady-state iteration allocates nothing.  A
        // rank-deficient window degrades to a damped forward step inside
        // mix_into instead of aborting the solve.
        state.mix_into(&mut z_next)?;
        std::mem::swap(&mut z, &mut z_next);
    }
    Ok(SolveTrace { z, records, converged })
}

/// Baseline: plain forward iteration z ← f(z).
pub fn solve_forward(
    map: &dyn FixedPointMap,
    z0: &[f32],
    opts: AndersonOpts,
) -> SolveTrace {
    let n = map.dim();
    let mut z = z0.to_vec();
    let mut fz = vec![0.0f32; n];
    let mut records = Vec::new();
    let mut converged = false;

    for k in 0..opts.max_iter {
        map.apply(&z, &mut fz);
        let rel = rel_residual(&fz, &z, opts.lam);
        records.push(IterRecord { iter: k, rel_residual: rel, fevals: k + 1 });
        std::mem::swap(&mut z, &mut fz);
        if rel < opts.tol {
            converged = true;
            break;
        }
    }
    SolveTrace { z, records, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::maps::AffineMap;
    use crate::util::rng::Rng;

    fn opts(m: usize, tol: f32) -> AndersonOpts {
        AndersonOpts {
            window: m,
            tol,
            lam: 1e-8,
            max_iter: 2000,
            ..Default::default()
        }
    }

    #[test]
    fn anderson_beats_forward_on_stiff_affine() {
        // Spectral radius 0.99 → forward needs ~ log(tol)/log(0.99) iters
        // (~1300 to 1e-4); Anderson(m=5, small λ) needs ~100.
        let map = AffineMap::random(40, 0.99, 7);
        let z0 = vec![0.0; 40];
        let fw = solve_forward(&map, &z0, opts(5, 1e-4));
        let an = solve_anderson(&map, &z0, opts(5, 1e-4)).unwrap();
        assert!(an.converged, "anderson did not converge");
        assert!(
            an.iters() < fw.iters() / 3,
            "anderson {} vs forward {}",
            an.iters(),
            fw.iters()
        );
    }

    #[test]
    fn anderson_exact_with_full_window() {
        // Window > dim ⇒ Krylov exactness on affine maps.
        let map = AffineMap::random(6, 0.9, 3);
        let z0 = vec![0.0; 6];
        let mut o = opts(8, 1e-5);
        o.lam = 1e-8;
        let tr = solve_anderson(&map, &z0, o).unwrap();
        assert!(tr.converged);
        assert!(tr.iters() <= 10, "iters={}", tr.iters());
        let sol = map.solution().unwrap();
        let err: f32 = tr
            .z
            .iter()
            .zip(&sol)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-2, "err={err}");
    }

    #[test]
    fn window_one_equals_forward() {
        // m=1, β=1: z⁺ = f(z) exactly.
        let map = AffineMap::random(10, 0.7, 1);
        let z0 = vec![0.5; 10];
        let a = solve_anderson(&map, &z0, opts(1, 1e-5)).unwrap();
        let f = solve_forward(&map, &z0, opts(1, 1e-5));
        assert_eq!(a.iters(), f.iters());
        for (x, y) in a.z.iter().zip(&f.z) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn alpha_sums_to_one() {
        let mut st = AndersonState::new(4, 8, 1.0, 1e-5);
        let mut r = Rng::new(3);
        for _ in 0..6 {
            let z = r.normal_vec(8, 1.0);
            let f = r.normal_vec(8, 1.0);
            st.push(&z, &f);
            let (_, alpha) = st.mix().unwrap();
            let s: f32 = alpha.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum={s}");
            assert_eq!(alpha.len(), st.valid());
        }
    }

    #[test]
    fn degenerate_fallback_targets_newest_slot() {
        // Regression: the fallback index must name the slot of the pair
        // pushed last — including under ring wraparound — and stay below
        // valid() so it can address the α vector.  Pins the ring
        // invariant `newest = (count − 1) % m` that the degenerate
        // branch of mix() relies on.
        let mut st = AndersonState::new(3, 2, 1.0, 1e-4);
        for k in 1usize..=8 {
            let pair = vec![k as f32; 2];
            st.push(&pair, &pair);
            assert_eq!(st.newest_slot(), (k - 1) % 3, "after push {k}");
            assert!(st.newest_slot() < st.valid(), "slot must be valid");
            // The named slot holds exactly the pair just pushed.
            let s = st.newest_slot();
            assert_eq!(st.xs_raw()[s * 2], k as f32, "after push {k}");
            assert_eq!(st.fs_raw()[s * 2 + 1], k as f32, "after push {k}");
        }
    }

    #[test]
    fn rank_deficient_window_falls_back_to_forward_step() {
        // λ = 0 and a zero-residual pair ⇒ H = GGᵀ = 0: Cholesky breaks
        // down deterministically.  Regression: mix() used to propagate
        // the error and abort the whole solve; it must now degrade to a
        // β-damped forward step from the newest pair.
        let mut st = AndersonState::new(2, 2, 1.0, 0.0);
        st.push(&[1.0, 2.0], &[1.0, 2.0]);
        let (z, alpha) = st.mix().unwrap();
        assert_eq!(z, vec![1.0, 2.0]);
        assert_eq!(alpha, vec![1.0]);
    }

    #[test]
    fn duplicated_iterate_window_mixes_to_forward_step() {
        // A duplicated-iterate window (the same (z, f) pair pushed twice,
        // λ = 0) makes H rank-1.  Whether Cholesky breaks down exactly or
        // squeaks through on a rounded pivot, the mix over identical
        // slots must come out as the forward step f — finite, no error.
        let mut st = AndersonState::new(3, 2, 1.0, 0.0);
        st.push(&[1.0, 2.0], &[3.0, 4.0]);
        st.push(&[1.0, 2.0], &[3.0, 4.0]);
        let (z, alpha) = st.mix().unwrap();
        assert_eq!(alpha.len(), 2);
        for (got, want) in z.iter().zip(&[3.0f32, 4.0]) {
            assert!(got.is_finite() && (got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn solve_survives_rank_deficient_window() {
        // f(z) = −z oscillates with period 2: from the second iteration
        // on, the window holds (±1, ∓1) pairs whose residual rows are
        // collinear, so H is exactly singular with λ = 0.  The solve used
        // to abort here; now every degenerate iteration degrades to a
        // forward step and the trace runs to max_iter.
        struct Flip;
        impl FixedPointMap for Flip {
            fn dim(&self) -> usize {
                1
            }
            fn apply(&self, z: &[f32], out: &mut [f32]) {
                out[0] = -z[0];
            }
        }
        let o = AndersonOpts {
            window: 2,
            beta: 1.0,
            lam: 0.0,
            tol: 1e-6,
            max_iter: 8,
        };
        let tr = solve_anderson(&Flip, &[1.0], o).unwrap();
        assert!(!tr.converged);
        assert_eq!(tr.iters(), 8);
        assert!(tr.z[0].is_finite());
        assert_eq!(tr.z[0].abs(), 1.0, "forward-step fallback drifted");
    }

    #[test]
    fn mix_into_reuses_caller_buffer() {
        let map = AffineMap::random(12, 0.8, 4);
        let mut st = AndersonState::new(3, 12, 1.0, 1e-6);
        let mut z = vec![0.0f32; 12];
        let mut fz = vec![0.0f32; 12];
        let mut z_next = vec![0.0f32; 12];
        for _ in 0..5 {
            map.apply(&z, &mut fz);
            st.push(&z, &fz);
            let alpha_len = st.mix_into(&mut z_next).unwrap().len();
            assert_eq!(alpha_len, st.valid());
            std::mem::swap(&mut z, &mut z_next);
        }
        // Parity with the allocating wrapper on the same window.
        let (z_ref, _) = st.mix().unwrap();
        let mut z_buf = vec![0.0f32; 12];
        st.mix_into(&mut z_buf).unwrap();
        for (a, b) in z_buf.iter().zip(&z_ref) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn parallel_gram_build_matches_reference_math() {
        // m·m·n = 8·8·4096 sits exactly at the parallel threshold, so
        // this window takes the pool-fanned G/Gram build; the reference
        // below recomputes Eqs. 4–5 serially on host-built rows.
        let (m, n) = (8usize, 4096usize);
        let lam = 1e-3f32;
        let mut st = AndersonState::new(m, n, 1.0, lam);
        let mut r = Rng::new(5);
        let mut pairs: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for _ in 0..m {
            let z = r.normal_vec(n, 1.0);
            let f = r.normal_vec(n, 1.0);
            st.push(&z, &f);
            pairs.push((z, f));
        }
        let (zmix, alpha) = st.mix().unwrap();
        let mut g = vec![0.0f32; m * n];
        for (i, (z, f)) in pairs.iter().enumerate() {
            for t in 0..n {
                g[i * n + t] = f[t] - z[t];
            }
        }
        let mut h = vec![0.0f32; m * m];
        linalg::gram(&g, m, n, &mut h);
        for i in 0..m {
            h[i * m + i] += lam;
        }
        let ones = vec![1.0f32; m];
        let a = linalg::solve_spd(&h, m, &ones).unwrap();
        let sum: f32 = a.iter().sum();
        let alpha_ref: Vec<f32> = a.iter().map(|v| v / sum).collect();
        assert_eq!(alpha.len(), m);
        for (x, y) in alpha.iter().zip(&alpha_ref) {
            assert!((x - y).abs() < 1e-3, "alpha {x} vs {y}");
        }
        // β = 1 ⇒ z⁺ = Σ αᵢ fᵢ; spot-check a few coordinates.
        for t in [0usize, 1, n - 1] {
            let want: f32 =
                (0..m).map(|i| alpha_ref[i] * pairs[i].1[t]).sum();
            assert!((zmix[t] - want).abs() < 1e-3, "z[{t}]");
        }
    }

    #[test]
    fn residual_definition() {
        let f = vec![3.0, 4.0];
        let z = vec![0.0, 0.0];
        // ||f-z|| = 5, ||f|| = 5 → 5/(5+λ)
        let r = rel_residual(&f, &z, 1.0);
        assert!((r - 5.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn trace_iters_to() {
        let map = AffineMap::random(12, 0.8, 5);
        let tr = solve_forward(&map, &vec![0.0; 12], opts(1, 1e-6));
        let t = tr.iters_to(1e-3).unwrap();
        assert!(t > 0 && t < tr.iters());
        assert!(tr.iters_to(0.0).is_none());
    }
}

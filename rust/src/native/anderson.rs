//! Pure-Rust Anderson extrapolation over arbitrary fixed-point maps.
//!
//! This is the *native twin* of the AOT Anderson kernel: the same math
//! (paper Alg. 1, Eqs. 1-5) implemented directly in Rust over a
//! [`FixedPointMap`] trait.  It exists because the coordinator needs an
//! XLA-independent solver for
//!
//!   * the device cost-model simulations behind Figs. 1 & 6 (arbitrary
//!     problem sizes, no artifact compilation),
//!   * property tests of the solver invariants (window masking, Σα = 1,
//!     Krylov exactness on affine maps), cross-checked against the Pallas
//!     kernel through the runtime integration tests,
//!   * hyperparameter sweeps (window m, damping β, λ) that would be
//!     wasteful through PJRT dispatch.

use anyhow::Result;

use crate::native::linalg;

/// A vector-valued fixed-point problem z = f(z).
pub trait FixedPointMap {
    fn dim(&self) -> usize;
    /// Evaluate `out = f(z)`.
    fn apply(&self, z: &[f32], out: &mut [f32]);
    /// Optional known solution (for tests / error tracking).
    fn solution(&self) -> Option<Vec<f32>> {
        None
    }
}

/// Solver configuration (paper Alg. 1 defaults).
#[derive(Debug, Clone, Copy)]
pub struct AndersonOpts {
    pub window: usize, // m
    pub beta: f32,
    pub lam: f32,
    pub tol: f32,
    pub max_iter: usize,
}

impl Default for AndersonOpts {
    fn default() -> Self {
        Self { window: 5, beta: 1.0, lam: 1e-4, tol: 1e-2, max_iter: 1000 }
    }
}

/// Per-iteration record of a solve.
#[derive(Debug, Clone)]
pub struct IterRecord {
    pub iter: usize,
    /// Paper residual: ‖f(z)−z‖₂ / (‖f(z)‖₂ + λ)
    pub rel_residual: f32,
    /// Function evaluations consumed so far (1 per iteration here).
    pub fevals: usize,
}

/// Result of a native solve.
#[derive(Debug, Clone)]
pub struct SolveTrace {
    pub z: Vec<f32>,
    pub records: Vec<IterRecord>,
    pub converged: bool,
}

impl SolveTrace {
    pub fn iters(&self) -> usize {
        self.records.len()
    }
    pub fn final_residual(&self) -> f32 {
        self.records.last().map(|r| r.rel_residual).unwrap_or(f32::NAN)
    }
    /// First iteration index whose residual ≤ target, if reached.
    pub fn iters_to(&self, target: f32) -> Option<usize> {
        self.records.iter().find(|r| r.rel_residual <= target).map(|r| r.iter)
    }
}

/// Ring-buffer window of (iterate, image) pairs + the Anderson solve.
///
/// Memory: 2·m·n floats — the "memory for speed" trade the paper discusses
/// (§1.2).  The mixing step costs O(m·n + m³) per iteration on top of the
/// function evaluation; that is the *mixing penalty* of Fig. 1.
pub struct AndersonState {
    m: usize,
    n: usize,
    beta: f32,
    lam: f32,
    xs: Vec<f32>, // (m, n) ring
    fs: Vec<f32>, // (m, n) ring
    count: usize, // total pushes
}

impl AndersonState {
    pub fn new(m: usize, n: usize, beta: f32, lam: f32) -> Self {
        assert!(m >= 1 && m <= 64);
        Self {
            m,
            n,
            beta,
            lam,
            xs: vec![0.0; m * n],
            fs: vec![0.0; m * n],
            count: 0,
        }
    }

    /// Number of valid history slots (min(count, m)).
    pub fn valid(&self) -> usize {
        self.count.min(self.m)
    }

    /// Ring slot holding the newest pushed pair: `(count − 1) mod m`.
    /// Because the ring fills slots 0..m in order before wrapping, this
    /// index is always `< valid()`, so it is safe to address α by it.
    pub fn newest_slot(&self) -> usize {
        assert!(self.count >= 1, "newest_slot() before any push()");
        (self.count - 1) % self.m
    }

    /// Raw (m, n) iterate window — consumed by the stochastic variant.
    pub fn xs_raw(&self) -> &[f32] {
        &self.xs
    }

    /// Raw (m, n) image window.
    pub fn fs_raw(&self) -> &[f32] {
        &self.fs
    }

    /// Record a new (z, f(z)) pair.
    pub fn push(&mut self, z: &[f32], fz: &[f32]) {
        assert_eq!(z.len(), self.n);
        assert_eq!(fz.len(), self.n);
        let slot = self.count % self.m;
        self.xs[slot * self.n..(slot + 1) * self.n].copy_from_slice(z);
        self.fs[slot * self.n..(slot + 1) * self.n].copy_from_slice(fz);
        self.count += 1;
    }

    /// Compute the Anderson-mixed next iterate from the current window.
    /// Returns (z_next, alpha) with Σα = 1 over the valid slots.
    pub fn mix(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        let nv = self.valid();
        assert!(nv >= 1, "mix() before any push()");
        let n = self.n;

        // G rows: residuals f_i - x_i over valid slots.
        let mut g = vec![0.0f32; nv * n];
        for i in 0..nv {
            for t in 0..n {
                g[i * n + t] = self.fs[i * n + t] - self.xs[i * n + t];
            }
        }

        // H = G Gᵀ + λI, solve H a = 1, α = a / Σa  (the unconstrained
        // reduction of the paper's bordered system Eq. 4).
        let mut h = vec![0.0f32; nv * nv];
        linalg::gram(&g, nv, n, &mut h);
        for i in 0..nv {
            h[i * nv + i] += self.lam;
        }
        let ones = vec![1.0f32; nv];
        let a = linalg::solve_spd(&h, nv, &ones)?;
        let sum: f32 = a.iter().sum();
        let alpha: Vec<f32> = if sum.abs() < 1e-30 {
            // Degenerate window — fall back to a plain forward step from
            // the newest pair.  The previous `(count − 1) % min(m, nv)`
            // index only named the right slot through the side condition
            // nv == min(count, m); `newest_slot()` states the ring
            // invariant directly (and the regression test pins it), so a
            // future change to the fill rule can't silently turn this
            // into a stale-slot read.
            let mut e = vec![0.0; nv];
            e[self.newest_slot()] = 1.0;
            e
        } else {
            a.iter().map(|v| v / sum).collect()
        };

        // z⁺ = (1-β)·Σ αᵢ xᵢ + β·Σ αᵢ fᵢ   (Eq. 5)
        let mut z = vec![0.0f32; n];
        for i in 0..nv {
            let (ax, af) = ((1.0 - self.beta) * alpha[i], self.beta * alpha[i]);
            let xrow = &self.xs[i * n..(i + 1) * n];
            let frow = &self.fs[i * n..(i + 1) * n];
            for t in 0..n {
                z[t] += ax * xrow[t] + af * frow[t];
            }
        }
        Ok((z, alpha))
    }
}

/// Relative residual per the paper.
pub fn rel_residual(fz: &[f32], z: &[f32], lam: f32) -> f32 {
    let mut num = 0.0f32;
    let mut den = 0.0f32;
    for (f, x) in fz.iter().zip(z) {
        num += (f - x) * (f - x);
        den += f * f;
    }
    num.sqrt() / (den.sqrt() + lam)
}

/// Solve with Anderson extrapolation; records the residual trajectory.
pub fn solve_anderson(
    map: &dyn FixedPointMap,
    z0: &[f32],
    opts: AndersonOpts,
) -> Result<SolveTrace> {
    let n = map.dim();
    let mut state = AndersonState::new(opts.window, n, opts.beta, opts.lam);
    let mut z = z0.to_vec();
    let mut fz = vec![0.0f32; n];
    let mut records = Vec::new();
    let mut converged = false;

    for k in 0..opts.max_iter {
        map.apply(&z, &mut fz);
        let rel = rel_residual(&fz, &z, opts.lam);
        records.push(IterRecord { iter: k, rel_residual: rel, fevals: k + 1 });
        if rel < opts.tol {
            converged = true;
            z = fz.clone();
            break;
        }
        state.push(&z, &fz);
        let (znext, _alpha) = state.mix()?;
        z = znext;
    }
    Ok(SolveTrace { z, records, converged })
}

/// Baseline: plain forward iteration z ← f(z).
pub fn solve_forward(
    map: &dyn FixedPointMap,
    z0: &[f32],
    opts: AndersonOpts,
) -> SolveTrace {
    let n = map.dim();
    let mut z = z0.to_vec();
    let mut fz = vec![0.0f32; n];
    let mut records = Vec::new();
    let mut converged = false;

    for k in 0..opts.max_iter {
        map.apply(&z, &mut fz);
        let rel = rel_residual(&fz, &z, opts.lam);
        records.push(IterRecord { iter: k, rel_residual: rel, fevals: k + 1 });
        std::mem::swap(&mut z, &mut fz);
        if rel < opts.tol {
            converged = true;
            break;
        }
    }
    SolveTrace { z, records, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::maps::AffineMap;
    use crate::util::rng::Rng;

    fn opts(m: usize, tol: f32) -> AndersonOpts {
        AndersonOpts {
            window: m,
            tol,
            lam: 1e-8,
            max_iter: 2000,
            ..Default::default()
        }
    }

    #[test]
    fn anderson_beats_forward_on_stiff_affine() {
        // Spectral radius 0.99 → forward needs ~ log(tol)/log(0.99) iters
        // (~1300 to 1e-4); Anderson(m=5, small λ) needs ~100.
        let map = AffineMap::random(40, 0.99, 7);
        let z0 = vec![0.0; 40];
        let fw = solve_forward(&map, &z0, opts(5, 1e-4));
        let an = solve_anderson(&map, &z0, opts(5, 1e-4)).unwrap();
        assert!(an.converged, "anderson did not converge");
        assert!(
            an.iters() < fw.iters() / 3,
            "anderson {} vs forward {}",
            an.iters(),
            fw.iters()
        );
    }

    #[test]
    fn anderson_exact_with_full_window() {
        // Window > dim ⇒ Krylov exactness on affine maps.
        let map = AffineMap::random(6, 0.9, 3);
        let z0 = vec![0.0; 6];
        let mut o = opts(8, 1e-5);
        o.lam = 1e-8;
        let tr = solve_anderson(&map, &z0, o).unwrap();
        assert!(tr.converged);
        assert!(tr.iters() <= 10, "iters={}", tr.iters());
        let sol = map.solution().unwrap();
        let err: f32 = tr
            .z
            .iter()
            .zip(&sol)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-2, "err={err}");
    }

    #[test]
    fn window_one_equals_forward() {
        // m=1, β=1: z⁺ = f(z) exactly.
        let map = AffineMap::random(10, 0.7, 1);
        let z0 = vec![0.5; 10];
        let a = solve_anderson(&map, &z0, opts(1, 1e-5)).unwrap();
        let f = solve_forward(&map, &z0, opts(1, 1e-5));
        assert_eq!(a.iters(), f.iters());
        for (x, y) in a.z.iter().zip(&f.z) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn alpha_sums_to_one() {
        let mut st = AndersonState::new(4, 8, 1.0, 1e-5);
        let mut r = Rng::new(3);
        for _ in 0..6 {
            let z = r.normal_vec(8, 1.0);
            let f = r.normal_vec(8, 1.0);
            st.push(&z, &f);
            let (_, alpha) = st.mix().unwrap();
            let s: f32 = alpha.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum={s}");
            assert_eq!(alpha.len(), st.valid());
        }
    }

    #[test]
    fn degenerate_fallback_targets_newest_slot() {
        // Regression: the fallback index must name the slot of the pair
        // pushed last — including under ring wraparound — and stay below
        // valid() so it can address the α vector.  Pins the ring
        // invariant `newest = (count − 1) % m` that the degenerate
        // branch of mix() relies on.
        let mut st = AndersonState::new(3, 2, 1.0, 1e-4);
        for k in 1usize..=8 {
            let pair = vec![k as f32; 2];
            st.push(&pair, &pair);
            assert_eq!(st.newest_slot(), (k - 1) % 3, "after push {k}");
            assert!(st.newest_slot() < st.valid(), "slot must be valid");
            // The named slot holds exactly the pair just pushed.
            let s = st.newest_slot();
            assert_eq!(st.xs_raw()[s * 2], k as f32, "after push {k}");
            assert_eq!(st.fs_raw()[s * 2 + 1], k as f32, "after push {k}");
        }
    }

    #[test]
    fn residual_definition() {
        let f = vec![3.0, 4.0];
        let z = vec![0.0, 0.0];
        // ||f-z|| = 5, ||f|| = 5 → 5/(5+λ)
        let r = rel_residual(&f, &z, 1.0);
        assert!((r - 5.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn trace_iters_to() {
        let map = AffineMap::random(12, 0.8, 5);
        let tr = solve_forward(&map, &vec![0.0; 12], opts(1, 1e-6));
        let t = tr.iters_to(1e-3).unwrap();
        assert!(t > 0 && t < tr.iters());
        assert!(tr.iters_to(0.0).is_none());
    }
}

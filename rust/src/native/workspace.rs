//! Reusable scratch-buffer pool for the native hot path.
//!
//! The paper's thesis is "fewer, more compute-intensive but generally
//! cacheable iterations" — yet a hot loop that allocates a fresh `Vec`
//! for every `f`, residual and mixed iterate is the opposite of
//! cacheable.  [`Workspace`] is the fix: a best-fit pool of `f32`
//! buffers keyed by capacity.  `take(len)` hands out a zeroed buffer
//! (reusing a pooled allocation when one is large enough), `give`
//! returns it.  Once a steady-state loop has warmed the pool, every
//! `take` is a hit and the loop performs **zero** heap allocation — the
//! [`WorkspaceStats::allocs`] counter makes that an assertable invariant
//! (see the workspace-reuse tests in `runtime::native_engine` and
//! `tests/native_kernels.rs`).
//!
//! Ownership is by move (`take` → `Vec<f32>` → `give`), so the pool
//! composes with APIs that want owned storage — in particular
//! `HostTensor` outputs, which flow back via `Backend::recycle`.

/// Upper bound on pooled buffers; beyond it `give` drops the buffer so a
/// pathological caller can't grow the pool without bound.
const MAX_POOLED: usize = 64;

/// Counters describing how well the pool is serving its callers — plus,
/// when read through `NativeEngine::workspace_stats`, the engine's
/// weight-pack cache counters (the `Workspace` itself leaves them zero).
/// Together they make the two steady-state invariants assertable: zero
/// fresh buffer allocation (`allocs` flat) and zero weight packing
/// (`pack_misses` + `pack_invalidations` flat while `pack_hits` grows).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// `take` calls served from the pool (no allocation).
    pub hits: u64,
    /// `take` calls that had to allocate a fresh buffer.
    pub allocs: u64,
    /// Buffers currently parked in the pool.
    pub pooled: usize,
    /// Pack-cache lookups served from a cached weight pack.
    pub pack_hits: u64,
    /// Pack-cache lookups that packed a weight seen for the first time.
    pub pack_misses: u64,
    /// Pack-cache entries re-packed because the parameter version moved
    /// (one per weight per `train_update`, never during inference).
    pub pack_invalidations: u64,
    /// Packs performed for unversioned tensors (never cached — raw
    /// `HostTensor`s that did not come from a `ParamSet`).
    pub pack_uncached: u64,
    /// Resident bytes of cached f32 weight packs (the pack-cache memory
    /// footprint gauge; zero when the engine runs bf16 panels).
    pub pack_bytes_f32: usize,
    /// Resident bytes of cached bf16 weight packs — exactly half the
    /// f32 bytes for the same weights.
    pub pack_bytes_bf16: usize,
    /// Resident packs across all cache slots and precisions (a slot
    /// holding both an f32 and a bf16 pack counts twice).
    pub pack_entries: usize,
}

/// A best-fit pool of reusable `f32` scratch buffers.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    hits: u64,
    allocs: u64,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed buffer of exactly `len` elements.  Served from the pool
    /// (best fit: the smallest parked buffer whose capacity suffices)
    /// when possible; allocates otherwise.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_dirty(len);
        v.fill(0.0);
        v
    }

    /// Like [`Self::take`] but with **arbitrary contents**: the prefix
    /// reused from a pooled buffer is stale data.  For callers that fully
    /// overwrite the buffer (GEMM outputs, residual norms) — skips the
    /// zeroing pass on the hot path.
    pub fn take_dirty(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, buf) in self.free.iter().enumerate() {
            let cap = buf.capacity();
            if cap >= len && best.map(|(_, c)| cap < c).unwrap_or(true) {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                self.hits += 1;
                let mut v = self.free.swap_remove(i);
                v.truncate(len);
                v.resize(len, 0.0); // within capacity: no allocation
                v
            }
            None => {
                self.allocs += 1;
                vec![0.0; len]
            }
        }
    }

    /// Park a spent buffer for reuse.  Zero-capacity buffers and
    /// overflow beyond [`MAX_POOLED`] are dropped.
    pub fn give(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.free.len() < MAX_POOLED {
            self.free.push(v);
        }
    }

    pub fn stats(&self) -> WorkspaceStats {
        WorkspaceStats {
            hits: self.hits,
            allocs: self.allocs,
            pooled: self.free.len(),
            ..WorkspaceStats::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroes_and_reuses() {
        let mut ws = Workspace::new();
        let mut a = ws.take(8);
        assert_eq!(a, vec![0.0; 8]);
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.give(a);
        let b = ws.take(8); // same capacity class → pool hit, re-zeroed
        assert_eq!(b, vec![0.0; 8]);
        let s = ws.stats();
        assert_eq!(s.allocs, 1);
        assert_eq!(s.hits, 1);
    }

    #[test]
    fn take_dirty_reuses_without_zeroing_contract() {
        let mut ws = Workspace::new();
        let mut a = ws.take_dirty(4);
        a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        ws.give(a);
        // Contents are arbitrary (here: stale), length is exact, and the
        // pool still counts it as a hit.
        let b = ws.take_dirty(3);
        assert_eq!(b.len(), 3);
        let s = ws.stats();
        assert_eq!((s.hits, s.allocs), (1, 1));
        // A fresh miss is still zero-initialized (vec! allocation).
        let c = ws.take_dirty(2);
        assert_eq!(c, vec![0.0, 0.0]);
    }

    #[test]
    fn best_fit_preserves_size_classes() {
        let mut ws = Workspace::new();
        let big = ws.take(1024);
        let small = ws.take(4);
        ws.give(big);
        ws.give(small);
        // A small request must take the small buffer, leaving the big
        // one for the next big request — otherwise alternating sizes
        // would churn allocations forever.
        let s1 = ws.take(4);
        assert!(s1.capacity() < 1024, "best fit picked the big buffer");
        let b1 = ws.take(1024);
        assert!(b1.capacity() >= 1024);
        assert_eq!(ws.stats().allocs, 2, "steady state must not allocate");
        assert_eq!(ws.stats().hits, 2);
    }

    #[test]
    fn steady_state_loop_is_allocation_free() {
        let mut ws = Workspace::new();
        for _ in 0..3 {
            // Warm-up shapes of a solve iteration.
            let bufs: Vec<_> = [256usize, 8, 8, 40, 25, 5].iter().map(|&l| ws.take(l)).collect();
            for b in bufs {
                ws.give(b);
            }
        }
        let allocs_warm = ws.stats().allocs;
        for _ in 0..100 {
            let bufs: Vec<_> = [256usize, 8, 8, 40, 25, 5].iter().map(|&l| ws.take(l)).collect();
            for b in bufs {
                ws.give(b);
            }
        }
        assert_eq!(ws.stats().allocs, allocs_warm, "steady state allocated");
    }

    #[test]
    fn pool_is_bounded() {
        let mut ws = Workspace::new();
        for _ in 0..(MAX_POOLED + 20) {
            ws.give(vec![0.0; 4]);
        }
        assert!(ws.stats().pooled <= MAX_POOLED);
        ws.give(Vec::new()); // zero-capacity: dropped, not pooled
        assert!(ws.stats().pooled <= MAX_POOLED);
    }
}

//! Persistent worker pool for the native compute substrate.
//!
//! PR 3's parallel kernels spawned a fresh `thread::scope` fan-out on
//! every large GEMM — thread creation (~10–50 µs each) on the hot path
//! of *every* solve iteration.  [`WorkerPool`] replaces that with
//! long-lived workers parked on a condvar: a steady-state solve
//! iteration performs **zero** thread spawns, which the
//! [`PoolStats::spawned`] counter makes assertable (it only ever moves
//! at construction).
//!
//! Work distribution is batch-at-a-time: [`WorkerPool::run`] enqueues a
//! set of jobs, wakes the workers, and blocks until every job in *that
//! batch* has finished (concurrent batches from different caller threads
//! are tracked independently).  Because `run` never returns before its
//! batch completes, jobs may safely borrow from the caller's stack — the
//! same guarantee `thread::scope` gives, provided here by erasing the
//! closure lifetime internally and joining on a per-batch latch.
//!
//! Sizing: the engine builds its pool once at construction
//! (`NativeConfig::threads`, falling back to the `DEQ_NATIVE_THREADS`
//! env knob read at that moment — see [`crate::native::kernels::max_threads`]);
//! free functions like `kernels::gemm` share a lazily-built
//! process-wide pool ([`shared_pool`]).  Tests build pools of explicit
//! sizes to exercise serial vs parallel paths deterministically in one
//! process.
//!
//! Shutdown: dropping a `WorkerPool` drains queued jobs, parks no new
//! work, and **joins** every worker — no detached threads outlive the
//! owner (the engine-drop test in `tests/native_kernels.rs` pins this
//! via [`WorkerPool::exit_probe`]).

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A queued unit of work (lifetime-erased; see [`WorkerPool::run`]).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Counters describing pool activity.  `spawned` moves only inside
/// `WorkerPool::new`, so "steady state spawns no threads" is the
/// assertion `spawned_before == spawned_after`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker threads owned by the pool.
    pub workers: usize,
    /// Threads ever created (== `workers` for the pool's whole life).
    pub spawned: u64,
    /// `run` calls that dispatched at least one job.
    pub batches: u64,
    /// Jobs executed through the queue.
    pub jobs: u64,
}

/// Per-`run` completion latch: `run` blocks until `remaining == 0`.
struct Batch {
    remaining: Mutex<usize>,
    done: Condvar,
    /// First panic payload from a job in this batch, re-thrown in the
    /// caller so a worker panic is never silently swallowed.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct QueueState {
    jobs: VecDeque<(Job, Arc<Batch>)>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    work: Condvar,
}

thread_local! {
    /// Set while a pool worker is executing a job: a nested `run` from
    /// inside a job executes inline instead of re-entering the queue
    /// (queueing behind yourself on a size-1 pool is a deadlock).
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A fixed-size pool of long-lived worker threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    exited: Arc<AtomicUsize>,
    spawned: u64,
    batches: AtomicU64,
    jobs: AtomicU64,
}

impl WorkerPool {
    /// Spawn `size` workers (clamped to ≥ 1).  This is the only place
    /// threads are ever created.
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
        });
        let exited = Arc::new(AtomicUsize::new(0));
        let handles = (0..size)
            .map(|i| {
                let shared = shared.clone();
                let exited = exited.clone();
                std::thread::Builder::new()
                    .name(format!("deq-pool-{i}"))
                    .spawn(move || worker_loop(shared, exited))
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            shared,
            handles,
            exited,
            spawned: size as u64,
            batches: AtomicU64::new(0),
            jobs: AtomicU64::new(0),
        }
    }

    /// Build from the `DEQ_NATIVE_THREADS` env knob, read **once, here**
    /// (see [`crate::native::kernels::max_threads`]).
    pub fn from_env() -> Self {
        Self::new(crate::native::kernels::max_threads())
    }

    pub fn size(&self) -> usize {
        self.spawned as usize
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.spawned as usize,
            spawned: self.spawned,
            batches: self.batches.load(Ordering::Relaxed),
            jobs: self.jobs.load(Ordering::Relaxed),
        }
    }

    /// Counter of workers that have fully exited their loop — cloned out
    /// before dropping the pool, it asserts "drop joined every thread".
    pub fn exit_probe(&self) -> Arc<AtomicUsize> {
        self.exited.clone()
    }

    /// Execute every task, blocking until all of them have finished.
    ///
    /// Tasks may borrow from the caller's stack (`'env`): the lifetime is
    /// erased internally, which is sound because this function does not
    /// return — by completion or by panic — until every task has run to
    /// completion on a worker.  A panicking task is caught on the worker,
    /// the batch still completes, and the first panic payload is
    /// re-thrown here in the caller.
    ///
    /// Called from *inside* a pool job, the tasks run inline on the
    /// current thread (re-entering the queue could deadlock a small
    /// pool); top-level callers always go through the workers.
    pub fn run<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if tasks.is_empty() {
            return;
        }
        if IN_WORKER.with(|f| f.get()) {
            for t in tasks {
                t();
            }
            return;
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.jobs.fetch_add(tasks.len() as u64, Ordering::Relaxed);
        let batch = Arc::new(Batch {
            remaining: Mutex::new(tasks.len()),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // Lifetime erasure: 'env → 'static.  Sound because the
                // wait below keeps every borrow alive until the job is
                // done (see the method docs).
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'env>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(t)
                };
                q.jobs.push_back((job, batch.clone()));
            }
        }
        self.shared.work.notify_all();
        let mut remaining = batch.remaining.lock().unwrap();
        while *remaining != 0 {
            remaining = batch.done.wait(remaining).unwrap();
        }
        drop(remaining);
        if let Some(payload) = batch.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    /// Drain, signal shutdown, and **join** every worker: no thread
    /// outlives the pool.
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, exited: Arc<AtomicUsize>) {
    loop {
        let item = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(it) = q.jobs.pop_front() {
                    break Some(it);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        let Some((job, batch)) = item else { break };
        IN_WORKER.with(|f| f.set(true));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        IN_WORKER.with(|f| f.set(false));
        if let Err(payload) = result {
            let mut p = batch.panic.lock().unwrap();
            if p.is_none() {
                *p = Some(payload);
            }
        }
        let mut remaining = batch.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            batch.done.notify_all();
        }
    }
    exited.fetch_add(1, Ordering::SeqCst);
}

/// The process-wide pool behind the *free* parallel kernels
/// (`kernels::gemm`, `kernels::gemv`, the Anderson Gram build): built
/// lazily on the first parallel-sized call, sized from
/// `DEQ_NATIVE_THREADS` at that moment, and alive for the process — one
/// bounded set of parked workers instead of a scoped fan-out per call.
/// Engines own their *own* pool (shut down on engine drop); this one
/// only serves callers with no pool to pass.
pub fn shared_pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(WorkerPool::from_env)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_borrowed_jobs_to_completion() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0u32; 8];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(2)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 2 + j) as u32;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(tasks);
        assert_eq!(out, (0..8).collect::<Vec<u32>>());
        let s = pool.stats();
        assert_eq!((s.workers, s.spawned, s.batches, s.jobs), (3, 3, 1, 4));
    }

    #[test]
    fn concurrent_batches_from_many_threads() {
        let pool = Arc::new(WorkerPool::new(2));
        let hits = Arc::new(AtomicU32::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                let hits = hits.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                            .map(|_| {
                                let hits = hits.clone();
                                Box::new(move || {
                                    hits.fetch_add(1, Ordering::Relaxed);
                                })
                                    as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        pool.run(tasks);
                    }
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4 * 10 * 3);
        // Steady state: the worker count never moved.
        assert_eq!(pool.stats().spawned, 2);
    }

    #[test]
    fn job_panic_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(vec![
                Box::new(|| panic!("job exploded")) as Box<dyn FnOnce() + Send>,
                Box::new(|| {}),
            ]);
        }));
        assert!(result.is_err(), "panic must reach the caller");
        // The pool survives a panicking job and keeps serving.
        let ok = AtomicU32::new(0);
        pool.run(vec![Box::new(|| {
            ok.fetch_add(1, Ordering::Relaxed);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_run_from_a_job_executes_inline() {
        // A size-1 pool would deadlock if the inner run re-entered the
        // queue; the IN_WORKER guard makes it execute inline instead.
        let pool = WorkerPool::new(1);
        let inner_ran = AtomicU32::new(0);
        pool.run(vec![Box::new(|| {
            pool.run(vec![Box::new(|| {
                inner_ran.fetch_add(1, Ordering::Relaxed);
            }) as Box<dyn FnOnce() + Send + '_>]);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(inner_ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = WorkerPool::new(4);
        let probe = pool.exit_probe();
        pool.run(vec![Box::new(|| {}) as Box<dyn FnOnce() + Send>]);
        assert_eq!(probe.load(Ordering::SeqCst), 0, "workers exited early");
        drop(pool);
        assert_eq!(probe.load(Ordering::SeqCst), 4, "drop leaked workers");
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let pool = WorkerPool::new(1);
        pool.run(Vec::<Box<dyn FnOnce() + Send>>::new());
        assert_eq!(pool.stats().batches, 0);
    }
}

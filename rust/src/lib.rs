//! # deq-anderson
//!
//! Production-grade reproduction of *"Accelerating AI Performance using
//! Anderson Extrapolation on GPUs"* (Al Dajani & Keyes, 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 1 (Pallas)**: fused Anderson-mixing, tiled matmul and fused
//!   GroupNorm kernels (`python/compile/kernels/`), AOT-lowered.
//! - **Layer 2 (JAX)**: the deep-equilibrium model of the paper's Fig. 4,
//!   with JFB / Neumann training updates (`python/compile/model.py`).
//! - **Layer 3 (this crate)**: the coordinator — fixed-point solver
//!   drivers, training loop, inference server, device/energy simulators
//!   and the experiment harness reproducing every table and figure.
//!
//! ## Execution backends
//!
//! The coordinator's policy layer (when to evaluate, when to mix, when to
//! stop) is substrate-independent: everything above `runtime/` speaks to
//! compute through the [`runtime::Backend`] trait.  Two engines implement
//! it:
//!
//! | backend                     | feature | substrate                       |
//! |-----------------------------|---------|---------------------------------|
//! | [`runtime::NativeEngine`]   | always  | pure Rust (`native/` substrate) |
//! | `runtime::Engine` (PJRT)    | `pjrt`  | AOT HLO artifacts via XLA       |
//!
//! The default build is **hermetic**: no XLA install, no `make artifacts`
//! — `cargo test` exercises solvers, trainer, server and experiments
//! against the native twin, and parity tests pin its `anderson_update` to
//! the reference math.  With `--features pjrt` (and real `xla` bindings
//! patched over the in-tree API stub in `vendor/xla`), the same
//! coordinator drives the compiled artifacts: Python never runs on the
//! request path; `make artifacts` lowers the model once to HLO text which
//! the PJRT engine loads.
//!
//! Backend selection at runtime: [`runtime::backend_from_dir`] (binaries
//! expose it as `--backend auto|native|pjrt`).
// The crate is dense-numeric-kernel heavy (native/, runtime/native_engine)
// and its style throughout is explicit (row, col) indexing; the iterator
// forms this lint suggests obscure that math, so it is allowed crate-wide.
// Other lints stay at default severity (CI runs clippy -D warnings).
#![allow(clippy::needless_range_loop)]

pub mod data;
pub mod experiments;
pub mod infer;
pub mod metrics;
pub mod model;
pub mod native;
pub mod runtime;
pub mod server;
pub mod simulate;
pub mod solver;
pub mod train;
pub mod util;

//! # deq-anderson
//!
//! Production-grade reproduction of *"Accelerating AI Performance using
//! Anderson Extrapolation on GPUs"* (Al Dajani & Keyes, 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 1 (Pallas)**: fused Anderson-mixing, tiled matmul and fused
//!   GroupNorm kernels (`python/compile/kernels/`), AOT-lowered.
//! - **Layer 2 (JAX)**: the deep-equilibrium model of the paper's Fig. 4,
//!   with JFB / Neumann training updates (`python/compile/model.py`).
//! - **Layer 3 (this crate)**: the coordinator — fixed-point solver
//!   drivers, training loop, inference server, device/energy simulators
//!   and the experiment harness reproducing every table and figure.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! model once to HLO text which [`runtime::Engine`] loads via PJRT.

pub mod data;
pub mod experiments;
pub mod infer;
pub mod metrics;
pub mod model;
pub mod native;
pub mod runtime;
pub mod server;
pub mod simulate;
pub mod solver;
pub mod train;
pub mod util;

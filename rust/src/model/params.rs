//! Parameter sets in the manifest's canonical order.
//!
//! The AOT entry points accept parameters as their leading positional
//! arguments, in exactly the order of `manifest.params`.  `ParamSet` keeps
//! that invariant: a `Vec<HostTensor>` indexed identically, with flat-file
//! (de)serialization for checkpoints.
//!
//! Checkpoint format (little-endian):
//!   magic  "DEQA"        4 bytes
//!   version u32          (=1)
//!   count   u32          number of f32 values
//!   data    count * f32  concatenated tensors in manifest order

use std::io::{Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::HostTensor;

const MAGIC: &[u8; 4] = b"DEQA";
const VERSION: u32 = 1;

/// Process-wide parameter version counter.  Every tensor that enters a
/// `ParamSet` gets a fresh, unique, nonzero revision id from here; the
/// native engine keys its packed-weight cache on it, so a training step
/// (which builds a *new* `ParamSet` from the update outputs) invalidates
/// exactly the stale packs while inference iterations — which replay the
/// same versions — hit the cache every time.  Never reset, so two
/// distinct parameter revisions can never collide on a version.
static NEXT_PARAM_VERSION: AtomicU64 = AtomicU64::new(1);

/// A fresh, process-unique, nonzero parameter revision id.
pub fn next_param_version() -> u64 {
    NEXT_PARAM_VERSION.fetch_add(1, Ordering::Relaxed)
}

/// The model parameters (and, during training, momentum buffers).
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub tensors: Vec<HostTensor>,
}

impl ParamSet {
    /// Wrap already-built tensors, stamping each with a fresh revision id
    /// (see [`next_param_version`]) — the constructor every parameter
    /// update must go through so downstream weight caches invalidate.
    pub fn from_tensors(mut tensors: Vec<HostTensor>) -> Self {
        for t in tensors.iter_mut() {
            t.version = next_param_version();
        }
        Self { tensors }
    }

    /// Split a flat f32 buffer into tensors per the manifest layout.
    pub fn from_flat(manifest: &Manifest, flat: &[f32]) -> Result<Self> {
        let want: usize = manifest.model.param_count;
        if flat.len() != want {
            bail!("flat checkpoint has {} values, manifest wants {want}", flat.len());
        }
        let mut tensors = Vec::with_capacity(manifest.params.len());
        let mut off = 0;
        for spec in &manifest.params {
            let n = spec.elements();
            tensors.push(HostTensor::f32(
                spec.shape.clone(),
                flat[off..off + n].to_vec(),
            )?);
            off += n;
        }
        Ok(Self::from_tensors(tensors))
    }

    /// All-zero tensors with the parameter layout (momentum buffers).
    pub fn zeros_like(manifest: &Manifest) -> Self {
        Self::from_tensors(
            manifest
                .params
                .iter()
                .map(|s| HostTensor::zeros(s.shape.clone()))
                .collect(),
        )
    }

    /// Load the deterministic initial checkpoint written by `aot.py`.
    pub fn load_init(manifest: &Manifest) -> Result<Self> {
        Self::load_flat_f32(manifest, &manifest.init_params_path())
    }

    /// Load a raw little-endian f32 file (the init format).
    pub fn load_flat_f32(manifest: &Manifest, path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("{}: size not a multiple of 4", path.display());
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::from_flat(manifest, &flat)
    }

    /// Flatten back to manifest order.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for t in &self.tensors {
            out.extend_from_slice(t.f32s().expect("params are f32"));
        }
        out
    }

    /// Save a versioned checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let flat = self.to_flat();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(flat.len() as u32).to_le_bytes())?;
        for v in &flat {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Load a versioned checkpoint saved by [`ParamSet::save`].
    pub fn load(manifest: &Manifest, path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut head = [0u8; 12];
        f.read_exact(&mut head).context("checkpoint header")?;
        if &head[0..4] != MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if version != VERSION {
            bail!("{}: unsupported checkpoint version {version}", path.display());
        }
        let count = u32::from_le_bytes(head[8..12].try_into().unwrap()) as usize;
        let mut bytes = vec![0u8; count * 4];
        f.read_exact(&mut bytes).context("checkpoint body")?;
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::from_flat(manifest, &flat)
    }

    /// Max |w| across all tensors — cheap divergence guard for training.
    pub fn max_abs(&self) -> f32 {
        self.tensors
            .iter()
            .flat_map(|t| t.f32s().unwrap().iter())
            .fold(0.0f32, |m, v| m.max(v.abs()))
    }

    pub fn all_finite(&self) -> bool {
        self.tensors
            .iter()
            .all(|t| t.f32s().unwrap().iter().all(|v| v.is_finite()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_tensors_stamps_unique_nonzero_versions() {
        let a = ParamSet::from_tensors(vec![
            HostTensor::zeros(vec![2]),
            HostTensor::zeros(vec![3]),
        ]);
        let b = ParamSet::from_tensors(vec![HostTensor::zeros(vec![2])]);
        let mut seen: Vec<u64> = a
            .tensors
            .iter()
            .chain(&b.tensors)
            .map(|t| t.version)
            .collect();
        assert!(seen.iter().all(|&v| v != 0), "versions must be nonzero");
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 3, "versions must be unique across sets");
    }
}

//! Model parameter management: loading the deterministic init checkpoint,
//! save/load of training checkpoints, and the canonical flat layout the
//! AOT entry points consume.

pub mod params;

pub use params::ParamSet;

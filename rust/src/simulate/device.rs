//! Roofline device cost model — the GPU-vs-CPU substitution (DESIGN.md §6).
//!
//! The paper benchmarks Anderson vs forward iteration on an NVIDIA Tesla
//! V100 against an Intel Xeon host (Google Colab Pro).  This environment
//! is CPU-only, but the paper's GPU claims are *throughput ratios over
//! identical math*: the residual trajectory of a solve is device
//! independent; only the timestamps differ.  So we measure trajectories
//! exactly (native or PJRT solves) and assign each iteration a modeled
//! duration from a roofline cost model:
//!
//! ```text
//! t_iter = max(flops / peak_flops, bytes / mem_bw) + launches * t_launch
//! ```
//!
//! with published device parameters.  This reproduces the *shape* of
//! Figs. 1 & 6 — who wins, the crossover location, and the ~100-150x
//! GPU:CPU gap the paper reports for Anderson.

use std::time::Duration;

/// Roofline parameters for one device.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Peak f32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// Sustained memory bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Fixed overhead per kernel launch / dispatch (seconds).
    pub launch_s: f64,
    /// Fraction of peak realistically sustained by these kernels.
    pub efficiency: f64,
}

/// NVIDIA Tesla V100 (the paper's GPU): 15.7 TFLOP/s fp32, 900 GB/s HBM2,
/// ~5 µs launch latency.
pub const V100: DeviceModel = DeviceModel {
    name: "V100",
    peak_flops: 15.7e12,
    mem_bw: 900e9,
    launch_s: 5e-6,
    efficiency: 0.55,
};

/// Colab-class Intel Xeon host (2 vCPU) running an eager-mode framework,
/// matching the paper's PyTorch CPU baseline: theoretical AVX2 peak is
/// ~150 GFLOP/s, but sustained throughput on 3x3 convolutions at these
/// sizes in eager mode is far lower (un-fused ops, per-op dispatch,
/// NHWC↔blocked repacking) — we model 25 GFLOP/s peak at 25% sustained
/// efficiency (~6 GFLOP/s effective) with ~12 GB/s DRAM bandwidth and
/// ~20 µs per-op framework overhead.  This reproduces the paper's
/// observed ~100-150x V100:CPU gap (Fig. 6).
pub const XEON: DeviceModel = DeviceModel {
    name: "Xeon",
    peak_flops: 25e9,
    mem_bw: 12e9,
    launch_s: 20e-6,
    efficiency: 0.25,
};

/// Operation counts for one solver iteration at a given problem size.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpCount {
    pub flops: f64,
    pub bytes: f64,
    pub kernels: f64,
}

impl OpCount {
    pub fn add(self, other: OpCount) -> OpCount {
        OpCount {
            flops: self.flops + other.flops,
            bytes: self.bytes + other.bytes,
            kernels: self.kernels + other.kernels,
        }
    }
}

/// Workload geometry for the DEQ cell + Anderson mixing.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub batch: usize,
    pub latent_hw: usize,
    pub channels: usize,
    pub window: usize,
}

impl Workload {
    pub fn latent_dim(&self) -> usize {
        self.latent_hw * self.latent_hw * self.channels
    }

    /// One DEQ cell evaluation f(z, x): two 3x3 convs (im2col matmuls) +
    /// three fused groupnorm passes.
    pub fn cell_ops(&self) -> OpCount {
        let b = self.batch as f64;
        let hw = (self.latent_hw * self.latent_hw) as f64;
        let c = self.channels as f64;
        let conv_flops = 2.0 * b * hw * 9.0 * c * c; // per conv
        let act_bytes = 4.0 * b * hw * c;
        OpCount {
            flops: 2.0 * conv_flops + 3.0 * 10.0 * b * hw * c,
            // conv reads patches (9c) + weights + writes; gn reads+writes x3
            bytes: 2.0 * (act_bytes * 10.0 + 4.0 * 9.0 * c * c) + 3.0 * 2.0 * act_bytes,
            kernels: 5.0,
        }
    }

    /// One Anderson mixing step: Gram (m²n), solve (m³), mix (mn).
    pub fn anderson_ops(&self) -> OpCount {
        let b = self.batch as f64;
        let n = self.latent_dim() as f64;
        let m = self.window as f64;
        OpCount {
            flops: b * (2.0 * m * m * n + m * m * m + 2.0 * m * n),
            // stream X and F windows + write z
            bytes: 4.0 * b * (2.0 * m * n + n),
            kernels: 3.0,
        }
    }

    /// Per-iteration op counts for each solver.
    pub fn iter_ops(&self, anderson: bool) -> OpCount {
        if anderson {
            self.cell_ops().add(self.anderson_ops())
        } else {
            self.cell_ops()
        }
    }
}

impl DeviceModel {
    /// Modeled wallclock for an op bundle.
    pub fn time(&self, ops: OpCount) -> Duration {
        let compute = ops.flops / (self.peak_flops * self.efficiency);
        let memory = ops.bytes / (self.mem_bw * self.efficiency);
        let launch = ops.kernels * self.launch_s;
        Duration::from_secs_f64(compute.max(memory) + launch)
    }

    /// Modeled per-iteration time for a workload.
    pub fn iter_time(&self, w: &Workload, anderson: bool) -> Duration {
        self.time(w.iter_ops(anderson))
    }
}

/// Assign modeled timestamps to an iteration-indexed residual trace.
pub fn simulate_timestamps(
    residuals: &[f32],
    device: &DeviceModel,
    w: &Workload,
    anderson: bool,
) -> Vec<(Duration, f32)> {
    let dt = device.iter_time(w, anderson);
    residuals
        .iter()
        .enumerate()
        .map(|(k, &r)| (dt * (k as u32 + 1), r))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl() -> Workload {
        Workload { batch: 32, latent_hw: 16, channels: 48, window: 5 }
    }

    #[test]
    fn gpu_much_faster_than_cpu() {
        // The paper's Fig. 6 claim (~100-150x to target with Anderson) is
        // a single-input measurement: launch overhead bounds the GPU at
        // b=1. At b=32 the gap grows compute-bound.
        let w1 = Workload { batch: 1, ..wl() };
        let r1 = XEON.iter_time(&w1, true).as_secs_f64()
            / V100.iter_time(&w1, true).as_secs_f64();
        assert!(r1 > 50.0 && r1 < 300.0, "b=1 ratio={r1}");
        let w32 = wl();
        let r32 = XEON.iter_time(&w32, true).as_secs_f64()
            / V100.iter_time(&w32, true).as_secs_f64();
        assert!(r32 > r1, "batching must widen the gap: {r32} vs {r1}");
    }

    #[test]
    fn anderson_iteration_costs_more() {
        // The mixing penalty must be visible on both devices.
        let w = wl();
        for d in [&V100, &XEON] {
            let a = d.iter_time(&w, true);
            let f = d.iter_time(&w, false);
            assert!(a > f, "{}: {a:?} <= {f:?}", d.name);
            // ...but not catastrophically so (paper: penalty is modest
            // relative to convergence gains).
            assert!(a.as_secs_f64() / f.as_secs_f64() < 3.0);
        }
    }

    #[test]
    fn flops_scale_with_batch() {
        let w1 = Workload { batch: 1, ..wl() };
        let w32 = Workload { batch: 32, ..wl() };
        assert!(w32.cell_ops().flops > 30.0 * w1.cell_ops().flops);
    }

    #[test]
    fn timestamps_monotone() {
        let res = vec![1.0, 0.5, 0.25, 0.12];
        let ts = simulate_timestamps(&res, &V100, &wl(), true);
        for w in ts.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        assert_eq!(ts.len(), 4);
    }
}

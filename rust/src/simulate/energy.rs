//! Energy / carbon projection model behind the paper's Fig. 2.
//!
//! The figure projects AI electricity demand toward 2030 (>2% of global
//! demand; data centers + infrastructure >10%) from the cited sources
//! [Andrae & Edler 2015; de Vries 2023; Jones 2018; Patterson 2021], and
//! overlays the savings an efficiency technique like Anderson+GPU could
//! deliver.  We reproduce the *series* with a transparent parameterized
//! model; every assumption is a struct field with the paper's cited value
//! as default.

/// Projection assumptions (all rates are annual, fractional).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub base_year: u32,
    /// Global electricity demand in the base year (TWh). IEA ~2022.
    pub global_twh: f64,
    /// Global demand growth per year.
    pub global_growth: f64,
    /// Data-center (+infrastructure) share in the base year.
    pub dc_share0: f64,
    /// Data-center share by the target year (paper: >10%).
    pub dc_share_target: f64,
    /// AI fraction of data-center demand in the base year.
    pub ai_frac0: f64,
    /// AI fraction of data-center demand by the target year
    /// (drives the paper's ">2% of global" claim).
    pub ai_frac_target: f64,
    pub target_year: u32,
    /// Compute saved by Anderson acceleration (paper Table 1: 50-88%).
    pub anderson_savings: f64,
    /// Fraction of AI workloads to which the technique applies.
    pub adoption: f64,
    /// Grid carbon intensity (kg CO2 per kWh).
    pub carbon_kg_per_kwh: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            base_year: 2022,
            global_twh: 25_500.0,
            global_growth: 0.025,
            dc_share0: 0.015,
            dc_share_target: 0.10,
            ai_frac0: 0.08,
            ai_frac_target: 0.25,
            target_year: 2030,
            anderson_savings: 0.70, // mid of the paper's 50-88% band
            // Fraction of AI workloads amenable to fixed-point/implicit
            // acceleration; 0.3 reproduces the paper's ~160 TWh/yr claim.
            adoption: 0.3,
            carbon_kg_per_kwh: 0.4,
        }
    }
}

/// One projected year.
#[derive(Debug, Clone, Copy)]
pub struct YearPoint {
    pub year: u32,
    pub global_twh: f64,
    pub dc_twh: f64,
    pub ai_twh: f64,
    /// AI demand as a share of global demand.
    pub ai_share_of_global: f64,
    /// TWh avoided with Anderson acceleration deployed.
    pub saved_twh: f64,
    /// Mt CO2 avoided.
    pub saved_mt_co2: f64,
}

impl EnergyModel {
    fn lerp(&self, a: f64, b: f64, year: u32) -> f64 {
        let span = (self.target_year - self.base_year) as f64;
        let t = ((year - self.base_year) as f64 / span).clamp(0.0, 1.0);
        a + (b - a) * t
    }

    /// Project one year.
    pub fn project_year(&self, year: u32) -> YearPoint {
        let dt = (year - self.base_year) as f64;
        let global = self.global_twh * (1.0 + self.global_growth).powf(dt);
        let dc_share = self.lerp(self.dc_share0, self.dc_share_target, year);
        let ai_frac = self.lerp(self.ai_frac0, self.ai_frac_target, year);
        let dc = global * dc_share;
        let ai = dc * ai_frac;
        let saved = ai * self.adoption * self.anderson_savings;
        YearPoint {
            year,
            global_twh: global,
            dc_twh: dc,
            ai_twh: ai,
            ai_share_of_global: ai / global,
            saved_twh: saved,
            saved_mt_co2: saved * 1e9 * self.carbon_kg_per_kwh / 1e9, // TWh→kWh→kg→Mt
        }
    }

    /// Full series base_year..=target_year.
    pub fn series(&self) -> Vec<YearPoint> {
        (self.base_year..=self.target_year)
            .map(|y| self.project_year(y))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_2030_claims() {
        let m = EnergyModel::default();
        let p = m.project_year(2030);
        // Paper: AI > 2% of global electricity by 2030.
        assert!(
            p.ai_share_of_global > 0.02,
            "ai share = {:.3}",
            p.ai_share_of_global
        );
        // Paper: data centers + infrastructure > 10% of global is the
        // trajectory; we model the DC share reaching 10%.
        assert!((p.dc_twh / p.global_twh - 0.10).abs() < 1e-9);
        // Paper: ~160 TWh/yr saved by 2030 ("up to 90%" reduction). Our
        // default (70% savings, 90% adoption) lands in the right decade.
        assert!(
            p.saved_twh > 120.0 && p.saved_twh < 600.0,
            "saved = {:.0} TWh",
            p.saved_twh
        );
    }

    #[test]
    fn series_monotone_growth() {
        let s = EnergyModel::default().series();
        assert_eq!(s.len(), 9);
        for w in s.windows(2) {
            assert!(w[1].global_twh > w[0].global_twh);
            assert!(w[1].ai_twh > w[0].ai_twh);
        }
    }

    #[test]
    fn savings_scale_with_adoption() {
        let mut m = EnergyModel::default();
        m.adoption = 0.5;
        let half = m.project_year(2030).saved_twh;
        m.adoption = 1.0;
        let full = m.project_year(2030).saved_twh;
        assert!((full / half - 2.0).abs() < 1e-9);
    }

    #[test]
    fn carbon_proportional_to_energy() {
        let m = EnergyModel::default();
        let p = m.project_year(2028);
        assert!((p.saved_mt_co2 - p.saved_twh * 0.4).abs() < 1e-9);
    }
}

//! Hardware and impact simulators (DESIGN.md §6 substitutions):
//! roofline device cost models (V100 vs Xeon) for the paper's GPU-vs-CPU
//! figures, and the Fig. 2 energy/carbon projection model.

pub mod device;
pub mod energy;

pub use device::{simulate_timestamps, DeviceModel, OpCount, Workload, V100, XEON};
pub use energy::{EnergyModel, YearPoint};

//! Shuffling mini-batch iterator over a [`Dataset`].
//!
//! Fixed batch size (the AOT artifacts are compiled per bucket): the final
//! partial batch of an epoch is dropped, matching the usual drop_last
//! convention and keeping every PJRT call on the compiled shape.

use crate::data::Dataset;
use crate::util::rng::Rng;

pub struct Batcher<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    shuffle: bool,
}

impl<'a> Batcher<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64, shuffle: bool) -> Self {
        assert!(batch >= 1 && batch <= data.len());
        let order: Vec<usize> = (0..data.len()).collect();
        let mut b = Self {
            data,
            batch,
            order,
            cursor: 0,
            rng: Rng::new(seed),
            shuffle,
        };
        if shuffle {
            b.rng.shuffle(&mut b.order);
        }
        b
    }

    /// Batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.data.len() / self.batch
    }

    /// Start the next epoch (reshuffles).
    pub fn next_epoch(&mut self) {
        self.cursor = 0;
        if self.shuffle {
            self.rng.shuffle(&mut self.order);
        }
    }

    /// Next batch, or None at epoch end.
    pub fn next_batch(&mut self) -> Option<(Vec<f32>, Vec<i32>)> {
        if self.cursor + self.batch > self.data.len() {
            return None;
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        Some(self.data.gather(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn covers_epoch_without_repeats() {
        let d = synthetic::generate(50, 1);
        let mut b = Batcher::new(&d, 8, 0, true);
        assert_eq!(b.batches_per_epoch(), 6);
        let mut count = 0;
        while let Some((imgs, labs)) = b.next_batch() {
            assert_eq!(labs.len(), 8);
            assert_eq!(imgs.len(), 8 * d.image_dim());
            count += 1;
        }
        assert_eq!(count, 6);
    }

    #[test]
    fn epochs_reshuffle() {
        let d = synthetic::generate(32, 2);
        let mut b = Batcher::new(&d, 32, 3, true);
        let (_, l1) = b.next_batch().unwrap();
        b.next_epoch();
        let (_, l2) = b.next_batch().unwrap();
        // Same multiset, (almost surely) different order.
        let mut s1 = l1.clone();
        let mut s2 = l2.clone();
        s1.sort_unstable();
        s2.sort_unstable();
        assert_eq!(s1, s2);
        assert_ne!(l1, l2);
    }

    #[test]
    fn unshuffled_is_sequential() {
        let d = synthetic::generate(16, 4);
        let mut b = Batcher::new(&d, 4, 0, false);
        let (_, labs) = b.next_batch().unwrap();
        assert_eq!(labs, d.labels[0..4].to_vec());
    }
}

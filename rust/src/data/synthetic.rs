//! Deterministic synthetic CIFAR10-like generator.
//!
//! Ten classes of 32x32x3 images with class-conditional *structure* rather
//! than class-conditional *means*: each class owns an oriented sinusoidal
//! texture (frequency + orientation + color phase) and a blob layout, with
//! per-sample random phase, position jitter, amplitude and additive noise.
//! The task is linearly non-separable on raw pixels but comfortably
//! learnable by the small DEQ — giving training dynamics (plateaus,
//! fluctuations) qualitatively matching the paper's CIFAR10 curves.

use crate::data::Dataset;
use crate::util::rng::Rng;

pub const HW: usize = 32;
pub const C: usize = 3;
pub const NUM_CLASSES: usize = 10;

/// Per-class texture parameters (fixed; independent of the sample RNG).
struct ClassSpec {
    freq: f32,
    angle: f32,
    color_phase: [f32; 3],
    blob_x: f32,
    blob_y: f32,
    blob_sign: f32,
}

fn class_spec(k: usize) -> ClassSpec {
    // Deterministic per class, spread across frequency/orientation space.
    let kf = k as f32;
    ClassSpec {
        freq: 0.25 + 0.11 * kf,
        angle: std::f32::consts::PI * (kf * 0.37 % 1.0),
        color_phase: [
            (kf * 1.3).sin(),
            (kf * 2.1 + 0.5).sin(),
            (kf * 0.7 + 1.1).sin(),
        ],
        blob_x: 8.0 + 16.0 * ((kf * 0.61) % 1.0),
        blob_y: 8.0 + 16.0 * ((kf * 0.29) % 1.0),
        blob_sign: if k % 2 == 0 { 1.0 } else { -1.0 },
    }
}

/// Generate one image into `out` (flat HW*HW*C, NHWC).
fn render(spec: &ClassSpec, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), HW * HW * C);
    let phase = rng.range(0.0, std::f32::consts::TAU);
    let amp = rng.range(0.8, 1.2);
    let jx = rng.range(-3.0, 3.0);
    let jy = rng.range(-3.0, 3.0);
    let (sa, ca) = spec.angle.sin_cos();
    for y in 0..HW {
        for x in 0..HW {
            let (xf, yf) = (x as f32, y as f32);
            // Oriented sinusoid (the class "texture").
            let u = ca * xf + sa * yf;
            let wave = (spec.freq * u + phase).sin();
            // Class blob.
            let dx = xf - (spec.blob_x + jx);
            let dy = yf - (spec.blob_y + jy);
            let blob = spec.blob_sign * (-(dx * dx + dy * dy) / 40.0).exp();
            for ch in 0..C {
                let tex = amp * wave * (1.0 + 0.5 * spec.color_phase[ch]);
                // Noise level calibrated so raw-pixel nearest-centroid sits
                // near ~35% (clear signal, far from saturating) and the DEQ
                // needs several epochs to separate the classes — leaving
                // headroom for the Anderson-vs-forward comparison.
                let noise = 0.9 * rng.normal();
                out[(y * HW + x) * C + ch] = 0.55 * tex + 0.9 * blob + noise;
            }
        }
    }
}

/// Generate `n` images with balanced class labels, shuffled.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let dim = HW * HW * C;
    let mut images = vec![0.0f32; n * dim];
    let mut labels = vec![0i32; n];

    // Balanced labels, then shuffled for batching realism.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for (slot, &i) in order.iter().enumerate() {
        let class = slot % NUM_CLASSES;
        labels[i] = class as i32;
        let spec = class_spec(class);
        render(&spec, &mut rng, &mut images[i * dim..(i + 1) * dim]);
    }

    Dataset { images, labels, hw: HW, channels: C, num_classes: NUM_CLASSES }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(20, 7);
        let b = generate(20, 7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
    }

    #[test]
    fn seeds_differ() {
        let a = generate(10, 1);
        let b = generate(10, 2);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn balanced_classes() {
        let d = generate(100, 3);
        let h = d.class_histogram();
        assert_eq!(h, vec![10; 10]);
    }

    #[test]
    fn roughly_normalized() {
        let d = generate(50, 5);
        let n = d.images.len() as f32;
        let mean: f32 = d.images.iter().sum::<f32>() / n;
        let var: f32 =
            d.images.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n;
        assert!(mean.abs() < 0.5, "mean={mean}");
        assert!(var > 0.2 && var < 5.0, "var={var}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-class-centroid on raw pixels should beat chance clearly —
        // the signal a model needs is present.
        let train = generate(400, 11);
        let test = generate(100, 12);
        let dim = train.image_dim();
        let mut centroids = vec![0.0f64; NUM_CLASSES * dim];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..train.len() {
            let k = train.labels[i] as usize;
            counts[k] += 1;
            for (j, &v) in train.image(i).iter().enumerate() {
                centroids[k * dim + j] += v as f64;
            }
        }
        for k in 0..NUM_CLASSES {
            for j in 0..dim {
                centroids[k * dim + j] /= counts[k] as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let img = test.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for k in 0..NUM_CLASSES {
                let mut d2 = 0.0f64;
                for j in 0..dim {
                    let d = img[j] as f64 - centroids[k * dim + j];
                    d2 += d * d;
                }
                if d2 < best.0 {
                    best = (d2, k);
                }
            }
            if best.1 == test.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / test.len() as f32;
        assert!(acc > 0.3, "nearest-centroid acc={acc} (chance=0.1)");
    }

    #[test]
    fn gather_layout() {
        let d = generate(10, 4);
        let (imgs, labs) = d.gather(&[3, 7]);
        assert_eq!(imgs.len(), 2 * d.image_dim());
        assert_eq!(labs, vec![d.labels[3], d.labels[7]]);
        assert_eq!(&imgs[..d.image_dim()], d.image(3));
    }
}

//! Real CIFAR-10 loader (binary version, `cifar-10-batches-bin`).
//!
//! Record format: 1 byte label + 3072 bytes pixels (R plane, then G, then
//! B, each 32x32 row-major), 10000 records per file.  Pixels are converted
//! to f32, per-channel standardized with the canonical CIFAR-10 statistics,
//! and transposed to NHWC to match the model's layout.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;

const HW: usize = 32;
const C: usize = 3;
const RECORD: usize = 1 + HW * HW * C;

/// Canonical CIFAR-10 channel means / stds (of pixel/255).
const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];

/// Parse one binary batch file into (images NHWC, labels), appending.
fn parse_batch(
    bytes: &[u8],
    limit: usize,
    images: &mut Vec<f32>,
    labels: &mut Vec<i32>,
) -> Result<usize> {
    if bytes.len() % RECORD != 0 {
        bail!("batch file size {} not a multiple of {}", bytes.len(), RECORD);
    }
    let n = (bytes.len() / RECORD).min(limit);
    for r in 0..n {
        let rec = &bytes[r * RECORD..(r + 1) * RECORD];
        let label = rec[0];
        if label > 9 {
            bail!("record {r}: label {label} out of range");
        }
        labels.push(label as i32);
        // CHW planes -> NHWC standardized floats.
        for y in 0..HW {
            for x in 0..HW {
                for ch in 0..C {
                    let v = rec[1 + ch * HW * HW + y * HW + x] as f32 / 255.0;
                    images.push((v - MEAN[ch]) / STD[ch]);
                }
            }
        }
    }
    Ok(n)
}

/// Load up to `train_size` training images (data_batch_1..5.bin) and
/// `test_size` test images (test_batch.bin) from `dir`.
pub fn load_cifar10(
    dir: &Path,
    train_size: usize,
    test_size: usize,
) -> Result<(Dataset, Dataset)> {
    let mut tr_images = Vec::new();
    let mut tr_labels = Vec::new();
    let mut remaining = train_size;
    for i in 1..=5 {
        if remaining == 0 {
            break;
        }
        let path = dir.join(format!("data_batch_{i}.bin"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let got = parse_batch(&bytes, remaining, &mut tr_images, &mut tr_labels)?;
        remaining -= got;
    }

    let mut te_images = Vec::new();
    let mut te_labels = Vec::new();
    let test_path = dir.join("test_batch.bin");
    let bytes = std::fs::read(&test_path)
        .with_context(|| format!("reading {}", test_path.display()))?;
    parse_batch(&bytes, test_size, &mut te_images, &mut te_labels)?;

    let mk = |images, labels| Dataset {
        images,
        labels,
        hw: HW,
        channels: C,
        num_classes: 10,
    };
    Ok((mk(tr_images, tr_labels), mk(te_images, te_labels)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny fake batch file in memory.
    fn fake_batch(n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n * RECORD);
        for r in 0..n {
            out.push((r % 10) as u8);
            for p in 0..HW * HW * C {
                out.push(((r * 31 + p) % 256) as u8);
            }
        }
        out
    }

    #[test]
    fn parses_fake_batch() {
        let bytes = fake_batch(5);
        let mut imgs = Vec::new();
        let mut labs = Vec::new();
        let n = parse_batch(&bytes, 100, &mut imgs, &mut labs).unwrap();
        assert_eq!(n, 5);
        assert_eq!(labs, vec![0, 1, 2, 3, 4]);
        assert_eq!(imgs.len(), 5 * HW * HW * C);
        assert!(imgs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn respects_limit() {
        let bytes = fake_batch(5);
        let mut imgs = Vec::new();
        let mut labs = Vec::new();
        let n = parse_batch(&bytes, 2, &mut imgs, &mut labs).unwrap();
        assert_eq!(n, 2);
        assert_eq!(labs.len(), 2);
    }

    #[test]
    fn rejects_bad_size() {
        let mut imgs = Vec::new();
        let mut labs = Vec::new();
        assert!(parse_batch(&[0u8; 100], 1, &mut imgs, &mut labs).is_err());
    }

    #[test]
    fn rejects_bad_label() {
        let mut bytes = fake_batch(1);
        bytes[0] = 99;
        let mut imgs = Vec::new();
        let mut labs = Vec::new();
        assert!(parse_batch(&bytes, 1, &mut imgs, &mut labs).is_err());
    }

    #[test]
    fn channel_transpose_is_nhwc() {
        // Pixel (y=0,x=0): planes R,G,B at offsets 1, 1+1024, 1+2048.
        let mut bytes = vec![0u8; RECORD];
        bytes[0] = 3;
        bytes[1] = 255; // R(0,0)
        bytes[1 + 1024] = 0; // G(0,0)
        bytes[1 + 2048] = 128; // B(0,0)
        let mut imgs = Vec::new();
        let mut labs = Vec::new();
        parse_batch(&bytes, 1, &mut imgs, &mut labs).unwrap();
        let r = (255.0 / 255.0 - MEAN[0]) / STD[0];
        let g = (0.0 - MEAN[1]) / STD[1];
        let b = (128.0 / 255.0 - MEAN[2]) / STD[2];
        assert!((imgs[0] - r).abs() < 1e-5);
        assert!((imgs[1] - g).abs() < 1e-5);
        assert!((imgs[2] - b).abs() < 1e-5);
    }
}

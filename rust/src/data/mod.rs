//! Data pipeline: CIFAR10-like datasets and batching.
//!
//! Per DESIGN.md §Substitutions: the build environment has no network, so
//! the default dataset is a deterministic *synthetic* CIFAR10-like
//! generator with class-conditional structure (the paper's evaluation
//! measures solver behaviour, which needs a learnable 10-class 32x32x3
//! task, not CIFAR's specific pixels).  If a real CIFAR-10 binary
//! directory is present (`data/cifar-10-batches-bin/`), [`load_auto`]
//! uses it instead.

pub mod batcher;
pub mod cifar;
pub mod synthetic;

pub use batcher::Batcher;

/// An in-memory labeled image dataset, NHWC f32.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<f32>, // (n, hw, hw, c) row-major
    pub labels: Vec<i32>, // (n,)
    pub hw: usize,
    pub channels: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image_dim(&self) -> usize {
        self.hw * self.hw * self.channels
    }

    /// Borrow image `i` as a flat slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let d = self.image_dim();
        &self.images[i * d..(i + 1) * d]
    }

    /// Gather a batch by indices into (images, labels) flat buffers.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let d = self.image_dim();
        let mut imgs = Vec::with_capacity(idx.len() * d);
        let mut labs = Vec::with_capacity(idx.len());
        for &i in idx {
            imgs.extend_from_slice(self.image(i));
            labs.push(self.labels[i]);
        }
        (imgs, labs)
    }

    /// Per-class counts (sanity checks / stratification).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

/// Load real CIFAR-10 if available at `data/cifar-10-batches-bin`,
/// otherwise generate the synthetic dataset.  Returns (train, test, name).
pub fn load_auto(
    train_size: usize,
    test_size: usize,
    seed: u64,
) -> (Dataset, Dataset, &'static str) {
    let dir = std::path::Path::new("data/cifar-10-batches-bin");
    if dir.exists() {
        if let Ok((train, test)) = cifar::load_cifar10(dir, train_size, test_size) {
            return (train, test, "cifar10");
        }
    }
    let train = synthetic::generate(train_size, seed);
    let test = synthetic::generate(test_size, seed ^ 0x5EED_7E57);
    (train, test, "synthetic-cifar10")
}

//! Inference engine: encode → equilibrium solve → classify, with batch
//! padding to the compiled buckets and dataset-level evaluation.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::Dataset;
use crate::model::ParamSet;
use crate::runtime::{Backend, HostTensor};
use crate::solver::{self, SolveOptions};

/// Result of one inference call.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub logits: Vec<Vec<f32>>, // per sample
    pub predictions: Vec<usize>,
    pub solver_iters: usize,
    pub solver_residual: f32,
    pub latency: Duration,
}

/// Argmax over one logit row.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Softmax cross-entropy of one row against a label (host-side metric).
pub fn cross_entropy(row: &[f32], label: usize) -> f32 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = m + row.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
    lse - row[label]
}

/// Run inference on `images` (flat NHWC, `count` samples).  Pads up to the
/// smallest compiled batch bucket and slices the results back.
pub fn infer(
    engine: &dyn Backend,
    params: &ParamSet,
    images: &[f32],
    count: usize,
    opts: &SolveOptions,
) -> Result<InferResult> {
    let meta = engine.manifest().model.clone();
    let dim = meta.image_dim();
    anyhow::ensure!(images.len() == count * dim, "image buffer size mismatch");
    let bucket = engine.manifest().bucket_for("encode", count)?;
    anyhow::ensure!(count <= bucket, "batch {count} exceeds largest bucket {bucket}");

    let t0 = Instant::now();
    // Pad with zeros to the bucket.
    let mut buf = images.to_vec();
    buf.resize(bucket * dim, 0.0);
    let x_img = HostTensor::f32(meta.image_shape(bucket), buf)?;

    let mut enc_in: Vec<HostTensor> = params.tensors.clone();
    enc_in.push(x_img);
    let x_feat = engine.execute("encode", bucket, &enc_in)?.remove(0);

    let report = solver::solve(engine, &params.tensors, &x_feat, opts)?;

    let mut cls_in: Vec<HostTensor> = params.tensors.clone();
    cls_in.push(report.z_star.clone());
    let logits_t = engine.execute("classify", bucket, &cls_in)?.remove(0);
    let nc = meta.num_classes;
    let flat = logits_t.f32s()?;

    let logits: Vec<Vec<f32>> = (0..count)
        .map(|i| flat[i * nc..(i + 1) * nc].to_vec())
        .collect();
    let predictions = logits.iter().map(|r| argmax(r)).collect();

    Ok(InferResult {
        logits,
        predictions,
        solver_iters: report.iters(),
        solver_residual: report.final_residual(),
        latency: t0.elapsed(),
    })
}

/// Dataset accuracy with the DEQ path.
pub fn evaluate(
    engine: &dyn Backend,
    params: &ParamSet,
    data: &Dataset,
    batch: usize,
    opts: &SolveOptions,
) -> Result<f32> {
    let mut correct = 0usize;
    let mut seen = 0usize;
    let n_batches = data.len() / batch;
    for b in 0..n_batches {
        let idx: Vec<usize> = (b * batch..(b + 1) * batch).collect();
        let (imgs, labels) = data.gather(&idx);
        let r = infer(engine, params, &imgs, batch, opts)?;
        for (p, l) in r.predictions.iter().zip(&labels) {
            if *p == *l as usize {
                correct += 1;
            }
        }
        seen += batch;
    }
    Ok(correct as f32 / seen.max(1) as f32)
}

/// Dataset accuracy with the explicit baseline network.
pub fn evaluate_explicit(
    engine: &dyn Backend,
    params: &ParamSet,
    data: &Dataset,
    batch: usize,
) -> Result<f32> {
    let meta = engine.manifest().model.clone();
    let nc = meta.num_classes;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let n_batches = data.len() / batch;
    for b in 0..n_batches {
        let idx: Vec<usize> = (b * batch..(b + 1) * batch).collect();
        let (imgs, labels) = data.gather(&idx);
        let x_img = HostTensor::f32(meta.image_shape(batch), imgs)?;
        let mut inputs: Vec<HostTensor> = params.tensors.clone();
        inputs.push(x_img);
        let logits_t = engine.execute("explicit_infer", batch, &inputs)?.remove(0);
        let flat = logits_t.f32s()?;
        for i in 0..batch {
            if argmax(&flat[i * nc..(i + 1) * nc]) == labels[i] as usize {
                correct += 1;
            }
        }
        seen += batch;
    }
    Ok(correct as f32 / seen.max(1) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn cross_entropy_sane() {
        // Confident correct prediction → small loss.
        let good = cross_entropy(&[10.0, 0.0, 0.0], 0);
        let bad = cross_entropy(&[10.0, 0.0, 0.0], 1);
        assert!(good < 0.01);
        assert!(bad > 5.0);
        // Uniform logits → ln(3).
        let u = cross_entropy(&[1.0, 1.0, 1.0], 2);
        assert!((u - 3.0f32.ln()).abs() < 1e-5);
    }
}

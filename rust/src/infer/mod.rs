//! Inference engine: encode → equilibrium solve → classify, with batch
//! padding to the compiled buckets and dataset-level evaluation.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::Dataset;
use crate::model::ParamSet;
use crate::runtime::{Backend, HostTensor};
use crate::solver::{self, SolveSpec};

/// Result of one inference call.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub logits: Vec<Vec<f32>>, // per sample
    pub predictions: Vec<usize>,
    /// Solve-loop iterations (what the whole batch waited for).
    pub solver_iters: usize,
    /// Cumulative cell evaluations of a lane active the whole solve.
    pub solver_fevals: usize,
    /// Per-sample iterations until each lane froze (lane order).
    pub sample_iters: Vec<usize>,
    /// Per-sample cell evaluations actually charged.
    pub sample_fevals: Vec<usize>,
    /// Per-sample converged flags.
    pub sample_converged: Vec<bool>,
    /// Per-sample quarantine flags: the lane's solve hit a non-finite
    /// residual and was retired with a numerical fault — its logits and
    /// prediction are garbage and callers must not trust them.
    pub sample_faulted: Vec<bool>,
    pub solver_residual: f32,
    pub latency: Duration,
}

/// Argmax over one logit row.  `total_cmp` rather than
/// `partial_cmp().unwrap()`: a quarantined lane's logits can be NaN, and
/// classifying a poisoned row must yield *a* class (the lane is reported
/// faulted), never a panic in the serving loop.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Softmax cross-entropy of one row against a label (host-side metric).
pub fn cross_entropy(row: &[f32], label: usize) -> f32 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = m + row.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
    lse - row[label]
}

/// Zero-pad `count` flat NHWC images up to `bucket` rows as the image
/// tensor every dispatch path shares (offline inference, the explicit
/// baseline, and the serving scheduler's admissions).
pub fn padded_image_tensor(
    meta: &crate::runtime::ModelMeta,
    images: &[f32],
    count: usize,
    bucket: usize,
) -> Result<HostTensor> {
    let dim = meta.image_dim();
    anyhow::ensure!(images.len() == count * dim, "image buffer size mismatch");
    anyhow::ensure!(count <= bucket, "batch {count} exceeds bucket {bucket}");
    let mut buf = images.to_vec();
    buf.resize(bucket * dim, 0.0);
    HostTensor::f32(meta.image_shape(bucket), buf)
}

/// Encode `count` images through the smallest compiled bucket that fits:
/// pad → params + x_img → `encode`.  Returns the feature tensor and the
/// bucket it rode.
pub fn encode_padded(
    engine: &dyn Backend,
    params: &ParamSet,
    images: &[f32],
    count: usize,
) -> Result<(HostTensor, usize)> {
    let meta = &engine.manifest().model;
    let bucket = engine.manifest().bucket_for("encode", count)?;
    let x_img = padded_image_tensor(meta, images, count, bucket)?;
    let mut enc_in: Vec<HostTensor> = params.tensors.clone();
    enc_in.push(x_img);
    let x_feat = engine.execute("encode", bucket, &enc_in)?.remove(0);
    Ok((x_feat, bucket))
}

/// Run inference on `images` (flat NHWC, `count` samples).  Pads up to the
/// smallest compiled batch bucket and slices the results back.
pub fn infer(
    engine: &dyn Backend,
    params: &ParamSet,
    images: &[f32],
    count: usize,
    spec: &SolveSpec,
) -> Result<InferResult> {
    let meta = engine.manifest().model.clone();
    let t0 = Instant::now();
    let (x_feat, bucket) = encode_padded(engine, params, images, count)?;

    let report = solver::solve_spec(engine, &params.tensors, &x_feat, spec)?;

    let mut cls_in: Vec<HostTensor> = params.tensors.clone();
    cls_in.push(report.z_star.clone());
    let logits_t = engine.execute("classify", bucket, &cls_in)?.remove(0);
    let nc = meta.num_classes;
    let flat = logits_t.f32s()?;

    let logits: Vec<Vec<f32>> = (0..count)
        .map(|i| flat[i * nc..(i + 1) * nc].to_vec())
        .collect();
    let predictions = logits.iter().map(|r| argmax(r)).collect();

    // Per-sample traces cover the padded bucket; slice to real samples.
    let take = |v: &[usize]| -> Vec<usize> {
        v.iter().take(count).copied().collect()
    };
    Ok(InferResult {
        logits,
        predictions,
        solver_iters: report.iters(),
        solver_fevals: report.fevals(),
        sample_iters: take(&report.sample_iters),
        sample_fevals: take(&report.sample_fevals),
        sample_converged: report
            .sample_converged
            .iter()
            .take(count)
            .copied()
            .collect(),
        sample_faulted: report
            .sample_faulted
            .iter()
            .take(count)
            .copied()
            .collect(),
        solver_residual: report.final_residual(),
        latency: t0.elapsed(),
    })
}

/// Dataset accuracy with the DEQ path.  The final partial batch (when
/// `data.len()` is not a multiple of `batch`) is evaluated through the
/// same bucket-padding path, so accuracy covers the whole dataset.
pub fn evaluate(
    engine: &dyn Backend,
    params: &ParamSet,
    data: &Dataset,
    batch: usize,
    spec: &SolveSpec,
) -> Result<f32> {
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut start = 0usize;
    while start < data.len() {
        let take = batch.min(data.len() - start);
        let idx: Vec<usize> = (start..start + take).collect();
        let (imgs, labels) = data.gather(&idx);
        let r = infer(engine, params, &imgs, take, spec)?;
        for (p, l) in r.predictions.iter().zip(&labels) {
            if *p == *l as usize {
                correct += 1;
            }
        }
        seen += take;
        start += take;
    }
    Ok(correct as f32 / seen.max(1) as f32)
}

/// Dataset accuracy with the explicit baseline network.  Like
/// [`evaluate`], the tail remainder rides a zero-padded bucket instead of
/// being dropped.
pub fn evaluate_explicit(
    engine: &dyn Backend,
    params: &ParamSet,
    data: &Dataset,
    batch: usize,
) -> Result<f32> {
    let meta = engine.manifest().model.clone();
    let nc = meta.num_classes;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut start = 0usize;
    while start < data.len() {
        let take = batch.min(data.len() - start);
        let idx: Vec<usize> = (start..start + take).collect();
        let (imgs, labels) = data.gather(&idx);
        let bucket = engine.manifest().bucket_for("explicit_infer", take)?;
        let x_img = padded_image_tensor(&meta, &imgs, take, bucket)?;
        let mut inputs: Vec<HostTensor> = params.tensors.clone();
        inputs.push(x_img);
        let logits_t = engine.execute("explicit_infer", bucket, &inputs)?.remove(0);
        let flat = logits_t.f32s()?;
        for i in 0..take {
            if argmax(&flat[i * nc..(i + 1) * nc]) == labels[i] as usize {
                correct += 1;
            }
        }
        seen += take;
        start += take;
    }
    Ok(correct as f32 / seen.max(1) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn argmax_survives_nan_rows() {
        // NaN sorts above every finite float under total_cmp, so a fully
        // poisoned row returns its last NaN index — any class is fine,
        // what matters is that it does not panic mid-serve.
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 1);
        // A partially poisoned row still never panics.
        let _ = argmax(&[0.5, f32::NAN, 0.9]);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn cross_entropy_sane() {
        // Confident correct prediction → small loss.
        let good = cross_entropy(&[10.0, 0.0, 0.0], 0);
        let bad = cross_entropy(&[10.0, 0.0, 0.0], 1);
        assert!(good < 0.01);
        assert!(bad > 5.0);
        // Uniform logits → ln(3).
        let u = cross_entropy(&[1.0, 1.0, 1.0], 2);
        assert!((u - 3.0f32.ln()).abs() < 1e-5);
    }
}

//! Run metrics: summary statistics, CSV emission, residual traces.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::rng::Rng;

/// Default reservoir capacity: enough raw samples for stable tail
/// percentiles while bounding a long-running server's memory.
const RESERVOIR_CAP: usize = 4096;

/// Online summary statistics (Welford) + a **bounded** reservoir of raw
/// values for percentiles.
///
/// The reservoir is a real one now (Vitter's Algorithm R, deterministic
/// via a fixed-seed [`Rng`]): under sustained serving traffic it holds at
/// most [`RESERVOIR_CAP`] samples, each retained with equal probability,
/// instead of growing without bound — the old `values.push` on every
/// sample was a memory leak dressed up as a reservoir.  Moments
/// (count/mean/std) and min/max stay exact over all samples;
/// percentiles are exact until the reservoir fills and within sampling
/// error after.
///
/// Non-finite samples (a NaN latency from a poisoned clock or a 0/0
/// rate) are counted in [`Self::non_finite`] and excluded from moments
/// and the reservoir: one bad sample must not poison the running mean —
/// or, as the old `partial_cmp().unwrap()` sort did, panic the whole
/// metrics snapshot.
#[derive(Debug, Clone)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    non_finite: u64,
    cap: usize,
    values: Vec<f64>,
    rng: Rng,
}

impl Default for Stats {
    fn default() -> Self {
        Self::with_capacity(RESERVOIR_CAP)
    }
}

impl Stats {
    /// A stats accumulator whose reservoir keeps at most `cap` samples.
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            non_finite: 0,
            cap: cap.max(1),
            values: Vec::new(),
            // Fixed seed: two Stats fed the same samples report the same
            // percentiles (reproducible benches and goldens).
            rng: Rng::new(0x5EED_57A7),
        }
    }

    pub fn push(&mut self, v: f64) {
        if !v.is_finite() {
            self.non_finite += 1;
            return;
        }
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        // Algorithm R: keep the first `cap` samples, then replace a
        // uniformly random slot with probability cap / n (n counts every
        // finite sample, i.e. every sample offered to the reservoir).
        if self.values.len() < self.cap {
            self.values.push(v);
        } else {
            let j = self.rng.below(self.n.min(usize::MAX as u64) as usize);
            if j < self.cap {
                self.values[j] = v;
            }
        }
    }

    pub fn push_duration(&mut self, d: Duration) {
        self.push(d.as_secs_f64());
    }

    /// Finite samples recorded (non-finite ones are counted separately).
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Non-finite samples rejected at `push`.
    pub fn non_finite(&self) -> u64 {
        self.non_finite
    }

    /// Samples currently held by the reservoir (≤ capacity).
    pub fn reservoir_len(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut v = self.values.clone();
        // total_cmp: a defensive total order — even if a non-finite value
        // ever reached the reservoir, sorting must not panic the
        // metrics snapshot.
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Exact minimum over every finite sample (+∞ before any, matching
    /// the old fold-over-empty behaviour).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::INFINITY
        } else {
            self.min
        }
    }

    /// Exact maximum over every finite sample.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NEG_INFINITY
        } else {
            self.max
        }
    }
}

/// A simple CSV table builder (header + typed rows), written atomically.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(columns: &[&str]) -> Self {
        Self {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, values: &[String]) {
        assert_eq!(values.len(), self.header.len(), "csv row arity");
        self.rows.push(values.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|s| esc(s)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, self.to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Format helpers shared by experiment reports.
pub fn fmt_duration(d: Duration) -> String {
    if d.as_secs_f64() >= 1.0 {
        format!("{:.2}s", d.as_secs_f64())
    } else if d.as_millis() >= 1 {
        format!("{:.2}ms", d.as_secs_f64() * 1e3)
    } else {
        format!("{:.1}us", d.as_secs_f64() * 1e6)
    }
}

pub fn fmt_pct(x: f32) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_moments() {
        let mut s = Stats::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.percentile(50.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_is_bounded_and_percentiles_hold() {
        // Regression: `values` grew unbounded under sustained traffic.
        let mut s = Stats::default();
        let total = 100_000u64;
        for i in 0..total {
            // A deterministic uniform-ish ramp over [0, 1).
            s.push((i % 1000) as f64 / 1000.0);
        }
        assert_eq!(s.count(), total);
        assert!(s.reservoir_len() <= RESERVOIR_CAP, "reservoir leaked");
        // Moments and extrema stay exact...
        assert!((s.mean() - 0.4995).abs() < 1e-9, "mean {}", s.mean());
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.999);
        // ...and percentiles are correct within sampling error.
        assert!((s.percentile(50.0) - 0.5).abs() < 0.05, "{}", s.percentile(50.0));
        assert!((s.percentile(95.0) - 0.95).abs() < 0.05, "{}", s.percentile(95.0));
    }

    #[test]
    fn reservoir_sampling_is_deterministic() {
        let mut a = Stats::default();
        let mut b = Stats::default();
        for i in 0..50_000 {
            let v = ((i * 2654435761u64) % 10_000) as f64;
            a.push(v);
            b.push(v);
        }
        for p in [1.0, 25.0, 50.0, 90.0, 99.0] {
            assert_eq!(a.percentile(p), b.percentile(p));
        }
    }

    #[test]
    fn nan_sample_does_not_panic_or_poison() {
        // Regression: one NaN latency used to panic the metrics snapshot
        // via `partial_cmp().unwrap()`, and would have stuck the Welford
        // mean at NaN forever.
        let mut s = Stats::default();
        s.push(1.0);
        s.push(f64::NAN);
        s.push(f64::INFINITY);
        s.push(3.0);
        assert_eq!(s.count(), 2);
        assert_eq!(s.non_finite(), 2);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        let p50 = s.percentile(50.0); // must not panic
        assert!(p50.is_finite());
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
    }

    #[test]
    fn tiny_capacity_reservoir_still_answers() {
        let mut s = Stats::with_capacity(4);
        for i in 0..1000 {
            s.push(i as f64);
        }
        assert_eq!(s.reservoir_len(), 4);
        assert!(s.percentile(50.0).is_finite());
        assert_eq!(s.count(), 1000);
    }

    #[test]
    fn csv_escaping_and_shape() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "x,y".into()]);
        c.row(&["2".into(), "q\"z".into()]);
        let text = c.to_string();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"q\"\"z\""));
        assert_eq!(c.len(), 2);
    }

    #[test]
    #[should_panic]
    fn csv_arity_checked() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["only-one".into()]);
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0us");
        assert_eq!(fmt_pct(0.123), "12.3%");
    }
}

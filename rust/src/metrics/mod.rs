//! Run metrics: summary statistics, CSV emission, residual traces.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

/// Online summary statistics (Welford) + reservoir of raw values for
/// percentiles.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    values: Vec<f64>,
}

impl Stats {
    pub fn push(&mut self, v: f64) {
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
        self.values.push(v);
    }

    pub fn push_duration(&mut self, d: Duration) {
        self.push(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// A simple CSV table builder (header + typed rows), written atomically.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(columns: &[&str]) -> Self {
        Self {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, values: &[String]) {
        assert_eq!(values.len(), self.header.len(), "csv row arity");
        self.rows.push(values.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|s| esc(s)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, self.to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Format helpers shared by experiment reports.
pub fn fmt_duration(d: Duration) -> String {
    if d.as_secs_f64() >= 1.0 {
        format!("{:.2}s", d.as_secs_f64())
    } else if d.as_millis() >= 1 {
        format!("{:.2}ms", d.as_secs_f64() * 1e3)
    } else {
        format!("{:.1}us", d.as_secs_f64() * 1e6)
    }
}

pub fn fmt_pct(x: f32) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_moments() {
        let mut s = Stats::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.percentile(50.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn csv_escaping_and_shape() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "x,y".into()]);
        c.row(&["2".into(), "q\"z".into()]);
        let text = c.to_string();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"q\"\"z\""));
        assert_eq!(c.len(), 2);
    }

    #[test]
    #[should_panic]
    fn csv_arity_checked() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["only-one".into()]);
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0us");
        assert_eq!(fmt_pct(0.123), "12.3%");
    }
}

//! The execution-backend abstraction: the engine contract the coordinator
//! actually uses.
//!
//! Everything above the runtime layer (solvers, trainer, inference,
//! serving, experiments) speaks to compute through [`Backend`]:
//! `execute(entry, batch, inputs)` over [`HostTensor`]s, plus the manifest
//! that names every entry point's signature.  Two implementations ship:
//!
//!   * [`crate::runtime::NativeEngine`] — pure Rust, hermetic, serves every
//!     entry point from the `native/` substrate; the default backend and
//!     the one CI tests against.
//!   * [`crate::runtime::Engine`] (feature `pjrt`) — loads and executes the
//!     AOT HLO artifacts through PJRT.
//!
//! Both share the manifest-driven input validation and the per-entry
//! execution statistics defined here, so a solver trace or a serving
//! benchmark reads identically regardless of substrate.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::model::ParamSet;
use crate::runtime::manifest::{EntrySpec, Manifest};
use crate::runtime::native_engine::NativeEngine;
use crate::runtime::tensor::HostTensor;

/// Cumulative execution stats for one (entry, batch) pair.
#[derive(Debug, Clone, Default)]
pub struct EntryStats {
    pub calls: u64,
    pub total: Duration,
    pub compile_time: Duration,
}

impl EntryStats {
    pub fn mean(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            self.total / self.calls as u32
        }
    }
}

/// An execution substrate serving the manifest's entry points.
pub trait Backend: Send + Sync {
    /// The contract: entry signatures, model geometry, solver defaults.
    fn manifest(&self) -> &Manifest;

    /// Human-readable substrate name (e.g. "cpu", "native-cpu").
    fn platform(&self) -> String;

    /// Execute one entry point at a batch bucket.  Implementations must
    /// validate `inputs` against the manifest spec (see [`check_inputs`])
    /// and return exactly the spec'd outputs.
    fn execute(
        &self,
        name: &str,
        batch: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>>;

    /// The deterministic initial parameter set this backend was built for
    /// (the AOT init checkpoint for PJRT; a seeded init for the native
    /// twin).
    fn init_params(&self) -> Result<ParamSet>;

    /// Return spent output tensors to the backend's scratch pool so
    /// steady-state solve loops stop allocating: the native engine
    /// re-issues the returned buffers from its [`crate::native::Workspace`]
    /// on the next `execute`.  Callers must hand back only tensors they
    /// own exclusively (a `HostTensor` clone is a deep copy, so this is
    /// the default).  Backends without a pool simply drop them — the
    /// default — which makes `recycle` always safe to call.
    fn recycle(&self, _tensors: Vec<HostTensor>) {}

    /// Prepare a set of entries so hot paths pay no first-call cost.
    /// Default: just validate the entries exist.
    fn warmup(&self, entries: &[(&str, usize)]) -> Result<()> {
        for (name, batch) in entries {
            self.manifest().entry(name, *batch)?;
        }
        Ok(())
    }

    /// Snapshot of per-entry stats, sorted by total time descending.
    fn stats(&self) -> Vec<((String, usize), EntryStats)>;

    /// Hot-path health counters (workspace pool + packed-weight cache)
    /// for backends that have them — the native engine reports its
    /// [`crate::native::WorkspaceStats`]; substrates without a pooled
    /// hot path return `None` (the default).  Serving stats surface
    /// these so pack-cache behaviour is observable in production.
    fn hot_stats(&self) -> Option<crate::native::WorkspaceStats> {
        None
    }

    /// Human-readable stats table (for `--stats` / experiment footers).
    fn stats_report(&self) -> String {
        render_stats(&self.stats())
    }

    /// Total faults injected by a [`crate::runtime::faults::FaultInjector`]
    /// wrapping this backend; `0` (the default) for every real substrate.
    /// Serving stats surface this so chaos runs can assert their plan
    /// actually fired.
    fn faults_injected(&self) -> u64 {
        0
    }
}

/// Validate an input list against an entry spec (count, shape, dtype).
/// Shared by every backend so error messages are uniform.
pub fn check_inputs(
    spec: &EntrySpec,
    name: &str,
    batch: usize,
    inputs: &[HostTensor],
) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{name}@b{batch}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
    }
    for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if t.shape != s.shape {
            bail!(
                "{name}@b{batch} input {i} ({}): shape {:?} != spec {:?}",
                s.name,
                t.shape,
                s.shape
            );
        }
        if t.dtype() != s.dtype {
            bail!("{name}@b{batch} input {i} ({}): dtype mismatch", s.name);
        }
    }
    Ok(())
}

/// Thread-safe per-entry stats ledger shared by backend implementations.
#[derive(Debug, Default)]
pub struct StatsBook {
    inner: Mutex<HashMap<(String, usize), EntryStats>>,
}

impl StatsBook {
    pub fn record(&self, name: &str, batch: usize, elapsed: Duration) {
        let mut book = self.inner.lock().unwrap();
        let e = book.entry((name.to_string(), batch)).or_default();
        e.calls += 1;
        e.total += elapsed;
    }

    pub fn record_compile(&self, name: &str, batch: usize, t: Duration) {
        let mut book = self.inner.lock().unwrap();
        book.entry((name.to_string(), batch)).or_default().compile_time = t;
    }

    /// Sorted snapshot (total time descending).
    pub fn snapshot(&self) -> Vec<((String, usize), EntryStats)> {
        let mut v: Vec<_> = self
            .inner
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total.cmp(&a.1.total));
        v
    }
}

/// Render a stats snapshot as the standard fixed-width table.
pub fn render_stats(rows: &[((String, usize), EntryStats)]) -> String {
    let mut out = String::from(
        "entry                         batch    calls     mean       total      compile\n",
    );
    for ((name, batch), s) in rows {
        out.push_str(&format!(
            "{:<30}{:>5}{:>9}{:>12.3?}{:>12.3?}{:>12.3?}\n",
            name,
            batch,
            s.calls,
            s.mean(),
            s.total,
            s.compile_time
        ));
    }
    out
}

/// Build a backend by explicit choice:
///
///   * `"native"` — the hermetic pure-Rust [`NativeEngine`];
///   * `"pjrt"`   — the PJRT `Engine` over `dir` (errors unless built
///     with the `pjrt` feature);
///   * `"auto"`   — PJRT when the feature is enabled *and*
///     `dir/manifest.json` exists, native otherwise.
pub fn select_backend(choice: &str, dir: &Path) -> Result<Arc<dyn Backend>> {
    // Chaos runs wrap whatever substrate was chosen; with `DEQ_FAULTS`
    // unset this is the identity (same Arc, no decorator, no cost).
    crate::runtime::faults::wrap_from_env(select_raw(choice, dir)?)
}

fn select_raw(choice: &str, dir: &Path) -> Result<Arc<dyn Backend>> {
    if choice == "native" {
        return Ok(Arc::new(NativeEngine::tiny()));
    }
    if choice == "pjrt" {
        #[cfg(feature = "pjrt")]
        return Ok(Arc::new(crate::runtime::engine::Engine::new(dir)?));
        #[cfg(not(feature = "pjrt"))]
        bail!(
            "backend 'pjrt' unavailable: this build has no XLA support \
             (rebuild with `--features pjrt`)"
        );
    }
    if choice != "auto" {
        bail!("unknown backend '{choice}' (expected auto|native|pjrt)");
    }
    #[cfg(feature = "pjrt")]
    if dir.join("manifest.json").exists() {
        return Ok(Arc::new(crate::runtime::engine::Engine::new(dir)?));
    }
    let _ = dir;
    Ok(Arc::new(NativeEngine::tiny()))
}

/// `select_backend("auto", dir)` — the common entry point for binaries,
/// benches and tests: PJRT over real artifacts when available, the
/// hermetic native twin otherwise.
pub fn backend_from_dir(dir: impl AsRef<Path>) -> Result<Arc<dyn Backend>> {
    select_backend("auto", dir.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_stats_mean() {
        let mut s = EntryStats::default();
        assert_eq!(s.mean(), Duration::ZERO);
        s.calls = 4;
        s.total = Duration::from_millis(8);
        assert_eq!(s.mean(), Duration::from_millis(2));
    }

    #[test]
    fn stats_book_records_and_sorts() {
        let book = StatsBook::default();
        book.record("a", 1, Duration::from_millis(1));
        book.record("b", 8, Duration::from_millis(5));
        book.record("a", 1, Duration::from_millis(1));
        book.record_compile("a", 1, Duration::from_millis(9));
        let snap = book.snapshot();
        assert_eq!(snap.len(), 2);
        // b has the larger total, so it sorts first.
        assert_eq!(snap[0].0, ("b".to_string(), 8));
        assert_eq!(snap[1].1.calls, 2);
        assert_eq!(snap[1].1.compile_time, Duration::from_millis(9));
        let table = render_stats(&snap);
        assert!(table.contains("entry"));
        assert!(table.contains('b'));
    }

    #[test]
    fn select_backend_native_and_unknown() {
        let b = select_backend("native", Path::new(".")).unwrap();
        assert_eq!(b.platform(), "native-cpu");
        assert!(select_backend("bogus", Path::new(".")).is_err());
    }

    #[test]
    fn auto_falls_back_to_native_without_artifacts() {
        let dir = std::env::temp_dir().join("deqa_no_artifacts_here");
        let b = backend_from_dir(&dir).unwrap();
        // Without artifacts (or without the pjrt feature) auto == native.
        assert!(!b.manifest().entries.is_empty());
    }
}

//! `NativeEngine`: the pure-Rust twin of the PJRT `Engine`, serving every
//! manifest entry point from the `native/` substrate with no XLA, no AOT
//! artifacts, and no files on disk.
//!
//! The engine exists so the coordinator's *policy* layer — windowed
//! Anderson mixing, crossover detection, stagnation fallback, dynamic
//! batching, JFB training — is testable hermetically: the integration test
//! tier runs against this backend in CI instead of skipping when
//! `artifacts/manifest.json` is absent, and parity tests cross-check its
//! `anderson_update` against the reference math in [`crate::native`].
//!
//! The served model is a deliberately small DEQ with the same tensor
//! contract as the AOT artifacts:
//!
//! ```text
//! encode:    x_feat = W_enc·vec(x_img) + b_enc            (random proj)
//! cell_step: f(z,x) = tanh(W_cell·z + b_cell + x)          (contraction)
//! classify:  logits = W_cls·z + b_cls
//! ```
//!
//! `W_cell` is initialized with spectral radius < 1, so forward iteration
//! converges linearly and Anderson accelerates exactly as on the compiled
//! artifacts.  Masking semantics, residual outputs (`‖f−z‖`, `‖f‖` per
//! sample), batch bucketing and the training-update output layout
//! (params, momentum, loss, correct) are identical to the PJRT entries
//! (`crate::runtime::Engine`, behind the `pjrt` feature).

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::model::ParamSet;
use crate::native::anderson::mix_masked_window;
use crate::native::pack::{self, PackPrecision, PackedB, SimdLevel};
use crate::native::{kernels, PoolStats, WorkerPool, Workspace, WorkspaceStats};
use crate::runtime::backend::{check_inputs, Backend, EntryStats, StatsBook};
use crate::runtime::manifest::{
    EntrySpec, Manifest, ModelMeta, SolverMeta, TensorSpec, TrainMeta,
};
use crate::runtime::tensor::{Dtype, HostTensor, TensorData};
use crate::util::rng::Rng;

/// Parameter slots, in canonical manifest order.
const P_W_ENC: usize = 0;
const P_B_ENC: usize = 1;
const P_W_CELL: usize = 2;
const P_B_CELL: usize = 3;
const P_W_CLS: usize = 4;
const P_B_CLS: usize = 5;
/// Number of parameter tensors.
const NP: usize = 6;

/// Geometry + hyperparameters of the native model.  The defaults mirror
/// the AOT pipeline's shapes where it matters (32×32×3 images, 10
/// classes, window-5 Anderson) at a latent size small enough that the
/// full integration tier runs in seconds.
#[derive(Debug, Clone)]
pub struct NativeConfig {
    pub image_hw: usize,
    pub image_channels: usize,
    pub latent_hw: usize,
    pub channels: usize,
    pub groups: usize,
    pub num_classes: usize,
    /// Batch buckets entries are "compiled" for (ascending).
    pub buckets: Vec<usize>,
    pub solver: SolverMeta,
    pub train: TrainMeta,
    /// Spectral scale of the cell weight init (< 1 ⇒ contraction).
    pub cell_gain: f32,
    /// Seed of the deterministic parameter init.
    pub init_seed: u64,
    /// Worker threads for the engine's persistent pool; `0` (the
    /// default) reads `DEQ_NATIVE_THREADS` once at engine construction
    /// (see [`kernels::max_threads`]).  Tests pin explicit sizes to
    /// exercise serial vs parallel paths deterministically.
    pub threads: usize,
    /// Microkernel SIMD level; `None` (the default) resolves the
    /// `DEQ_NATIVE_SIMD` knob against CPU detection once at engine
    /// construction ([`SimdLevel::from_env`]).  Tests pin explicit
    /// levels to exercise scalar vs SIMD paths without env races.
    pub simd: Option<SimdLevel>,
    /// Packed-panel storage precision; `None` (the default) reads
    /// `DEQ_NATIVE_PRECISION` once at engine construction
    /// ([`PackPrecision::from_env`]).
    pub precision: Option<PackPrecision>,
    /// Optional fault-injection plan text (see [`crate::runtime::faults`]
    /// for the format).  `None` — the default — builds no injector at
    /// all; construct through [`crate::runtime::faults::native_with_faults`]
    /// for the knob to take effect (the engine itself never injects).
    pub faults: Option<String>,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self {
            image_hw: 32,
            image_channels: 3,
            latent_hw: 4,
            channels: 4,
            groups: 1,
            num_classes: 10,
            buckets: vec![1, 8, 32],
            solver: SolverMeta {
                window: 5,
                beta: 1.0,
                lam: 1e-4,
                tol: 1e-3,
                max_iter: 60,
                fused_steps: 8,
            },
            train: TrainMeta {
                lr: 0.01,
                momentum: 0.9,
                neumann_terms: 3,
                explicit_depth: 6,
            },
            cell_gain: 0.8,
            init_seed: 17,
            threads: 0,
            simd: None,
            precision: None,
            faults: None,
        }
    }
}

impl NativeConfig {
    pub fn image_dim(&self) -> usize {
        self.image_hw * self.image_hw * self.image_channels
    }

    pub fn latent_dim(&self) -> usize {
        self.latent_hw * self.latent_hw * self.channels
    }

    /// Canonical parameter layout (order defines the flat checkpoint).
    fn param_specs(&self) -> Vec<TensorSpec> {
        let (idim, n, nc) = (self.image_dim(), self.latent_dim(), self.num_classes);
        let f32spec = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            shape,
            dtype: Dtype::F32,
        };
        vec![
            f32spec("w_enc", vec![idim, n]),
            f32spec("b_enc", vec![n]),
            f32spec("w_cell", vec![n, n]),
            f32spec("b_cell", vec![n]),
            f32spec("w_cls", vec![n, nc]),
            f32spec("b_cls", vec![nc]),
        ]
    }
}

/// out[j] = b[j] + Σ_i x[i]·w[i·out_dim + j]   (w row-major (in_dim, out_dim)).
/// The per-sample reference the packed batch paths replaced — kept as
/// the parity oracle for the engine unit tests.
#[cfg(test)]
fn affine(x: &[f32], w: &[f32], b: &[f32], in_dim: usize, out_dim: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), in_dim);
    debug_assert_eq!(w.len(), in_dim * out_dim);
    debug_assert_eq!(b.len(), out_dim);
    debug_assert_eq!(out.len(), out_dim);
    out.copy_from_slice(b);
    for i in 0..in_dim {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for j in 0..out_dim {
            out[j] += xi * row[j];
        }
    }
}

/// One cell application f = tanh(W_cell·z + b_cell + x) for one sample
/// (test-only parity oracle, like [`affine`]).
#[cfg(test)]
fn cell_apply(w_cell: &[f32], b_cell: &[f32], z: &[f32], x: &[f32], n: usize, out: &mut [f32]) {
    affine(z, w_cell, b_cell, n, n, out);
    for j in 0..n {
        out[j] = (out[j] + x[j]).tanh();
    }
}

/// Softmax cross-entropy on one logits row.  Returns the loss, whether
/// the argmax equals `label`, and dL/dlogits pre-scaled by `inv_b`.
fn softmax_xent(logits: &[f32], label: usize, inv_b: f32) -> (f32, bool, Vec<f32>) {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = logits.iter().map(|v| (v - mx).exp()).collect();
    let psum: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= psum;
    }
    let loss = psum.ln() + mx - logits[label];
    let pred = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let mut dl = probs;
    dl[label] -= 1.0;
    for d in dl.iter_mut() {
        *d *= inv_b;
    }
    (loss, pred == label, dl)
}

/// v = W_cls·dl — the loss cotangent pulled back to the classifier input.
fn vjp_classifier(w_cls: &[f32], dl: &[f32], n: usize, nc: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    for j in 0..n {
        let row = &w_cls[j * nc..(j + 1) * nc];
        let mut acc = 0.0f32;
        for c in 0..nc {
            acc += row[c] * dl[c];
        }
        v[j] = acc;
    }
    v
}

/// Per-sample parameter-gradient accumulation shared by every training
/// entry: classifier grads from (`cls_feat`, `dl`), cell grads from the
/// final cell step's input `cell_in` and pre-activation cotangent `u`,
/// encoder grads from the image `xb` (x_feat enters the cell additively).
#[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
fn add_param_grads(
    grads: &mut [Vec<f32>],
    cls_feat: &[f32],
    cell_in: &[f32],
    xb: &[f32],
    dl: &[f32],
    u: &[f32],
    idim: usize,
    n: usize,
    nc: usize,
) {
    for j in 0..n {
        let zj = cls_feat[j];
        if zj != 0.0 {
            let grow = &mut grads[P_W_CLS][j * nc..(j + 1) * nc];
            for c in 0..nc {
                grow[c] += zj * dl[c];
            }
        }
    }
    for c in 0..nc {
        grads[P_B_CLS][c] += dl[c];
    }
    for kk in 0..n {
        let zk = cell_in[kk];
        if zk != 0.0 {
            let grow = &mut grads[P_W_CELL][kk * n..(kk + 1) * n];
            for j in 0..n {
                grow[j] += zk * u[j];
            }
        }
    }
    for j in 0..n {
        grads[P_B_CELL][j] += u[j];
        grads[P_B_ENC][j] += u[j];
    }
    for i in 0..idim {
        let xi = xb[i];
        if xi != 0.0 {
            let grow = &mut grads[P_W_ENC][i * n..(i + 1) * n];
            for j in 0..n {
                grow[j] += xi * u[j];
            }
        }
    }
}

/// One pack-cache slot: the parameter revision the packs were built
/// from, plus up to one resident pack per storage precision.  Both
/// precisions key off the same `version`, so a new parameter revision
/// drops them together — the f32 and bf16 panels of a slot can never
/// disagree about which weights they hold.
#[derive(Debug)]
struct PackEntry {
    version: u64,
    f32_pack: Option<Arc<PackedB>>,
    bf16_pack: Option<Arc<PackedB>>,
}

impl PackEntry {
    fn fresh(version: u64, precision: PackPrecision, p: &Arc<PackedB>) -> Self {
        let mut e = Self { version, f32_pack: None, bf16_pack: None };
        *e.slot_mut(precision) = Some(p.clone());
        e
    }

    fn get(&self, precision: PackPrecision) -> Option<&Arc<PackedB>> {
        match precision {
            PackPrecision::F32 => self.f32_pack.as_ref(),
            PackPrecision::Bf16 => self.bf16_pack.as_ref(),
        }
    }

    fn slot_mut(&mut self, precision: PackPrecision) -> &mut Option<Arc<PackedB>> {
        match precision {
            PackPrecision::F32 => &mut self.f32_pack,
            PackPrecision::Bf16 => &mut self.bf16_pack,
        }
    }
}

/// The engine's packed-weight cache: one [`PackEntry`] per parameter
/// slot, keyed by the tensor's [`crate::model::params`] version.
/// Steady-state solve iterations replay the same versions and hit every
/// time; a training step stamps fresh versions and the next forward
/// re-packs exactly the changed weights (`invalidations` counts those
/// re-packs, and clears *both* precisions of the slot).  A version
/// match that lacks the requested precision is a `miss` — the new pack
/// joins the resident one, so f32 and bf16 panels coexist per slot.
/// Unversioned tensors (version 0 — not from a `ParamSet`) are packed
/// per call and never cached, so stale data can't be served.
#[derive(Debug, Default)]
struct PackCache {
    entries: Vec<Option<PackEntry>>,
    hits: u64,
    misses: u64,
    invalidations: u64,
    uncached: u64,
}

/// The hermetic pure-Rust backend.
pub struct NativeEngine {
    cfg: NativeConfig,
    manifest: Manifest,
    stats: StatsBook,
    /// Scratch-buffer pool behind every entry point: outputs and
    /// intermediates draw from here, and spent tensors flow back via
    /// [`Backend::recycle`], so a warmed steady-state solve loop performs
    /// zero per-iteration heap allocation ([`Self::workspace_stats`]
    /// makes that assertable).
    ws: Mutex<Workspace>,
    /// Persistent worker pool behind every parallel-sized entry: built
    /// once at engine construction, joined on engine drop — steady-state
    /// iterations spawn zero threads ([`Self::pool_stats`] asserts it).
    pool: WorkerPool,
    /// Packed-weight cache (see [`PackCache`]).
    packs: Mutex<PackCache>,
    /// Microkernel SIMD level, resolved once at construction (config
    /// pin, else `DEQ_NATIVE_SIMD` against CPU detection) — dispatch is
    /// a latched field read, never a per-call feature probe.
    simd: SimdLevel,
    /// Packed-panel storage precision, resolved once at construction
    /// (config pin, else `DEQ_NATIVE_PRECISION`).
    precision: PackPrecision,
}

impl NativeEngine {
    /// The default test-scale engine (see [`NativeConfig::default`]).
    pub fn tiny() -> Self {
        Self::new(NativeConfig::default())
    }

    pub fn new(cfg: NativeConfig) -> Self {
        let manifest = build_manifest(&cfg);
        let threads = if cfg.threads > 0 { cfg.threads } else { kernels::max_threads() };
        let simd = cfg.simd.unwrap_or_else(SimdLevel::from_env);
        let precision = cfg.precision.unwrap_or_else(PackPrecision::from_env);
        Self {
            cfg,
            manifest,
            stats: StatsBook::default(),
            ws: Mutex::new(Workspace::new()),
            pool: WorkerPool::new(threads),
            packs: Mutex::new(PackCache {
                entries: (0..NP).map(|_| None).collect(),
                ..PackCache::default()
            }),
            simd,
            precision,
        }
    }

    /// The SIMD microkernel level latched at construction.
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// The packed-panel storage precision latched at construction.
    pub fn pack_precision(&self) -> PackPrecision {
        self.precision
    }

    pub fn config(&self) -> &NativeConfig {
        &self.cfg
    }

    /// Pool counters (hits / fresh allocations / parked buffers) plus
    /// the pack-cache counters — the assertion surface for the
    /// no-allocation / no-repack steady-state invariants.
    pub fn workspace_stats(&self) -> WorkspaceStats {
        let mut s = self.ws.lock().unwrap().stats();
        let pc = self.packs.lock().unwrap();
        s.pack_hits = pc.hits;
        s.pack_misses = pc.misses;
        s.pack_invalidations = pc.invalidations;
        s.pack_uncached = pc.uncached;
        for e in pc.entries.iter().flatten() {
            if let Some(p) = &e.f32_pack {
                s.pack_bytes_f32 += p.packed_bytes();
                s.pack_entries += 1;
            }
            if let Some(p) = &e.bf16_pack {
                s.pack_bytes_bf16 += p.packed_bytes();
                s.pack_entries += 1;
            }
        }
        s
    }

    /// Worker-pool counters — `spawned` only moves at construction, so
    /// "steady state spawns zero threads" is assertable.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// The engine's persistent pool (test surface: its
    /// [`WorkerPool::exit_probe`] asserts drop-time shutdown).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    fn take(&self, len: usize) -> Vec<f32> {
        self.ws.lock().unwrap().take(len)
    }

    /// Pool buffer with arbitrary contents — only for outputs the callee
    /// fully overwrites (see [`Workspace::take_dirty`]).
    fn take_dirty(&self, len: usize) -> Vec<f32> {
        self.ws.lock().unwrap().take_dirty(len)
    }

    fn give(&self, v: Vec<f32>) {
        self.ws.lock().unwrap().give(v);
    }

    /// The microkernel-ready pack of a (k, n) weight tensor, served from
    /// the version-keyed cache when possible.  Versioned tensors (from a
    /// `ParamSet`) hit the cache on every steady-state iteration and are
    /// re-packed exactly once per parameter revision *and* storage
    /// precision; unversioned tensors are packed fresh each call and
    /// never cached.
    fn packed_weight(
        &self,
        slot: usize,
        t: &HostTensor,
        k: usize,
        n: usize,
    ) -> Result<Arc<PackedB>> {
        let prec = self.precision;
        // Fast path under the lock: pure bookkeeping.  The O(k·n) pack
        // itself always runs *outside* the mutex so a concurrent cache
        // hit on another thread never blocks behind a repack.
        {
            let mut pc = self.packs.lock().unwrap();
            if t.version == 0 {
                pc.uncached += 1;
            } else {
                let cached = pc.entries[slot]
                    .as_ref()
                    .filter(|e| e.version == t.version)
                    .and_then(|e| e.get(prec).cloned());
                if let Some(p) = cached {
                    pc.hits += 1;
                    return Ok(p);
                }
            }
        }
        let p = Arc::new(PackedB::pack_with(t.f32s()?, k, n, prec));
        if t.version == 0 {
            return Ok(p); // never cached (counted above)
        }
        let mut guard = self.packs.lock().unwrap();
        let pc = &mut *guard;
        match &mut pc.entries[slot] {
            Some(e) if e.version == t.version => {
                // Another thread raced us to the same revision and
                // precision: serve the cached pack (identical contents)
                // and drop ours.
                if let Some(cached) = e.get(prec).cloned() {
                    pc.hits += 1;
                    return Ok(cached);
                }
                // Same revision, other precision resident: a genuine
                // miss for this precision — both packs now share the
                // slot (and the version key).
                *e.slot_mut(prec) = Some(p.clone());
                pc.misses += 1;
            }
            other => {
                // New revision drops every precision at once; a bare
                // slot is a plain first-time miss.
                if other.is_some() {
                    pc.invalidations += 1;
                } else {
                    pc.misses += 1;
                }
                *other = Some(PackEntry::fresh(t.version, prec, &p));
            }
        }
        Ok(p)
    }

    /// C = A · Wᵖ through the packed microkernel, chunked across the
    /// engine pool for parallel-sized problems; A-pack scratch draws
    /// from the workspace, so the warmed path allocates nothing.
    fn gemm_cached(&self, a: &[f32], wp: &PackedB, m: usize, c: &mut [f32]) {
        let chunks = kernels::parallel_chunks(m, wp.k, wp.n, self.pool.size());
        if chunks <= 1 {
            let mut apack = self.take_dirty(pack::apack_len(m, wp.k));
            pack::gemm_packed(a, wp, m, c, &mut apack, self.simd);
            self.give(apack);
            return;
        }
        let rows_per = m.div_ceil(chunks);
        let len = pack::apack_len(rows_per, wp.k);
        let nchunks = m.div_ceil(rows_per);
        let mut apacks: Vec<Vec<f32>> =
            (0..nchunks).map(|_| self.take_dirty(len)).collect();
        pack::gemm_packed_chunked(a, wp, m, c, chunks, &self.pool, &mut apacks, self.simd);
        for b in apacks {
            self.give(b);
        }
    }

    /// out = X · Wᵖ + bias (row-broadcast): the batched encode/classify
    /// affine over a cached weight pack.
    fn affine_cached(
        &self,
        x: &[f32],
        wp: &PackedB,
        bias: &[f32],
        batch: usize,
        out: &mut [f32],
    ) {
        self.gemm_cached(x, wp, batch, out);
        let n = wp.n;
        for row in out.chunks_mut(n) {
            for (o, b) in row.iter_mut().zip(bias) {
                *o += *b;
            }
        }
    }

    /// The fused DEQ cell over a cached weight pack, chunked across the
    /// engine pool (see [`pack::cell_batch_packed`]).
    #[allow(clippy::too_many_arguments)] // flat numeric kernel, no state to bundle
    fn cell_cached(
        &self,
        wp: &PackedB,
        bias: &[f32],
        z: &[f32],
        x: &[f32],
        batch: usize,
        n: usize,
        f: &mut [f32],
        res: &mut [f32],
        fnorm: &mut [f32],
    ) {
        let chunks = kernels::parallel_chunks(batch, n, n, self.pool.size());
        if chunks <= 1 {
            // Serial fast path: one pooled scratch buffer, no dispatch
            // bookkeeping — the common case stays truly allocation-free.
            let mut apack = self.take_dirty(pack::apack_len(batch, n));
            pack::cell_rows_packed(
                wp, bias, z, x, batch, n, f, res, fnorm, &mut apack, self.simd,
            );
            self.give(apack);
            return;
        }
        let rows_per = batch.div_ceil(chunks);
        let nbufs = batch.div_ceil(rows_per);
        let len = pack::apack_len(rows_per, n);
        let mut apacks: Vec<Vec<f32>> =
            (0..nbufs).map(|_| self.take_dirty(len)).collect();
        pack::cell_batch_packed(
            wp, bias, z, x, batch, n, f, res, fnorm, chunks, Some(&self.pool),
            &mut apacks, self.simd,
        );
        for b in apacks {
            self.give(b);
        }
    }

    fn dispatch(
        &self,
        name: &str,
        batch: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        match name {
            "encode" => self.encode(batch, inputs),
            "cell_step" => self.cell_step(batch, inputs),
            "forward_solve_k" => self.forward_solve_k(batch, inputs),
            "anderson_update" => self.anderson_update(batch, inputs),
            "classify" => self.classify(batch, inputs),
            "explicit_infer" => self.explicit_infer(batch, inputs),
            "train_update" => self.train_update(batch, inputs, 1),
            "train_update_neumann" => {
                self.train_update(batch, inputs, self.cfg.train.neumann_terms.max(1))
            }
            "explicit_train" => self.explicit_train(batch, inputs),
            other => bail!("native backend has no entry '{other}'"),
        }
    }

    /// x_feat = W_enc·vec(x_img) + b_enc: one packed-microkernel
    /// batch×image GEMM over the cached encoder pack.
    fn encode(&self, batch: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (idim, n) = (self.cfg.image_dim(), self.cfg.latent_dim());
        let wp = self.packed_weight(P_W_ENC, &inputs[P_W_ENC], idim, n)?;
        let b = inputs[P_B_ENC].f32s()?;
        let x = inputs[NP].f32s()?;
        let mut feat = self.take_dirty(batch * n);
        self.affine_cached(x, &wp, b, batch, &mut feat);
        Ok(vec![HostTensor::f32(self.manifest.model.latent_shape(batch), feat)?])
    }

    /// f = tanh(W_cell·z + b_cell + x) with fused per-sample residual
    /// norms — one packed-microkernel batch×latent GEMM over the cached
    /// cell pack plus a single fused pass over f (see
    /// [`pack::cell_batch_packed`]).  All three outputs draw from the
    /// workspace pool; the steady-state iteration packs no weights and
    /// spawns no threads.
    fn cell_step(&self, batch: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let n = self.cfg.latent_dim();
        let wp = self.packed_weight(P_W_CELL, &inputs[P_W_CELL], n, n)?;
        let b = inputs[P_B_CELL].f32s()?;
        let z = inputs[NP].f32s()?;
        let x = inputs[NP + 1].f32s()?;
        let mut f = self.take_dirty(batch * n);
        let mut res = self.take_dirty(batch);
        let mut fnorm = self.take_dirty(batch);
        self.cell_cached(&wp, b, z, x, batch, n, &mut f, &mut res, &mut fnorm);
        Ok(vec![
            HostTensor::f32(self.manifest.model.latent_shape(batch), f)?,
            HostTensor::f32(vec![batch], res)?,
            HostTensor::f32(vec![batch], fnorm)?,
        ])
    }

    /// K fused forward steps; residual outputs describe the *last* step,
    /// matching the AOT `forward_solve_k` artifact semantics (the last
    /// cell application's norms are exactly ‖z_K − z_{K−1}‖ and ‖z_K‖).
    /// The cell pack is fetched once and reused across all K steps.
    fn forward_solve_k(&self, batch: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let n = self.cfg.latent_dim();
        let k = self.cfg.solver.fused_steps.max(1);
        let wp = self.packed_weight(P_W_CELL, &inputs[P_W_CELL], n, n)?;
        let b = inputs[P_B_CELL].f32s()?;
        let z0 = inputs[NP].f32s()?;
        let x = inputs[NP + 1].f32s()?;
        let mut z = self.take_dirty(batch * n);
        z.copy_from_slice(z0);
        let mut f = self.take_dirty(batch * n);
        let mut res = self.take_dirty(batch);
        let mut fnorm = self.take_dirty(batch);
        for _ in 0..k {
            self.cell_cached(&wp, b, &z, x, batch, n, &mut f, &mut res, &mut fnorm);
            std::mem::swap(&mut z, &mut f);
        }
        self.give(f);
        Ok(vec![
            HostTensor::f32(self.manifest.model.latent_shape(batch), z)?,
            HostTensor::f32(vec![batch], res)?,
            HostTensor::f32(vec![batch], fnorm)?,
        ])
    }

    /// Masked windowed Anderson mixing (paper Alg. 1, Eqs. 4–5), batched.
    ///
    /// Slots with `mask ≈ 0` are excluded from the Gram system and receive
    /// α = 0, so a single entry serves every warm-up fill and every
    /// runtime window ≤ the compiled one — the same contract as the fused
    /// Pallas kernel.  With no valid slots the update degenerates to zero
    /// output (the artifact's behaviour on an all-zero mask).
    fn anderson_update(&self, batch: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let m = self.cfg.solver.window;
        let n = self.cfg.latent_dim();
        let (beta, lam) = (self.cfg.solver.beta, self.cfg.solver.lam);
        let xh = inputs[0].f32s()?;
        let fh = inputs[1].f32s()?;
        let mask = inputs[2].f32s()?;
        let valid: Vec<usize> = (0..m).filter(|&i| mask[i] > 0.5).collect();
        let nv = valid.len();
        // mix_masked_window fully overwrites both outputs per sample, so
        // dirty buffers suffice when any slot is valid; the all-masked
        // degenerate case zero-fills below.
        let mut z = self.take_dirty(batch * n);
        let mut alpha_out = self.take_dirty(batch * m);
        if nv == 0 {
            z.fill(0.0);
            alpha_out.fill(0.0);
        }
        if nv > 0 {
            // Per-sample work (residual rows, Gram system, mix — see
            // [`mix_masked_window`]) fans out over the engine pool in
            // contiguous sample chunks, each chunk with its own pooled
            // g/h/a scratch and disjoint slices of z / α; below the
            // parallel threshold one chunk runs inline.  Either way the
            // per-sample arithmetic is identical (and identical to the
            // rank-deficient-window fallback semantics the serial loop
            // had), so results do not depend on the chunking.
            let chunks = kernels::parallel_chunks(
                batch,
                nv * n,
                nv.max(1),
                self.pool.size(),
            );
            if chunks <= 1 {
                // Serial fast path: one pooled g/h/a scratch set walked
                // over the batch inline — no dispatch bookkeeping, so the
                // common case stays truly allocation-free.
                let mut g = self.take_dirty(nv * n);
                let mut h = self.take_dirty(nv * nv);
                let mut a = self.take_dirty(nv);
                for s in 0..batch {
                    mix_masked_window(
                        &xh[s * m * n..(s + 1) * m * n],
                        &fh[s * m * n..(s + 1) * m * n],
                        &valid,
                        m,
                        n,
                        beta,
                        lam,
                        &mut g,
                        &mut h,
                        &mut a,
                        &mut z[s * n..(s + 1) * n],
                        &mut alpha_out[s * m..(s + 1) * m],
                    );
                }
                self.give(g);
                self.give(h);
                self.give(a);
            } else {
                let rows_per = batch.div_ceil(chunks);
                let nchunks = batch.div_ceil(rows_per);
                let mut scratch: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..nchunks)
                    .map(|_| {
                        (
                            self.take_dirty(nv * n),
                            self.take_dirty(nv * nv),
                            self.take_dirty(nv),
                        )
                    })
                    .collect();
                {
                    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                        Vec::with_capacity(nchunks);
                    let iter = z
                        .chunks_mut(rows_per * n)
                        .zip(alpha_out.chunks_mut(rows_per * m))
                        .zip(scratch.iter_mut())
                        .enumerate();
                    for (ti, ((z_c, al_c), (g, h, a))) in iter {
                        let samples = al_c.len() / m;
                        let base = ti * rows_per * m * n;
                        let xh_c = &xh[base..base + samples * m * n];
                        let fh_c = &fh[base..base + samples * m * n];
                        let valid = &valid;
                        tasks.push(Box::new(move || {
                            for s in 0..samples {
                                mix_masked_window(
                                    &xh_c[s * m * n..(s + 1) * m * n],
                                    &fh_c[s * m * n..(s + 1) * m * n],
                                    valid,
                                    m,
                                    n,
                                    beta,
                                    lam,
                                    g,
                                    h,
                                    a,
                                    &mut z_c[s * n..(s + 1) * n],
                                    &mut al_c[s * m..(s + 1) * m],
                                );
                            }
                        }));
                    }
                    self.pool.run(tasks);
                }
                for (g, h, a) in scratch {
                    self.give(g);
                    self.give(h);
                    self.give(a);
                }
            }
        }
        Ok(vec![
            HostTensor::f32(vec![batch, n], z)?,
            HostTensor::f32(vec![batch, m], alpha_out)?,
        ])
    }

    /// logits = W_cls·z + b_cls: one packed batch×classes GEMM over the
    /// cached classifier pack.
    fn classify(&self, batch: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (n, nc) = (self.cfg.latent_dim(), self.cfg.num_classes);
        let wp = self.packed_weight(P_W_CLS, &inputs[P_W_CLS], n, nc)?;
        let b = inputs[P_B_CLS].f32s()?;
        let z = inputs[NP].f32s()?;
        let mut logits = self.take_dirty(batch * nc);
        self.affine_cached(z, &wp, b, batch, &mut logits);
        Ok(vec![HostTensor::f32(vec![batch, nc], logits)?])
    }

    /// Explicit weight-tied baseline: encode → D cell steps → classify,
    /// all three stages over cached weight packs.
    fn explicit_infer(&self, batch: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let n = self.cfg.latent_dim();
        let feat_t = self.encode(batch, inputs)?.remove(0);
        let wcell = self.packed_weight(P_W_CELL, &inputs[P_W_CELL], n, n)?;
        let b_cell = inputs[P_B_CELL].f32s()?;
        let mut z = self.take(batch * n); // zeroed: the initial iterate
        let mut f = self.take_dirty(batch * n);
        let mut res = self.take_dirty(batch);
        let mut fnorm = self.take_dirty(batch);
        {
            let feat = feat_t.f32s()?;
            for _ in 0..self.cfg.train.explicit_depth.max(1) {
                self.cell_cached(
                    &wcell, b_cell, &z, feat, batch, n, &mut f, &mut res,
                    &mut fnorm,
                );
                std::mem::swap(&mut z, &mut f);
            }
        }
        self.give(f);
        self.give(res);
        self.give(fnorm);
        if let TensorData::F32(v) = feat_t.data {
            self.give(v);
        }
        let nc = self.cfg.num_classes;
        let wcls = self.packed_weight(P_W_CLS, &inputs[P_W_CLS], n, nc)?;
        let b_cls = inputs[P_B_CLS].f32s()?;
        let mut logits = self.take_dirty(batch * nc);
        self.affine_cached(&z, &wcls, b_cls, batch, &mut logits);
        self.give(z);
        Ok(vec![HostTensor::f32(vec![batch, nc], logits)?])
    }

    /// Fused backward + SGD-momentum update at the equilibrium.
    ///
    /// `k_terms = 1` is Jacobian-Free Backpropagation (one phantom cell
    /// step); `k_terms > 1` accumulates the truncated Neumann series
    /// Σ_{k<K} (Jᵀ)^k of the implicit-function gradient.  Output layout
    /// matches the AOT artifact: new params, new momentum, mean loss,
    /// correct count.
    fn train_update(
        &self,
        batch: usize,
        inputs: &[HostTensor],
        k_terms: usize,
    ) -> Result<Vec<HostTensor>> {
        let (idim, n, nc) = (
            self.cfg.image_dim(),
            self.cfg.latent_dim(),
            self.cfg.num_classes,
        );
        let b_enc = inputs[P_B_ENC].f32s()?;
        let w_cell = inputs[P_W_CELL].f32s()?;
        let b_cell = inputs[P_B_CELL].f32s()?;
        let w_cls = inputs[P_W_CLS].f32s()?;
        let b_cls = inputs[P_B_CLS].f32s()?;
        let z_star = inputs[2 * NP].f32s()?;
        let x_img = inputs[2 * NP + 1].f32s()?;
        let y = inputs[2 * NP + 2].i32s()?;

        let mut grads: Vec<Vec<f32>> = self
            .manifest
            .params
            .iter()
            .map(|s| vec![0.0f32; s.elements()])
            .collect();
        let mut loss_sum = 0.0f32;
        let mut correct = 0i32;
        let inv_b = 1.0 / batch as f32;

        // Batched forward through the cached weight packs: encode, the
        // phantom cell step at the equilibrium (the JFB trick), and the
        // classifier logits, each one packed GEMM instead of per-sample
        // affine loops.
        let wenc_p = self.packed_weight(P_W_ENC, &inputs[P_W_ENC], idim, n)?;
        let wcell_p = self.packed_weight(P_W_CELL, &inputs[P_W_CELL], n, n)?;
        let wcls_p = self.packed_weight(P_W_CLS, &inputs[P_W_CLS], n, nc)?;
        let mut xf_all = self.take_dirty(batch * n);
        self.affine_cached(x_img, &wenc_p, b_enc, batch, &mut xf_all);
        let mut f_all = self.take_dirty(batch * n);
        let mut res_s = self.take_dirty(batch);
        let mut fn_s = self.take_dirty(batch);
        self.cell_cached(
            &wcell_p, b_cell, z_star, &xf_all, batch, n, &mut f_all, &mut res_s,
            &mut fn_s,
        );
        let mut logits_all = self.take_dirty(batch * nc);
        self.affine_cached(z_star, &wcls_p, b_cls, batch, &mut logits_all);

        for s in 0..batch {
            let zb = &z_star[s * n..(s + 1) * n];
            let xb = &x_img[s * idim..(s + 1) * idim];
            let f = &f_all[s * n..(s + 1) * n];
            let logits = &logits_all[s * nc..(s + 1) * nc];

            let yb = y[s];
            ensure!(
                (0..nc as i32).contains(&yb),
                "label {yb} out of range (num_classes {nc})"
            );
            // Loss + classifier cotangent (logits read z* directly).
            let (loss, hit, dl) = softmax_xent(logits, yb as usize, inv_b);
            loss_sum += loss;
            correct += hit as i32;

            // Truncated Neumann: acc = Σ_{k<K} (Jᵀ)^k v₀ with
            // J = diag(1−f²)·W_cell evaluated at the phantom step.
            let v0 = vjp_classifier(w_cls, &dl, n, nc);
            let mut acc = v0.clone();
            let mut cur = v0;
            for _ in 1..k_terms {
                let uk: Vec<f32> = cur
                    .iter()
                    .zip(f.iter())
                    .map(|(c, fj)| c * (1.0 - fj * fj))
                    .collect();
                let mut nxt = vec![0.0f32; n];
                for kk in 0..n {
                    let row = &w_cell[kk * n..(kk + 1) * n];
                    let mut sacc = 0.0f32;
                    for j in 0..n {
                        sacc += row[j] * uk[j];
                    }
                    nxt[kk] = sacc;
                }
                for (a, b2) in acc.iter_mut().zip(nxt.iter()) {
                    *a += b2;
                }
                cur = nxt;
            }
            // Cotangent on the pre-activation of the phantom step.
            let u: Vec<f32> = acc
                .iter()
                .zip(f.iter())
                .map(|(a, fj)| a * (1.0 - fj * fj))
                .collect();
            add_param_grads(&mut grads, zb, zb, xb, &dl, &u, idim, n, nc);
        }

        self.give(xf_all);
        self.give(f_all);
        self.give(res_s);
        self.give(fn_s);
        self.give(logits_all);
        self.apply_sgd(inputs, &grads, loss_sum * inv_b, correct)
    }

    /// Explicit-baseline update: unrolled forward, backward truncated to
    /// the last cell step (the JFB-style approximation the native twin
    /// documents; sufficient for the loss-descent contracts the tier
    /// checks).
    fn explicit_train(&self, batch: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (idim, n, nc) = (
            self.cfg.image_dim(),
            self.cfg.latent_dim(),
            self.cfg.num_classes,
        );
        let b_enc = inputs[P_B_ENC].f32s()?;
        let b_cell = inputs[P_B_CELL].f32s()?;
        let w_cls = inputs[P_W_CLS].f32s()?;
        let b_cls = inputs[P_B_CLS].f32s()?;
        let x_img = inputs[2 * NP].f32s()?;
        let y = inputs[2 * NP + 1].i32s()?;
        let depth = self.cfg.train.explicit_depth.max(1);

        let mut grads: Vec<Vec<f32>> = self
            .manifest
            .params
            .iter()
            .map(|s| vec![0.0f32; s.elements()])
            .collect();
        let mut loss_sum = 0.0f32;
        let mut correct = 0i32;
        let inv_b = 1.0 / batch as f32;

        // Batched unrolled forward through the cached weight packs:
        // encode once, D cell steps at batch width (keeping the
        // second-to-last iterate, which the truncated backward reads),
        // classify once.
        let wenc_p = self.packed_weight(P_W_ENC, &inputs[P_W_ENC], idim, n)?;
        let wcell_p = self.packed_weight(P_W_CELL, &inputs[P_W_CELL], n, n)?;
        let wcls_p = self.packed_weight(P_W_CLS, &inputs[P_W_CLS], n, nc)?;
        let mut xf_all = self.take_dirty(batch * n);
        self.affine_cached(x_img, &wenc_p, b_enc, batch, &mut xf_all);
        let mut z_all = self.take(batch * n); // zeroed initial iterate
        let mut zprev_all = self.take_dirty(batch * n);
        let mut f_all = self.take_dirty(batch * n);
        let mut res_s = self.take_dirty(batch);
        let mut fn_s = self.take_dirty(batch);
        for _ in 0..depth {
            zprev_all.copy_from_slice(&z_all);
            self.cell_cached(
                &wcell_p, b_cell, &zprev_all, &xf_all, batch, n, &mut f_all,
                &mut res_s, &mut fn_s,
            );
            std::mem::swap(&mut z_all, &mut f_all);
        }
        let mut logits_all = self.take_dirty(batch * nc);
        self.affine_cached(&z_all, &wcls_p, b_cls, batch, &mut logits_all);

        for s in 0..batch {
            let xb = &x_img[s * idim..(s + 1) * idim];
            let z = &z_all[s * n..(s + 1) * n];
            let z_prev = &zprev_all[s * n..(s + 1) * n];
            let logits = &logits_all[s * nc..(s + 1) * nc];

            let yb = y[s];
            ensure!(
                (0..nc as i32).contains(&yb),
                "label {yb} out of range (num_classes {nc})"
            );
            let (loss, hit, dl) = softmax_xent(logits, yb as usize, inv_b);
            loss_sum += loss;
            correct += hit as i32;

            // Backprop through the final cell step only (JFB-style
            // truncation of the depth-D chain).
            let v0 = vjp_classifier(w_cls, &dl, n, nc);
            let u: Vec<f32> = v0
                .iter()
                .zip(z.iter())
                .map(|(v, zj)| v * (1.0 - zj * zj))
                .collect();
            add_param_grads(&mut grads, z, z_prev, xb, &dl, &u, idim, n, nc);
        }

        self.give(xf_all);
        self.give(z_all);
        self.give(zprev_all);
        self.give(f_all);
        self.give(res_s);
        self.give(fn_s);
        self.give(logits_all);
        self.apply_sgd(inputs, &grads, loss_sum * inv_b, correct)
    }

    /// SGD-with-momentum step producing the artifact output layout:
    /// `[params'…, momentum'…, loss, correct]`.
    fn apply_sgd(
        &self,
        inputs: &[HostTensor],
        grads: &[Vec<f32>],
        loss: f32,
        correct: i32,
    ) -> Result<Vec<HostTensor>> {
        let (lr, mu) = (self.cfg.train.lr, self.cfg.train.momentum);
        let mut new_params = Vec::with_capacity(NP);
        let mut new_moms = Vec::with_capacity(NP);
        for pi in 0..NP {
            let p = inputs[pi].f32s()?;
            let v = inputs[NP + pi].f32s()?;
            let g = &grads[pi];
            let mut vm = Vec::with_capacity(p.len());
            let mut pn = Vec::with_capacity(p.len());
            for t in 0..p.len() {
                let m2 = mu * v[t] + g[t];
                vm.push(m2);
                pn.push(p[t] - lr * m2);
            }
            new_params.push(HostTensor::f32(inputs[pi].shape.clone(), pn)?);
            new_moms.push(HostTensor::f32(inputs[pi].shape.clone(), vm)?);
        }
        let mut out = new_params;
        out.extend(new_moms);
        out.push(HostTensor::scalar_f32(loss));
        out.push(HostTensor::i32(vec![], vec![correct])?);
        Ok(out)
    }
}

impl Backend for NativeEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Spent f32 tensors rejoin the workspace pool; i32 tensors (labels,
    /// counters) are dropped — the pool is f32-only.
    fn recycle(&self, tensors: Vec<HostTensor>) {
        let mut ws = self.ws.lock().unwrap();
        for t in tensors {
            if let TensorData::F32(v) = t.data {
                ws.give(v);
            }
        }
    }

    fn execute(
        &self,
        name: &str,
        batch: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.entry(name, batch)?;
        check_inputs(spec, name, batch, inputs)?;
        let n_outputs = spec.outputs.len();
        let t0 = Instant::now();
        let out = self.dispatch(name, batch, inputs)?;
        self.stats.record(name, batch, t0.elapsed());
        debug_assert_eq!(out.len(), n_outputs, "{name}: output arity drifted from spec");
        Ok(out)
    }

    /// Deterministic seeded init: weights scaled so encode features are
    /// O(1) and the cell is a contraction (spectral scale `cell_gain`).
    fn init_params(&self) -> Result<ParamSet> {
        let mut rng = Rng::new(self.cfg.init_seed);
        let (idim, n) = (self.cfg.image_dim(), self.cfg.latent_dim());
        let mut flat: Vec<f32> = Vec::with_capacity(self.manifest.model.param_count);
        for spec in &self.manifest.params {
            let count = spec.elements();
            match spec.name.as_str() {
                "w_enc" => flat.extend(rng.normal_vec(count, 1.0 / (idim as f32).sqrt())),
                "w_cell" => {
                    flat.extend(rng.normal_vec(count, self.cfg.cell_gain / (n as f32).sqrt()))
                }
                "w_cls" => flat.extend(rng.normal_vec(count, 1.0 / (n as f32).sqrt())),
                _ => flat.resize(flat.len() + count, 0.0),
            }
        }
        ParamSet::from_flat(&self.manifest, &flat)
    }

    fn stats(&self) -> Vec<((String, usize), EntryStats)> {
        self.stats.snapshot()
    }

    /// Workspace + pack-cache counters, surfaced so server stats can
    /// report hot-path health without knowing the concrete engine type.
    fn hot_stats(&self) -> Option<WorkspaceStats> {
        Some(self.workspace_stats())
    }
}

/// Output layout shared by the three training entries:
/// `[params'…, momentum'…, loss, correct]`.
fn train_output_specs(params: &[TensorSpec]) -> Vec<TensorSpec> {
    let mut outs: Vec<TensorSpec> = params.to_vec();
    outs.extend(params.iter().map(|s| TensorSpec {
        name: format!("mom_{}", s.name),
        shape: s.shape.clone(),
        dtype: s.dtype,
    }));
    outs.push(TensorSpec {
        name: "loss".to_string(),
        shape: vec![],
        dtype: Dtype::F32,
    });
    outs.push(TensorSpec {
        name: "correct".to_string(),
        shape: vec![],
        dtype: Dtype::I32,
    });
    outs
}

/// Assemble the in-memory manifest describing the native entry points.
fn build_manifest(cfg: &NativeConfig) -> Manifest {
    let params = cfg.param_specs();
    let param_count: usize = params.iter().map(TensorSpec::elements).sum();
    let model = ModelMeta {
        preset: "native-tiny".to_string(),
        image_hw: cfg.image_hw,
        image_channels: cfg.image_channels,
        channels: cfg.channels,
        latent_hw: cfg.latent_hw,
        groups: cfg.groups,
        num_classes: cfg.num_classes,
        param_count,
    };
    let f32spec = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.to_string(),
        shape,
        dtype: Dtype::F32,
    };
    let i32spec = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.to_string(),
        shape,
        dtype: Dtype::I32,
    };

    let mut entries = Vec::new();
    for &b in &cfg.buckets {
        let latent = model.latent_shape(b);
        let image = model.image_shape(b);
        let n = model.latent_dim();
        let nc = cfg.num_classes;
        let m = cfg.solver.window;
        let mut entry = |name: &str, extra_in: Vec<TensorSpec>, outputs: Vec<TensorSpec>,
                         with_params: bool, with_momentum: bool| {
            let mut inputs = Vec::new();
            if with_params {
                inputs.extend(params.iter().cloned());
            }
            if with_momentum {
                inputs.extend(params.iter().map(|s| TensorSpec {
                    name: format!("mom_{}", s.name),
                    shape: s.shape.clone(),
                    dtype: s.dtype,
                }));
            }
            inputs.extend(extra_in);
            entries.push(EntrySpec {
                name: name.to_string(),
                batch: b,
                file: "<native>".to_string(),
                inputs,
                outputs,
            });
        };

        entry(
            "encode",
            vec![f32spec("x_img", image.clone())],
            vec![f32spec("x_feat", latent.clone())],
            true,
            false,
        );
        let step_outputs = vec![
            f32spec("f", latent.clone()),
            f32spec("res_num", vec![b]),
            f32spec("f_norm", vec![b]),
        ];
        entry(
            "cell_step",
            vec![f32spec("z", latent.clone()), f32spec("x_feat", latent.clone())],
            step_outputs.clone(),
            true,
            false,
        );
        entry(
            "forward_solve_k",
            vec![f32spec("z", latent.clone()), f32spec("x_feat", latent.clone())],
            step_outputs,
            true,
            false,
        );
        entry(
            "anderson_update",
            vec![
                f32spec("xhist", vec![b, m, n]),
                f32spec("fhist", vec![b, m, n]),
                f32spec("mask", vec![m]),
            ],
            vec![f32spec("z_mixed", vec![b, n]), f32spec("alpha", vec![b, m])],
            false,
            false,
        );
        entry(
            "classify",
            vec![f32spec("z", latent.clone())],
            vec![f32spec("logits", vec![b, nc])],
            true,
            false,
        );
        entry(
            "explicit_infer",
            vec![f32spec("x_img", image.clone())],
            vec![f32spec("logits", vec![b, nc])],
            true,
            false,
        );
        entry(
            "train_update",
            vec![
                f32spec("z_star", latent.clone()),
                f32spec("x_img", image.clone()),
                i32spec("y", vec![b]),
            ],
            train_output_specs(&params),
            true,
            true,
        );
        entry(
            "train_update_neumann",
            vec![
                f32spec("z_star", latent.clone()),
                f32spec("x_img", image.clone()),
                i32spec("y", vec![b]),
            ],
            train_output_specs(&params),
            true,
            true,
        );
        entry(
            "explicit_train",
            vec![f32spec("x_img", image), i32spec("y", vec![b])],
            train_output_specs(&params),
            true,
            true,
        );
    }

    Manifest {
        dir: PathBuf::from("<native>"),
        model,
        solver: cfg.solver.clone(),
        train: cfg.train.clone(),
        params,
        entries,
        init_params_file: "<native-init>".to_string(),
        use_pallas: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::AndersonState;

    #[test]
    fn manifest_is_self_consistent() {
        let e = NativeEngine::tiny();
        let m = e.manifest();
        let total: usize = m.params.iter().map(TensorSpec::elements).sum();
        assert_eq!(total, m.model.param_count);
        for name in [
            "encode",
            "cell_step",
            "anderson_update",
            "forward_solve_k",
            "classify",
            "explicit_infer",
            "train_update",
            "train_update_neumann",
            "explicit_train",
        ] {
            for &b in &e.config().buckets {
                assert!(m.entry(name, b).is_ok(), "{name}@b{b} missing");
            }
        }
        assert_eq!(m.batches_for("encode"), vec![1, 8, 32]);
    }

    #[test]
    fn init_params_deterministic_and_finite() {
        let a = NativeEngine::tiny().init_params().unwrap();
        let b = NativeEngine::tiny().init_params().unwrap();
        assert_eq!(a.to_flat(), b.to_flat());
        assert!(a.all_finite());
        assert!(a.max_abs() > 0.0);
    }

    #[test]
    fn cell_step_matches_manual_math() {
        let e = NativeEngine::tiny();
        let p = e.init_params().unwrap();
        let n = e.config().latent_dim();
        let mut rng = Rng::new(3);
        let z = rng.normal_vec(n, 1.0);
        let x = rng.normal_vec(n, 1.0);
        let mut inputs = p.tensors.clone();
        inputs.push(
            HostTensor::f32(e.manifest().model.latent_shape(1), z.clone()).unwrap(),
        );
        inputs.push(
            HostTensor::f32(e.manifest().model.latent_shape(1), x.clone()).unwrap(),
        );
        let out = e.execute("cell_step", 1, &inputs).unwrap();
        let f = out[0].f32s().unwrap();
        let w = p.tensors[P_W_CELL].f32s().unwrap();
        let b = p.tensors[P_B_CELL].f32s().unwrap();
        let mut want = vec![0.0f32; n];
        cell_apply(w, b, &z, &x, n, &mut want);
        // The packed kernel adds the bias after the matmul reduction
        // (cell_apply seeds the accumulator with it), so the f32 rounding
        // differs at the last few ulps; parity is at 1e-4, not exactness.
        for (a, b2) in f.iter().zip(&want) {
            assert!((a - b2).abs() < 1e-4);
        }
        // Residual outputs match host-recomputed norms.
        let num: f32 = f
            .iter()
            .zip(&z)
            .map(|(a, b2)| (a - b2) * (a - b2))
            .sum::<f32>()
            .sqrt();
        let den: f32 = f.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((out[1].f32s().unwrap()[0] - num).abs() < 1e-4);
        assert!((out[2].f32s().unwrap()[0] - den).abs() < 1e-4);
    }

    #[test]
    fn anderson_update_matches_reference_state() {
        let e = NativeEngine::tiny();
        let m = e.config().solver.window;
        let n = e.config().latent_dim();
        let (beta, lam) = (e.config().solver.beta, e.config().solver.lam);
        let mut rng = Rng::new(11);
        let xh = rng.normal_vec(m * n, 1.0);
        let fh: Vec<f32> = xh.iter().map(|v| v + 0.1 * rng.normal()).collect();
        let out = e
            .execute(
                "anderson_update",
                1,
                &[
                    HostTensor::f32(vec![1, m, n], xh.clone()).unwrap(),
                    HostTensor::f32(vec![1, m, n], fh.clone()).unwrap(),
                    HostTensor::f32(vec![m], vec![1.0; m]).unwrap(),
                ],
            )
            .unwrap();
        let mut st = AndersonState::new(m, n, beta, lam);
        for i in 0..m {
            st.push(&xh[i * n..(i + 1) * n], &fh[i * n..(i + 1) * n]);
        }
        let (z_ref, a_ref) = st.mix().unwrap();
        for (a, b) in out[0].f32s().unwrap().iter().zip(&z_ref) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for (a, b) in out[1].f32s().unwrap().iter().zip(&a_ref) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn anderson_update_rank_deficient_window_falls_back() {
        // λ = 0 plus a window whose residual rows are identical (exactly
        // what LaneHistory's replication-seeding produces on a fresh
        // lane) makes H = GGᵀ rank-1: Cholesky breaks down.  Regression:
        // this used to error out the whole batched update — and with it
        // the serving scheduler's solve loop; it must now degrade that
        // sample to a plain forward step from the last valid slot.
        let cfg = NativeConfig {
            solver: SolverMeta { lam: 0.0, ..NativeConfig::default().solver },
            ..NativeConfig::default()
        };
        let e = NativeEngine::new(cfg);
        let m = e.config().solver.window;
        let n = e.config().latent_dim();
        let xh: Vec<f32> = vec![1.0; m * n];
        let fh: Vec<f32> = vec![2.0; m * n];
        let out = e
            .execute(
                "anderson_update",
                1,
                &[
                    HostTensor::f32(vec![1, m, n], xh).unwrap(),
                    HostTensor::f32(vec![1, m, n], fh.clone()).unwrap(),
                    HostTensor::f32(vec![m], vec![1.0; m]).unwrap(),
                ],
            )
            .expect("rank-deficient window must not error the update");
        // All slots hold the same pair, so any normalized α mixes to the
        // forward step f = 2; the fallback picks the last valid slot.
        for (got, want) in out[0].f32s().unwrap().iter().zip(&fh) {
            assert!(
                got.is_finite() && (got - want).abs() < 1e-4,
                "{got} vs {want}"
            );
        }
        let alpha = out[1].f32s().unwrap();
        let s: f32 = alpha.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "alpha not normalized: {s}");
    }

    #[test]
    fn steady_state_execute_loop_is_allocation_free() {
        // The no-allocation invariant of the tentpole: once recycled
        // outputs have warmed the workspace pool, repeated cell_step +
        // anderson_update dispatches perform zero fresh allocations.
        let e = NativeEngine::tiny();
        let p = e.init_params().unwrap();
        let m = e.config().solver.window;
        let n = e.config().latent_dim();
        let batch = 8;
        let mut cell_in = p.tensors.clone();
        cell_in.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        cell_in.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        let and_in = [
            HostTensor::zeros(vec![batch, m, n]),
            HostTensor::zeros(vec![batch, m, n]),
            HostTensor::f32(vec![m], vec![1.0; m]).unwrap(),
        ];
        let mut run = || {
            let out = e.execute("cell_step", batch, &cell_in).unwrap();
            e.recycle(out);
            let out = e.execute("anderson_update", batch, &and_in).unwrap();
            e.recycle(out);
        };
        for _ in 0..3 {
            run(); // warm the pool
        }
        let warm = e.workspace_stats();
        for _ in 0..20 {
            run();
        }
        let after = e.workspace_stats();
        assert_eq!(
            after.allocs, warm.allocs,
            "steady-state dispatch allocated ({} → {})",
            warm.allocs, after.allocs
        );
        assert!(after.hits > warm.hits, "pool was not exercised");
    }

    #[test]
    fn pack_cache_hits_on_repeat_and_invalidates_on_new_versions() {
        let e = NativeEngine::tiny();
        let p = e.init_params().unwrap();
        let batch = 8;
        let mut inputs = p.tensors.clone();
        inputs.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        inputs.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        e.execute("cell_step", batch, &inputs).unwrap();
        let s1 = e.workspace_stats();
        assert_eq!(
            (s1.pack_misses, s1.pack_hits, s1.pack_invalidations),
            (1, 0, 0),
            "first cell_step must pack W_cell exactly once"
        );
        e.execute("cell_step", batch, &inputs).unwrap();
        e.execute("cell_step", batch, &inputs).unwrap();
        let s2 = e.workspace_stats();
        assert_eq!(s2.pack_misses, 1, "repeat dispatch must not re-pack");
        assert_eq!(s2.pack_hits, 2);

        // A re-stamped ParamSet (fresh versions, same data) must
        // invalidate the cached pack exactly once.
        let p2 = crate::model::ParamSet::from_tensors(p.tensors.clone());
        let mut inputs2 = p2.tensors.clone();
        inputs2.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        inputs2.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        e.execute("cell_step", batch, &inputs2).unwrap();
        e.execute("cell_step", batch, &inputs2).unwrap();
        let s3 = e.workspace_stats();
        assert_eq!(s3.pack_invalidations, 1, "one re-pack per new version");
        assert_eq!(s3.pack_misses, 1, "invalidation is not a miss");
        assert_eq!(s3.pack_hits, 3);
    }

    #[test]
    fn unversioned_weights_pack_fresh_and_never_cache() {
        let e = NativeEngine::tiny();
        let batch = 1;
        // Raw tensors (version 0): correct shapes, no ParamSet stamping.
        let mut inputs: Vec<HostTensor> = e
            .manifest()
            .params
            .iter()
            .map(|s| HostTensor::zeros(s.shape.clone()))
            .collect();
        inputs.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        inputs.push(HostTensor::zeros(e.manifest().model.latent_shape(batch)));
        e.execute("cell_step", batch, &inputs).unwrap();
        e.execute("cell_step", batch, &inputs).unwrap();
        let s = e.workspace_stats();
        assert_eq!(s.pack_uncached, 2, "unversioned weights pack per call");
        assert_eq!((s.pack_misses, s.pack_hits), (0, 0));
    }

    #[test]
    fn bf16_engine_matches_f32_within_tolerance_and_halves_pack_bytes() {
        let mk = |prec| {
            NativeEngine::new(NativeConfig {
                precision: Some(prec),
                ..NativeConfig::default()
            })
        };
        let ef = mk(PackPrecision::F32);
        let eb = mk(PackPrecision::Bf16);
        let p = ef.init_params().unwrap();
        let n = ef.config().latent_dim();
        let batch = 8;
        let mut rng = Rng::new(41);
        let z = rng.normal_vec(batch * n, 1.0);
        let x = rng.normal_vec(batch * n, 1.0);
        let mut inputs = p.tensors.clone();
        let shape = ef.manifest().model.latent_shape(batch);
        inputs.push(HostTensor::f32(shape.clone(), z).unwrap());
        inputs.push(HostTensor::f32(shape, x).unwrap());
        let of = ef.execute("cell_step", batch, &inputs).unwrap();
        let ob = eb.execute("cell_step", batch, &inputs).unwrap();
        // bf16 storage carries ~2^-9 relative weight error; tanh and the
        // contraction keep the output deviation well under 0.05.
        for (a, b) in of[0].f32s().unwrap().iter().zip(ob[0].f32s().unwrap()) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        let (sf, sb) = (ef.workspace_stats(), eb.workspace_stats());
        assert_eq!(sf.pack_bytes_bf16, 0);
        assert_eq!(sb.pack_bytes_f32, 0);
        assert_eq!(
            sf.pack_bytes_f32,
            2 * sb.pack_bytes_bf16,
            "bf16 panels must cost exactly half the f32 bytes"
        );
        assert_eq!((sf.pack_entries, sb.pack_entries), (1, 1));
    }

    #[test]
    fn pack_cache_keeps_both_precisions_per_slot_and_invalidates_together() {
        let mut e = NativeEngine::new(NativeConfig {
            precision: Some(PackPrecision::F32),
            ..NativeConfig::default()
        });
        let p = e.init_params().unwrap();
        let n = e.config().latent_dim();
        let t = p.tensors[P_W_CELL].clone();
        e.packed_weight(P_W_CELL, &t, n, n).unwrap();
        // Re-latch the other precision on the same engine: the bf16 pack
        // must join the resident f32 pack (a miss, not an invalidation).
        e.precision = PackPrecision::Bf16;
        e.packed_weight(P_W_CELL, &t, n, n).unwrap();
        let s = e.workspace_stats();
        assert_eq!(
            (s.pack_misses, s.pack_hits, s.pack_invalidations),
            (2, 0, 0),
            "second precision is a fresh miss on a version match"
        );
        assert_eq!(s.pack_entries, 2);
        assert!(s.pack_bytes_f32 > 0 && s.pack_bytes_bf16 > 0);
        assert_eq!(s.pack_bytes_f32, 2 * s.pack_bytes_bf16);
        e.packed_weight(P_W_CELL, &t, n, n).unwrap();
        assert_eq!(e.workspace_stats().pack_hits, 1, "bf16 now hits");
        // A new parameter revision must drop *both* precisions at once.
        let p2 = crate::model::ParamSet::from_tensors(p.tensors.clone());
        e.packed_weight(P_W_CELL, &p2.tensors[P_W_CELL], n, n).unwrap();
        let s = e.workspace_stats();
        assert_eq!(s.pack_invalidations, 1);
        assert_eq!(s.pack_entries, 1, "stale f32 pack must go too");
        assert_eq!(s.pack_bytes_f32, 0);
        assert!(s.pack_bytes_bf16 > 0);
    }

    #[test]
    fn anderson_update_parallel_chunking_matches_serial() {
        // Two engines, same inputs, pool sizes 1 and 4, at a latent wide
        // enough (512) that batch·nv²·n clears the parallel threshold:
        // the batched anderson_update fans samples across the pool, but
        // chunk boundaries must never change the per-sample arithmetic —
        // outputs are bit-identical.
        let mk = |threads: usize| {
            NativeEngine::new(NativeConfig {
                threads,
                latent_hw: 8,
                channels: 8,
                image_hw: 8,
                ..NativeConfig::default()
            })
        };
        let e1 = mk(1);
        let e4 = mk(4);
        let m = e1.config().solver.window;
        let n = e1.config().latent_dim();
        let batch = 32;
        let mut rng = Rng::new(23);
        let xh = rng.normal_vec(batch * m * n, 1.0);
        let fh: Vec<f32> = xh.iter().map(|v| v * 0.9 + 0.05).collect();
        let inputs = [
            HostTensor::f32(vec![batch, m, n], xh).unwrap(),
            HostTensor::f32(vec![batch, m, n], fh).unwrap(),
            HostTensor::f32(vec![m], vec![1.0; m]).unwrap(),
        ];
        let a = e1.execute("anderson_update", batch, &inputs).unwrap();
        let b = e4.execute("anderson_update", batch, &inputs).unwrap();
        assert_eq!(a[0].f32s().unwrap(), b[0].f32s().unwrap());
        assert_eq!(a[1].f32s().unwrap(), b[1].f32s().unwrap());
    }

    #[test]
    fn anderson_update_zero_mask_degenerates_to_zero() {
        let e = NativeEngine::tiny();
        let m = e.config().solver.window;
        let n = e.config().latent_dim();
        let out = e
            .execute(
                "anderson_update",
                1,
                &[
                    HostTensor::f32(vec![1, m, n], vec![1.0; m * n]).unwrap(),
                    HostTensor::f32(vec![1, m, n], vec![2.0; m * n]).unwrap(),
                    HostTensor::f32(vec![m], vec![0.0; m]).unwrap(),
                ],
            )
            .unwrap();
        assert!(out[0].f32s().unwrap().iter().all(|&v| v == 0.0));
        assert!(out[1].f32s().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn execute_validates_against_spec() {
        let e = NativeEngine::tiny();
        let err = e.execute("anderson_update", 1, &[]).unwrap_err();
        assert!(format!("{err}").contains("expected 3 inputs"), "{err}");
        assert!(e.execute("nope", 1, &[]).is_err());
        assert!(e.execute("encode", 7, &[]).is_err(), "7 is not a bucket");
    }

    #[test]
    fn stats_recorded_per_entry() {
        let e = NativeEngine::tiny();
        let m = e.config().solver.window;
        let n = e.config().latent_dim();
        let inputs = [
            HostTensor::zeros(vec![1, m, n]),
            HostTensor::zeros(vec![1, m, n]),
            HostTensor::zeros(vec![m]),
        ];
        e.execute("anderson_update", 1, &inputs).unwrap();
        e.execute("anderson_update", 1, &inputs).unwrap();
        let stats = Backend::stats(&e);
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].0, ("anderson_update".to_string(), 1));
        assert_eq!(stats[0].1.calls, 2);
        assert!(e.stats_report().contains("anderson_update"));
    }
}

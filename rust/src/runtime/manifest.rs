//! Typed view of `artifacts/manifest.json` (written by `python/compile/aot.py`).
//!
//! The manifest is the single contract between the build-time Python layer
//! and the Rust coordinator: model/solver/train hyperparameters, the
//! canonical parameter layout, and the input/output specs of every AOT
//! artifact.  Nothing in the Rust tree hard-codes shapes.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::Dtype;
use crate::util::json::{self, Json};

/// One tensor slot in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("spec missing name"))?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            v.get("dtype").and_then(Json::as_str).unwrap_or("float32"),
        )?;
        Ok(Self { name, shape, dtype })
    }
}

/// One compiled artifact: an entry point at a fixed batch size.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub batch: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model geometry (mirrors python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub preset: String,
    pub image_hw: usize,
    pub image_channels: usize,
    pub channels: usize,
    pub latent_hw: usize,
    pub groups: usize,
    pub num_classes: usize,
    pub param_count: usize,
}

impl ModelMeta {
    /// Flattened per-sample latent dimension `n` used by Anderson.
    pub fn latent_dim(&self) -> usize {
        self.latent_hw * self.latent_hw * self.channels
    }

    pub fn latent_shape(&self, batch: usize) -> Vec<usize> {
        vec![batch, self.latent_hw, self.latent_hw, self.channels]
    }

    pub fn image_shape(&self, batch: usize) -> Vec<usize> {
        vec![batch, self.image_hw, self.image_hw, self.image_channels]
    }

    pub fn image_dim(&self) -> usize {
        self.image_hw * self.image_hw * self.image_channels
    }
}

/// Solver defaults baked into the artifacts (beta/lam are *compiled in*;
/// window/tol/max_iter are runtime knobs seeded from these defaults).
#[derive(Debug, Clone)]
pub struct SolverMeta {
    pub window: usize,
    pub beta: f32,
    pub lam: f32,
    pub tol: f32,
    pub max_iter: usize,
    pub fused_steps: usize,
}

/// Training hyperparameters compiled into train_update artifacts.
#[derive(Debug, Clone)]
pub struct TrainMeta {
    pub lr: f32,
    pub momentum: f32,
    pub neumann_terms: usize,
    pub explicit_depth: usize,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub solver: SolverMeta,
    pub train: TrainMeta,
    pub params: Vec<TensorSpec>,
    pub entries: Vec<EntrySpec>,
    pub init_params_file: String,
    pub use_pallas: bool,
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest missing '{key}'"))
}

fn req_f32(v: &Json, key: &str) -> Result<f32> {
    v.get(key)
        .and_then(Json::as_f64)
        .map(|f| f as f32)
        .ok_or_else(|| anyhow!("manifest missing '{key}'"))
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let fv = req_usize(&v, "format_version")?;
        if fv != 1 {
            bail!("unsupported manifest format_version {fv}");
        }

        let m = v.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let model = ModelMeta {
            preset: m
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            image_hw: req_usize(m, "image_hw")?,
            image_channels: req_usize(m, "image_channels")?,
            channels: req_usize(m, "channels")?,
            latent_hw: req_usize(m, "latent_hw")?,
            groups: req_usize(m, "groups")?,
            num_classes: req_usize(m, "num_classes")?,
            param_count: req_usize(&v, "param_count")?,
        };

        let s = v.get("solver").ok_or_else(|| anyhow!("missing solver"))?;
        let solver = SolverMeta {
            window: req_usize(s, "window")?,
            beta: req_f32(s, "beta")?,
            lam: req_f32(s, "lam")?,
            tol: req_f32(s, "tol")?,
            max_iter: req_usize(s, "max_iter")?,
            fused_steps: req_usize(s, "fused_steps")?,
        };

        let t = v.get("train").ok_or_else(|| anyhow!("missing train"))?;
        let train = TrainMeta {
            lr: req_f32(t, "lr")?,
            momentum: req_f32(t, "momentum")?,
            neumann_terms: req_usize(t, "neumann_terms")?,
            explicit_depth: req_usize(t, "explicit_depth")?,
        };

        let params = v
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing params"))?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;

        let mut entries = Vec::new();
        for e in v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing entries"))?
        {
            entries.push(EntrySpec {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string(),
                batch: req_usize(e, "batch")?,
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string(),
                inputs: e
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: e
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
            });
        }

        let init_params_file = v
            .path(&["init_params", "file"])
            .and_then(Json::as_str)
            .unwrap_or("init_params.bin")
            .to_string();
        let use_pallas = v
            .get("use_pallas")
            .and_then(Json::as_bool)
            .unwrap_or(true);

        let manifest = Self {
            dir,
            model,
            solver,
            train,
            params,
            entries,
            init_params_file,
            use_pallas,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<()> {
        let total: usize = self.params.iter().map(TensorSpec::elements).sum();
        if total != self.model.param_count {
            bail!(
                "param shapes sum to {total}, manifest says {}",
                self.model.param_count
            );
        }
        if self.solver.window == 0 || self.solver.window > 8 {
            bail!("solver window {} out of range", self.solver.window);
        }
        for e in &self.entries {
            if !self.dir.join(&e.file).exists() {
                bail!("artifact file missing: {}", e.file);
            }
        }
        Ok(())
    }

    /// Find an entry by name + batch.
    pub fn entry(&self, name: &str, batch: usize) -> Result<&EntrySpec> {
        self.entries
            .iter()
            .find(|e| e.name == name && e.batch == batch)
            .ok_or_else(|| {
                let have: Vec<usize> = self.batches_for(name);
                anyhow!(
                    "no artifact '{name}' at batch {batch} (have batches {have:?})"
                )
            })
    }

    /// All batch buckets compiled for an entry, ascending.
    pub fn batches_for(&self, name: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.name == name)
            .map(|e| e.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Smallest compiled bucket that can hold `n` samples (for serving).
    ///
    /// Errors explicitly when `n` exceeds every compiled bucket.  The old
    /// behaviour silently clamped to the largest bucket, which could not
    /// actually hold the batch — downstream padding then failed with a
    /// confusing "batch exceeds bucket" shape error (or would have
    /// truncated samples).  Callers with oversize batches must split them
    /// (dataset evaluation already chunks by `batch`; the serving workers
    /// drain at most one bucket per batch by construction).
    pub fn bucket_for(&self, name: &str, n: usize) -> Result<usize> {
        let batches = self.batches_for(name);
        if batches.is_empty() {
            bail!("no artifacts for entry '{name}'");
        }
        match batches.iter().find(|&&b| b >= n) {
            Some(&b) => Ok(b),
            None => bail!(
                "batch of {n} exceeds the largest compiled bucket ({}) for \
                 '{name}': split the batch or compile a larger bucket",
                batches.last().expect("batches non-empty")
            ),
        }
    }

    pub fn artifact_path(&self, e: &EntrySpec) -> PathBuf {
        self.dir.join(&e.file)
    }

    pub fn init_params_path(&self) -> PathBuf {
        self.dir.join(&self.init_params_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// A miniature manifest for unit tests (no artifact files on disk →
    /// validate() relaxed by creating the files).
    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        for f in ["a_b1.hlo.txt"] {
            std::fs::File::create(dir.join(f)).unwrap();
        }
        let text = r#"{
          "format_version": 1,
          "param_count": 6,
          "model": {"name":"t","image_hw":8,"image_channels":3,"channels":2,
                    "latent_hw":2,"groups":1,"num_classes":10,
                    "enc_stride":2,"enc_pool":2},
          "solver": {"window":5,"beta":1.0,"lam":1e-5,"tol":1e-2,
                     "max_iter":50,"fused_steps":8},
          "train": {"lr":1e-3,"momentum":0.9,"weight_decay":0.0,
                    "neumann_terms":3,"explicit_depth":6},
          "params": [{"name":"w","shape":[2,3],"dtype":"float32"}],
          "entries": [{"name":"a","batch":1,"file":"a_b1.hlo.txt",
                       "inputs":[{"name":"x","shape":[1,4],"dtype":"float32"}],
                       "outputs":[{"name":"out0","shape":[1,4],"dtype":"float32"}]}],
          "init_params": {"file":"init_params.bin","count":6,"seed":0},
          "use_pallas": true
        }"#;
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(text.as_bytes()).unwrap();
    }

    #[test]
    fn loads_fixture() {
        let dir = std::env::temp_dir().join("deqa_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.channels, 2);
        assert_eq!(m.model.latent_dim(), 8);
        assert_eq!(m.solver.window, 5);
        assert_eq!(m.entry("a", 1).unwrap().inputs[0].shape, vec![1, 4]);
        assert!(m.entry("a", 2).is_err());
        assert_eq!(m.batches_for("a"), vec![1]);
        assert_eq!(m.bucket_for("a", 1).unwrap(), 1);
        // Oversize batches are rejected with an explicit error instead of
        // silently clamping to a bucket that cannot hold them.
        let err = m.bucket_for("a", 99).unwrap_err();
        assert!(
            format!("{err}").contains("exceeds the largest compiled bucket"),
            "{err}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Exercised against the actual artifacts when they exist
        // (`make artifacts`); skipped otherwise so unit tests stay hermetic.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.model.param_count > 0);
            assert!(!m.entries.is_empty());
            assert!(m.entry("cell_step", 32).is_ok());
        }
    }
}

//! Runtime layer: the [`Backend`] execution abstraction, manifest
//! registry, host tensors, and the two engines that implement it.
//!
//! The coordinator's only gateway to compute is
//! `Backend::execute(entry, batch, inputs)` over [`HostTensor`]s, with
//! shapes/dtypes validated against the manifest:
//!
//!   * [`NativeEngine`] — hermetic pure-Rust twin (always available, the
//!     default; what CI and the integration test tier run against);
//!   * `Engine` — PJRT execution of the AOT HLO artifacts from
//!     `artifacts/manifest.json` (behind the `pjrt` cargo feature, so
//!     intentionally not linked here: default rustdoc builds omit it).
//!
//! [`backend::backend_from_dir`] picks between them automatically.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod faults;
pub mod manifest;
pub mod native_engine;
pub mod tensor;

pub use backend::{backend_from_dir, select_backend, Backend, EntryStats};
pub use faults::{FaultInjector, FaultKind, FaultPlan, FaultRule};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::{EntrySpec, Manifest, ModelMeta, SolverMeta, TensorSpec, TrainMeta};
pub use native_engine::{NativeConfig, NativeEngine};
pub use tensor::{Dtype, HostTensor, TensorData};

//! PJRT runtime: manifest registry, host tensors, execution engine.
//!
//! The coordinator's only gateway to the AOT-compiled JAX/Pallas compute:
//! `Engine::execute(entry, batch, inputs)` over `HostTensor`s, with
//! shapes/dtypes validated against `artifacts/manifest.json`.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{EntrySpec, Manifest, ModelMeta, SolverMeta, TensorSpec, TrainMeta};
pub use tensor::{Dtype, HostTensor, TensorData};

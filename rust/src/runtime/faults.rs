//! Deterministic fault injection: a seeded [`FaultPlan`] wrapped around
//! any [`Backend`] as a decorator, so every tier above the runtime —
//! solver drivers, the iteration-level scheduler, the replica
//! supervisor, the TCP front-end — can be chaos-tested without touching
//! engine code.
//!
//! **Off by default and zero-cost when off**: the injector is a separate
//! `Backend` wrapper that only exists when a plan is configured
//! (`DEQ_FAULTS` env var, the [`NativeConfig::faults`] knob, or an
//! explicit [`FaultInjector::new`]).  With no plan there is no wrapper —
//! no extra dispatch, no extra allocation on the hot path — which is
//! what keeps the steady-state alloc assertions and the bench gates
//! byte-identical to a build without this module.
//!
//! # Plan format
//!
//! A plan is a semicolon-separated list of clauses:
//!
//! ```text
//! seed=42;panic@cell_step#7;nan@encode#3;stall@cell_step%0.05:25ms
//! ```
//!
//! * `seed=N` — seeds the PRNG used by rate triggers (default 0).
//! * `panic@ENTRY#N` — panic on the N-th call (1-based) of `ENTRY`.
//! * `nan@ENTRY#N` — return the real outputs with row 0 of every output
//!   tensor overwritten with NaN (poisons exactly one lane of a batched
//!   call — the per-sample kernels keep the rot from spreading).
//! * `stall@ENTRY#N:MSms` — sleep `MS` milliseconds before the call
//!   (injected latency; the call then proceeds normally).
//! * `KIND@ENTRY%P[...]` — rate form: instead of an exact call count,
//!   fire with probability `P` (0..=1) per call, drawn from the seeded
//!   PRNG — deterministic for a fixed seed and call sequence.
//! * `ENTRY` may be `*` to match every entry point.
//!
//! Call counts are tracked per entry name across the injector's
//! lifetime, so a respawned replica sharing the engine `Arc` keeps
//! counting where the crashed one stopped — an exact-count panic fires
//! once, not once per respawn.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::model::ParamSet;
use crate::runtime::backend::Backend;
use crate::runtime::manifest::Manifest;
use crate::runtime::native_engine::{NativeConfig, NativeEngine};
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Rng;

/// Environment variable holding the fault plan for process-wide
/// injection (applied by [`crate::runtime::select_backend`]).
pub const FAULTS_ENV: &str = "DEQ_FAULTS";

/// What an injected fault does to the matched call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Panic before dispatching (simulates a crashed replica worker).
    Panic,
    /// Execute normally, then overwrite row 0 of every output tensor
    /// with NaN (simulates numerical breakdown in one lane).
    NonFinite,
    /// Sleep this long before dispatching (injected latency).
    Stall(Duration),
}

/// When a rule fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// On exactly the N-th matching call (1-based), once.
    OnCall(u64),
    /// With this probability per matching call, from the seeded PRNG.
    Rate(f32),
}

/// One clause of a plan.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRule {
    /// Entry-point name to match (`*` matches every entry).
    pub entry: String,
    pub trigger: Trigger,
    pub kind: FaultKind,
}

impl FaultRule {
    fn matches(&self, entry: &str) -> bool {
        self.entry == "*" || self.entry == entry
    }
}

/// A parsed, deterministic fault schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse the plan text format documented at module level.
    pub fn parse(text: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        for clause in text.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(seed) = clause.strip_prefix("seed=") {
                plan.seed = seed
                    .parse()
                    .with_context(|| format!("bad fault seed '{seed}'"))?;
                continue;
            }
            plan.rules.push(parse_rule(clause)?);
        }
        Ok(plan)
    }

    /// Read a plan from `DEQ_FAULTS`; `Ok(None)` when unset or empty.
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var(FAULTS_ENV) {
            Ok(text) if !text.trim().is_empty() => {
                Ok(Some(Self::parse(&text).with_context(|| {
                    format!("parsing {FAULTS_ENV}='{text}'")
                })?))
            }
            _ => Ok(None),
        }
    }
}

/// Parse one `KIND@ENTRY(#N|%P)[:MSms]` clause.
fn parse_rule(clause: &str) -> Result<FaultRule> {
    let (kind_name, rest) = clause
        .split_once('@')
        .with_context(|| format!("fault clause '{clause}' missing '@'"))?;
    // The stall duration rides after a ':' on the trigger half.
    let (rest, stall_ms) = match rest.split_once(':') {
        Some((head, ms)) => {
            let ms = ms
                .strip_suffix("ms")
                .with_context(|| format!("stall duration '{ms}' missing 'ms'"))?
                .parse::<u64>()
                .with_context(|| format!("bad stall duration in '{clause}'"))?;
            (head, Some(ms))
        }
        None => (rest, None),
    };
    let (entry, trigger) = if let Some((entry, n)) = rest.split_once('#') {
        let n: u64 = n
            .parse()
            .with_context(|| format!("bad call count in '{clause}'"))?;
        anyhow::ensure!(n >= 1, "call counts are 1-based in '{clause}'");
        (entry, Trigger::OnCall(n))
    } else if let Some((entry, p)) = rest.split_once('%') {
        let p: f32 = p
            .parse()
            .with_context(|| format!("bad rate in '{clause}'"))?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&p),
            "rate must be in 0..=1 in '{clause}'"
        );
        (entry, Trigger::Rate(p))
    } else {
        bail!("fault clause '{clause}' needs '#N' or '%P'");
    };
    anyhow::ensure!(!entry.is_empty(), "empty entry in '{clause}'");
    let kind = match (kind_name, stall_ms) {
        ("panic", None) => FaultKind::Panic,
        ("nan", None) => FaultKind::NonFinite,
        ("stall", Some(ms)) => FaultKind::Stall(Duration::from_millis(ms)),
        ("stall", None) => bail!("stall clause '{clause}' needs ':MSms'"),
        _ => bail!(
            "unknown fault kind '{kind_name}' (expected panic|nan|stall)"
        ),
    };
    Ok(FaultRule { entry: entry.to_string(), trigger, kind })
}

/// The decorator: delegates everything to the inner backend, injecting
/// the plan's faults on matching `execute` calls.
pub struct FaultInjector {
    inner: Arc<dyn Backend>,
    plan: FaultPlan,
    /// Per-entry call counts (exact-count triggers index into these).
    calls: Mutex<HashMap<String, u64>>,
    rng: Mutex<Rng>,
    injected: AtomicU64,
}

impl FaultInjector {
    pub fn new(inner: Arc<dyn Backend>, plan: FaultPlan) -> Self {
        let rng = Mutex::new(Rng::new(plan.seed));
        Self {
            inner,
            plan,
            calls: Mutex::new(HashMap::new()),
            rng,
            injected: AtomicU64::new(0),
        }
    }

    /// Total faults injected so far (all kinds).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Decide whether this call faults.  All locks are released before
    /// returning so a `Panic` decision never poisons injector state.
    fn decide(&self, entry: &str) -> Option<(FaultKind, u64)> {
        let count = {
            let mut calls = self
                .calls
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            let c = calls.entry(entry.to_string()).or_insert(0);
            *c += 1;
            *c
        };
        for rule in &self.plan.rules {
            if !rule.matches(entry) {
                continue;
            }
            let fires = match rule.trigger {
                Trigger::OnCall(n) => count == n,
                Trigger::Rate(p) => {
                    let draw = self
                        .rng
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .uniform();
                    draw < p
                }
            };
            if fires {
                return Some((rule.kind, count));
            }
        }
        None
    }
}

/// Overwrite row 0 of the tensor with NaN (one lane of a batched call).
fn poison_row0(t: &mut HostTensor) {
    let rw = t.row_len();
    if let Ok(data) = t.f32s_mut() {
        let rw = rw.min(data.len());
        for v in &mut data[..rw] {
            *v = f32::NAN;
        }
    }
}

impl Backend for FaultInjector {
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn platform(&self) -> String {
        format!("{}+faults", self.inner.platform())
    }

    fn execute(
        &self,
        name: &str,
        batch: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        match self.decide(name) {
            None => self.inner.execute(name, batch, inputs),
            Some((FaultKind::Panic, count)) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                panic!(
                    "injected fault: panic on {name}@b{batch} call #{count}"
                );
            }
            Some((FaultKind::Stall(d), _)) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(d);
                self.inner.execute(name, batch, inputs)
            }
            Some((FaultKind::NonFinite, _)) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                let mut out = self.inner.execute(name, batch, inputs)?;
                for t in &mut out {
                    poison_row0(t);
                }
                Ok(out)
            }
        }
    }

    fn init_params(&self) -> Result<ParamSet> {
        self.inner.init_params()
    }

    fn recycle(&self, tensors: Vec<HostTensor>) {
        self.inner.recycle(tensors);
    }

    fn warmup(&self, entries: &[(&str, usize)]) -> Result<()> {
        self.inner.warmup(entries)
    }

    fn stats(&self) -> Vec<((String, usize), super::backend::EntryStats)> {
        self.inner.stats()
    }

    fn hot_stats(&self) -> Option<crate::native::WorkspaceStats> {
        self.inner.hot_stats()
    }

    fn faults_injected(&self) -> u64 {
        self.injected()
    }
}

/// Wrap `backend` with the `DEQ_FAULTS` plan when one is set; the
/// identity (no wrapper, no cost) otherwise.
pub fn wrap_from_env(backend: Arc<dyn Backend>) -> Result<Arc<dyn Backend>> {
    Ok(match FaultPlan::from_env()? {
        Some(plan) => {
            eprintln!(
                "[faults] DEQ_FAULTS active: {} rule(s), seed {}",
                plan.rules.len(),
                plan.seed
            );
            Arc::new(FaultInjector::new(backend, plan))
        }
        None => backend,
    })
}

/// Build a native engine from `cfg`, honoring its `faults` plan knob:
/// the configured plan wraps the engine, `None` returns it bare.
pub fn native_with_faults(cfg: NativeConfig) -> Result<Arc<dyn Backend>> {
    let plan = match &cfg.faults {
        Some(text) => Some(FaultPlan::parse(text)?),
        None => None,
    };
    let engine: Arc<dyn Backend> = Arc::new(NativeEngine::new(cfg));
    Ok(match plan {
        Some(plan) => Arc::new(FaultInjector::new(engine, plan)),
        None => engine,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_all_clause_forms() {
        let p = FaultPlan::parse(
            "seed=7;panic@cell_step#3;nan@*#1;stall@encode%0.25:15ms",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules.len(), 3);
        assert_eq!(
            p.rules[0],
            FaultRule {
                entry: "cell_step".into(),
                trigger: Trigger::OnCall(3),
                kind: FaultKind::Panic,
            }
        );
        assert_eq!(
            p.rules[1],
            FaultRule {
                entry: "*".into(),
                trigger: Trigger::OnCall(1),
                kind: FaultKind::NonFinite,
            }
        );
        assert_eq!(
            p.rules[2],
            FaultRule {
                entry: "encode".into(),
                trigger: Trigger::Rate(0.25),
                kind: FaultKind::Stall(Duration::from_millis(15)),
            }
        );
        // Empty plan is valid (no rules).
        assert_eq!(FaultPlan::parse("").unwrap().rules.len(), 0);
    }

    #[test]
    fn plan_rejects_malformed_clauses() {
        for bad in [
            "panic@cell_step",      // no trigger
            "panic@cell_step#0",    // counts are 1-based
            "warp@cell_step#1",     // unknown kind
            "stall@cell_step#1",    // stall without duration
            "panic@cell_step#1:5ms", // duration on a non-stall
            "nan@cell_step%1.5",    // rate out of range
            "seed=x",               // bad seed
            "panic@#1",             // empty entry
        ] {
            assert!(
                FaultPlan::parse(bad).is_err(),
                "'{bad}' should not parse"
            );
        }
    }

    #[test]
    fn exact_count_trigger_fires_once_and_counts() {
        let plan = FaultPlan::parse("nan@cell_step#2").unwrap();
        let inner: Arc<dyn Backend> = Arc::new(NativeEngine::tiny());
        let inj = FaultInjector::new(inner.clone(), plan);
        let meta = inj.manifest().model.clone();
        let p = inj.init_params().unwrap();
        let mut inputs = p.tensors.clone();
        inputs.push(HostTensor::zeros(meta.latent_shape(1)));
        inputs.push(HostTensor::zeros(meta.latent_shape(1)));
        // Call 1: clean.  Call 2: poisoned.  Call 3: clean again.
        let clean = inj.execute("cell_step", 1, &inputs).unwrap();
        assert!(clean[0].f32s().unwrap().iter().all(|v| v.is_finite()));
        let bad = inj.execute("cell_step", 1, &inputs).unwrap();
        assert!(bad[0].f32s().unwrap()[0].is_nan(), "row 0 not poisoned");
        // Per-sample norm outputs get their lane-0 slot poisoned too.
        assert!(bad[1].f32s().unwrap()[0].is_nan());
        let clean2 = inj.execute("cell_step", 1, &inputs).unwrap();
        assert!(clean2[0].f32s().unwrap().iter().all(|v| v.is_finite()));
        assert_eq!(inj.injected(), 1);
        assert_eq!(inj.faults_injected(), 1);
        assert!(inj.platform().ends_with("+faults"));
    }

    #[test]
    fn rate_trigger_is_deterministic_for_a_seed() {
        let fire_pattern = |seed: u64| -> Vec<bool> {
            let plan =
                FaultPlan::parse(&format!("seed={seed};stall@x%0.5:0ms"))
                    .unwrap();
            let inner: Arc<dyn Backend> = Arc::new(NativeEngine::tiny());
            let inj = FaultInjector::new(inner, plan);
            (0..32)
                .map(|_| {
                    let before = inj.injected();
                    // decide() is exercised through execute on a bogus
                    // entry; the inner engine rejects it, but the
                    // injection decision (a stall of 0ms) happens first.
                    let _ = inj.execute("x", 1, &[]);
                    inj.injected() > before
                })
                .collect()
        };
        let a = fire_pattern(11);
        let b = fire_pattern(11);
        let c = fire_pattern(12);
        assert_eq!(a, b, "same seed must fire identically");
        assert_ne!(a, c, "different seeds should differ (32 draws)");
        assert!(a.iter().any(|&f| f) && !a.iter().all(|&f| f));
    }

    #[test]
    fn env_plan_absent_means_no_wrapper() {
        // Hermetic: read via an explicit empty-var simulation — from_env
        // on the (unset in tests) var returns None, and wrap_from_env
        // then returns the exact same Arc.
        if std::env::var(FAULTS_ENV).is_ok() {
            return; // the chaos CI job sets it; skip the identity check
        }
        let b: Arc<dyn Backend> = Arc::new(NativeEngine::tiny());
        let before = Arc::as_ptr(&b) as *const ();
        let wrapped = wrap_from_env(b).unwrap();
        assert_eq!(before, Arc::as_ptr(&wrapped) as *const ());
    }
}

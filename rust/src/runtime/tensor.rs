//! Host-side tensors, plus conversion to/from PJRT `Literal`s when the
//! `pjrt` feature is enabled.
//!
//! Everything the coordinator moves across a [`crate::runtime::Backend`]
//! boundary goes through `HostTensor`: a shape plus flat row-major data
//! (f32 or i32 — the only dtypes the model entry points use).

use anyhow::{anyhow, bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "float32" | "f32" => Ok(Dtype::F32),
            "int32" | "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: row-major data + shape, plus an optional content
/// *version* tag.
///
/// `version == 0` (the default for every constructor) means
/// "unversioned".  A nonzero version is a process-unique revision id
/// stamped by [`crate::model::ParamSet`] on parameter tensors: backends
/// key derived artifacts (the native engine's packed-weight cache) on
/// it, so a fresh version after a training step invalidates exactly the
/// stale packs.  Versions ride along with `clone()` and are ignored by
/// equality — two tensors with the same shape and data compare equal
/// whatever their revision tags say.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
    /// Content revision tag (0 = unversioned); see the type docs.
    /// Managed by `ParamSet` — mutate the data through `f32s_mut` and
    /// the tag goes stale, so parameter updates must re-stamp.
    pub version: u64,
}

/// Equality is shape + data only: the version tag is an identity hint
/// for caches, not part of the value.
impl PartialEq for HostTensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data == other.data
    }
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!(
                "shape {:?} wants {} elements, data has {}",
                shape,
                want,
                data.len()
            );
        }
        Ok(Self { shape, data: TensorData::F32(data), version: 0 })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!(
                "shape {:?} wants {} elements, data has {}",
                shape,
                want,
                data.len()
            );
        }
        Ok(Self { shape, data: TensorData::I32(data), version: 0 })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: TensorData::F32(vec![0.0; n]), version: 0 }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(vec![v]), version: 0 }
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow f32 data (errors on dtype mismatch).
    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(anyhow!("tensor is f32, expected i32")),
        }
    }

    /// Single scalar value (errors unless exactly one element).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            bail!("item_f32 on tensor with {} elements", v.len());
        }
        Ok(v[0])
    }

    pub fn item_i32(&self) -> Result<i32> {
        let v = self.i32s()?;
        if v.len() != 1 {
            bail!("item_i32 on tensor with {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if want != self.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// Elements per batch-major row (the product of every axis after the
    /// leading one).  Scalars and rank-1 tensors have row length 1.
    pub fn row_len(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    /// Borrow batch-major row `i` as f32 data.
    pub fn row_f32(&self, i: usize) -> Result<&[f32]> {
        let w = self.row_len();
        let v = self.f32s()?;
        if (i + 1) * w > v.len() {
            bail!("row {i} out of range for shape {:?}", self.shape);
        }
        Ok(&v[i * w..(i + 1) * w])
    }

    /// Overwrite batch-major row `i` with `src`.
    pub fn set_row_f32(&mut self, i: usize, src: &[f32]) -> Result<()> {
        let w = self.row_len();
        if src.len() != w {
            bail!("row data has {} elements, row wants {w}", src.len());
        }
        let v = self.f32s_mut()?;
        if (i + 1) * w > v.len() {
            bail!("row {i} out of range");
        }
        v[i * w..(i + 1) * w].copy_from_slice(src);
        Ok(())
    }

    /// Copy `src`'s data into this tensor without reallocating — the
    /// no-allocation twin of `clone_from` for the pooled hot paths.
    /// Shapes and dtypes must match exactly.
    pub fn copy_from(&mut self, src: &HostTensor) -> Result<()> {
        if self.shape != src.shape {
            bail!(
                "copy_from shape mismatch: {:?} vs {:?}",
                self.shape,
                src.shape
            );
        }
        match (&mut self.data, &src.data) {
            (TensorData::F32(d), TensorData::F32(s)) => d.copy_from_slice(s),
            (TensorData::I32(d), TensorData::I32(s)) => d.copy_from_slice(s),
            _ => bail!("copy_from dtype mismatch"),
        }
        // Content identity travels with the content.
        self.version = src.version;
        Ok(())
    }

    /// Per-lane masking helper: replace this tensor's row `i` with `src`'s
    /// row `i` wherever `mask[i]` is true.  Shapes must match and the
    /// leading axis must equal `mask.len()`.  This is how solver drivers
    /// and the lane scheduler freeze converged samples while the rest of
    /// the batch keeps iterating.
    pub fn overwrite_rows_where(
        &mut self,
        src: &HostTensor,
        mask: &[bool],
    ) -> Result<()> {
        if self.shape != src.shape {
            bail!(
                "row merge shape mismatch: {:?} vs {:?}",
                self.shape,
                src.shape
            );
        }
        let batch = *self.shape.first().unwrap_or(&0);
        if mask.len() != batch {
            bail!("mask has {} lanes, leading axis is {batch}", mask.len());
        }
        let w = self.row_len();
        let s = src.f32s()?;
        let d = self.f32s_mut()?;
        for (i, &take) in mask.iter().enumerate() {
            if take {
                d[i * w..(i + 1) * w].copy_from_slice(&s[i * w..(i + 1) * w]);
            }
        }
        Ok(())
    }
}

/// PJRT literal round-trips (feature `pjrt` only).
#[cfg(feature = "pjrt")]
impl HostTensor {
    /// Convert to a PJRT literal (copies once).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
            TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
        };
        lit.reshape(&dims)
            .with_context(|| format!("reshape literal to {:?}", self.shape))
    }

    /// Convert back from a PJRT literal (copies once).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = match lit.shape().context("literal shape")? {
            xla::Shape::Array(a) => a,
            other => bail!("expected array literal, got {other:?}"),
        };
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().context("literal to_vec f32")?;
                HostTensor::f32(dims, data)
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().context("literal to_vec i32")?;
                HostTensor::i32(dims, data)
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::i32(vec![2], vec![1]).is_err());
    }

    #[test]
    fn accessors() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.len(), 4);
        assert_eq!(t.f32s().unwrap()[3], 4.0);
        assert!(t.i32s().is_err());
        assert!(t.item_f32().is_err());
        assert_eq!(HostTensor::scalar_f32(5.0).item_f32().unwrap(), 5.0);
    }

    #[test]
    fn reshape_checks_count() {
        let t = HostTensor::zeros(vec![4, 2]);
        assert!(t.clone().reshaped(vec![2, 4]).is_ok());
        assert!(t.reshaped(vec![3, 3]).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("float32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("int32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("float64").is_err());
    }

    #[test]
    fn row_accessors() {
        let mut t =
            HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(t.row_len(), 3);
        assert_eq!(t.row_f32(1).unwrap(), &[4.0, 5.0, 6.0]);
        assert!(t.row_f32(2).is_err());
        t.set_row_f32(0, &[7.0, 8.0, 9.0]).unwrap();
        assert_eq!(t.row_f32(0).unwrap(), &[7.0, 8.0, 9.0]);
        assert!(t.set_row_f32(0, &[1.0]).is_err());
    }

    #[test]
    fn copy_from_requires_matching_layout() {
        let mut dst = HostTensor::zeros(vec![2, 2]);
        let src = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        dst.copy_from(&src).unwrap();
        assert_eq!(dst.f32s().unwrap(), src.f32s().unwrap());
        let wrong_shape = HostTensor::zeros(vec![4]);
        assert!(dst.copy_from(&wrong_shape).is_err());
        let wrong_dtype = HostTensor::i32(vec![2, 2], vec![0; 4]).unwrap();
        assert!(dst.copy_from(&wrong_dtype).is_err());
    }

    #[test]
    fn overwrite_rows_masked() {
        let mut dst = HostTensor::zeros(vec![3, 2]);
        let src =
            HostTensor::f32(vec![3, 2], vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]).unwrap();
        dst.overwrite_rows_where(&src, &[true, false, true]).unwrap();
        assert_eq!(dst.f32s().unwrap(), &[1.0, 1.0, 0.0, 0.0, 3.0, 3.0]);
        // Mask arity and shape are checked.
        assert!(dst.overwrite_rows_where(&src, &[true]).is_err());
        let wrong = HostTensor::zeros(vec![2, 3]);
        assert!(dst.overwrite_rows_where(&wrong, &[true, false, true]).is_err());
    }

    #[test]
    fn version_tag_rides_clones_not_equality() {
        let mut a = HostTensor::zeros(vec![2]);
        let b = HostTensor::zeros(vec![2]);
        a.version = 7;
        assert_eq!(a, b, "version must not affect equality");
        assert_eq!(a.clone().version, 7, "version must survive clone");
        let mut c = HostTensor::zeros(vec![2]);
        c.copy_from(&a).unwrap();
        assert_eq!(c.version, 7, "copy_from must carry content identity");
    }

    // Literal round-trips are covered by rust/tests/integration_runtime.rs
    // (they need the PJRT shared library at runtime).
}

//! The PJRT execution engine: loads HLO-text artifacts, compiles them once
//! on the CPU client, and executes them from the coordinator's hot path.
//! Only built with the `pjrt` cargo feature; the hermetic default build
//! uses [`crate::runtime::NativeEngine`] instead.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.  Every
//! artifact returns a 1-tuple or n-tuple (lowered with `return_tuple=True`),
//! which `execute_entry` decomposes back into `HostTensor`s.
//!
//! The engine also keeps per-entry execution statistics (count, total time)
//! — the raw material for EXPERIMENTS.md §Perf and the device simulator's
//! calibration.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::ParamSet;
use crate::runtime::backend::{self, Backend, EntryStats, StatsBook};
use crate::runtime::manifest::{EntrySpec, Manifest};
use crate::runtime::tensor::HostTensor;

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: EntrySpec,
}

/// PJRT client + compiled-executable cache + stats.
///
/// `Engine` is shared across threads by the serving stack.  The `xla`
/// crate's wrappers hold `Rc`/raw pointers and are not `Send`/`Sync`, so
/// every PJRT interaction (compile *and* execute) is serialized behind
/// `pjrt_lock`; with that discipline the underlying PJRT CPU client is
/// thread-safe, which justifies the manual `Send`/`Sync` impls below.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(String, usize), &'static Compiled>>,
    stats: StatsBook,
    /// Serializes all PJRT calls (see struct docs).
    pjrt_lock: Mutex<()>,
}

// SAFETY: all uses of the non-Send `xla` wrapper types (`client`, the
// cached executables) happen while holding `pjrt_lock`, so cross-thread
// access is serialized; the wrappers' Rc refcounts are never touched
// concurrently.  Literal conversion happens on caller threads but operates
// on thread-local literals only.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: StatsBook::default(),
            pjrt_lock: Mutex::new(()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an entry point at a batch bucket.
    fn compiled(&self, name: &str, batch: usize) -> Result<&'static Compiled> {
        let key = (name.to_string(), batch);
        if let Some(c) = self.cache.lock().unwrap().get(&key) {
            return Ok(c);
        }
        let spec = self.manifest.entry(name, batch)?.clone();
        let path = self.manifest.artifact_path(&spec);
        let t0 = Instant::now();
        let _pjrt = self.pjrt_lock.lock().unwrap();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", spec.file))?;
        self.stats.record_compile(name, batch, t0.elapsed());

        // Executables live for the engine's lifetime; engines live for the
        // process's lifetime in every binary here. Leaking the box gives
        // stable references without self-referential lifetimes.
        let leaked: &'static Compiled = Box::leak(Box::new(Compiled { exe, spec }));
        self.cache.lock().unwrap().insert(key, leaked);
        Ok(leaked)
    }

    /// Eagerly compile a set of entries (so hot paths never hit compile).
    pub fn warmup(&self, entries: &[(&str, usize)]) -> Result<()> {
        for (name, batch) in entries {
            self.compiled(name, *batch)?;
        }
        Ok(())
    }

    /// Execute an artifact: validates inputs against the manifest spec,
    /// runs, decomposes the output tuple, validates output count.
    pub fn execute(
        &self,
        name: &str,
        batch: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let c = self.compiled(name, batch)?;
        backend::check_inputs(&c.spec, name, batch, inputs)?;

        let _pjrt = self.pjrt_lock.lock().unwrap();
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = c
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("execute {name}@b{batch}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        self.stats.record(name, batch, t0.elapsed());

        let parts = root.to_tuple().context("decompose output tuple")?;
        if parts.len() != c.spec.outputs.len() {
            bail!(
                "{name}@b{batch}: expected {} outputs, got {}",
                c.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()
    }

    /// Snapshot of per-entry stats, sorted by total time descending.
    pub fn stats(&self) -> Vec<((String, usize), EntryStats)> {
        self.stats.snapshot()
    }

    /// Human-readable stats table (for `--stats` / experiment footers).
    pub fn stats_report(&self) -> String {
        backend::render_stats(&self.stats())
    }
}

impl Backend for Engine {
    fn manifest(&self) -> &Manifest {
        Engine::manifest(self)
    }

    fn platform(&self) -> String {
        Engine::platform(self)
    }

    fn execute(
        &self,
        name: &str,
        batch: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        Engine::execute(self, name, batch, inputs)
    }

    fn init_params(&self) -> Result<ParamSet> {
        ParamSet::load_init(self.manifest())
    }

    fn warmup(&self, entries: &[(&str, usize)]) -> Result<()> {
        Engine::warmup(self, entries)
    }

    fn stats(&self) -> Vec<((String, usize), EntryStats)> {
        Engine::stats(self)
    }

    fn stats_report(&self) -> String {
        Engine::stats_report(self)
    }
}

//! `deq-anderson` — CLI for the Anderson-extrapolated DEQ stack.
//!
//! Subcommands:
//!   train              train the DEQ (or explicit baseline) on CIFAR10(-like)
//!   infer              classify a few samples, report solver stats
//!   serve              start the continuous-batching TCP inference server
//!   experiment <id>    regenerate a paper table/figure (table1 fig1 fig2
//!                      fig5 fig6 fig7 ablation serving, or `all`)
//!   sweep              native Anderson hyperparameter sweep (window/beta)
//!   artifacts-check    validate the selected backend + numeric cross-check
//!
//! Common flags: --artifacts DIR (default `artifacts`), --backend
//! auto|native|pjrt (default `auto`: PJRT over artifacts when available,
//! the hermetic pure-Rust NativeEngine otherwise), --out DIR (default
//! `results`), --seed N.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use deq_anderson::data;
use deq_anderson::experiments::{self, ExpOptions};
use deq_anderson::infer;
use deq_anderson::metrics::fmt_duration;
use deq_anderson::model::ParamSet;
use deq_anderson::native::{self, maps::DeqLikeMap, AndersonOpts};
use deq_anderson::runtime::{select_backend, Backend};
use deq_anderson::server::{tcp, Router, RouterConfig, SchedMode};
use deq_anderson::solver::{
    Damping, GramMode, SolveClamps, SolveSpec, SolverKind, StagnationRule,
};
use deq_anderson::train::{default_config, Backward, Trainer};
use deq_anderson::util::cli::Args;

const USAGE: &str = "\
usage: deq-anderson <command> [flags]

commands:
  train             --solver anderson|forward|hybrid|auto --epochs N
                    --train-size N --test-size N --batch N
                    --backward jfb|neumann --checkpoint PATH --explicit
  infer             --n N [--checkpoint PATH]
  serve             --addr 127.0.0.1:7070 --max-wait-ms N
                    --sched iteration|batch (default iteration: lanes
                    retire the moment their sample converges)
                    --min-tol F --max-iter-cap N (server-side clamps on
                    per-request solver overrides)
                    --replicas N (engine replicas draining one shared
                    queue; default 1) --queue-cap N (shed beyond this
                    backlog with an overloaded/retry_after_ms reply)
                    --max-inflight N (per-connection in-flight cap)
                    --deadline-ms N (default deadline for requests that
                    don't send their own; 0 = none)
                    --redrive-budget N (times an in-flight request is
                    re-queued after a replica crash; default 1)
                    --solver auto (per-lane forward/Anderson crossover
                    auto-selection, seeded by learned per-bucket priors;
                    clients may also send \"solver\":\"auto\" per request)
  experiment ID     table1|fig1|fig2|fig5|fig6|fig7|ablation|serving|all
                    --train-size N --test-size N --epochs N
  sweep             --windows 1,2,5,8 --betas 0.5,0.8,1.0 --dim N
  artifacts-check
solver flags (train/infer/serve, built into a SolveSpec):
  --solver KIND  --window N  --tol F  --max-iter N  --max-fevals N
  --stagnation-eps F  --no-fused-forward  --damping-beta F
  --restart-on-breakdown
  --adaptive-window  --errorfactor F  --cond-max F  --safeguard
                    (condition-monitored window + safeguarded mixed step)
  --gram-sketch N   (sketched Gram condition probes for window
                    adaptation; 0 = exact Gram, the default)
common flags: --artifacts DIR  --backend auto|native|pjrt  --out DIR
              --seed N  --quiet
";

/// Build the execution backend selected by `--backend` (default `auto`:
/// PJRT over `--artifacts` when available, the hermetic native twin
/// otherwise).
fn backend_from(args: &Args) -> Result<Arc<dyn Backend>> {
    let dir = args.str_or("artifacts", "artifacts");
    let choice = args.str_or("backend", "auto");
    select_backend(&choice, std::path::Path::new(&dir))
        .with_context(|| format!("creating '{choice}' backend over '{dir}'"))
}

/// Apply the shared solver flags (see USAGE) on top of a base spec,
/// through the validating builder — a degenerate combination (window 0,
/// tol ≤ 0, …) errors here with a descriptive message instead of
/// panicking mid-solve.  `train` applies them over its capped training
/// defaults, `infer`/`serve` over the manifest defaults.
fn apply_solver_flags(args: &Args, base: SolveSpec) -> Result<SolveSpec> {
    let mut b = base
        .to_builder()
        .window(args.usize_or("window", base.window))
        .tol(args.f32_or("tol", base.tol))
        .max_iter(args.usize_or("max-iter", base.max_iter))
        .max_fevals(args.usize_or("max-fevals", base.max_fevals))
        .stagnation(StagnationRule {
            window: base.stagnation.window,
            eps: args.f32_or("stagnation-eps", base.stagnation.eps),
        })
        .fused_forward(base.fused_forward && !args.has("no-fused-forward"))
        .restart_on_breakdown(
            args.has("restart-on-breakdown") || base.restart_on_breakdown,
        )
        .adaptive_window(args.has("adaptive-window") || base.adaptive_window)
        .errorfactor(args.f32_or("errorfactor", base.errorfactor))
        .cond_max(args.f32_or("cond-max", base.cond_max))
        .safeguard(args.has("safeguard") || base.safeguard)
        .gram(GramMode::from_sketch_dim(
            args.usize_or("gram-sketch", base.gram.sketch_dim()),
        ));
    if args.has("damping-beta") {
        b = b.damping(Damping::Constant(args.f32_or("damping-beta", 1.0)));
    }
    b.build().context("bad solver flags")
}

/// Solve spec for `infer`/`serve`: manifest defaults for the `--solver`
/// kind, plus the shared solver flags.
fn spec_from(args: &Args, engine: &dyn Backend) -> Result<SolveSpec> {
    let kind = SolverKind::parse(&args.str_or("solver", "anderson"))
        .with_context(|| {
            format!("bad --solver (expected {})", SolverKind::expected())
        })?;
    apply_solver_flags(args, SolveSpec::from_manifest(engine, kind))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "train" => cmd_train(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "experiment" => cmd_experiment(&args),
        "sweep" => cmd_sweep(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let engine = backend_from(args)?;
    let kind = SolverKind::parse(&args.str_or("solver", "anderson"))
        .with_context(|| {
            format!("bad --solver (expected {})", SolverKind::expected())
        })?;
    let epochs = args.usize_or("epochs", 5);
    let mut cfg = default_config(&engine, kind, epochs);
    cfg.batch = args.usize_or("batch", 32);
    cfg.seed = args.u64_or("seed", 0);
    cfg.verbose = !args.has("quiet");
    // The full shared solver-flag surface applies to training too, on
    // top of the training defaults (which cap max_iter at 30).
    cfg.solver = apply_solver_flags(args, cfg.solver.clone())?;
    if args.str_or("backward", "jfb") == "neumann" {
        cfg.backward = Backward::Neumann;
    }

    let (train_data, test_data, ds) = data::load_auto(
        args.usize_or("train-size", 960),
        args.usize_or("test-size", 320),
        cfg.seed,
    );
    println!(
        "training DEQ: solver={} backward={:?} backend={} dataset={ds} \
         train={} test={} epochs={epochs} params={}",
        kind.name(),
        cfg.backward,
        engine.platform(),
        train_data.len(),
        test_data.len(),
        engine.manifest().model.param_count
    );

    let init = engine.init_params()?;
    let trainer = Trainer::new(engine.as_ref(), cfg)?;
    let report = if args.has("explicit") {
        trainer.train_explicit(&init, &train_data, &test_data)?
    } else {
        trainer.train(&init, &train_data, &test_data)?
    };

    println!(
        "done in {}: final train acc {:.1}%, best test acc {:.1}%{}",
        fmt_duration(report.total_time),
        100.0 * report.final_train_acc(),
        100.0 * report.best_test_acc().unwrap_or(0.0),
        if report.diverged { " [DIVERGED]" } else { "" }
    );
    if let Some(path) = args.get("checkpoint") {
        report.params.save(&PathBuf::from(path))?;
        println!("saved checkpoint to {path}");
    }
    if args.has("stats") {
        println!("{}", engine.stats_report());
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let engine = backend_from(args)?;
    let spec = spec_from(args, engine.as_ref())?;
    let n = args.usize_or("n", 8);
    let params = match args.get("checkpoint") {
        Some(p) => ParamSet::load(engine.manifest(), &PathBuf::from(p))?,
        None => engine.init_params()?,
    };
    let (data, _, ds) = data::load_auto(n.max(32), 8, args.u64_or("seed", 0));
    let idx: Vec<usize> = (0..n).collect();
    let (imgs, labels) = data.gather(&idx);
    let r = infer::infer(engine.as_ref(), &params, &imgs, n, &spec)?;
    println!(
        "inference: dataset={ds} n={n} solver={} iters={} residual={:.2e} latency={}",
        spec.kind.name(),
        r.solver_iters,
        r.solver_residual,
        fmt_duration(r.latency)
    );
    let correct = r
        .predictions
        .iter()
        .zip(&labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    println!("predictions: {:?}", r.predictions);
    println!("labels:      {:?}", labels);
    println!("accuracy: {}/{n}", correct);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine = backend_from(args)?;
    let spec = spec_from(args, engine.as_ref())?;
    let params = Arc::new(match args.get("checkpoint") {
        Some(p) => ParamSet::load(engine.manifest(), &PathBuf::from(p))?,
        None => engine.init_params()?,
    });
    let mode = SchedMode::parse(&args.str_or("sched", "iteration"))
        .context("bad --sched (expected iteration|batch)")?;
    let default_clamps = SolveClamps::default();
    let cfg = RouterConfig {
        solver: spec,
        clamps: SolveClamps {
            min_tol: args.f32_or("min-tol", default_clamps.min_tol),
            max_iter: args.usize_or("max-iter-cap", default_clamps.max_iter),
        },
        mode,
        max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 10)),
        queue_cap: args.usize_or("queue-cap", 1024),
        replicas: args.usize_or("replicas", 1),
        default_deadline: match args.u64_or("deadline-ms", 0) {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        },
        redrive_budget: args.u64_or("redrive-budget", 1) as u32,
    };
    let replicas = cfg.replicas;
    let image_dim = engine.manifest().model.image_dim();
    // Pre-compile all serving buckets so first requests aren't slow.
    let buckets = engine.manifest().batches_for("encode");
    let warm: Vec<(&str, usize)> = buckets
        .iter()
        .flat_map(|&b| {
            [("encode", b), ("cell_step", b), ("anderson_update", b), ("classify", b)]
        })
        .collect();
    engine.warmup(&warm)?;
    println!(
        "[server] scheduling mode: {} replicas: {replicas}",
        mode.name()
    );
    let router = Arc::new(Router::start(engine, params, cfg)?);
    let addr = args.str_or("addr", "127.0.0.1:7070");
    let max_inflight =
        args.usize_or("max-inflight", tcp::DEFAULT_MAX_INFLIGHT);
    tcp::serve_tcp_with(router, image_dim, &addr, max_inflight)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .context("experiment id required (or 'all')")?;
    let opts = ExpOptions {
        out_dir: PathBuf::from(args.str_or("out", "results")),
        train_size: args.usize_or("train-size", 960),
        test_size: args.usize_or("test-size", 320),
        epochs: args.usize_or("epochs", 6),
        seed: args.u64_or("seed", 0),
        verbose: !args.has("quiet"),
    };
    // fig2 / fig6 are native-only analyses; the rest need a backend.
    let needs_engine = |id: &str| !matches!(id, "fig2" | "fig6");
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    let engine: Option<Arc<dyn Backend>> = if ids.iter().any(|i| needs_engine(i)) {
        Some(backend_from(args)?)
    } else {
        None
    };
    for id in ids {
        println!("\n================ experiment {id} ================");
        experiments::run(id, engine.as_ref(), &opts)?;
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    // Native hyperparameter sweep: window m and damping beta on a
    // DEQ-like synthetic map (paper §6 limitation: "these results do not
    // comprehensively search the Anderson hyperparameter space" — we do).
    let dim = args.usize_or("dim", 256);
    let windows = args.usize_list_or("windows", &[1, 2, 3, 5, 8]);
    let betas: Vec<f32> = args
        .str_or("betas", "0.5,0.8,1.0")
        .split(',')
        .map(|s| s.trim().parse().expect("bad --betas"))
        .collect();
    let map = DeqLikeMap::random(dim, 0.9, args.u64_or("seed", 0));
    let z0 = vec![0.0f32; dim];
    println!(
        "{:>7} {:>6} {:>8} {:>14} {:>12}",
        "window", "beta", "iters", "final_res", "converged"
    );
    for &m in &windows {
        for &b in &betas {
            let o = AndersonOpts {
                window: m,
                beta: b,
                lam: 1e-4,
                tol: 1e-6,
                max_iter: 200,
            };
            let tr = native::solve_anderson(&map, &z0, o)?;
            println!(
                "{:>7} {:>6.2} {:>8} {:>14.3e} {:>12}",
                m,
                b,
                tr.iters(),
                tr.final_residual(),
                tr.converged
            );
        }
    }
    let fw = native::solve_forward(
        &map,
        &z0,
        AndersonOpts { tol: 1e-6, max_iter: 200, ..Default::default() },
    );
    println!(
        "{:>7} {:>6} {:>8} {:>14.3e} {:>12}   (forward baseline)",
        "-",
        "-",
        fw.iters(),
        fw.final_residual(),
        fw.converged
    );
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let engine = backend_from(args)?;
    let m = engine.manifest().clone();
    println!(
        "manifest: preset={} params={} entries={} pallas={} platform={}",
        m.model.preset,
        m.model.param_count,
        m.entries.len(),
        m.use_pallas,
        engine.platform()
    );
    // Numeric cross-check: anderson_update artifact vs the native solver
    // on identical inputs.
    let batch = 1usize;
    let n = m.model.latent_dim();
    let window = m.solver.window;
    use deq_anderson::runtime::HostTensor;
    use deq_anderson::util::rng::Rng;
    let mut rng = Rng::new(7);
    let xh = rng.normal_vec(batch * window * n, 1.0);
    let fh: Vec<f32> = xh.iter().map(|v| v + 0.1 * rng.normal()).collect();
    let mask = vec![1.0f32; window];
    let out = engine.execute(
        "anderson_update",
        batch,
        &[
            HostTensor::f32(vec![batch, window, n], xh.clone())?,
            HostTensor::f32(vec![batch, window, n], fh.clone())?,
            HostTensor::f32(vec![window], mask)?,
        ],
    )?;
    // Native twin.
    let mut st = deq_anderson::native::AndersonState::new(
        window,
        n,
        m.solver.beta,
        m.solver.lam,
    );
    for i in 0..window {
        st.push(&xh[i * n..(i + 1) * n], &fh[i * n..(i + 1) * n]);
    }
    let (z_native, alpha_native) = st.mix()?;
    let z_art = out[0].f32s()?;
    let alpha_art = out[1].f32s()?;
    let zerr = z_art
        .iter()
        .zip(&z_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let aerr = alpha_art
        .iter()
        .zip(&alpha_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "anderson artifact vs native twin: max|Δz|={zerr:.2e} max|Δα|={aerr:.2e}"
    );
    anyhow::ensure!(zerr < 1e-2 && aerr < 1e-2, "artifact/native divergence");
    // Exercise every entry once at its smallest batch with zero inputs.
    for name in [
        "encode", "cell_step", "anderson_update", "classify",
        "forward_solve_k", "explicit_infer",
    ] {
        let b = *m.batches_for(name).first().context("no buckets")?;
        let spec = m.entry(name, b)?;
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| match s.dtype {
                deq_anderson::runtime::Dtype::F32 => {
                    HostTensor::zeros(s.shape.clone())
                }
                deq_anderson::runtime::Dtype::I32 => {
                    HostTensor::i32(s.shape.clone(), vec![0; s.elements()])
                        .unwrap()
                }
            })
            .collect();
        let out = engine.execute(name, b, &inputs)?;
        println!("  {name}@b{b}: ok ({} outputs)", out.len());
    }
    println!("artifacts-check: ALL OK");
    if args.has("stats") {
        println!("{}", engine.stats_report());
    }
    Ok(())
}

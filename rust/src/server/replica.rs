//! Engine replicas: N scheduler/batcher workers over one shared
//! `Arc<dyn Backend>` + parameter set, draining one shared queue.
//!
//! Work-stealing falls out of the shared queue: every replica drains it
//! at its own iteration boundaries, so an idle replica picks up work
//! the moment a busy one leaves it queued.  [`ReplicaSlots`] adds a
//! *fair-share* admission split on top — each replica publishes its
//! free-lane count at every boundary and takes only its proportional
//! share of the backlog, so a burst shards across replicas (filling
//! small buckets everywhere) instead of serializing behind whichever
//! replica's lock attempt wins the race.
//!
//! With one replica the split degenerates to `min(queued, free)` —
//! exactly the pre-replica admission rule, keeping `--replicas 1`
//! bit-for-bit identical to the single-worker router.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::Result;

use crate::server::supervise::{Exit, ReplicaCtx};
use crate::server::{batcher, scheduler, SchedMode};

/// Published free-lane counts, one slot per replica.  Advisory only:
/// counts are racy snapshots (Relaxed loads), which is fine — the split
/// is a placement heuristic, and the shared queue guarantees no request
/// is ever lost or double-admitted regardless of what the counts say.
pub(crate) struct ReplicaSlots {
    free: Vec<AtomicUsize>,
}

impl ReplicaSlots {
    /// All replicas start fully idle (`lanes` free lanes each).
    pub fn new(replicas: usize, lanes: usize) -> Self {
        Self { free: (0..replicas).map(|_| AtomicUsize::new(lanes)).collect() }
    }

    /// Publish `replica`'s current free-lane count.
    pub fn set_free(&self, replica: usize, free: usize) {
        self.free[replica].store(free, Ordering::Relaxed);
    }

    /// How many of `queued` requests `replica` should admit right now,
    /// given it has `my_free` open lanes: its ceil-rounded proportional
    /// share of the backlog by free capacity.  Ceil keeps small
    /// backlogs moving (a lone request is never split to zero) and lets
    /// the fastest replica steal the remainder on its next boundary.
    pub fn fair_take(&self, replica: usize, queued: usize, my_free: usize) -> usize {
        if queued == 0 || my_free == 0 {
            return 0;
        }
        if self.free.len() == 1 {
            return queued.min(my_free);
        }
        // Ensure our own published count is part of the total even if
        // the slot is stale (another thread read-modify-wrote since).
        let total: usize = self
            .free
            .iter()
            .enumerate()
            .map(|(r, f)| if r == replica { my_free } else { f.load(Ordering::Relaxed) })
            .sum();
        let share = queued.saturating_mul(my_free).div_ceil(total.max(1));
        share.min(my_free).min(queued)
    }
}

/// Spawn one replica worker (scheduler or batcher per the configured
/// mode), named `deq-scheduler-{r}` / `deq-batcher-{r}`.  The worker's
/// last act is reporting how its serve loop ended (clean exit, or a
/// crash with the recovered in-flight requests) over `exits` — the
/// supervisor joins the handle and reacts (see `supervise.rs`).
pub(crate) fn spawn(
    replica: usize,
    ctx: Arc<ReplicaCtx>,
    exits: Sender<Exit>,
) -> Result<std::thread::JoinHandle<()>> {
    let name = match ctx.cfg.mode {
        SchedMode::IterationLevel => format!("deq-scheduler-{replica}"),
        SchedMode::BatchGranular => format!("deq-batcher-{replica}"),
    };
    Ok(std::thread::Builder::new().name(name).spawn(move || {
        let outcome = match ctx.cfg.mode {
            SchedMode::IterationLevel => scheduler::run(&ctx, replica),
            SchedMode::BatchGranular => batcher::run(&ctx, replica),
        };
        let _ = exits.send(Exit { replica, outcome });
    })?)
}

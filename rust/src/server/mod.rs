//! Inference serving stack: a dynamic-batching request router in the
//! vLLM-router mold, sized for the DEQ workload.
//!
//! Architecture (std-only; the offline crate set has no tokio — threads +
//! condvar stand in for the async runtime, see DESIGN.md §Substitutions):
//!
//!   clients → [`Router::submit`] → shared queue → batcher thread
//!           → bucket-padded PJRT inference → per-request responses
//!
//! The batcher implements the classic dynamic-batching policy: wait until
//! either (a) the largest compiled bucket fills, or (b) the oldest queued
//! request has waited `max_wait`; then take the best-fitting bucket.
//! A TCP front-end (`serve_tcp`) speaks newline-delimited JSON for the
//! `deq-anderson serve` subcommand and the serving example.

pub mod batcher;
pub mod tcp;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::infer;
use crate::metrics::Stats;
use crate::model::ParamSet;
use crate::runtime::Backend;
use crate::solver::SolveOptions;

/// One inference request: a flat NHWC image.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
    pub respond: Sender<Response>,
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub class: usize,
    pub logits: Vec<f32>,
    pub solver_iters: usize,
    /// Total time in the system (queue + solve).
    pub latency: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub solver: SolveOptions,
    /// Max time the oldest request may wait before a partial batch fires.
    pub max_wait: Duration,
    /// Upper bound on queued requests (backpressure).
    pub queue_cap: usize,
}

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub latency: Mutex<Stats>,
    pub batch_fill: Mutex<Stats>,
}

impl ServerMetrics {
    pub fn record(&self, latency: Duration, batch: usize, bucket: usize) {
        self.served.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().push_duration(latency);
        let _ = batch;
        self.batch_fill
            .lock()
            .unwrap()
            .push(batch as f64 / bucket as f64);
    }

    pub fn summary(&self) -> String {
        let lat = self.latency.lock().unwrap();
        let fill = self.batch_fill.lock().unwrap();
        format!(
            "served={} batches={} p50={:.1}ms p95={:.1}ms p99={:.1}ms mean_fill={:.2}",
            self.served.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            lat.percentile(50.0) * 1e3,
            lat.percentile(95.0) * 1e3,
            lat.percentile(99.0) * 1e3,
            fill.mean(),
        )
    }
}

pub(crate) struct Queue {
    pub(crate) items: Mutex<Vec<Request>>,
    pub(crate) signal: Condvar,
    pub(crate) shutdown: AtomicBool,
}

/// The dynamic-batching inference router.
pub struct Router {
    queue: Arc<Queue>,
    pub metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
    worker: Option<std::thread::JoinHandle<()>>,
    cfg: RouterConfig,
}

impl Router {
    /// Spawn the batcher thread over an engine + parameters.
    pub fn start(
        engine: Arc<dyn Backend>,
        params: Arc<ParamSet>,
        cfg: RouterConfig,
    ) -> Result<Self> {
        let queue = Arc::new(Queue {
            items: Mutex::new(Vec::new()),
            signal: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(ServerMetrics::default());
        let buckets = engine.manifest().batches_for("encode");
        anyhow::ensure!(!buckets.is_empty(), "no encode artifacts");

        let worker = {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let cfg2 = cfg.clone();
            std::thread::Builder::new()
                .name("deq-batcher".into())
                .spawn(move || {
                    batcher::run(engine, params, queue, metrics, cfg2, buckets)
                })?
        };

        Ok(Self {
            queue,
            metrics,
            next_id: AtomicU64::new(1),
            worker: Some(worker),
            cfg,
        })
    }

    /// Submit one image; returns a receiver for the response.
    /// Errors when the queue is at capacity (backpressure).
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.queue.items.lock().unwrap();
            anyhow::ensure!(
                q.len() < self.cfg.queue_cap,
                "queue full ({} requests)",
                q.len()
            );
            q.push(Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                image,
                enqueued: Instant::now(),
                respond: tx,
            });
        }
        self.queue.signal.notify_one();
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| anyhow::anyhow!("router dropped request"))
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.items.lock().unwrap().len()
    }

    /// Stop the batcher thread (drains nothing; pending requests error out).
    pub fn shutdown(mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.signal.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.signal.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// The inference work a batch performs — shared by the batcher thread.
pub(crate) fn run_batch(
    engine: &dyn Backend,
    params: &ParamSet,
    solver: &SolveOptions,
    mut batch: Vec<Request>,
    bucket: usize,
    metrics: &ServerMetrics,
) {
    let dim = engine.manifest().model.image_dim();
    let count = batch.len();
    let mut images = Vec::with_capacity(count * dim);
    for r in &batch {
        images.extend_from_slice(&r.image);
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    match infer::infer(engine, params, &images, count, solver) {
        Ok(result) => {
            for (i, req) in batch.drain(..).enumerate() {
                let latency = req.enqueued.elapsed();
                metrics.record(latency, count, bucket);
                let _ = req.respond.send(Response {
                    id: req.id,
                    class: result.predictions[i],
                    logits: result.logits[i].clone(),
                    solver_iters: result.solver_iters,
                    latency,
                    batch_size: count,
                });
            }
        }
        Err(e) => {
            eprintln!("[server] batch failed: {e:#}");
            // Drop senders → clients see RecvError.
        }
    }
}

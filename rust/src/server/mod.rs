//! Inference serving stack: an iteration-level continuous-batching router
//! in the vLLM mold, applied to DEQ equilibrium solves — with
//! **per-request solver control** end to end.
//!
//! Architecture (std-only; the offline crate set has no tokio — threads +
//! condvar stand in for the async runtime, see DESIGN.md §Substitutions):
//!
//!   clients ⇄ TCP (multiplexed NDJSON: per-connection reader + writer
//!           │      threads, replies matched by client id, optional
//!           │      per-iteration progress frames)
//!           → [`Router::try_submit`] (validate → clamp → backpressure:
//!           │      beyond `queue_cap` the request is *shed* with an
//!           │      explicit `overloaded` + `retry_after_ms` reply)
//!           → shared bounded queue ─┬→ replica 0 (solve-loop lanes)
//!                                   ├→ replica 1   … work-stealing
//!                                   └→ replica N−1   admission at
//!           → per-lane equilibrium solve      iteration boundaries
//!           → progress frames (streaming) + per-request responses
//!
//! Every [`Request`] carries its own **effective [`SolveSpec`]**: the
//! router's default spec, with the client's [`SolveOverrides`] (solver
//! kind, tol, max_iter, plus the adaptivity knobs `adaptive_window` /
//! `errorfactor` / `cond_max` / `safeguard`) applied under the
//! operator's [`SolveClamps`] (min tol, max iteration cap) — resolved
//! and validated at submission, so a malformed override errors at the
//! door and a greedy one cannot pin a lane.  The adaptivity knobs are
//! validated but unclamped: adaptation only ever *shrinks* a lane's
//! effective window, so heterogeneous buckets can mix adaptive and
//! fixed-window lanes freely.  The [`Response`] echoes the spec the
//! solve actually ran.
//!
//! Two scheduling modes ([`SchedMode`]):
//!
//!  * **Iteration-level** (default, [`scheduler`]): a persistent solve
//!    loop over `max_bucket` lanes.  Lanes are fully **heterogeneous**:
//!    each owns its request's spec and a [`crate::solver::SolvePolicy`]
//!    instance built from it, so one batch can mix tolerances, iteration
//!    caps and even solver kinds — a lane is *retired the iteration its
//!    sample converges at its own tol* (the response carries that
//!    sample's own `solver_iters`), and queued requests are admitted
//!    into freed lanes at iteration boundaries by re-encoding into the
//!    lane's slice.  A stiff sample therefore never delays an easy one,
//!    and nobody pays for the slowest sample in the batch.
//!  * **Batch-granular** ([`batcher`]): the classic fire-and-wait policy
//!    (wait for a full bucket or `max_wait`, solve, respond all at once).
//!    Kept as the measured baseline for the serving experiment and
//!    bench.  Requests with distinct effective specs are solved as
//!    separate sub-batches (a lockstep solve has one tol for everyone).
//!
//! The router runs `cfg.replicas` identical workers (scheduler or
//! batcher) over one shared `Arc<dyn Backend>` + parameter set and one
//! shared queue — see `replica.rs` for the work-stealing admission
//! split.  `--replicas 1` (the default) is bit-for-bit the single
//! worker of old.
//!
//! Replies are `Result`-shaped: on shutdown the queue is drained with an
//! explicit "server shutting down" error instead of silently dropping
//! senders, and solve failures report the error text to every waiter.
//! A TCP front-end (`serve_tcp`) speaks the multiplexed NDJSON protocol
//! documented in [`protocol`] for the `deq-anderson serve` subcommand
//! and the serving example; it parses the per-request override fields
//! and echoes the effective spec.

pub mod batcher;
pub mod protocol;
pub(crate) mod replica;
pub mod scheduler;
pub(crate) mod supervise;
pub mod tcp;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::infer;
use crate::metrics::Stats;
use crate::model::ParamSet;
use crate::runtime::Backend;
use crate::solver::{
    ProfileStore, SolveClamps, SolveOverrides, SolveSpec, SolverKind,
    WorkloadProfile,
};
use crate::util::json::{self, Json};

/// Per-iteration streaming callback: `(iteration, relative residual)`,
/// invoked by the iteration-level scheduler from its solve loop for
/// every iteration the request's lane runs — including the retiring
/// one, *before* the final reply is sent, so a streaming client always
/// sees progress frames ahead of the answer.  Implementations MUST NOT
/// block (the TCP front-end drops frames on a full writer queue rather
/// than stalling every other lane).  The batch-granular baseline
/// ignores progress hooks — it has no per-iteration boundary to report.
pub type ProgressHook = Box<dyn Fn(usize, f32) + Send>;

/// One inference request: a flat NHWC image plus the effective solve
/// spec it should run under (router default + client overrides, already
/// clamped and validated at submission).
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub spec: SolveSpec,
    pub enqueued: Instant,
    /// Absolute wallclock deadline (per-request `deadline_ms`, or the
    /// router's `default_deadline`).  Checked at admission — an expired
    /// request is shed before costing an encode — and at iteration
    /// boundaries, where the lane is retired with `deadline_exceeded`.
    pub deadline: Option<Instant>,
    /// Redrives remaining: how many more times this request may be
    /// pushed back onto the queue after its replica dies mid-flight.
    /// At 0 a crash becomes a terminal retryable-internal reply.
    pub redrives_left: u32,
    pub respond: Sender<Reply>,
    /// Streaming progress subscription, if any (see [`ProgressHook`]).
    pub progress: Option<ProgressHook>,
}

impl Request {
    /// Whether this request's deadline has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// What a waiter receives: the answer, or a structured failure (backend
/// error, deadline, crashed replica, numerical fault, shutdown drain)
/// instead of a silently dropped channel.
pub type Reply = Result<Response, ServeFailure>;

/// Failure taxonomy of one request — what the wire layer turns into the
/// distinct `{"error":…}` reply shapes (see [`protocol::failure_frame`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// Plain request/backend error (bad image, encode failure, solve
    /// failure, shutdown drain).  Displays as the bare detail text —
    /// the legacy reply format, byte-compatible with pre-taxonomy
    /// clients and goldens.
    Error,
    /// The request's deadline passed (in queue or mid-solve).
    DeadlineExceeded,
    /// The serving replica died and the redrive budget is exhausted;
    /// the request itself may be fine — safe to retry.
    Internal,
    /// The lane hit a non-finite residual and was quarantined.
    Numerical,
}

/// A structured failure reply: kind + human detail + the partial
/// per-request solve stats at the moment of failure (0/0 when the
/// request never reached a lane).
#[derive(Debug, Clone)]
pub struct ServeFailure {
    pub kind: FailureKind,
    pub detail: String,
    /// Iterations this request's lane ran before failing.
    pub iters: usize,
    /// Cell evaluations charged before failing.
    pub fevals: usize,
}

impl ServeFailure {
    /// Plain error (legacy shape — Display is the bare detail).
    pub fn error(detail: impl Into<String>) -> Self {
        Self { kind: FailureKind::Error, detail: detail.into(), iters: 0, fevals: 0 }
    }

    /// Deadline exceeded, with the partial stats accrued so far.
    pub fn deadline(iters: usize, fevals: usize) -> Self {
        Self {
            kind: FailureKind::DeadlineExceeded,
            detail: "deadline exceeded".to_string(),
            iters,
            fevals,
        }
    }

    /// Replica crash with the redrive budget exhausted (retryable).
    pub fn internal(detail: impl Into<String>) -> Self {
        Self { kind: FailureKind::Internal, detail: detail.into(), iters: 0, fevals: 0 }
    }

    /// Non-finite quarantine, with the partial stats accrued so far.
    pub fn numerical(detail: impl Into<String>, iters: usize, fevals: usize) -> Self {
        Self { kind: FailureKind::Numerical, detail: detail.into(), iters, fevals }
    }

    /// Whether a client may safely resubmit the identical request.
    pub fn retryable(&self) -> bool {
        self.kind == FailureKind::Internal
    }
}

impl std::fmt::Display for ServeFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            // Bare detail: the pre-taxonomy reply text, pinned by the
            // TCP golden tests.
            FailureKind::Error => f.write_str(&self.detail),
            FailureKind::DeadlineExceeded => write!(
                f,
                "deadline_exceeded after {} iterations",
                self.iters
            ),
            FailureKind::Internal => {
                write!(f, "internal: {} (retryable)", self.detail)
            }
            FailureKind::Numerical => {
                write!(f, "numerical fault: {}", self.detail)
            }
        }
    }
}

impl std::error::Error for ServeFailure {}

/// Lock a mutex, recovering the guard if a previous holder panicked.
/// Every server-side mutex (queue, metrics reservoirs, gauges) guards
/// plain data that stays structurally valid across a panic at any
/// await-free point, so poisoning must not cascade the panic into
/// waiters and siblings — the supervisor handles the crashed thread.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub class: usize,
    pub logits: Vec<f32>,
    /// Iteration-level mode: this sample's own solve iterations.
    /// Batch-granular mode: the batch's iteration count — what the
    /// request actually waited for (every rider pays the slowest lane).
    pub solver_iters: usize,
    /// Cell evaluations on the same accounting as `solver_iters`.
    pub solver_fevals: usize,
    /// False when the lane was retired at `max_iter` without crossing
    /// `tol` — the logits come from a non-converged iterate.
    pub converged: bool,
    /// Total time in the system (queue + solve).
    pub latency: Duration,
    /// Lanes occupied at retirement (iteration-level) or the batch size
    /// this request rode in (batch-granular).
    pub batch_size: usize,
    /// The effective solve spec this request actually ran under (router
    /// default + clamped client overrides) — echoed so clients can see
    /// what their overrides resolved to.
    pub spec: SolveSpec,
}

/// How the worker schedules queued requests onto the solve loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedMode {
    /// Slot-based continuous batching: admit/retire at iteration
    /// boundaries (the default).
    #[default]
    IterationLevel,
    /// Fire-and-wait dynamic batching: the measured baseline.
    BatchGranular,
}

impl SchedMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "iteration" | "iteration-level" => Some(Self::IterationLevel),
            "batch" | "batch-granular" => Some(Self::BatchGranular),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::IterationLevel => "iteration-level",
            Self::BatchGranular => "batch-granular",
        }
    }
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Default solve spec for requests without overrides (validated at
    /// [`Router::start`]).
    pub solver: SolveSpec,
    /// Server-side bounds on per-request overrides (min tol, max
    /// iteration cap) so a client cannot pin a lane.
    pub clamps: SolveClamps,
    /// Scheduling mode (see [`SchedMode`]).
    pub mode: SchedMode,
    /// Batch-granular only: max time the oldest request may wait before a
    /// partial batch fires.  The iteration-level scheduler admits at
    /// every iteration boundary and never waits.
    pub max_wait: Duration,
    /// Upper bound on queued requests.  Beyond it requests are *shed*:
    /// [`Router::try_submit`] returns [`SubmitRejection::Overloaded`]
    /// with a `retry_after_ms` hint instead of queueing unboundedly.
    pub queue_cap: usize,
    /// Engine replicas: independent scheduler/batcher workers draining
    /// the shared queue (work-stealing at iteration boundaries).  The
    /// default 1 preserves the single-worker router bit-for-bit.
    pub replicas: usize,
    /// Deadline applied to requests that don't carry their own
    /// `deadline_ms`.  `None` (the default) means requests without an
    /// explicit deadline never expire — the pre-deadline behaviour.
    pub default_deadline: Option<Duration>,
    /// How many times an in-flight request may be pushed back onto the
    /// queue after its replica crashes before the supervisor gives up
    /// and replies `internal` (retryable).  Default 1.
    pub redrive_budget: u32,
}

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub served: AtomicU64,
    /// Batch-granular: batches fired.  Iteration-level: solve-loop
    /// iterations executed.
    pub batches: AtomicU64,
    pub latency: Mutex<Stats>,
    pub batch_fill: Mutex<Stats>,
    /// Iteration-level gauge: occupied-lane fraction, sampled once per
    /// solve-loop iteration.
    pub lane_occupancy: Mutex<Stats>,
    /// Iteration-level gauge: wallclock from lane admission to
    /// retirement, per request (solve time excluding queue wait).
    pub time_to_retire: Mutex<Stats>,
    /// Cell evaluations actually charged to samples (Σ occupied lanes
    /// over iterations).
    pub lane_fevals: AtomicU64,
    /// What a lockstep batch-granular solve of the *same occupied set*
    /// would have charged per iteration (its padded bucket, not the full
    /// lane width — so idle lanes never count as savings); see
    /// [`Self::fevals_saved`].
    pub lockstep_fevals: AtomicU64,
    /// Requests shed with an explicit `overloaded` reply (shared queue
    /// at capacity, or a connection over its in-flight cap).
    pub shed: AtomicU64,
    /// Replica workers respawned by the supervisor after a crash.
    pub replica_restarts: AtomicU64,
    /// In-flight requests re-queued (redriven) after their replica
    /// crashed mid-solve.
    pub redrives: AtomicU64,
    /// Requests retired with a `deadline_exceeded` reply — expired in
    /// queue (shed before encode) or at an iteration boundary.
    pub deadline_exceeded: AtomicU64,
    /// Lanes quarantined after a non-finite residual (the request got a
    /// `numerical_fault` reply; its bucket-mates were unaffected).
    pub quarantined: AtomicU64,
    /// Queue depth observed at each successful submission (after the
    /// push), so `queue_depth_p50`/`max` describe the backlog admitted
    /// requests actually waited behind.
    pub queue_depth: Mutex<Stats>,
    /// Forward↔Anderson switches taken by auto-selection lanes (the
    /// [`crate::solver::AutoPolicy`] controller), summed at retirement.
    pub auto_switches: AtomicU64,
    /// Lane-retirement histogram by effective solver kind, indexed in
    /// [`SolverKind::ALL`] order (forward, anderson, hybrid, auto).
    pub retired_by_kind: [AtomicU64; 4],
    /// Per-replica gauges, one slot per worker.  Empty under
    /// `Default`; sized by [`ServerMetrics::new`] (the router always
    /// uses `new`).
    pub replicas: Vec<ReplicaGauges>,
}

/// Observability for one engine replica.
#[derive(Debug, Default)]
pub struct ReplicaGauges {
    /// Requests this replica retired (answered).
    pub served: AtomicU64,
    /// Solve-loop iterations executed (scheduler) / batches fired
    /// (batcher) by this replica.
    pub iterations: AtomicU64,
    /// Occupied-lane fraction per iteration (scheduler) or batch fill
    /// (batcher) of this replica.
    pub occupancy: Mutex<Stats>,
}

impl ServerMetrics {
    /// Metrics sized for `replicas` workers.
    pub fn new(replicas: usize) -> Self {
        Self {
            replicas: (0..replicas).map(|_| ReplicaGauges::default()).collect(),
            ..Self::default()
        }
    }

    /// One scheduling step by `replica`: `occupied` of `lanes` lanes
    /// busy (scheduler iteration) or a `occupied`-of-`lanes` batch
    /// fired (batcher).
    pub fn replica_iteration(&self, replica: usize, occupied: usize, lanes: usize) {
        if let Some(g) = self.replicas.get(replica) {
            g.iterations.fetch_add(1, Ordering::Relaxed);
            lock_unpoisoned(&g.occupancy)
                .push(occupied as f64 / lanes.max(1) as f64);
        }
    }

    /// One request answered by `replica`.
    pub fn replica_served(&self, replica: usize) {
        if let Some(g) = self.replicas.get(replica) {
            g.served.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record(&self, latency: Duration, batch: usize, bucket: usize) {
        self.served.fetch_add(1, Ordering::Relaxed);
        lock_unpoisoned(&self.latency).push_duration(latency);
        lock_unpoisoned(&self.batch_fill).push(batch as f64 / bucket as f64);
    }

    /// One solve-loop iteration over `occupied` of `lanes` total lanes;
    /// `lockstep_bucket` is the compiled bucket a batch-granular solve of
    /// just the occupied samples would have ridden (its padding is the
    /// honest per-iteration baseline cost — a conservative estimate, as
    /// it excludes the baseline's early-retirement losses).
    pub fn record_iteration(
        &self,
        occupied: usize,
        lanes: usize,
        lockstep_bucket: usize,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        lock_unpoisoned(&self.lane_occupancy)
            .push(occupied as f64 / lanes.max(1) as f64);
        self.lane_fevals.fetch_add(occupied as u64, Ordering::Relaxed);
        self.lockstep_fevals
            .fetch_add(lockstep_bucket as u64, Ordering::Relaxed);
    }

    /// One lane retired after `solve` wallclock in its lane.
    pub fn record_retire(&self, solve: Duration) {
        lock_unpoisoned(&self.time_to_retire).push_duration(solve);
    }

    /// One request retired under effective solver `kind` — feeds the
    /// per-kind retirement histogram in [`Self::stat_pairs`].
    pub fn record_kind_retired(&self, kind: SolverKind) {
        let idx = SolverKind::ALL
            .iter()
            .position(|k| *k == kind)
            .expect("SolverKind::ALL covers every kind");
        self.retired_by_kind[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Cell evaluations saved vs a lockstep batch-granular solve of the
    /// same occupied samples (early-retired lanes stop paying; idle
    /// lanes never counted on either side).
    pub fn fevals_saved(&self) -> u64 {
        self.lockstep_fevals
            .load(Ordering::Relaxed)
            .saturating_sub(self.lane_fevals.load(Ordering::Relaxed))
    }

    pub fn summary(&self) -> String {
        let lat = lock_unpoisoned(&self.latency);
        let fill = lock_unpoisoned(&self.batch_fill);
        let occ = lock_unpoisoned(&self.lane_occupancy);
        let retire = lock_unpoisoned(&self.time_to_retire);
        let mut s = format!(
            "served={} batches={} p50={:.1}ms p95={:.1}ms p99={:.1}ms mean_fill={:.2}",
            self.served.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            lat.percentile(50.0) * 1e3,
            lat.percentile(95.0) * 1e3,
            lat.percentile(99.0) * 1e3,
            fill.mean(),
        );
        if occ.count() > 0 {
            s.push_str(&format!(
                " occupancy={:.2} retire_p50={:.1}ms retire_p95={:.1}ms fevals_saved={}",
                occ.mean(),
                retire.percentile(50.0) * 1e3,
                retire.percentile(95.0) * 1e3,
                self.fevals_saved(),
            ));
        }
        s
    }

    /// Structured stats for the TCP `stats` command: counters and
    /// percentiles as individual JSON fields plus a `replicas` array of
    /// per-worker gauges.  The legacy one-line blob rides along under
    /// `"summary"` for humans and old scrapers.  Percentiles of empty
    /// reservoirs report 0 (NaN is not representable in JSON).
    pub fn stat_pairs(&self) -> Vec<(&'static str, Json)> {
        fn pct_ms(stats: &Stats, p: f64) -> Json {
            let v = if stats.count() == 0 { 0.0 } else { stats.percentile(p) };
            json::num(v * 1e3)
        }
        // `summary()` takes the same locks — build it before holding any.
        let summary = self.summary();
        let lat = lock_unpoisoned(&self.latency);
        let fill = lock_unpoisoned(&self.batch_fill);
        let occ = lock_unpoisoned(&self.lane_occupancy);
        let retire = lock_unpoisoned(&self.time_to_retire);
        let depth = lock_unpoisoned(&self.queue_depth);
        let mut pairs = vec![
            ("served", json::num(self.served.load(Ordering::Relaxed) as f64)),
            ("batches", json::num(self.batches.load(Ordering::Relaxed) as f64)),
            ("shed", json::num(self.shed.load(Ordering::Relaxed) as f64)),
            (
                "replica_restarts",
                json::num(self.replica_restarts.load(Ordering::Relaxed) as f64),
            ),
            ("redrives", json::num(self.redrives.load(Ordering::Relaxed) as f64)),
            (
                "deadline_exceeded",
                json::num(self.deadline_exceeded.load(Ordering::Relaxed) as f64),
            ),
            (
                "quarantined",
                json::num(self.quarantined.load(Ordering::Relaxed) as f64),
            ),
            ("latency_p50_ms", pct_ms(&lat, 50.0)),
            ("latency_p95_ms", pct_ms(&lat, 95.0)),
            ("latency_p99_ms", pct_ms(&lat, 99.0)),
            ("mean_fill", json::num(fill.mean())),
            ("occupancy", json::num(occ.mean())),
            ("retire_p50_ms", pct_ms(&retire, 50.0)),
            ("retire_p95_ms", pct_ms(&retire, 95.0)),
            ("fevals_saved", json::num(self.fevals_saved() as f64)),
            ("queue_depth_p50", {
                let v = if depth.count() == 0 { 0.0 } else { depth.percentile(50.0) };
                json::num(v)
            }),
            ("queue_depth_max", {
                let v = if depth.count() == 0 { 0.0 } else { depth.max() };
                json::num(v)
            }),
            (
                "auto_switches",
                json::num(self.auto_switches.load(Ordering::Relaxed) as f64),
            ),
            ("retired_by_kind", {
                let kinds: Vec<(&'static str, Json)> = SolverKind::ALL
                    .iter()
                    .zip(&self.retired_by_kind)
                    .map(|(k, n)| {
                        (k.name(), json::num(n.load(Ordering::Relaxed) as f64))
                    })
                    .collect();
                json::obj(kinds)
            }),
            ("summary", json::s(&summary)),
        ];
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, g)| {
                let g_occ = lock_unpoisoned(&g.occupancy);
                json::obj(vec![
                    ("replica", json::num(i as f64)),
                    ("served", json::num(g.served.load(Ordering::Relaxed) as f64)),
                    (
                        "iterations",
                        json::num(g.iterations.load(Ordering::Relaxed) as f64),
                    ),
                    ("occupancy", json::num(g_occ.mean())),
                ])
            })
            .collect();
        pairs.push(("replicas", Json::Arr(replicas)));
        pairs
    }
}

pub(crate) struct Queue {
    pub(crate) items: Mutex<Vec<Request>>,
    pub(crate) signal: Condvar,
    pub(crate) shutdown: AtomicBool,
}

/// Reply to and drop every queued request with an error message — the
/// shutdown path, so waiters see "server shutting down" instead of a
/// closed channel.
pub(crate) fn drain_with_error(items: &mut Vec<Request>, why: &str) {
    for req in items.drain(..) {
        let _ = req.respond.send(Err(ServeFailure::error(why)));
    }
}

/// Retry hint before any retire/latency sample exists: a cold router
/// always answers `retry_after_ms == COLD_RETRY_PRIOR_MS` on its first
/// shed (pinned by a golden test — clients key backoff off it).
pub const COLD_RETRY_PRIOR_MS: u64 = 25;

/// Why [`Router::try_submit`] refused a request.
#[derive(Debug)]
pub enum SubmitRejection {
    /// The shared queue is at capacity: the request was shed.  The hint
    /// estimates when capacity frees up, from the live retire-time p50
    /// and the number of admission waves the backlog represents.
    Overloaded { retry_after_ms: u64 },
    /// Malformed request (wrong image size, invalid override values).
    Invalid(String),
    /// The router is shutting down (or its workers died).
    ShuttingDown,
}

impl std::fmt::Display for SubmitRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Overloaded { retry_after_ms } => {
                write!(f, "overloaded; retry in {retry_after_ms}ms")
            }
            Self::Invalid(msg) => f.write_str(msg),
            Self::ShuttingDown => {
                f.write_str("router worker is not running (shut down or failed)")
            }
        }
    }
}

impl std::error::Error for SubmitRejection {}

/// The continuous-batching inference router.
pub struct Router {
    queue: Arc<Queue>,
    pub metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
    /// The supervisor thread owns the replica worker handles: it joins
    /// crashed replicas, redrives their in-flight requests, and
    /// respawns them (see `supervise.rs`).  Joined on shutdown/drop.
    supervisor: Option<std::thread::JoinHandle<()>>,
    cfg: RouterConfig,
    /// Flat image length the model expects; checked at submission so one
    /// malformed request can never fail a whole batch downstream.
    image_dim: usize,
    /// Σ lanes across replicas (largest bucket × replicas): the service
    /// capacity one admission wave represents, for retry-hint math.
    total_lanes: usize,
    /// The serving backend, kept so stats endpoints can surface its
    /// hot-path counters (workspace pool, packed-weight cache).
    backend: Arc<dyn Backend>,
    /// Per-bucket workload profiles learned by the schedulers (decay
    /// rates, mixing penalties, retirement mix) — seeds auto-selection
    /// priors and feeds the TCP `stats` surface.
    profiles: Arc<ProfileStore>,
}

impl Router {
    /// Spawn `cfg.replicas` worker threads (schedulers or batchers, per
    /// `cfg.mode`) over a shared engine + parameters.
    pub fn start(
        engine: Arc<dyn Backend>,
        params: Arc<ParamSet>,
        mut cfg: RouterConfig,
    ) -> Result<Self> {
        // Reject degenerate default specs and clamps here, not N
        // requests later.
        cfg.solver.validate()?;
        cfg.clamps.validate()?;
        anyhow::ensure!(cfg.replicas >= 1, "router needs at least one replica");
        // Clamps can never make an override *stricter than the default*:
        // a client restating the server's own tol/max_iter must get
        // exactly the default spec back, so the clamps widen to admit it.
        cfg.clamps.min_tol = cfg.clamps.min_tol.min(cfg.solver.tol);
        cfg.clamps.max_iter = cfg.clamps.max_iter.max(cfg.solver.max_iter);
        let queue = Arc::new(Queue {
            items: Mutex::new(Vec::new()),
            signal: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(ServerMetrics::new(cfg.replicas));
        let buckets = engine.manifest().batches_for("encode");
        anyhow::ensure!(!buckets.is_empty(), "no encode artifacts");
        let max_bucket = *buckets.last().unwrap();
        let image_dim = engine.manifest().model.image_dim();
        let backend = engine.clone();
        let slots = Arc::new(replica::ReplicaSlots::new(cfg.replicas, max_bucket));
        let profiles = Arc::new(ProfileStore::new());

        let ctx = Arc::new(supervise::ReplicaCtx {
            engine,
            params,
            queue: queue.clone(),
            metrics: metrics.clone(),
            cfg: cfg.clone(),
            buckets,
            slots,
            profiles: profiles.clone(),
        });
        // The supervisor keeps a sender clone alive, so `recv` on this
        // channel can never see Disconnected while it runs.
        let (exit_tx, exit_rx) = mpsc::channel();
        let mut handles = Vec::with_capacity(cfg.replicas);
        for r in 0..cfg.replicas {
            handles.push(Some(replica::spawn(r, ctx.clone(), exit_tx.clone())?));
        }
        let supervisor = std::thread::Builder::new()
            .name("deq-supervisor".into())
            .spawn(move || supervise::supervise(ctx, handles, exit_rx, exit_tx))?;

        let total_lanes = max_bucket * cfg.replicas;
        Ok(Self {
            queue,
            metrics,
            next_id: AtomicU64::new(1),
            supervisor: Some(supervisor),
            cfg,
            image_dim,
            total_lanes,
            backend,
            profiles,
        })
    }

    /// Snapshot of the per-bucket workload profiles the schedulers have
    /// learned so far (empty until auto/learning traffic retires lanes)
    /// — surfaced by the TCP `stats` command.
    pub fn profile_snapshot(&self) -> Vec<(usize, WorkloadProfile)> {
        self.profiles.snapshot()
    }

    /// Hot-path counters of the serving backend (workspace pool +
    /// packed-weight cache), when it has them — surfaced by the TCP
    /// `stats` command so cache behaviour is observable in production.
    pub fn backend_hot_stats(&self) -> Option<crate::native::WorkspaceStats> {
        self.backend.hot_stats()
    }

    /// Faults injected so far by the backend's fault-injection wrapper
    /// (0 when `DEQ_FAULTS` is unset and the backend is bare) — surfaced
    /// by the TCP `stats` command so chaos runs can assert their plan
    /// actually fired.
    pub fn backend_faults_injected(&self) -> u64 {
        self.backend.faults_injected()
    }

    /// Submit one image under the router's default solve spec; returns a
    /// receiver for the reply.  See [`Self::submit_with`].
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Reply>> {
        self.submit_with(image, &SolveOverrides::default())
    }

    /// Submit one image with per-request solver overrides.  The
    /// overrides resolve against the router's default spec under its
    /// [`SolveClamps`] **here**, so a malformed override (tol ≤ 0,
    /// max_iter 0) errors at submission instead of poisoning a batch.
    /// Also errors on a wrong-sized image, when the queue is at capacity
    /// (shed — see [`Self::try_submit`] for the structured rejection),
    /// or when the workers are gone (shut down, or the scheduler hit a
    /// fatal backend error) — a request enqueued after that would never
    /// be answered.
    pub fn submit_with(
        &self,
        image: Vec<f32>,
        overrides: &SolveOverrides,
    ) -> Result<Receiver<Reply>> {
        self.try_submit(image, overrides, None, None)
            .map_err(|r| anyhow::anyhow!(r.to_string()))
    }

    /// Structured admission: validate, clamp, and enqueue — or say
    /// precisely why not.  The wire front-end uses this to turn
    /// [`SubmitRejection::Overloaded`] into an explicit
    /// `{"error":"overloaded","retry_after_ms":…}` shed reply, to
    /// attach a per-iteration [`ProgressHook`] for streaming requests,
    /// and to carry the client's `deadline_ms` (falling back to the
    /// router's `default_deadline` when `None`).
    pub fn try_submit(
        &self,
        image: Vec<f32>,
        overrides: &SolveOverrides,
        progress: Option<ProgressHook>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Reply>, SubmitRejection> {
        if image.len() != self.image_dim {
            return Err(SubmitRejection::Invalid(format!(
                "image has {} values, model wants {}",
                image.len(),
                self.image_dim
            )));
        }
        let spec = overrides
            .apply(&self.cfg.solver, &self.cfg.clamps)
            .map_err(|e| SubmitRejection::Invalid(format!("{e:#}")))?;
        let (tx, rx) = mpsc::channel();
        // One clock read serves both the queue timestamp and the
        // absolute deadline, so `deadline_ms=N` means N ms from the
        // moment of admission, exactly.
        let now = Instant::now();
        let deadline = deadline
            .or(self.cfg.default_deadline)
            .map(|d| now + d);
        {
            let mut q = lock_unpoisoned(&self.queue.items);
            if self.queue.shutdown.load(Ordering::SeqCst) {
                return Err(SubmitRejection::ShuttingDown);
            }
            if q.len() >= self.cfg.queue_cap {
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                let retry_after_ms = self.retry_estimate_ms(q.len());
                return Err(SubmitRejection::Overloaded { retry_after_ms });
            }
            q.push(Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                image,
                spec,
                enqueued: now,
                deadline,
                redrives_left: self.cfg.redrive_budget,
                respond: tx,
                progress,
            });
            lock_unpoisoned(&self.metrics.queue_depth).push(q.len() as f64);
        }
        self.queue.signal.notify_one();
        Ok(rx)
    }

    /// Estimated milliseconds until queue capacity frees, for shed
    /// replies: the observed retire-time p50 (falling back to the
    /// latency p50, then the [`COLD_RETRY_PRIOR_MS`] prior before any
    /// sample exists) times the number of admission waves the current
    /// backlog represents.
    fn retry_estimate_ms(&self, queued: usize) -> u64 {
        let retire_p50 = {
            let retire = lock_unpoisoned(&self.metrics.time_to_retire);
            (retire.count() > 0).then(|| retire.percentile(50.0))
        };
        let latency_p50 = {
            let lat = lock_unpoisoned(&self.metrics.latency);
            (lat.count() > 0).then(|| lat.percentile(50.0))
        };
        let p50 = retire_p50
            .or(latency_p50)
            .unwrap_or(COLD_RETRY_PRIOR_MS as f64 / 1e3);
        let waves = (queued as f64 / self.total_lanes.max(1) as f64).ceil().max(1.0);
        ((p50 * waves * 1e3).ceil() as u64).clamp(1, 60_000)
    }

    /// Current shed hint for callers that refuse work *before* the
    /// queue (e.g. the per-connection in-flight cap in the TCP layer).
    pub fn retry_after_hint(&self) -> u64 {
        self.retry_estimate_ms(self.queue_depth())
    }

    /// Blocking convenience: submit and wait.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Response> {
        self.infer_blocking_with(image, &SolveOverrides::default())
    }

    /// Blocking convenience with per-request solver overrides.
    pub fn infer_blocking_with(
        &self,
        image: Vec<f32>,
        overrides: &SolveOverrides,
    ) -> Result<Response> {
        let rx = self.submit_with(image, overrides)?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(fail)) => Err(anyhow::anyhow!(fail)),
            Err(_) => Err(anyhow::anyhow!("router dropped request")),
        }
    }

    pub fn queue_depth(&self) -> usize {
        lock_unpoisoned(&self.queue.items).len()
    }

    /// Stop every replica worker.  Queued (and, in iteration-level
    /// mode, in-flight) requests receive an explicit "server shutting
    /// down" error reply rather than a dropped channel; the call
    /// returns only after the supervisor has joined all replicas and
    /// exited.
    pub fn shutdown(mut self) {
        signal_shutdown(&self.queue);
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }
}

/// Raise the shutdown flag *while holding the queue lock*, so the worker
/// either sees the flag on its next check or is parked on the condvar
/// when the notify lands — a store outside the lock can slip between the
/// worker's check and its wait, losing the wakeup for a full timeout.
fn signal_shutdown(queue: &Queue) {
    {
        let _guard = lock_unpoisoned(&queue.items);
        queue.shutdown.store(true, Ordering::SeqCst);
    }
    queue.signal.notify_all();
}

impl Drop for Router {
    fn drop(&mut self) {
        signal_shutdown(&self.queue);
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }
}

/// The inference work a batch performs — the batch-granular path.  All
/// requests in `batch` share one effective spec (`solver` — the batcher
/// groups by spec before calling); every rider is billed the batch's
/// iteration count (`solver_iters` of the whole solve): that is what it
/// had to wait for, and exactly the cost model the iteration-level
/// scheduler exists to beat.
pub(crate) fn run_batch(
    engine: &dyn Backend,
    params: &ParamSet,
    solver: &SolveSpec,
    batch: &mut Vec<Request>,
    bucket: usize,
    metrics: &ServerMetrics,
    replica: usize,
) {
    let dim = engine.manifest().model.image_dim();
    let count = batch.len();
    let mut images = Vec::with_capacity(count * dim);
    for r in batch.iter() {
        images.extend_from_slice(&r.image);
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.replica_iteration(replica, count, bucket);
    match infer::infer(engine, params, &images, count, solver) {
        Ok(result) => {
            // `batch` is taken by reference and drained only after the
            // solve succeeds: if the backend panics mid-infer, the
            // supervisor recovers every un-answered rider for redrive.
            for (i, req) in batch.drain(..).enumerate() {
                let latency = req.enqueued.elapsed();
                if result.sample_faulted.get(i).copied().unwrap_or(false) {
                    // This rider's lane went non-finite; its logits are
                    // garbage.  Quarantine it alone — bucket-mates above
                    // already got (or below will get) their real answers.
                    metrics.quarantined.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(ServeFailure::numerical(
                        "non-finite residual during solve",
                        result.sample_iters.get(i).copied().unwrap_or(0),
                        result.sample_fevals.get(i).copied().unwrap_or(0),
                    )));
                    continue;
                }
                metrics.record(latency, count, bucket);
                metrics.replica_served(replica);
                metrics.record_kind_retired(req.spec.kind);
                let _ = req.respond.send(Ok(Response {
                    id: req.id,
                    class: result.predictions[i],
                    logits: result.logits[i].clone(),
                    solver_iters: result.solver_iters,
                    solver_fevals: result.solver_fevals,
                    converged: result.sample_converged[i],
                    latency,
                    batch_size: count,
                    spec: req.spec,
                }));
            }
        }
        Err(e) => {
            let msg = format!("batch inference failed: {e:#}");
            eprintln!("[server] {msg}");
            for req in batch.drain(..) {
                let _ = req.respond.send(Err(ServeFailure::error(msg.clone())));
            }
        }
    }
}

//! Iteration-level continuous batching: a persistent equilibrium solve
//! loop over `max_bucket` lanes, with **heterogeneous per-lane solver
//! control**.
//!
//! The batch-granular batcher admits a batch, solves it to the *slowest*
//! sample's convergence, and only then responds and takes new work.  This
//! scheduler instead treats the compiled bucket as a set of **lanes**:
//!
//!  * every solve-loop iteration runs `cell_step` (and, for lanes whose
//!    policy mixes, `anderson_update`) over the whole bucket;
//!  * each lane owns the **effective [`SolveSpec`](crate::solver::SolveSpec)**
//!    its request resolved to (router default + clamped overrides) and a
//!    [`SolvePolicy`] instance built from it — so one batch can mix
//!    tolerances, iteration caps and solver kinds;
//!  * a lane is **retired the iteration its sample's residual crosses
//!    *its own* `tol`** (or its own `max_iter`/feval budget runs out) —
//!    the sample takes f as its terminal iterate, is classified, and the
//!    response (carrying its own `solver_iters`/`solver_fevals` and the
//!    spec it ran under) is sent immediately;
//!  * freed lanes are **refilled at iteration boundaries**: each
//!    boundary's admissions are encoded together in one batched dispatch
//!    and spliced into their lanes' slices of the persistent
//!    `x_feat`/`z` batch tensors.
//!
//! Per-lane Anderson state lives in [`LaneHistory`]: each lane fills its
//! own ring at its own pace, seeded by replication so a fresh lane's first
//! mixed update degenerates to a damped forward step (see its docs).  The
//! per-lane hybrid stagnation fallback — once hand-rolled here — now
//! falls out of per-lane policy state: a stagnating lane's
//! [`AndersonPolicy`](crate::solver::AndersonPolicy) flips itself to
//! forward steps without touching its neighbours, and a lane with
//! `restart_on_breakdown` restarts its own window.
//!
//! One knob stays router-wide: the residual regularizer `lam` (residual
//! norms for the whole bucket come out of one fused `cell_step` call).
//!
//! Cost model note: the kernels still run at the full bucket width, so
//! the win is measured in *per-sample* fevals (what each request waits
//! for) and loop iterations to drain the queue — `ServerMetrics`
//! publishes lane occupancy, time-to-retire percentiles, and fevals saved
//! vs a lockstep batch-granular solve of the same occupied samples.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::infer;
use crate::model::ParamSet;
use crate::runtime::{Backend, HostTensor, ModelMeta};
use crate::server::batcher::pick_bucket;
use crate::server::supervise::{panic_text, ReplicaCtx, RunOutcome};
use crate::server::{
    drain_with_error, lock_unpoisoned, Request, Response, ServeFailure,
};
use crate::solver::anderson::LaneHistory;
use crate::solver::driver::damp_in_place;
use crate::solver::{
    per_sample_rel, policy_for, AutoPolicy, LaneStep, ProfileStore,
    SolvePolicy, SolverKind,
};

/// One occupied slot of the solve loop.
struct Lane {
    req: Request,
    /// This lane's solve policy, built from `req.spec` at admission —
    /// per-lane mixing/fallback/restart state lives in here.
    policy: Box<dyn SolvePolicy + Send>,
    /// Iterations this sample has run (its true `solver_iters`).
    iters: usize,
    /// Cell evaluations charged to this sample.
    fevals: usize,
    /// When the lane was admitted (time-to-retire starts here).
    admitted: Instant,
}

/// The scheduler thread body for one replica.  On a backend failure the
/// error text goes to every waiter — queued *and* in-flight — instead
/// of a dropped channel (the contract [`crate::server::Reply`]
/// documents).  A *panic* in the serve loop (injected fault, backend
/// bug) is caught here: the lanes vector lives outside the unwind
/// boundary, so the in-flight requests survive and travel back to the
/// supervisor for redrive.
pub(crate) fn run(ctx: &ReplicaCtx, replica: usize) -> RunOutcome {
    let bucket = *ctx.buckets.last().expect("router checked buckets non-empty");
    let mut lanes: Vec<Option<Lane>> = (0..bucket).map(|_| None).collect();
    // AssertUnwindSafe: on panic we only *extract requests* from `lanes`
    // (each a channel sender + plain data, valid at any interruption
    // point) and drop the solve-state tensors wholesale.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_loop(ctx, &mut lanes, replica)
    }));
    match result {
        Ok(Ok(())) => RunOutcome::Clean,
        Ok(Err(e)) => {
            // Fatal but orderly backend error: every waiter is told, the
            // router stops admitting.  Nothing left to recover.
            let msg = format!("scheduler failed: {e:#}");
            eprintln!("[server] {msg}");
            retire_all_with_error(&mut lanes, &msg);
            // Raise the shutdown flag under the queue lock before
            // draining: `submit` checks it under the same lock, so no
            // request can slip in after the drain and hang on a reply
            // that will never come.
            {
                let mut items = lock_unpoisoned(&ctx.queue.items);
                ctx.queue.shutdown.store(true, Ordering::SeqCst);
                drain_with_error(&mut items, &msg);
            }
            ctx.queue.signal.notify_all();
            RunOutcome::Clean
        }
        Err(payload) => RunOutcome::Crashed {
            inflight: lanes
                .iter_mut()
                .filter_map(|slot| slot.take())
                .map(|lane| lane.req)
                .collect(),
            panic_msg: panic_text(payload.as_ref()),
        },
    }
}

/// Admit one iteration boundary's worth of requests: validate images,
/// encode them all in a single dispatch at the smallest bucket that
/// fits, and splice each feature row + a zero initial iterate into its
/// lane's slices of the persistent batch tensors.  Each admitted lane
/// gets a fresh policy instance built from its request's effective spec
/// (window clamped to the scheduler's shared history window); `auto`
/// lanes are seeded with the workload prior `profiles` has learned for
/// `prior_bucket`, so the controller's crossover estimate starts from
/// this workload's observed decay rate and mixing penalty instead of
/// cold defaults.  Client-level problems (bad image size, encode
/// failure) are replied inline and leave the lane free; only internal
/// invariant violations propagate as `Err`.
#[allow(clippy::too_many_arguments)] // flat splice over the loop's state
fn admit_all(
    engine: &dyn Backend,
    params: &ParamSet,
    meta: &ModelMeta,
    z: &mut HostTensor,
    x_feat: &mut HostTensor,
    hist: &mut LaneHistory,
    lanes: &mut [Option<Lane>],
    admitted: Vec<(usize, Request)>,
    window: usize,
    profiles: &ProfileStore,
    prior_bucket: usize,
) -> Result<()> {
    if admitted.is_empty() {
        return Ok(());
    }
    let dim = meta.image_dim();
    let mut good: Vec<(usize, Request)> = Vec::with_capacity(admitted.len());
    for (lane_idx, req) in admitted {
        if req.image.len() == dim {
            good.push((lane_idx, req));
        } else {
            let _ = req.respond.send(Err(ServeFailure::error(format!(
                "image has {} values, model wants {dim}",
                req.image.len()
            ))));
        }
    }
    if good.is_empty() {
        return Ok(());
    }
    let mut flat = Vec::with_capacity(good.len() * dim);
    for (_, req) in &good {
        flat.extend_from_slice(&req.image);
    }
    let feat = match infer::encode_padded(engine, params, &flat, good.len()) {
        Ok((t, _bucket)) => t,
        Err(e) => {
            let msg = format!("admission encode failed: {e:#}");
            eprintln!("[server] {msg}");
            for (_, req) in good {
                let _ = req.respond.send(Err(ServeFailure::error(msg.clone())));
            }
            return Ok(());
        }
    };
    let zero = vec![0.0f32; meta.latent_dim()];
    for (row, (lane_idx, mut req)) in good.into_iter().enumerate() {
        x_feat.set_row_f32(lane_idx, feat.row_f32(row)?)?;
        z.set_row_f32(lane_idx, &zero)?;
        hist.clear_lane(lane_idx);
        // The lane rides the scheduler's shared history window; the
        // echoed spec reflects that (an override can't widen a ring that
        // is allocated once for all lanes).
        req.spec.window = window;
        let policy: Box<dyn SolvePolicy + Send> = if req.spec.kind == SolverKind::Auto {
            Box::new(AutoPolicy::with_prior(
                &req.spec,
                profiles.prior(prior_bucket),
            ))
        } else {
            policy_for(&req.spec)
        };
        lanes[lane_idx] = Some(Lane {
            req,
            policy,
            iters: 0,
            fevals: 0,
            admitted: Instant::now(),
        });
    }
    // The padded feature tensor has been spliced into the lanes; hand its
    // buffer back to the backend pool so admissions don't leak it.
    engine.recycle(vec![feat]);
    Ok(())
}

/// Reply with an error to every in-flight lane (shutdown path).
fn retire_all_with_error(lanes: &mut [Option<Lane>], why: &str) {
    for slot in lanes.iter_mut() {
        if let Some(lane) = slot.take() {
            let _ = lane.req.respond.send(Err(ServeFailure::error(why)));
        }
    }
}

// `lanes` lives in run(), outside the unwind boundary, so a panic here
// leaves the in-flight requests recoverable for redrive.
fn serve_loop(
    ctx: &ReplicaCtx,
    lanes: &mut Vec<Option<Lane>>,
    replica: usize,
) -> Result<()> {
    let engine = ctx.engine.as_ref();
    let params = ctx.params.as_ref();
    let queue = ctx.queue.as_ref();
    let metrics = ctx.metrics.as_ref();
    let profiles = ctx.profiles.as_ref();
    let cfg = &ctx.cfg;
    let buckets = &ctx.buckets;
    let slots = ctx.slots.as_ref();
    let meta = engine.manifest().model.clone();
    let bucket = *buckets.last().expect("router checked buckets non-empty");
    let n = meta.latent_dim();
    let nc = meta.num_classes;
    let compiled_m = engine.manifest().solver.window;
    let window = cfg.solver.window.min(compiled_m).max(1);

    let mut hist = LaneHistory::new(bucket, window, compiled_m, n);

    // The canonical iterate and feature tensors live directly in the
    // cell-input slots; admissions splice rows into them in place.  The
    // classify and anderson_update inputs are preallocated and refilled
    // in place, masks are reused across iterations, and spent backend
    // outputs are recycled — so a fully occupied steady-state lane loop
    // performs no per-iteration bucket-sized allocation.
    let mut cell_inputs: Vec<HostTensor> = params.tensors.clone();
    let z_slot = cell_inputs.len();
    cell_inputs.push(HostTensor::zeros(meta.latent_shape(bucket)));
    let x_slot = z_slot + 1;
    cell_inputs.push(HostTensor::zeros(meta.latent_shape(bucket)));
    // Classify inputs are preallocated like cell_inputs: only the latent
    // slot is overwritten per retiring iteration, never the params.
    let mut cls_inputs: Vec<HostTensor> = params.tensors.clone();
    let cls_z_slot = cls_inputs.len();
    cls_inputs.push(HostTensor::zeros(meta.latent_shape(bucket)));
    let mut and_inputs: [HostTensor; 3] = [
        HostTensor::zeros(vec![bucket, compiled_m, n]),
        HostTensor::zeros(vec![bucket, compiled_m, n]),
        HostTensor::zeros(vec![compiled_m]),
    ];
    let mut retire_mask = vec![false; bucket];
    let mut mix_mask = vec![false; bucket];
    let mut fwd_mask = vec![false; bucket];
    // Scratch row for per-lane damped forward blends (β < 1 lanes).
    let mut blend_row = vec![0.0f32; n];
    // Preallocated zero row: quarantined lanes' iterate rows are wiped
    // so a non-finite value never rides into the next bucket-wide
    // dispatch (all kernels are row-wise, but a wiped row is cheap
    // insurance and keeps dumps readable).
    let zero_row = vec![0.0f32; n];

    loop {
        // --- admission at the iteration boundary ---
        let free: Vec<usize> = lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| if l.is_none() { Some(i) } else { None })
            .collect();
        let any_busy = free.len() < bucket;
        // Publish our free-lane count so sibling replicas' fair shares
        // reflect this boundary.
        slots.set_free(replica, free.len());
        let admitted: Vec<(usize, Request)> = {
            let mut items = lock_unpoisoned(&queue.items);
            loop {
                if queue.shutdown.load(Ordering::SeqCst) {
                    drain_with_error(&mut items, "server shutting down");
                    drop(items);
                    retire_all_with_error(lanes, "server shutting down");
                    return Ok(());
                }
                if any_busy || !items.is_empty() {
                    // Take our fair share of the backlog by free
                    // capacity (all of it, up to free lanes, when this
                    // is the only replica).  Whatever is left is picked
                    // up — stolen — by the next replica to hit an
                    // iteration boundary.
                    let take =
                        slots.fair_take(replica, items.len(), free.len());
                    let reqs: Vec<Request> = items.drain(..take).collect();
                    break free.iter().copied().zip(reqs).collect();
                }
                // All lanes idle and nothing queued: sleep until work
                // arrives (periodic wake to recheck shutdown).
                let (guard, _timeout) = queue
                    .signal
                    .wait_timeout(items, Duration::from_millis(50))
                    .unwrap_or_else(|e| e.into_inner());
                items = guard;
            }
        };
        // Shed requests whose deadline expired while they queued,
        // *before* paying their encode.  (Empty on the steady-state
        // fully-occupied path: collecting an empty iterator does not
        // allocate.)
        let now = Instant::now();
        let admitted: Vec<(usize, Request)> = admitted
            .into_iter()
            .filter_map(|(lane_idx, req)| {
                if req.expired(now) {
                    metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(ServeFailure::deadline(0, 0)));
                    None
                } else {
                    Some((lane_idx, req))
                }
            })
            .collect();
        slots.set_free(replica, free.len() - admitted.len());
        {
            // The workload-profile key is the lockstep bucket the lane
            // set occupies after this admission wave — the same key
            // retirements and iteration costs are recorded under below.
            let occupied_after = bucket - (free.len() - admitted.len());
            let prior_bucket = pick_bucket(buckets, occupied_after);
            let (head, tail) = cell_inputs.split_at_mut(x_slot);
            admit_all(
                engine,
                params,
                &meta,
                &mut head[z_slot],
                &mut tail[0],
                &mut hist,
                lanes,
                admitted,
                window,
                profiles,
                prior_bucket,
            )?;
        }
        if lanes.iter().all(Option::is_none) {
            continue;
        }

        // --- one solve iteration over the whole lane set ---
        let iter_t0 = Instant::now();
        let mut out = engine.execute("cell_step", bucket, &cell_inputs)?;
        let fnorm_t = out.pop().expect("cell_step returns 3 outputs");
        let res_t = out.pop().expect("cell_step returns 3 outputs");
        let f = out.pop().expect("cell_step returns 3 outputs");
        let rel = per_sample_rel(&res_t, &fnorm_t, cfg.solver.lam)?;
        engine.recycle(vec![res_t, fnorm_t]);
        let occupied = lanes.iter().filter(|l| l.is_some()).count();
        let lockstep = pick_bucket(buckets, occupied);
        metrics.record_iteration(occupied, bucket, lockstep);
        metrics.replica_iteration(replica, occupied, bucket);

        retire_mask.fill(false);
        // One clock read serves every lane's deadline check this
        // iteration (the check is at iteration granularity anyway).
        let now = Instant::now();
        for (i, slot) in lanes.iter_mut().enumerate() {
            let Some(lane) = slot.as_mut() else { continue };
            lane.iters += 1;
            lane.fevals += 1;
            // Streaming: report this iteration's residual before any
            // retirement decision, so the final progress frame always
            // precedes the reply (the hook and the reply channel feed
            // the same FIFO writer queue).
            if let Some(hook) = &lane.req.progress {
                hook(lane.iters, rel[i]);
            }
            if !rel[i].is_finite() {
                // Non-finite residual: quarantine this lane *alone* —
                // every kernel is row-wise, so its bucket-mates' rows
                // are untouched and keep iterating bit-identically.
                // The request gets a terminal numerical-fault reply
                // (its logits would be garbage), the lane frees, and
                // its state is wiped.
                let lane = slot.take().expect("lane checked occupied");
                metrics.quarantined.fetch_add(1, Ordering::Relaxed);
                let _ = lane.req.respond.send(Err(ServeFailure::numerical(
                    format!("non-finite residual at iteration {}", lane.iters),
                    lane.iters,
                    lane.fevals,
                )));
                hist.clear_lane(i);
                cell_inputs[z_slot].set_row_f32(i, &zero_row)?;
                continue;
            }
            if lane.req.expired(now) {
                // Deadline passed mid-solve: retire with the partial
                // stats instead of burning more iterations on an answer
                // nobody is waiting for.
                let lane = slot.take().expect("lane checked occupied");
                metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                let _ = lane.req.respond.send(Err(ServeFailure::deadline(
                    lane.iters,
                    lane.fevals,
                )));
                hist.clear_lane(i);
                continue;
            }
            // Retirement is per-lane policy: this lane's own tol,
            // iteration cap and (optional) feval budget.
            let spec = &lane.req.spec;
            if rel[i] < spec.tol
                || lane.iters >= spec.max_iter
                || (spec.max_fevals > 0 && lane.fevals >= spec.max_fevals)
            {
                retire_mask[i] = true;
            }
        }

        // --- retire converged (or exhausted) lanes this very iteration ---
        if retire_mask.iter().any(|&r| r) {
            // Retiring lanes take f as their terminal iterate, like the
            // batch drivers' terminal step; classify the whole bucket and
            // slice out the retiring rows.
            cls_inputs[cls_z_slot].copy_from(&cell_inputs[z_slot])?;
            cls_inputs[cls_z_slot].overwrite_rows_where(&f, &retire_mask)?;
            let logits_t =
                engine.execute("classify", bucket, &cls_inputs)?.remove(0);
            let flat = logits_t.f32s()?;
            for (i, slot) in lanes.iter_mut().enumerate() {
                if !retire_mask[i] {
                    continue;
                }
                let lane = slot.take().expect("retiring lane is occupied");
                let row = flat[i * nc..(i + 1) * nc].to_vec();
                let latency = lane.req.enqueued.elapsed();
                metrics.record(latency, occupied, bucket);
                metrics.record_retire(lane.admitted.elapsed());
                metrics.replica_served(replica);
                metrics.record_kind_retired(lane.req.spec.kind);
                // Feed the workload profile: every retirement updates
                // the bucket's iteration/feval averages, and auto lanes
                // additionally contribute their fitted decay rate,
                // observed Anderson speedup and switch count — the
                // prior the next auto lane in this bucket starts from.
                let auto = lane.policy.auto_stats();
                if let Some(a) = &auto {
                    metrics
                        .auto_switches
                        .fetch_add(a.switches, Ordering::Relaxed);
                }
                profiles.record_retirement(
                    lockstep,
                    lane.req.spec.kind,
                    lane.iters,
                    lane.fevals,
                    auto,
                );
                // Distinguishes tol-crossing retirement from a lane cut
                // off at its iteration/feval budget.
                let converged = rel[i] < lane.req.spec.tol;
                let _ = lane.req.respond.send(Ok(Response {
                    id: lane.req.id,
                    class: infer::argmax(&row),
                    logits: row,
                    solver_iters: lane.iters,
                    solver_fevals: lane.fevals,
                    converged,
                    latency,
                    batch_size: occupied,
                    spec: lane.req.spec,
                }));
                hist.clear_lane(i);
            }
            engine.recycle(vec![logits_t]);
        }

        // --- advance the surviving lanes, each by its own policy ---
        mix_mask.fill(false);
        fwd_mask.fill(false);
        for (i, slot) in lanes.iter_mut().enumerate() {
            let Some(lane) = slot.as_mut() else { continue };
            match lane.policy.observe(rel[i]) {
                LaneStep::Forward { beta } => {
                    if beta < 1.0 {
                        // Damped blend for this lane only: z ← z + β(f−z).
                        blend_row.copy_from_slice(f.row_f32(i)?);
                        damp_in_place(
                            &mut blend_row,
                            cell_inputs[z_slot].row_f32(i)?,
                            beta,
                        );
                        cell_inputs[z_slot].set_row_f32(i, &blend_row)?;
                    } else {
                        fwd_mask[i] = true;
                    }
                }
                LaneStep::Mix => {
                    hist.push_lane(
                        i,
                        cell_inputs[z_slot].row_f32(i)?,
                        f.row_f32(i)?,
                    );
                    // Per-lane window adaptation: adaptive policies
                    // prune this lane's ring (overwrite-with-newest —
                    // the mask is shared bucket-wide) before the mix;
                    // fixed-window lanes return None and are untouched.
                    if let Some(rule) = lane.policy.window_rule() {
                        hist.adapt_lane(i, rule, cfg.solver.lam);
                    }
                    // Auto lanes additionally cap the mixing depth at
                    // the window their controller sized from the
                    // predicted remaining decades.
                    if let Some(depth) = lane.policy.window_depth() {
                        hist.truncate_lane(i, depth);
                    }
                    mix_mask[i] = true;
                }
                LaneStep::Restart => {
                    // Per-lane restart-on-breakdown: forget this lane's
                    // window; the re-seeded push degenerates the next
                    // mixed step to a damped forward step.
                    hist.clear_lane(i);
                    hist.push_lane(
                        i,
                        cell_inputs[z_slot].row_f32(i)?,
                        f.row_f32(i)?,
                    );
                    mix_mask[i] = true;
                }
            }
        }
        if mix_mask.iter().any(|&b| b) {
            {
                let [xh, fh, mask_t] = &mut and_inputs;
                hist.fill_tensors(xh, fh, mask_t)?;
            }
            let mut update =
                engine.execute("anderson_update", bucket, &and_inputs)?;
            let alpha =
                update.pop().expect("anderson_update returns 2 outputs");
            let mixed = update
                .pop()
                .expect("anderson_update returns 2 outputs")
                .reshaped(meta.latent_shape(bucket))?;
            cell_inputs[z_slot].overwrite_rows_where(&mixed, &mix_mask)?;
            engine.recycle(vec![alpha, mixed]);
        }
        if fwd_mask.iter().any(|&b| b) {
            cell_inputs[z_slot].overwrite_rows_where(&f, &fwd_mask)?;
        }
        engine.recycle(vec![f]);
        // Live mixing-penalty estimate: per-lane wallclock of this
        // iteration, binned by whether any lane mixed — the ratio of
        // the two EWMAs is the penalty `p` auto lanes price Anderson
        // steps with (Fig. 1 crossover, measured in situ).
        if occupied > 0 {
            profiles.record_iteration_cost(
                lockstep,
                mix_mask.iter().any(|&b| b),
                iter_t0.elapsed().as_secs_f64() / occupied as f64,
            );
        }
    }
}

//! Replica supervision: the fault-tolerance layer between the router
//! and its worker threads.
//!
//! Each replica worker (scheduler or batcher) runs its serve loop under
//! `catch_unwind` and reports how it ended over an exit channel: `Clean`
//! (shutdown or fatal-but-drained backend error) or `Crashed`, carrying
//! the in-flight requests recovered from its lanes plus the panic text.
//! The supervisor thread owns every worker `JoinHandle` and reacts:
//!
//!  * **Crashed** → join the dead thread, bump `replica_restarts`,
//!    *redrive* each recovered request (push it back onto the shared
//!    queue so any surviving replica picks it up) while its per-request
//!    redrive budget lasts; an exhausted budget becomes a terminal
//!    `internal` (retryable) reply, an expired deadline a
//!    `deadline_exceeded` reply — **no waiter ever hangs** on a crashed
//!    replica.  The replica is then respawned, unless the router is
//!    shutting down.
//!  * **Clean** → join and retire the handle.
//!
//! Redriven requests bypass `queue_cap`: they were already admitted
//! once, and shedding an admitted request on a replica crash would turn
//! an internal fault into client-visible backpressure.
//!
//! The supervisor exits once shutdown is raised and every worker handle
//! has been joined — `Router::shutdown`/`Drop` join the supervisor only,
//! never individual workers.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::model::ParamSet;
use crate::runtime::Backend;
use crate::server::replica::ReplicaSlots;
use crate::server::{
    drain_with_error, lock_unpoisoned, Queue, Request, RouterConfig,
    ServeFailure, ServerMetrics,
};
use crate::solver::ProfileStore;

/// Everything a replica worker needs to run, bundled so respawning a
/// crashed replica is a single `replica::spawn(r, ctx, exits)` call.
pub(crate) struct ReplicaCtx {
    pub engine: Arc<dyn Backend>,
    pub params: Arc<ParamSet>,
    pub queue: Arc<Queue>,
    pub metrics: Arc<ServerMetrics>,
    pub cfg: RouterConfig,
    pub buckets: Vec<usize>,
    pub slots: Arc<ReplicaSlots>,
    /// Per-bucket workload learning (auto-selection priors), shared with
    /// the router's stats surface.
    pub profiles: Arc<ProfileStore>,
}

/// How one replica worker's serve loop ended.
pub(crate) enum RunOutcome {
    /// Shutdown drain, or a fatal backend error already reported to
    /// every affected waiter.  Nothing to recover.
    Clean,
    /// The serve loop panicked.  `inflight` holds the requests that
    /// were admitted to lanes (or drained into a batch) and not yet
    /// answered — recovered for redrive.
    Crashed { inflight: Vec<Request>, panic_msg: String },
}

/// A [`RunOutcome`] tagged with the replica that produced it, as sent
/// over the exit channel.
pub(crate) struct Exit {
    pub replica: usize,
    pub outcome: RunOutcome,
}

/// Human-readable panic payload (the `String`/`&str` cases cover every
/// `panic!` in this codebase and the injected faults).
pub(crate) fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The supervisor loop.  `handles[r]` is replica `r`'s join handle
/// (`None` once joined); `keep_alive` is a sender clone held so `exits`
/// can never disconnect while the supervisor runs, and the source of
/// senders for respawned replicas.
pub(crate) fn supervise(
    ctx: Arc<ReplicaCtx>,
    mut handles: Vec<Option<JoinHandle<()>>>,
    exits: Receiver<Exit>,
    keep_alive: Sender<Exit>,
) {
    loop {
        match exits.recv_timeout(Duration::from_millis(100)) {
            Ok(exit) => handle_exit(&ctx, &mut handles, exit, &keep_alive),
            Err(RecvTimeoutError::Timeout) => {
                // A thread that died without sending (e.g. killed by the
                // OS, or a panic inside the exit send itself) would
                // otherwise leave its handle dangling forever: sweep for
                // finished-but-silent workers and treat them as crashed
                // with nothing recoverable.
                for r in 0..handles.len() {
                    let finished =
                        handles[r].as_ref().is_some_and(|h| h.is_finished());
                    if finished {
                        handle_exit(
                            &ctx,
                            &mut handles,
                            Exit {
                                replica: r,
                                outcome: RunOutcome::Crashed {
                                    inflight: Vec::new(),
                                    panic_msg: "worker exited without reporting"
                                        .to_string(),
                                },
                            },
                            &keep_alive,
                        );
                    }
                }
            }
            // Defensive: unreachable while `keep_alive` is held.
            Err(RecvTimeoutError::Disconnected) => break,
        }

        let shutting_down = ctx.queue.shutdown.load(Ordering::SeqCst);
        if shutting_down && handles.iter().all(Option::is_none) {
            break;
        }
        if !shutting_down && handles.iter().all(Option::is_none) {
            // Every replica is dead and could not be respawned: the
            // router can never answer again.  Fail queued waiters
            // explicitly instead of letting them block forever.
            eprintln!("[server] all replicas dead; shutting the router down");
            {
                let mut q = lock_unpoisoned(&ctx.queue.items);
                ctx.queue.shutdown.store(true, Ordering::SeqCst);
                drain_with_error(&mut q, "server shutting down");
            }
            ctx.queue.signal.notify_all();
            break;
        }
    }
}

fn handle_exit(
    ctx: &Arc<ReplicaCtx>,
    handles: &mut [Option<JoinHandle<()>>],
    exit: Exit,
    exit_tx: &Sender<Exit>,
) {
    let Exit { replica, outcome } = exit;
    if let Some(h) = handles.get_mut(replica).and_then(Option::take) {
        // The worker sent its exit as its last act; the join is
        // immediate and only reclaims the thread.
        let _ = h.join();
    }
    let RunOutcome::Crashed { inflight, panic_msg } = outcome else {
        return;
    };

    eprintln!(
        "[server] replica {replica} crashed ({} in flight): {panic_msg}",
        inflight.len()
    );
    ctx.metrics.replica_restarts.fetch_add(1, Ordering::Relaxed);
    redrive(ctx, replica, inflight, &panic_msg);

    if !ctx.queue.shutdown.load(Ordering::SeqCst) {
        match crate::server::replica::spawn(replica, ctx.clone(), exit_tx.clone())
        {
            Ok(h) => handles[replica] = Some(h),
            // Spawn failure (thread exhaustion): leave the slot dead;
            // the all-dead check above handles the terminal case.
            Err(e) => eprintln!("[server] respawn of replica {replica} failed: {e:#}"),
        }
    }
}

/// Route each recovered in-flight request: terminal reply (shutdown,
/// expired deadline, exhausted redrive budget) or back onto the queue.
fn redrive(
    ctx: &Arc<ReplicaCtx>,
    replica: usize,
    inflight: Vec<Request>,
    panic_msg: &str,
) {
    if inflight.is_empty() {
        return;
    }
    let now = Instant::now();
    let shutting_down = ctx.queue.shutdown.load(Ordering::SeqCst);
    let mut requeued = 0usize;
    {
        let mut q = lock_unpoisoned(&ctx.queue.items);
        for mut req in inflight {
            if shutting_down {
                let _ = req.respond.send(Err(ServeFailure::error(
                    "server shutting down",
                )));
            } else if req.expired(now) {
                ctx.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(ServeFailure::deadline(0, 0)));
            } else if req.redrives_left == 0 {
                let _ = req.respond.send(Err(ServeFailure::internal(format!(
                    "replica {replica} crashed while serving this request: \
                     {panic_msg}"
                ))));
            } else {
                req.redrives_left -= 1;
                ctx.metrics.redrives.fetch_add(1, Ordering::Relaxed);
                q.push(req);
                requeued += 1;
            }
        }
    }
    if requeued > 0 {
        ctx.queue.signal.notify_all();
    }
}

//! The batch-granular fire-and-wait loop: bucket selection + wait policy.
//!
//! This is the classic dynamic-batching baseline the iteration-level
//! scheduler ([`super::scheduler`]) is measured against: wait until either
//! (a) the largest compiled bucket fills, or (b) the oldest queued request
//! has waited `max_wait`; then solve the whole batch to the slowest
//! sample's convergence and respond all at once.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::server::supervise::{panic_text, ReplicaCtx, RunOutcome};
use crate::server::{
    drain_with_error, lock_unpoisoned, run_batch, Request, ServeFailure,
};
use crate::solver::SolveSpec;

/// Pick the compiled bucket for `n` queued requests: the smallest bucket
/// ≥ n.
///
/// `n` must not exceed the largest bucket.  Both worker loops guarantee
/// this by construction — the batcher drains at most `max_bucket`
/// requests per batch and the scheduler's occupancy is bounded by its
/// lane count — so an oversize `n` here is an internal invariant
/// violation (asserted in debug builds), **not** a request to clamp.
/// The old `unwrap_or(last)` silently rode a too-small bucket and blew
/// up downstream with a confusing shape error; oversize *client* batches
/// are now rejected with an explicit error where they enter, in
/// [`crate::runtime::Manifest::bucket_for`].
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(!buckets.is_empty());
    debug_assert!(
        n <= *buckets.last().expect("buckets non-empty"),
        "batch of {n} exceeds the largest compiled bucket — split it first"
    );
    *buckets
        .iter()
        .find(|&&b| b >= n)
        .unwrap_or_else(|| buckets.last().expect("buckets non-empty"))
}

/// Decide whether to fire now: full bucket, or oldest waiter exceeded
/// `max_wait`.
pub fn should_fire(
    queued: usize,
    oldest_wait: Option<Duration>,
    max_bucket: usize,
    max_wait: Duration,
) -> bool {
    if queued == 0 {
        return false;
    }
    queued >= max_bucket || oldest_wait.map(|w| w >= max_wait).unwrap_or(false)
}

/// The batcher thread body for one replica.  Multi-replica bursts shard
/// naturally: each replica drains at most one largest-bucket batch per
/// fire, leaving the remainder for its siblings' condvar wakeups.
///
/// Each per-spec sub-batch solves under its own `catch_unwind`: a panic
/// (injected fault, backend bug) loses neither the un-answered riders of
/// the panicking sub-batch nor the later sub-batches — all travel back
/// to the supervisor for redrive.
pub(crate) fn run(ctx: &ReplicaCtx, replica: usize) -> RunOutcome {
    let max_bucket = *ctx.buckets.last().expect("router checked buckets non-empty");
    loop {
        // Wait for work (or shutdown), with the timeout needed to honor
        // max_wait on partially filled batches.
        let batch: Vec<Request> = {
            let mut items = lock_unpoisoned(&ctx.queue.items);
            loop {
                if ctx.queue.shutdown.load(Ordering::SeqCst) {
                    drain_with_error(&mut items, "server shutting down");
                    return RunOutcome::Clean;
                }
                let oldest = items.first().map(|r| r.enqueued.elapsed());
                if should_fire(items.len(), oldest, max_bucket, ctx.cfg.max_wait)
                {
                    let take = items.len().min(max_bucket);
                    break items.drain(..take).collect();
                }
                // Sleep until notified or until the oldest request ages out.
                let wait = match items.first() {
                    Some(r) => ctx
                        .cfg
                        .max_wait
                        .saturating_sub(r.enqueued.elapsed())
                        .max(Duration::from_micros(100)),
                    None => Duration::from_millis(50),
                };
                let (guard, _timeout) = ctx
                    .queue
                    .signal
                    .wait_timeout(items, wait)
                    .unwrap_or_else(|e| e.into_inner());
                items = guard;
            }
        };

        // Shed requests whose deadline expired while they queued before
        // paying for their solve.
        let now = Instant::now();
        let mut live = Vec::with_capacity(batch.len());
        for req in batch {
            if req.expired(now) {
                ctx.metrics
                    .deadline_exceeded
                    .fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(ServeFailure::deadline(0, 0)));
            } else {
                live.push(req);
            }
        }

        // A lockstep solve runs one spec for every rider, so requests
        // with distinct effective specs (per-request overrides) are
        // solved as separate sub-batches.  The common case — no
        // overrides — stays a single group.
        let mut groups = split_by_spec(live);
        for gi in 0..groups.len() {
            let bucket = pick_bucket(&ctx.buckets, groups[gi].1.len());
            let panicked = {
                let (spec, group) = &mut groups[gi];
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_batch(
                        ctx.engine.as_ref(),
                        &ctx.params,
                        spec,
                        group,
                        bucket,
                        &ctx.metrics,
                        replica,
                    )
                }))
                .err()
            };
            if let Some(payload) = panicked {
                // Un-answered riders of the panicking sub-batch (answered
                // ones were drained out before the panic) plus every
                // later sub-batch go back for redrive.
                let mut inflight: Vec<Request> = Vec::new();
                for (_, group) in groups.iter_mut().skip(gi) {
                    inflight.append(group);
                }
                return RunOutcome::Crashed {
                    inflight,
                    panic_msg: panic_text(payload.as_ref()),
                };
            }
        }
    }
}

/// Partition a drained batch into per-effective-spec groups, preserving
/// arrival order within each group.
pub(crate) fn split_by_spec(
    batch: Vec<Request>,
) -> Vec<(SolveSpec, Vec<Request>)> {
    let mut groups: Vec<(SolveSpec, Vec<Request>)> = Vec::new();
    for req in batch {
        match groups.iter_mut().find(|(s, _)| *s == req.spec) {
            Some((_, reqs)) => reqs.push(req),
            None => {
                let spec = req.spec.clone();
                groups.push((spec, vec![req]));
            }
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = vec![1, 8, 32];
        assert_eq!(pick_bucket(&b, 1), 1);
        assert_eq!(pick_bucket(&b, 2), 8);
        assert_eq!(pick_bucket(&b, 8), 8);
        assert_eq!(pick_bucket(&b, 9), 32);
        assert_eq!(pick_bucket(&b, 32), 32);
    }

    #[test]
    #[should_panic(expected = "exceeds the largest compiled bucket")]
    fn oversize_bucket_is_an_invariant_violation() {
        // The silent clamp is gone: a batch the workers failed to split
        // trips the debug assertion instead of riding a too-small bucket
        // into a shape error.
        pick_bucket(&[1, 8, 32], 100);
    }

    #[test]
    fn split_by_spec_groups_and_preserves_order() {
        use crate::solver::{SolveSpec, SolverKind};
        use std::sync::mpsc;
        use std::time::Instant;
        let spec_a = SolveSpec::new(SolverKind::Anderson);
        let spec_b = SolveSpec { tol: 0.5, ..spec_a.clone() };
        let mk = |id: u64, spec: &SolveSpec| {
            let (tx, _rx) = mpsc::channel();
            Request {
                id,
                image: Vec::new(),
                spec: spec.clone(),
                enqueued: Instant::now(),
                deadline: None,
                redrives_left: 0,
                respond: tx,
                progress: None,
            }
        };
        let batch = vec![
            mk(1, &spec_a),
            mk(2, &spec_b),
            mk(3, &spec_a),
            mk(4, &spec_b),
        ];
        let groups = split_by_spec(batch);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0, spec_a);
        let ids: Vec<u64> = groups[0].1.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3]);
        let ids: Vec<u64> = groups[1].1.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 4]);
        // No overrides → one group (the common fast path).
        let uniform = vec![mk(5, &spec_a), mk(6, &spec_a)];
        assert_eq!(split_by_spec(uniform).len(), 1);
    }

    #[test]
    fn fire_policy() {
        let w = Duration::from_millis(5);
        assert!(!should_fire(0, None, 32, w));
        assert!(should_fire(32, Some(Duration::ZERO), 32, w));
        assert!(should_fire(40, Some(Duration::ZERO), 32, w));
        assert!(!should_fire(3, Some(Duration::from_millis(1)), 32, w));
        assert!(should_fire(3, Some(Duration::from_millis(6)), 32, w));
    }
}

//! The batch-granular fire-and-wait loop: bucket selection + wait policy.
//!
//! This is the classic dynamic-batching baseline the iteration-level
//! scheduler ([`super::scheduler`]) is measured against: wait until either
//! (a) the largest compiled bucket fills, or (b) the oldest queued request
//! has waited `max_wait`; then solve the whole batch to the slowest
//! sample's convergence and respond all at once.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use crate::model::ParamSet;
use crate::runtime::Backend;
use crate::server::{
    drain_with_error, run_batch, Request, RouterConfig, ServerMetrics,
};

pub(crate) type QueueHandle = Arc<super::Queue>;

/// Pick the compiled bucket for `n` queued requests: the smallest bucket
/// ≥ n, else the largest (and we take only that many requests).
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(!buckets.is_empty());
    *buckets
        .iter()
        .find(|&&b| b >= n)
        .unwrap_or(buckets.last().unwrap())
}

/// Decide whether to fire now: full bucket, or oldest waiter exceeded
/// `max_wait`.
pub fn should_fire(
    queued: usize,
    oldest_wait: Option<Duration>,
    max_bucket: usize,
    max_wait: Duration,
) -> bool {
    if queued == 0 {
        return false;
    }
    queued >= max_bucket || oldest_wait.map(|w| w >= max_wait).unwrap_or(false)
}

/// The batcher thread body.
pub(crate) fn run(
    engine: Arc<dyn Backend>,
    params: Arc<ParamSet>,
    queue: QueueHandle,
    metrics: Arc<ServerMetrics>,
    cfg: RouterConfig,
    buckets: Vec<usize>,
) {
    let max_bucket = *buckets.last().unwrap();
    loop {
        // Wait for work (or shutdown), with the timeout needed to honor
        // max_wait on partially filled batches.
        let batch: Vec<Request> = {
            let mut items = queue.items.lock().unwrap();
            loop {
                if queue.shutdown.load(Ordering::SeqCst) {
                    drain_with_error(&mut items, "server shutting down");
                    return;
                }
                let oldest = items.first().map(|r| r.enqueued.elapsed());
                if should_fire(items.len(), oldest, max_bucket, cfg.max_wait) {
                    let take = items.len().min(max_bucket);
                    break items.drain(..take).collect();
                }
                // Sleep until notified or until the oldest request ages out.
                let wait = match items.first() {
                    Some(r) => cfg
                        .max_wait
                        .saturating_sub(r.enqueued.elapsed())
                        .max(Duration::from_micros(100)),
                    None => Duration::from_millis(50),
                };
                let (guard, _timeout) =
                    queue.signal.wait_timeout(items, wait).unwrap();
                items = guard;
            }
        };

        let bucket = pick_bucket(&buckets, batch.len());
        run_batch(engine.as_ref(), &params, &cfg.solver, batch, bucket, &metrics);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = vec![1, 8, 32];
        assert_eq!(pick_bucket(&b, 1), 1);
        assert_eq!(pick_bucket(&b, 2), 8);
        assert_eq!(pick_bucket(&b, 8), 8);
        assert_eq!(pick_bucket(&b, 9), 32);
        assert_eq!(pick_bucket(&b, 100), 32);
    }

    #[test]
    fn fire_policy() {
        let w = Duration::from_millis(5);
        assert!(!should_fire(0, None, 32, w));
        assert!(should_fire(32, Some(Duration::ZERO), 32, w));
        assert!(should_fire(40, Some(Duration::ZERO), 32, w));
        assert!(!should_fire(3, Some(Duration::from_millis(1)), 32, w));
        assert!(should_fire(3, Some(Duration::from_millis(6)), 32, w));
    }
}

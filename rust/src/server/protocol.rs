//! Wire protocol for the multiplexed NDJSON serving front-end: frame
//! parsing and frame building, split from the socket plumbing in
//! [`super::tcp`] so every shape on the wire is a pure, unit-testable
//! function.
//!
//! One JSON object per line, in either direction.  Requests carry a
//! **client-chosen `id`** (any JSON value, echoed verbatim); many
//! requests may be in flight per connection and replies are matched by
//! `id`, **not** by order — a fast solve overtakes a stiff one:
//!
//! ```text
//! → {"id":"a","image":[…],"tol":1e-5}          (stiff: many iterations)
//! → {"id":"b","image":[…],"tol":0.3}           (easy: a few iterations)
//! ← {"id":"b","class":3,"solver_iters":2,…}    (b retires first)
//! ← {"id":"a","class":7,"solver_iters":41,…}
//! ```
//!
//! An opt-in `"stream": true` field subscribes the request to
//! per-iteration **progress frames**, emitted live from the scheduler's
//! solve loop before the final reply:
//!
//! ```text
//! → {"id":5,"image":[…],"stream":true}
//! ← {"event":"progress","id":5,"iter":1,"residual":0.81}
//! ← {"event":"progress","id":5,"iter":2,"residual":0.13}
//! ← {"id":5,"class":3,"solver_iters":3,…}
//! ```
//!
//! Progress frames are lossy by design: they are dropped (never
//! buffered unboundedly, never blocking the solve loop) when the
//! connection's writer queue is full.  The final reply is reliable.
//!
//! Load shedding is part of the wire format: a request refused at the
//! admission door (shared queue at capacity, or the connection over its
//! in-flight cap) gets an explicit
//! `{"error":"overloaded","retry_after_ms":…}` reply — the hint is
//! computed from the live retire-time p50 — instead of an opaque error
//! or a silently growing queue.
//!
//! Error replies carry the request's `id` when one was parseable, so a
//! multiplexing client can always match them.  **Back-compat:** a
//! legacy request without an `id` (and without `"stream"`) receives
//! byte-identical replies to the old synchronous protocol — same keys,
//! same error strings — and the blocking entry point
//! [`super::tcp::process_line`] preserves the old no-id error shapes
//! exactly (pinned by golden tests).

use crate::server::{FailureKind, Response, ServeFailure};
use crate::solver::spec::f32_json;
use crate::solver::{GramMode, SolveOverrides, SolverKind};
use crate::util::json::{self, Json};

/// Default per-connection in-flight request cap: one client cannot hold
/// more lanes than this across all replicas, no matter how fast it
/// pipelines (`--max-inflight` on `deq-anderson serve`).
pub const DEFAULT_MAX_INFLIGHT: usize = 64;

/// A parsed inference request line.
pub struct InferFrame {
    /// Client-chosen correlation id, echoed verbatim on every frame the
    /// request produces (progress, final reply, errors).
    pub id: Option<Json>,
    pub image: Vec<f32>,
    pub overrides: SolveOverrides,
    /// Subscribe to per-iteration progress frames.
    pub stream: bool,
    /// Per-request deadline in milliseconds from admission.  A request
    /// that cannot finish in time is retired with
    /// `{"error":"deadline_exceeded",…}` carrying its partial solve
    /// stats.  `None` falls back to the router's `--deadline-ms`.
    pub deadline_ms: Option<u64>,
}

/// One parsed protocol line, dispatched by the connection handler.
pub enum Incoming {
    /// `{"cmd": "..."}` — ping / stats.
    Cmd { cmd: String },
    /// An inference request.
    Infer(InferFrame),
    /// Rejected at parse/validation time.  `id` is what the wire path
    /// echoes on the error frame (None for legacy no-id requests, whose
    /// error replies stay byte-identical to the old protocol).
    Bad { msg: String, id: Option<Json> },
}

/// Parse one protocol line.  Validation order matches the legacy
/// protocol exactly (malformed JSON → cmd dispatch → image → overrides)
/// so every legacy error string is preserved; the `stream` flag is
/// validated last, after the legacy surface.
pub fn parse_line(image_dim: usize, line: &str) -> Incoming {
    let parsed = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Incoming::Bad { msg: format!("malformed json: {e}"), id: None }
        }
    };
    if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
        return Incoming::Cmd { cmd: cmd.to_string() };
    }
    let id = parsed.get("id").cloned();
    let image = match parse_image(&parsed, image_dim) {
        Ok(img) => img,
        Err(msg) => return Incoming::Bad { msg, id },
    };
    let overrides = match parse_overrides(&parsed) {
        Ok(ov) => ov,
        Err(msg) => return Incoming::Bad { msg, id },
    };
    let stream = match parsed.get("stream") {
        None => false,
        Some(v) => match v.as_bool() {
            Some(b) => b,
            None => {
                return Incoming::Bad {
                    msg: "'stream' must be a boolean".to_string(),
                    id,
                }
            }
        },
    };
    let deadline_ms = match parsed.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_f64() {
            Some(x) if x.fract() == 0.0 && x >= 1.0 => Some(x as u64),
            _ => {
                return Incoming::Bad {
                    msg: "'deadline_ms' must be a positive integer"
                        .to_string(),
                    id,
                }
            }
        },
    };
    Incoming::Infer(InferFrame { id, image, overrides, stream, deadline_ms })
}

/// Extract and validate the `image` array.  Every element must be a
/// number: the old `filter_map(Json::as_f64)` silently *dropped*
/// non-numeric elements, reporting a wrong-length image downstream — or
/// worse, passing with shifted values when the length still matched.
pub fn parse_image(parsed: &Json, image_dim: usize) -> Result<Vec<f32>, String> {
    let arr = parsed
        .get("image")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'image' array".to_string())?;
    let mut image = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        match v.as_f64() {
            Some(x) => image.push(x as f32),
            None => return Err(format!("image[{i}] is not a number")),
        }
    }
    if image.len() != image_dim {
        return Err(format!(
            "image has {} values, model wants {image_dim}",
            image.len()
        ));
    }
    Ok(image)
}

/// Parse the optional per-request solver override fields.  Shape errors
/// (wrong JSON type, unknown solver name, non-integer iteration cap) are
/// caught here with stable messages; *value* errors (tol ≤ 0 etc.) are
/// caught by `SolveOverrides::apply` at submission.
pub fn parse_overrides(parsed: &Json) -> Result<SolveOverrides, String> {
    let mut ov = SolveOverrides::default();
    if let Some(v) = parsed.get("solver") {
        let name = v
            .as_str()
            .ok_or_else(|| "override 'solver' must be a string".to_string())?;
        ov.kind = Some(SolverKind::parse(name).ok_or_else(|| {
            // Derived from the kind enum so the accepted-name list can
            // never drift from what `parse` actually takes.
            format!(
                "unknown solver '{name}' (expected {})",
                SolverKind::expected()
            )
        })?);
    }
    if let Some(v) = parsed.get("tol") {
        let tol = v
            .as_f64()
            .ok_or_else(|| "override 'tol' must be a number".to_string())?;
        ov.tol = Some(tol as f32);
    }
    if let Some(v) = parsed.get("max_iter") {
        let x = v.as_f64().ok_or_else(|| {
            "override 'max_iter' must be a positive integer".to_string()
        })?;
        if x.fract() != 0.0 || x < 1.0 {
            return Err(
                "override 'max_iter' must be a positive integer".to_string()
            );
        }
        ov.max_iter = Some(x as usize);
    }
    if let Some(v) = parsed.get("adaptive") {
        let on = v.as_bool().ok_or_else(|| {
            "override 'adaptive' must be a boolean".to_string()
        })?;
        ov.adaptive_window = Some(on);
    }
    if let Some(v) = parsed.get("safeguard") {
        let on = v.as_bool().ok_or_else(|| {
            "override 'safeguard' must be a boolean".to_string()
        })?;
        ov.safeguard = Some(on);
    }
    if let Some(v) = parsed.get("errorfactor") {
        let f = v.as_f64().ok_or_else(|| {
            "override 'errorfactor' must be a number".to_string()
        })?;
        ov.errorfactor = Some(f as f32);
    }
    if let Some(v) = parsed.get("cond_max") {
        let c = v.as_f64().ok_or_else(|| {
            "override 'cond_max' must be a number".to_string()
        })?;
        ov.cond_max = Some(c as f32);
    }
    if let Some(v) = parsed.get("gram") {
        const MSG: &str =
            "override 'gram' must be \"exact\" or a positive integer";
        let mode = if let Some(s) = v.as_str() {
            if s == "exact" {
                GramMode::Exact
            } else {
                return Err(MSG.to_string());
            }
        } else {
            match v.as_f64() {
                Some(n) if n >= 1.0 && n.fract() == 0.0 => {
                    GramMode::Sketched { dim: n as usize }
                }
                _ => return Err(MSG.to_string()),
            }
        };
        ov.gram = Some(mode);
    }
    Ok(ov)
}

/// Append the echoed client id (when known) and build the frame.  Keys
/// serialize sorted, so attachment order never changes the bytes.
fn with_id(mut pairs: Vec<(&str, Json)>, id: Option<&Json>) -> Json {
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    json::obj(pairs)
}

/// `{"error": msg}` (+ `"id"` when the request carried one).
pub fn error_frame(msg: &str, id: Option<&Json>) -> Json {
    with_id(vec![("error", json::s(msg))], id)
}

/// The reply for a structured [`ServeFailure`], one distinct shape per
/// [`FailureKind`]:
///
/// * `Error` → `{"error": detail}` — byte-identical to the legacy
///   [`error_frame`] shape (shutdown drains, encode failures, …);
/// * `DeadlineExceeded` → `{"error":"deadline_exceeded"}` plus the
///   partial `solver_iters`/`solver_fevals` at retirement;
/// * `Internal` → `{"error":"internal","retryable":true,"detail":…}` —
///   the serving replica died, the request may be resubmitted verbatim;
/// * `Numerical` → `{"error":"numerical_fault","detail":…}` plus the
///   partial stats — the lane was quarantined, resubmitting the same
///   request will likely fault again.
pub fn failure_frame(fail: &ServeFailure, id: Option<&Json>) -> Json {
    match fail.kind {
        FailureKind::Error => error_frame(&fail.detail, id),
        FailureKind::DeadlineExceeded => with_id(
            vec![
                ("error", json::s("deadline_exceeded")),
                ("solver_iters", json::num(fail.iters as f64)),
                ("solver_fevals", json::num(fail.fevals as f64)),
            ],
            id,
        ),
        FailureKind::Internal => with_id(
            vec![
                ("error", json::s("internal")),
                ("retryable", Json::Bool(true)),
                ("detail", json::s(&fail.detail)),
            ],
            id,
        ),
        FailureKind::Numerical => with_id(
            vec![
                ("error", json::s("numerical_fault")),
                ("detail", json::s(&fail.detail)),
                ("solver_iters", json::num(fail.iters as f64)),
                ("solver_fevals", json::num(fail.fevals as f64)),
            ],
            id,
        ),
    }
}

/// The load-shedding reply: the request was refused at the admission
/// door and should be retried after `retry_after_ms`.
pub fn overloaded_frame(retry_after_ms: u64, id: Option<&Json>) -> Json {
    with_id(
        vec![
            ("error", json::s("overloaded")),
            ("retry_after_ms", json::num(retry_after_ms as f64)),
        ],
        id,
    )
}

/// One per-iteration streaming progress frame.
pub fn progress_frame(id: Option<&Json>, iter: usize, residual: f32) -> Json {
    with_id(
        vec![
            ("event", json::s("progress")),
            ("iter", json::num(iter as f64)),
            ("residual", f32_json(residual)),
        ],
        id,
    )
}

/// The final reply for a served request.  Exactly the legacy reply
/// shape — the solver/tol/max_iter/adaptivity fields echo the
/// *effective* spec the solve ran under — so a request without new
/// fields gets byte-identical bytes to the old protocol.
pub fn response_frame(resp: &Response, id: Option<&Json>) -> Json {
    let pairs = vec![
        ("class", json::num(resp.class as f64)),
        ("latency_ms", json::num(resp.latency.as_secs_f64() * 1e3)),
        ("batch", json::num(resp.batch_size as f64)),
        ("solver_iters", json::num(resp.solver_iters as f64)),
        ("solver_fevals", json::num(resp.solver_fevals as f64)),
        ("converged", Json::Bool(resp.converged)),
        ("solver", json::s(resp.spec.kind.name())),
        ("tol", f32_json(resp.spec.tol)),
        ("max_iter", json::num(resp.spec.max_iter as f64)),
        ("adaptive", Json::Bool(resp.spec.adaptive_window)),
        ("safeguard", Json::Bool(resp.spec.safeguard)),
        ("errorfactor", f32_json(resp.spec.errorfactor)),
        ("cond_max", f32_json(resp.spec.cond_max)),
        (
            "gram",
            match resp.spec.gram {
                GramMode::Exact => json::s("exact"),
                GramMode::Sketched { dim } => json::num(dim as f64),
            },
        ),
    ];
    with_id(pairs, id)
}

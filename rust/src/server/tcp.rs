//! TCP front-end: newline-delimited JSON over a `std::net` listener.
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": <any>, "image": [f32; hw*hw*c]}
//!             {"cmd": "stats"}    → server metrics
//!             {"cmd": "ping"}     → {"ok": true}
//!   response: {"id": ..., "class": k, "latency_ms": ..., "batch": n,
//!              "solver_iters": k, "solver_fevals": k}
//!             (iteration-level scheduling: solver_iters/fevals are this
//!              sample's own counts, not the batch's)
//!             {"error": "..."}    on malformed input or shutdown

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::server::Router;
use crate::util::json::{self, Json};

/// Handle one client connection (blocking, one request at a time per
/// connection; concurrency comes from one thread per connection).
fn handle_client(router: &Router, image_dim: usize, stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".into());
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = process_line(router, image_dim, &line);
        let text = json::to_string(&reply);
        if writer.write_all(text.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    let _ = peer;
}

/// Parse and execute one protocol line. Pure function → unit-testable.
pub fn process_line(router: &Router, image_dim: usize, line: &str) -> Json {
    let parsed = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return json::obj(vec![("error", json::s(&format!("{e}")))]),
    };

    if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => json::obj(vec![("ok", Json::Bool(true))]),
            "stats" => {
                let mut pairs =
                    vec![("stats", json::s(&router.metrics.summary()))];
                // Pack-cache + workspace health of the serving backend:
                // in steady state `pack_hits` grows while misses and
                // invalidations stay flat (invalidations move only when
                // parameters are hot-swapped by a training step).
                if let Some(h) = router.backend_hot_stats() {
                    pairs.push((
                        "hot_path",
                        json::obj(vec![
                            ("ws_hits", json::num(h.hits as f64)),
                            ("ws_allocs", json::num(h.allocs as f64)),
                            ("pack_hits", json::num(h.pack_hits as f64)),
                            ("pack_misses", json::num(h.pack_misses as f64)),
                            (
                                "pack_invalidations",
                                json::num(h.pack_invalidations as f64),
                            ),
                            (
                                "pack_uncached",
                                json::num(h.pack_uncached as f64),
                            ),
                        ]),
                    ));
                }
                json::obj(pairs)
            }
            other => json::obj(vec![(
                "error",
                json::s(&format!("unknown cmd '{other}'")),
            )]),
        };
    }

    let image: Vec<f32> = match parsed.get("image").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .filter_map(Json::as_f64)
            .map(|v| v as f32)
            .collect(),
        None => {
            return json::obj(vec![("error", json::s("missing 'image' array"))])
        }
    };
    if image.len() != image_dim {
        return json::obj(vec![(
            "error",
            json::s(&format!(
                "image has {} values, model wants {image_dim}",
                image.len()
            )),
        )]);
    }

    match router.infer_blocking(image) {
        Ok(resp) => {
            let mut pairs = vec![
                ("class", json::num(resp.class as f64)),
                ("latency_ms", json::num(resp.latency.as_secs_f64() * 1e3)),
                ("batch", json::num(resp.batch_size as f64)),
                ("solver_iters", json::num(resp.solver_iters as f64)),
                ("solver_fevals", json::num(resp.solver_fevals as f64)),
                ("converged", Json::Bool(resp.converged)),
            ];
            if let Some(id) = parsed.get("id") {
                pairs.push(("id", id.clone()));
            }
            json::obj(pairs)
        }
        Err(e) => json::obj(vec![("error", json::s(&format!("{e}")))]),
    }
}

/// Serve until the process is killed.  One thread per connection; the
/// router's batcher thread does the actual batching across connections.
pub fn serve_tcp(router: Arc<Router>, image_dim: usize, addr: &str) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    println!("[server] listening on {addr} (ndjson protocol)");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let router = router.clone();
                std::thread::spawn(move || handle_client(&router, image_dim, s));
            }
            Err(e) => eprintln!("[server] accept error: {e}"),
        }
    }
    Ok(())
}

//! TCP front-end: newline-delimited JSON over a `std::net` listener.
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": <any>, "image": [f32; hw*hw*c]}
//!             with optional per-request solver overrides:
//!               "solver":      "forward" | "anderson" | "hybrid"
//!               "tol":         <positive number>
//!               "max_iter":    <positive integer>
//!               "adaptive":    <bool>   (condition-monitored window)
//!               "safeguard":   <bool>   (damped fallback on a bad mix)
//!               "errorfactor": <number > 1>
//!               "cond_max":    <number ≥ 1>
//!               "gram":        "exact" | <integer ≥ 1>  (sketched Gram
//!                              condition probes for window adaptation)
//!             (overrides resolve against the server's default spec under
//!              its clamps — min tol, max iteration cap — so a request
//!              can loosen a solve freely but only tighten it within the
//!              operator's bounds; the adaptivity knobs are validated but
//!              unclamped, since adaptation only ever *shrinks* a lane's
//!              effective window)
//!             {"cmd": "stats"}    → server metrics
//!             {"cmd": "ping"}     → {"ok": true}
//!   response: {"id": ..., "class": k, "latency_ms": ..., "batch": n,
//!              "solver_iters": k, "solver_fevals": k, "converged": b,
//!              "solver": "...", "tol": t, "max_iter": m,
//!              "adaptive": b, "safeguard": b, "errorfactor": f,
//!              "cond_max": c, "gram": "exact" | s}
//!             (iteration-level scheduling: solver_iters/fevals are this
//!              sample's own counts, not the batch's; the solver/tol/
//!              max_iter/adaptivity fields echo the *effective* spec the
//!              solve ran under)
//!             {"error": "..."}    on malformed input or shutdown
//!
//! Error replies are part of the wire format: their exact JSON is pinned
//! by golden tests in `tests/integration_server.rs`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::server::Router;
use crate::solver::{spec::f32_json, GramMode, SolveOverrides, SolverKind};
use crate::util::json::{self, Json};

/// Handle one client connection (blocking, one request at a time per
/// connection; concurrency comes from one thread per connection).
fn handle_client(router: &Router, image_dim: usize, stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".into());
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = process_line(router, image_dim, &line);
        let text = json::to_string(&reply);
        if writer.write_all(text.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    let _ = peer;
}

fn error_reply(msg: &str) -> Json {
    json::obj(vec![("error", json::s(msg))])
}

/// Parse the optional per-request solver override fields.  Shape errors
/// (wrong JSON type, unknown solver name, non-integer iteration cap) are
/// caught here with stable messages; *value* errors (tol ≤ 0 etc.) are
/// caught by `SolveOverrides::apply` at submission.
fn parse_overrides(parsed: &Json) -> Result<SolveOverrides, String> {
    let mut ov = SolveOverrides::default();
    if let Some(v) = parsed.get("solver") {
        let name = v
            .as_str()
            .ok_or_else(|| "override 'solver' must be a string".to_string())?;
        ov.kind = Some(SolverKind::parse(name).ok_or_else(|| {
            format!("unknown solver '{name}' (expected forward|anderson|hybrid)")
        })?);
    }
    if let Some(v) = parsed.get("tol") {
        let tol = v
            .as_f64()
            .ok_or_else(|| "override 'tol' must be a number".to_string())?;
        ov.tol = Some(tol as f32);
    }
    if let Some(v) = parsed.get("max_iter") {
        let x = v.as_f64().ok_or_else(|| {
            "override 'max_iter' must be a positive integer".to_string()
        })?;
        if x.fract() != 0.0 || x < 1.0 {
            return Err(
                "override 'max_iter' must be a positive integer".to_string()
            );
        }
        ov.max_iter = Some(x as usize);
    }
    if let Some(v) = parsed.get("adaptive") {
        let on = v.as_bool().ok_or_else(|| {
            "override 'adaptive' must be a boolean".to_string()
        })?;
        ov.adaptive_window = Some(on);
    }
    if let Some(v) = parsed.get("safeguard") {
        let on = v.as_bool().ok_or_else(|| {
            "override 'safeguard' must be a boolean".to_string()
        })?;
        ov.safeguard = Some(on);
    }
    if let Some(v) = parsed.get("errorfactor") {
        let f = v.as_f64().ok_or_else(|| {
            "override 'errorfactor' must be a number".to_string()
        })?;
        ov.errorfactor = Some(f as f32);
    }
    if let Some(v) = parsed.get("cond_max") {
        let c = v.as_f64().ok_or_else(|| {
            "override 'cond_max' must be a number".to_string()
        })?;
        ov.cond_max = Some(c as f32);
    }
    if let Some(v) = parsed.get("gram") {
        const MSG: &str =
            "override 'gram' must be \"exact\" or a positive integer";
        let mode = if let Some(s) = v.as_str() {
            if s == "exact" {
                GramMode::Exact
            } else {
                return Err(MSG.to_string());
            }
        } else {
            match v.as_f64() {
                Some(n) if n >= 1.0 && n.fract() == 0.0 => {
                    GramMode::Sketched { dim: n as usize }
                }
                _ => return Err(MSG.to_string()),
            }
        };
        ov.gram = Some(mode);
    }
    Ok(ov)
}

/// Parse and execute one protocol line. Pure function → unit-testable.
pub fn process_line(router: &Router, image_dim: usize, line: &str) -> Json {
    let parsed = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return error_reply(&format!("malformed json: {e}")),
    };

    if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => json::obj(vec![("ok", Json::Bool(true))]),
            "stats" => {
                let mut pairs =
                    vec![("stats", json::s(&router.metrics.summary()))];
                // Pack-cache + workspace health of the serving backend:
                // in steady state `pack_hits` grows while misses and
                // invalidations stay flat (invalidations move only when
                // parameters are hot-swapped by a training step).
                if let Some(h) = router.backend_hot_stats() {
                    pairs.push((
                        "hot_path",
                        json::obj(vec![
                            ("ws_hits", json::num(h.hits as f64)),
                            ("ws_allocs", json::num(h.allocs as f64)),
                            ("pack_hits", json::num(h.pack_hits as f64)),
                            ("pack_misses", json::num(h.pack_misses as f64)),
                            (
                                "pack_invalidations",
                                json::num(h.pack_invalidations as f64),
                            ),
                            (
                                "pack_uncached",
                                json::num(h.pack_uncached as f64),
                            ),
                            (
                                "pack_bytes_f32",
                                json::num(h.pack_bytes_f32 as f64),
                            ),
                            (
                                "pack_bytes_bf16",
                                json::num(h.pack_bytes_bf16 as f64),
                            ),
                            (
                                "pack_entries",
                                json::num(h.pack_entries as f64),
                            ),
                        ]),
                    ));
                }
                json::obj(pairs)
            }
            other => error_reply(&format!("unknown cmd '{other}'")),
        };
    }

    let image: Vec<f32> = match parsed.get("image").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .filter_map(Json::as_f64)
            .map(|v| v as f32)
            .collect(),
        None => return error_reply("missing 'image' array"),
    };
    if image.len() != image_dim {
        return error_reply(&format!(
            "image has {} values, model wants {image_dim}",
            image.len()
        ));
    }
    let overrides = match parse_overrides(&parsed) {
        Ok(ov) => ov,
        Err(msg) => return error_reply(&msg),
    };

    match router.infer_blocking_with(image, &overrides) {
        Ok(resp) => {
            let mut pairs = vec![
                ("class", json::num(resp.class as f64)),
                ("latency_ms", json::num(resp.latency.as_secs_f64() * 1e3)),
                ("batch", json::num(resp.batch_size as f64)),
                ("solver_iters", json::num(resp.solver_iters as f64)),
                ("solver_fevals", json::num(resp.solver_fevals as f64)),
                ("converged", Json::Bool(resp.converged)),
                // Echo the *effective* spec the solve ran under, so a
                // client can see what its overrides resolved to after
                // server-side clamping.
                ("solver", json::s(resp.spec.kind.name())),
                ("tol", f32_json(resp.spec.tol)),
                ("max_iter", json::num(resp.spec.max_iter as f64)),
                ("adaptive", Json::Bool(resp.spec.adaptive_window)),
                ("safeguard", Json::Bool(resp.spec.safeguard)),
                ("errorfactor", f32_json(resp.spec.errorfactor)),
                ("cond_max", f32_json(resp.spec.cond_max)),
                (
                    "gram",
                    match resp.spec.gram {
                        GramMode::Exact => json::s("exact"),
                        GramMode::Sketched { dim } => json::num(dim as f64),
                    },
                ),
            ];
            if let Some(id) = parsed.get("id") {
                pairs.push(("id", id.clone()));
            }
            json::obj(pairs)
        }
        Err(e) => error_reply(&format!("{e}")),
    }
}

/// Serve until the process is killed.  One thread per connection; the
/// router's batcher thread does the actual batching across connections.
pub fn serve_tcp(router: Arc<Router>, image_dim: usize, addr: &str) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    println!("[server] listening on {addr} (ndjson protocol)");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let router = router.clone();
                std::thread::spawn(move || handle_client(&router, image_dim, s));
            }
            Err(e) => eprintln!("[server] accept error: {e}"),
        }
    }
    Ok(())
}

//! TCP front-end: multiplexed newline-delimited JSON over a `std::net`
//! listener.
//!
//! Frame shapes, ids, streaming, and shedding semantics live in
//! [`super::protocol`]; this module is the socket plumbing.  Per
//! connection:
//!
//! ```text
//!   reader (this thread) ──parse──► Router::try_submit ──► shared queue
//!        │ per request                    │rejected
//!        │ spawns a waiter thread         ▼
//!        │ that recv()s the reply    overloaded / error frame
//!        ▼
//!   bounded channel (replies + progress frames, any order)
//!        ▼
//!   writer thread ──serialized NDJSON──► socket
//! ```
//!
//! * The reader never blocks on a solve: each admitted request hands its
//!   reply receiver to a small waiter thread, so many requests are in
//!   flight per connection and replies go out in completion order.
//! * The writer thread is the only socket writer; interleaved replies
//!   and progress frames from different requests cannot tear.
//! * Reader and writer are decoupled by a *bounded* channel: a client
//!   that stops reading backpressures its own connection only.
//!   Progress frames use a non-blocking send and are dropped when the
//!   channel is full; final replies use a blocking send and are
//!   reliable.
//! * A per-connection in-flight cap (`max_inflight`) sheds the excess
//!   with `{"error":"overloaded","retry_after_ms":…}` so one client
//!   cannot monopolize every lane of every replica.
//!
//! Legacy clients need no changes: requests without `"id"`/`"stream"`
//! get byte-identical replies to the old synchronous protocol, and the
//! exact JSON of error replies is pinned by golden tests in
//! `tests/integration_server.rs` via [`process_line`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::server::protocol::{self, Incoming, InferFrame};
use crate::server::{ProgressHook, Router, SubmitRejection};
use crate::util::json::{self, Json};

pub use crate::server::protocol::DEFAULT_MAX_INFLIGHT;

/// Depth of the per-connection writer channel (frames, not bytes).
/// Final replies block when it fills; progress frames are dropped.
const WRITER_QUEUE_FRAMES: usize = 256;

/// Handle one client connection: parse lines, admit requests, and fan
/// replies back through the single writer thread.  Returns when the
/// client disconnects and all of its in-flight replies have drained.
fn handle_client(
    router: &Arc<Router>,
    image_dim: usize,
    stream: TcpStream,
    max_inflight: usize,
) {
    let writer_stream = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let (out_tx, out_rx) = sync_channel::<String>(WRITER_QUEUE_FRAMES);
    let writer = std::thread::spawn(move || {
        let mut w = writer_stream;
        let mut broken = false;
        // Keep draining after a write error so blocked senders always
        // unblock; the loop ends when every sender clone has dropped.
        while let Ok(text) = out_rx.recv() {
            if broken {
                continue;
            }
            if w.write_all(text.as_bytes()).is_err()
                || w.write_all(b"\n").is_err()
            {
                broken = true;
            }
        }
    });

    let inflight = Arc::new(AtomicUsize::new(0));
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        handle_line(router, image_dim, &line, &out_tx, &inflight, max_inflight);
    }

    // Reader done: drop our sender so the writer exits once the last
    // in-flight waiter (and progress hook) has sent its frames.
    drop(out_tx);
    let _ = writer.join();
}

/// Parse one line and either answer immediately (commands, parse
/// errors, shed requests) or admit it and spawn a waiter thread that
/// forwards the reply when the solve retires.
fn handle_line(
    router: &Arc<Router>,
    image_dim: usize,
    line: &str,
    out: &SyncSender<String>,
    inflight: &Arc<AtomicUsize>,
    max_inflight: usize,
) {
    let send = |frame: &Json| {
        let _ = out.send(json::to_string(frame));
    };
    match protocol::parse_line(image_dim, line) {
        Incoming::Bad { msg, id } => {
            send(&protocol::error_frame(&msg, id.as_ref()));
        }
        Incoming::Cmd { cmd } => send(&run_cmd(router, &cmd)),
        Incoming::Infer(frame) => {
            let InferFrame { id, image, overrides, stream, deadline_ms } = frame;
            if inflight.load(Ordering::Acquire) >= max_inflight {
                router.metrics.shed.fetch_add(1, Ordering::Relaxed);
                send(&protocol::overloaded_frame(
                    router.retry_after_hint(),
                    id.as_ref(),
                ));
                return;
            }
            let progress: Option<ProgressHook> = if stream {
                let tx = out.clone();
                let pid = id.clone();
                Some(Box::new(move |iter, residual| {
                    let frame =
                        protocol::progress_frame(pid.as_ref(), iter, residual);
                    // Lossy on purpose: a slow client drops progress
                    // frames instead of stalling the scheduler's lane
                    // step for every other request.
                    let _ = tx.try_send(json::to_string(&frame));
                }))
            } else {
                None
            };
            let deadline =
                deadline_ms.map(std::time::Duration::from_millis);
            match router.try_submit(image, &overrides, progress, deadline) {
                Ok(rx) => {
                    inflight.fetch_add(1, Ordering::AcqRel);
                    let tx = out.clone();
                    let inflight = inflight.clone();
                    std::thread::spawn(move || {
                        let frame = match rx.recv() {
                            Ok(Ok(resp)) => {
                                protocol::response_frame(&resp, id.as_ref())
                            }
                            Ok(Err(fail)) => {
                                protocol::failure_frame(&fail, id.as_ref())
                            }
                            Err(_) => protocol::error_frame(
                                "router worker is not running (shut down or failed)",
                                id.as_ref(),
                            ),
                        };
                        let _ = tx.send(json::to_string(&frame));
                        inflight.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(SubmitRejection::Overloaded { retry_after_ms }) => {
                    send(&protocol::overloaded_frame(retry_after_ms, id.as_ref()));
                }
                Err(other) => {
                    send(&protocol::error_frame(&other.to_string(), id.as_ref()));
                }
            }
        }
    }
}

fn error_reply(msg: &str) -> Json {
    json::obj(vec![("error", json::s(msg))])
}

/// Execute a `{"cmd": ...}` line.  `stats` returns structured JSON
/// fields (counters, percentiles, per-replica gauges) plus the legacy
/// one-line `summary` blob.
fn run_cmd(router: &Router, cmd: &str) -> Json {
    match cmd {
        "ping" => json::obj(vec![("ok", Json::Bool(true))]),
        "stats" => {
            let mut pairs = router.metrics.stat_pairs();
            pairs.push(("queue_now", json::num(router.queue_depth() as f64)));
            // Nonzero only when a DEQ_FAULTS plan wraps the backend —
            // chaos runs assert their plan actually fired through this.
            pairs.push((
                "faults_injected",
                json::num(router.backend_faults_injected() as f64),
            ));
            // Per-bucket workload profiles the schedulers have learned:
            // the priors seeding each new auto-selection lane, surfaced
            // so switch decisions are explainable from the outside.
            // Empty until lanes retire; rate/penalty/speedup fields are
            // omitted until at least one observation exists.
            let profiles: Vec<Json> = router
                .profile_snapshot()
                .into_iter()
                .map(|(bucket, p)| {
                    let mut fields = vec![
                        ("bucket", json::num(bucket as f64)),
                        ("lanes", json::num(p.lanes as f64)),
                        ("switches", json::num(p.switches as f64)),
                        (
                            "auto_on_anderson",
                            json::num(p.auto_on_anderson as f64),
                        ),
                    ];
                    if let Some(v) = p.mean_iters() {
                        fields.push(("mean_iters", json::num(v as f64)));
                    }
                    if let Some(v) = p.mean_fevals() {
                        fields.push(("mean_fevals", json::num(v as f64)));
                    }
                    if let Some(r) = p.decay_rate() {
                        fields.push(("decay_rate", json::num(r as f64)));
                    }
                    if let Some(s) = p.anderson_speedup() {
                        fields.push(("anderson_speedup", json::num(s as f64)));
                    }
                    if let Some(m) = p.mixing_penalty() {
                        fields.push(("mixing_penalty", json::num(m as f64)));
                    }
                    json::obj(fields)
                })
                .collect();
            pairs.push(("workload_profiles", Json::Arr(profiles)));
            // Pack-cache + workspace health of the serving backend:
            // in steady state `pack_hits` grows while misses and
            // invalidations stay flat (invalidations move only when
            // parameters are hot-swapped by a training step).
            if let Some(h) = router.backend_hot_stats() {
                pairs.push((
                    "hot_path",
                    json::obj(vec![
                        ("ws_hits", json::num(h.hits as f64)),
                        ("ws_allocs", json::num(h.allocs as f64)),
                        ("pack_hits", json::num(h.pack_hits as f64)),
                        ("pack_misses", json::num(h.pack_misses as f64)),
                        (
                            "pack_invalidations",
                            json::num(h.pack_invalidations as f64),
                        ),
                        (
                            "pack_uncached",
                            json::num(h.pack_uncached as f64),
                        ),
                        (
                            "pack_bytes_f32",
                            json::num(h.pack_bytes_f32 as f64),
                        ),
                        (
                            "pack_bytes_bf16",
                            json::num(h.pack_bytes_bf16 as f64),
                        ),
                        (
                            "pack_entries",
                            json::num(h.pack_entries as f64),
                        ),
                    ]),
                ));
            }
            json::obj(pairs)
        }
        other => error_reply(&format!("unknown cmd '{other}'")),
    }
}

/// Parse and execute one protocol line, blocking until the reply is
/// ready.  This is the legacy synchronous entry point: error replies
/// never carry an `id` and their exact JSON is pinned by golden tests
/// (the multiplexed wire path in [`serve_tcp`] attaches ids and sheds
/// with structured `overloaded` frames instead).  Pure function →
/// unit-testable.
pub fn process_line(router: &Router, image_dim: usize, line: &str) -> Json {
    match protocol::parse_line(image_dim, line) {
        Incoming::Bad { msg, .. } => error_reply(&msg),
        Incoming::Cmd { cmd } => run_cmd(router, &cmd),
        Incoming::Infer(frame) => {
            match router.infer_blocking_with(frame.image, &frame.overrides) {
                Ok(resp) => protocol::response_frame(&resp, frame.id.as_ref()),
                Err(e) => error_reply(&format!("{e}")),
            }
        }
    }
}

/// Serve until the process is killed with the default per-connection
/// in-flight cap.
pub fn serve_tcp(router: Arc<Router>, image_dim: usize, addr: &str) -> Result<()> {
    serve_tcp_with(router, image_dim, addr, DEFAULT_MAX_INFLIGHT)
}

/// Serve until the process is killed.  One reader thread plus one
/// writer thread per connection; the router's replicas do the actual
/// batching across connections.
pub fn serve_tcp_with(
    router: Arc<Router>,
    image_dim: usize,
    addr: &str,
    max_inflight: usize,
) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    println!("[server] listening on {addr} (multiplexed ndjson protocol)");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let peer = s
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "?".into());
                println!("[server] client {peer} connected");
                let router = router.clone();
                std::thread::spawn(move || {
                    handle_client(&router, image_dim, s, max_inflight);
                    println!("[server] client {peer} disconnected");
                });
            }
            Err(e) => eprintln!("[server] accept error: {e}"),
        }
    }
    Ok(())
}

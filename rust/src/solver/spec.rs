//! Declarative solve configuration: [`SolveSpec`] + the pieces that
//! compose it.
//!
//! The paper's crossover argument (Fig. 1) is about *choosing a policy
//! per workload* — when to mix, how hard to damp, when to fall back.
//! `SolveSpec` makes that whole policy space plain data: a validated,
//! JSON-round-trippable description of one equilibrium solve that the
//! generic driver ([`crate::solver::driver`]) executes through a
//! [`crate::solver::SolvePolicy`].  Because it is data, it can ride a
//! serving request: the TCP protocol carries per-request overrides
//! ([`SolveOverrides`]) which the router resolves against its default
//! spec under operator-set bounds ([`SolveClamps`]).
//!
//! Construction paths:
//!  * [`SolveSpec::from_manifest`] — backend defaults for a kind;
//!  * [`SolveSpec::builder`] / [`SolveSpecBuilder`] — explicit builder
//!    with validation at `build()`;
//!  * [`SolveSpec::from_json`] — the wire/config form.

use anyhow::{anyhow, bail, Result};

use crate::runtime::Backend;
use crate::solver::SolverKind;
use crate::util::json::{self, Json};

/// Damping schedule for *forward* (non-mixed) updates: the plain-forward
/// solver, the hybrid policy's post-stagnation steps, and restart steps.
/// β = 1 takes f(z) directly; β < 1 takes z ← (1−β)·z + β·f(z), the
/// safeguarded step of Lupo Pasini et al. (*Stable Anderson Acceleration
/// for Deep Learning*).  Anderson-mixed updates are *not* damped here —
/// their β is compiled into the `anderson_update` kernel (see
/// `SolverMeta::beta`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Damping {
    /// Undamped (β = 1): forward steps take f(z) directly.  The default,
    /// and the only schedule the pre-`SolveSpec` drivers had.
    Full,
    /// Constant β ∈ (0, 1].
    Constant(f32),
    /// Geometric anneal β_k = to + (from − to)·decay^k over the lane's
    /// forward-step count k (heavier damping early, relaxing toward
    /// `to`; or the reverse when from < to).
    Anneal { from: f32, to: f32, decay: f32 },
}

impl Damping {
    /// β for a lane's k-th forward step.
    pub fn beta(&self, k: usize) -> f32 {
        match *self {
            Damping::Full => 1.0,
            Damping::Constant(b) => b,
            Damping::Anneal { from, to, decay } => {
                to + (from - to) * decay.powi(k as i32)
            }
        }
    }

    fn validate(&self) -> Result<()> {
        let check = |name: &str, b: f32| -> Result<()> {
            if b.is_nan() || b <= 0.0 || b > 1.0 {
                bail!("damping {name} must be in (0, 1], got {b}");
            }
            Ok(())
        };
        match *self {
            Damping::Full => Ok(()),
            Damping::Constant(b) => check("beta", b),
            Damping::Anneal { from, to, decay } => {
                check("from", from)?;
                check("to", to)?;
                check("decay", decay)
            }
        }
    }

    fn to_json(self) -> Json {
        match self {
            Damping::Full => json::obj(vec![("mode", json::s("full"))]),
            Damping::Constant(b) => json::obj(vec![
                ("beta", f32_json(b)),
                ("mode", json::s("constant")),
            ]),
            Damping::Anneal { from, to, decay } => json::obj(vec![
                ("decay", f32_json(decay)),
                ("from", f32_json(from)),
                ("mode", json::s("anneal")),
                ("to", f32_json(to)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        let mode = v
            .get("mode")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("damping missing 'mode'"))?;
        let f = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("damping missing '{key}'"))
        };
        match mode {
            "full" => Ok(Damping::Full),
            "constant" => Ok(Damping::Constant(f("beta")?)),
            "anneal" => Ok(Damping::Anneal {
                from: f("from")?,
                to: f("to")?,
                decay: f("decay")?,
            }),
            other => bail!("unknown damping mode '{other}'"),
        }
    }
}

/// When the hybrid policy drops a lane from Anderson mixing to plain
/// forward steps: the best residual in the trailing `window` iterations
/// improved on the window before it by less than `eps` (relative).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagnationRule {
    /// Trailing-window length in iterations; 0 means "use the spec's
    /// Anderson window" (the pre-redesign behaviour).
    pub window: usize,
    /// Minimum relative improvement per window before fallback.
    pub eps: f32,
}

impl Default for StagnationRule {
    fn default() -> Self {
        Self { window: 0, eps: 0.03 }
    }
}

impl StagnationRule {
    /// The concrete window to watch, given the spec's Anderson window.
    pub fn effective_window(&self, spec_window: usize) -> usize {
        if self.window == 0 {
            spec_window
        } else {
            self.window
        }
    }

    fn validate(&self) -> Result<()> {
        if self.eps.is_nan() || self.eps <= 0.0 || self.eps >= 1.0 {
            bail!("stagnation eps must be in (0, 1), got {}", self.eps);
        }
        Ok(())
    }
}

/// How the adaptive window builds the regularized ΔF Gram system it
/// probes for conditioning (and truncates against `cond_max`).
///
/// `Exact` computes every Gram entry from full D-length residual rows —
/// O(window²·D) per adapt, and the bit-exact default.  `Sketched` draws
/// `dim` random coordinates (with replacement, scaled to keep the Gram
/// an unbiased estimate of GᵀG — `native::stochastic::sketch_coords`)
/// and builds the probe from those, cutting the adapt cost to
/// O(window²·dim): the randomized-sketching route Saad catalogs for
/// keeping wide-window mixing cheap relative to the map evaluation.
/// The sketch only steers *window truncation*; mixing weights are still
/// solved from the exact history, so solves land on the same fixed
/// point within tol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GramMode {
    /// Full-length Gram rows (the default; pre-sketch behaviour).
    Exact,
    /// Coordinate-sketched Gram rows of dimension `dim` (≥ 1; sketches
    /// wider than the state dimension degrade gracefully to exact).
    Sketched { dim: usize },
}

impl GramMode {
    /// The sketch dimension as a plain count (0 = exact) — the CLI form.
    pub fn sketch_dim(&self) -> usize {
        match *self {
            GramMode::Exact => 0,
            GramMode::Sketched { dim } => dim,
        }
    }

    /// Canonical mode from a plain count (0 = exact).
    pub fn from_sketch_dim(dim: usize) -> Self {
        if dim == 0 {
            GramMode::Exact
        } else {
            GramMode::Sketched { dim }
        }
    }

    fn validate(&self) -> Result<()> {
        if let GramMode::Sketched { dim } = *self {
            if dim == 0 {
                bail!(
                    "solver gram sketch dimension must be >= 1 \
                     (use \"exact\" for exact Gram builds)"
                );
            }
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        match self {
            GramMode::Exact => json::s("exact"),
            GramMode::Sketched { dim } => json::num(dim as f64),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        if let Some(s) = v.as_str() {
            if s == "exact" {
                return Ok(GramMode::Exact);
            }
            bail!("SolveSpec 'gram' must be \"exact\" or a positive integer, got \"{s}\"");
        }
        match v.as_f64() {
            Some(n) if n >= 1.0 && n.fract() == 0.0 => {
                Ok(GramMode::Sketched { dim: n as usize })
            }
            _ => bail!("SolveSpec 'gram' must be \"exact\" or a positive integer"),
        }
    }
}

/// Declarative description of one equilibrium solve.
///
/// Field-for-field superset of the old flat `SolveOptions`, so struct
/// update syntax migrates call sites directly:
///
/// ```ignore
/// let spec = SolveSpec {
///     tol: 1e-4,
///     max_iter: 80,
///     ..SolveSpec::from_manifest(engine, SolverKind::Anderson)
/// };
/// ```
///
/// Prefer the builder when constructing from scratch — it validates:
///
/// ```ignore
/// let spec = SolveSpec::builder(SolverKind::Hybrid)
///     .window(5)
///     .tol(1e-3)
///     .max_iter(60)
///     .stagnation(StagnationRule { window: 0, eps: 0.05 })
///     .build()?;
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct SolveSpec {
    /// Which policy drives the solve (forward / anderson / hybrid).
    pub kind: SolverKind,
    /// Anderson window m (ring-buffer length).  Must be ≥ 1 and, at
    /// solve time, ≤ the backend's compiled window.
    pub window: usize,
    /// Relative-residual convergence tolerance (per sample).
    pub tol: f32,
    /// Iteration/evaluation budget: forward solves count cell
    /// evaluations against it (a fused K-step dispatch costs K), the
    /// Anderson-family policies one per iteration.
    pub max_iter: usize,
    /// Hard cell-evaluation budget on top of `max_iter`; 0 = no extra
    /// budget.  Lets a serving operator bound worst-case lane cost
    /// independently of the iteration cap.
    pub max_fevals: usize,
    /// Residual regularizer λ in ‖f−z‖/(‖f‖+λ).
    pub lam: f32,
    /// Use the fused K-step entry for forward solves when compiled.
    /// Ignored when a damping schedule is armed — the fused kernel runs
    /// its K internal steps undamped, so damped solves dispatch per
    /// step.
    pub fused_forward: bool,
    /// Damping schedule for forward (non-mixed) updates.
    pub damping: Damping,
    /// Stagnation rule consulted by the hybrid policy.
    pub stagnation: StagnationRule,
    /// Restart a lane's Anderson window when its residual *rises* on a
    /// mixed step (windowed-restart safeguarding; Saad, *Acceleration
    /// methods for fixed point iterations*, catalogs the family).
    pub restart_on_breakdown: bool,
    /// Condition-monitored adaptive window (DFTK-style): before each mix
    /// the window drops history iterates whose residual norm exceeds
    /// `errorfactor × min_i ‖f(x_i) − x_i‖` and truncates further while
    /// the regularized Gram system's condition estimate exceeds
    /// `cond_max` (largest-residual iterates go first; the newest iterate
    /// is never dropped).  Off by default — the fixed-window policies and
    /// their bit-exact traces are untouched.
    pub adaptive_window: bool,
    /// Residual-spread bound for the adaptive window (must be > 1;
    /// consulted only when `adaptive_window` is set).  CDLS21 suggests
    /// 1e4 as a robust default.
    pub errorfactor: f32,
    /// Condition-estimate ceiling for the adaptive window (must be ≥ 1;
    /// consulted only when `adaptive_window` is set).
    pub cond_max: f32,
    /// Safeguarded mixing (Lupo Pasini et al., *Stable Anderson
    /// Acceleration for Deep Learning*): when a mixed step fails to
    /// reduce the residual, take the plain damped step from the newest
    /// iterate instead of mixing again, then resume.  Unlike
    /// `restart_on_breakdown` the history window is kept.  When both are
    /// armed the safeguard wins (it is the gentler recovery).
    pub safeguard: bool,
    /// How the adaptive window builds its Gram condition probe (exact or
    /// coordinate-sketched).  Consulted only when `adaptive_window` is
    /// set; the fixed-window policies never build the probe at all.
    pub gram: GramMode,
}

/// Default residual-spread bound for the adaptive window (CDLS21's
/// robust choice; DFTK ships 1e5 for SCF mixing).
pub const DEFAULT_ERRORFACTOR: f32 = 1e4;
/// Default condition-estimate ceiling for the adaptive window (DFTK's
/// default for the Anderson system).
pub const DEFAULT_COND_MAX: f32 = 1e6;

impl SolveSpec {
    /// Backend defaults for a solver kind (the manifest's SolverMeta).
    pub fn from_manifest(engine: &dyn Backend, kind: SolverKind) -> Self {
        let s = &engine.manifest().solver;
        Self {
            kind,
            window: s.window,
            tol: s.tol,
            max_iter: s.max_iter,
            max_fevals: 0,
            lam: s.lam,
            fused_forward: true,
            damping: Damping::Full,
            stagnation: StagnationRule::default(),
            restart_on_breakdown: false,
            adaptive_window: false,
            errorfactor: DEFAULT_ERRORFACTOR,
            cond_max: DEFAULT_COND_MAX,
            safeguard: false,
            gram: GramMode::Exact,
        }
    }

    /// Library defaults for a kind, for use without a backend at hand.
    pub fn new(kind: SolverKind) -> Self {
        Self {
            kind,
            window: 5,
            tol: 1e-3,
            max_iter: 100,
            max_fevals: 0,
            lam: 1e-5,
            fused_forward: true,
            damping: Damping::Full,
            stagnation: StagnationRule::default(),
            restart_on_breakdown: false,
            adaptive_window: false,
            errorfactor: DEFAULT_ERRORFACTOR,
            cond_max: DEFAULT_COND_MAX,
            safeguard: false,
            gram: GramMode::Exact,
        }
    }

    /// Start a builder from the library defaults for `kind`.
    pub fn builder(kind: SolverKind) -> SolveSpecBuilder {
        SolveSpecBuilder { spec: Self::new(kind) }
    }

    /// Turn this spec back into a builder (tweak-and-revalidate).
    pub fn to_builder(&self) -> SolveSpecBuilder {
        SolveSpecBuilder { spec: self.clone() }
    }

    /// Reject degenerate configurations with a descriptive error instead
    /// of letting them panic downstream (window 0 used to index past a
    /// ring of size 0; tol ≤ 0 made every solve run to `max_iter`;
    /// max_iter 0 returned an empty report with a NaN residual).
    pub fn validate(&self) -> Result<()> {
        if self.window == 0 {
            bail!("solver window must be >= 1 (a 0-length Anderson ring cannot hold history)");
        }
        if !self.tol.is_finite() || self.tol <= 0.0 {
            bail!("solver tol must be a positive finite number, got {}", self.tol);
        }
        if self.max_iter == 0 {
            bail!("solver max_iter must be >= 1 (a 0-iteration solve reports a NaN residual)");
        }
        if !self.lam.is_finite() || self.lam < 0.0 {
            bail!("solver lam must be finite and >= 0, got {}", self.lam);
        }
        self.damping.validate()?;
        self.stagnation.validate()?;
        if !self.errorfactor.is_finite() || self.errorfactor <= 1.0 {
            bail!(
                "solver errorfactor must be a finite number > 1 \
                 (a bound ≤ 1 would drop the minimum-residual iterate itself), got {}",
                self.errorfactor
            );
        }
        if !self.cond_max.is_finite() || self.cond_max < 1.0 {
            bail!(
                "solver cond_max must be a finite number >= 1 \
                 (an SPD system's condition number is never below 1), got {}",
                self.cond_max
            );
        }
        self.gram.validate()?;
        Ok(())
    }

    /// JSON object form (keys sorted by the serializer).  Floats render
    /// in the shortest decimal form that round-trips the f32 exactly.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("adaptive_window", Json::Bool(self.adaptive_window)),
            ("cond_max", f32_json(self.cond_max)),
            ("damping", self.damping.to_json()),
            ("errorfactor", f32_json(self.errorfactor)),
            ("safeguard", Json::Bool(self.safeguard)),
            ("fused_forward", Json::Bool(self.fused_forward)),
            ("gram", self.gram.to_json()),
            ("kind", json::s(self.kind.name())),
            ("lam", f32_json(self.lam)),
            ("max_fevals", json::num(self.max_fevals as f64)),
            ("max_iter", json::num(self.max_iter as f64)),
            (
                "restart_on_breakdown",
                Json::Bool(self.restart_on_breakdown),
            ),
            (
                "stagnation",
                json::obj(vec![
                    ("eps", f32_json(self.stagnation.eps)),
                    ("window", json::num(self.stagnation.window as f64)),
                ]),
            ),
            ("tol", f32_json(self.tol)),
            ("window", json::num(self.window as f64)),
        ])
    }

    /// Parse and validate the JSON form.
    ///
    /// The adaptivity fields (`adaptive_window`, `errorfactor`,
    /// `cond_max`, `safeguard`, `gram`) are *optional* and default to
    /// the fixed-policy values when absent, so specs serialized before
    /// the adaptive policies (or the Gram sketch) existed keep parsing
    /// unchanged.
    pub fn from_json(v: &Json) -> Result<Self> {
        let kind_name = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("SolveSpec missing 'kind'"))?;
        let kind = SolverKind::parse(kind_name)
            .ok_or_else(|| anyhow!("unknown solver kind '{kind_name}'"))?;
        let num_f32 = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("SolveSpec missing '{key}'"))
        };
        let num_usize = |key: &str| {
            v.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("SolveSpec missing '{key}'"))
        };
        let flag = |key: &str| {
            v.get(key)
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("SolveSpec missing '{key}'"))
        };
        let stag = v
            .get("stagnation")
            .ok_or_else(|| anyhow!("SolveSpec missing 'stagnation'"))?;
        let spec = Self {
            kind,
            window: num_usize("window")?,
            tol: num_f32("tol")?,
            max_iter: num_usize("max_iter")?,
            max_fevals: num_usize("max_fevals")?,
            lam: num_f32("lam")?,
            fused_forward: flag("fused_forward")?,
            damping: Damping::from_json(
                v.get("damping")
                    .ok_or_else(|| anyhow!("SolveSpec missing 'damping'"))?,
            )?,
            stagnation: StagnationRule {
                window: stag
                    .get("window")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("stagnation missing 'window'"))?,
                eps: stag
                    .get("eps")
                    .and_then(Json::as_f64)
                    .map(|x| x as f32)
                    .ok_or_else(|| anyhow!("stagnation missing 'eps'"))?,
            },
            restart_on_breakdown: flag("restart_on_breakdown")?,
            adaptive_window: v
                .get("adaptive_window")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            errorfactor: v
                .get("errorfactor")
                .and_then(Json::as_f64)
                .map(|x| x as f32)
                .unwrap_or(DEFAULT_ERRORFACTOR),
            cond_max: v
                .get("cond_max")
                .and_then(Json::as_f64)
                .map(|x| x as f32)
                .unwrap_or(DEFAULT_COND_MAX),
            safeguard: v
                .get("safeguard")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            // Absent on pre-sketch specs: default to exact Gram builds.
            gram: v
                .get("gram")
                .map(GramMode::from_json)
                .transpose()?
                .unwrap_or(GramMode::Exact),
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Builder for [`SolveSpec`]: chainable setters, validation at `build()`.
#[derive(Debug, Clone)]
pub struct SolveSpecBuilder {
    spec: SolveSpec,
}

impl SolveSpecBuilder {
    pub fn kind(mut self, kind: SolverKind) -> Self {
        self.spec.kind = kind;
        self
    }

    pub fn window(mut self, m: usize) -> Self {
        self.spec.window = m;
        self
    }

    pub fn tol(mut self, tol: f32) -> Self {
        self.spec.tol = tol;
        self
    }

    pub fn max_iter(mut self, n: usize) -> Self {
        self.spec.max_iter = n;
        self
    }

    pub fn max_fevals(mut self, n: usize) -> Self {
        self.spec.max_fevals = n;
        self
    }

    pub fn lam(mut self, lam: f32) -> Self {
        self.spec.lam = lam;
        self
    }

    pub fn fused_forward(mut self, on: bool) -> Self {
        self.spec.fused_forward = on;
        self
    }

    pub fn damping(mut self, d: Damping) -> Self {
        self.spec.damping = d;
        self
    }

    pub fn stagnation(mut self, rule: StagnationRule) -> Self {
        self.spec.stagnation = rule;
        self
    }

    pub fn restart_on_breakdown(mut self, on: bool) -> Self {
        self.spec.restart_on_breakdown = on;
        self
    }

    pub fn adaptive_window(mut self, on: bool) -> Self {
        self.spec.adaptive_window = on;
        self
    }

    pub fn errorfactor(mut self, f: f32) -> Self {
        self.spec.errorfactor = f;
        self
    }

    pub fn cond_max(mut self, c: f32) -> Self {
        self.spec.cond_max = c;
        self
    }

    pub fn safeguard(mut self, on: bool) -> Self {
        self.spec.safeguard = on;
        self
    }

    pub fn gram(mut self, g: GramMode) -> Self {
        self.spec.gram = g;
        self
    }

    /// Validate and produce the spec.
    pub fn build(self) -> Result<SolveSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

/// Per-request solver overrides, resolved against a server's default
/// spec under [`SolveClamps`].  `None` fields inherit the default.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SolveOverrides {
    pub kind: Option<SolverKind>,
    pub tol: Option<f32>,
    pub max_iter: Option<usize>,
    /// Arm (or disarm) the condition-monitored adaptive window.  The
    /// adaptivity knobs are validated but not clamped: shrinking a
    /// window only *reduces* a lane's per-iteration cost, so they are
    /// not a resource-pinning vector the way `tol`/`max_iter` are.
    pub adaptive_window: Option<bool>,
    pub errorfactor: Option<f32>,
    pub cond_max: Option<f32>,
    /// Arm (or disarm) the safeguarded mixed step.
    pub safeguard: Option<bool>,
    /// Switch the adaptive window's Gram build (exact or sketched).
    /// Like the other adaptivity knobs: validated, not clamped —
    /// sketching only *cheapens* the adapt probe.
    pub gram: Option<GramMode>,
}

impl SolveOverrides {
    pub fn is_empty(&self) -> bool {
        self.kind.is_none()
            && self.tol.is_none()
            && self.max_iter.is_none()
            && self.adaptive_window.is_none()
            && self.errorfactor.is_none()
            && self.cond_max.is_none()
            && self.safeguard.is_none()
            && self.gram.is_none()
    }

    /// Resolve against `base` under `clamps`: overrides are validated
    /// (so a malformed request errors at the door, not mid-batch), then
    /// clamped into the operator's bounds — a client may *loosen* a
    /// solve freely but can only tighten it down to `clamps.min_tol` /
    /// up to `clamps.max_iter`, so one request cannot pin a lane.
    pub fn apply(
        &self,
        base: &SolveSpec,
        clamps: &SolveClamps,
    ) -> Result<SolveSpec> {
        let mut spec = base.clone();
        if let Some(kind) = self.kind {
            spec.kind = kind;
        }
        if let Some(tol) = self.tol {
            if !tol.is_finite() || tol < 0.0 {
                bail!("override tol must be a positive finite number, got {tol}");
            }
            // tol == 0 (including the f32 underflow of a tiny positive
            // request) reads as "as tight as you allow": it clamps to
            // the operator floor like any other too-tight request,
            // rather than bouncing as malformed.
            spec.tol = tol.max(clamps.min_tol);
        }
        if let Some(max_iter) = self.max_iter {
            if max_iter == 0 {
                bail!("override max_iter must be >= 1");
            }
            spec.max_iter = max_iter.min(clamps.max_iter);
        }
        if let Some(on) = self.adaptive_window {
            spec.adaptive_window = on;
        }
        if let Some(f) = self.errorfactor {
            if !f.is_finite() || f <= 1.0 {
                bail!("override errorfactor must be a finite number > 1, got {f}");
            }
            spec.errorfactor = f;
        }
        if let Some(c) = self.cond_max {
            if !c.is_finite() || c < 1.0 {
                bail!("override cond_max must be a finite number >= 1, got {c}");
            }
            spec.cond_max = c;
        }
        if let Some(on) = self.safeguard {
            spec.safeguard = on;
        }
        if let Some(g) = self.gram {
            g.validate()
                .map_err(|_| anyhow!("override gram sketch dimension must be >= 1"))?;
            spec.gram = g;
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// Server-side bounds on per-request overrides: the operator's guardrail
/// against a client requesting an unbounded solve (tol → 0 or a huge
/// iteration cap would pin a scheduler lane for everyone else).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolveClamps {
    /// Tightest tolerance a request may ask for (override tols below
    /// this are raised to it).
    pub min_tol: f32,
    /// Largest per-request iteration cap (override caps above this are
    /// lowered to it).
    pub max_iter: usize,
}

impl Default for SolveClamps {
    fn default() -> Self {
        Self { min_tol: 1e-6, max_iter: 500 }
    }
}

impl SolveClamps {
    /// Reject degenerate clamp settings with a descriptive error: a
    /// non-positive or non-finite floor would silently disable the tol
    /// clamp (NaN never wins an `f32::max`), and a zero iteration cap
    /// would clamp every override into an invalid spec.
    pub fn validate(&self) -> Result<()> {
        if !self.min_tol.is_finite() || self.min_tol <= 0.0 {
            bail!(
                "clamps min_tol must be a positive finite number, got {}",
                self.min_tol
            );
        }
        if self.max_iter == 0 {
            bail!("clamps max_iter must be >= 1");
        }
        Ok(())
    }
}

/// JSON number carrying an f32 exactly: the shortest decimal that
/// round-trips the f32 (Rust's `{}` for f32) re-parsed as f64, so
/// serialized specs read `0.01`, not `0.009999999776482582`.
pub(crate) fn f32_json(v: f32) -> Json {
    let text = format!("{v}");
    Json::Num(text.parse::<f64>().unwrap_or(v as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> SolveSpec {
        SolveSpec::new(SolverKind::Anderson)
    }

    #[test]
    fn defaults_validate() {
        for kind in SolverKind::ALL {
            SolveSpec::new(kind).validate().unwrap();
        }
    }

    #[test]
    fn auto_spec_roundtrips_through_json() {
        let spec = SolveSpec::new(SolverKind::Auto);
        let back = SolveSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.kind, SolverKind::Auto);
        back.validate().unwrap();
    }

    #[test]
    fn validate_rejects_zero_window() {
        let spec = SolveSpec { window: 0, ..base() };
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("window must be >= 1"), "{err}");
    }

    #[test]
    fn validate_rejects_nonpositive_tol() {
        for tol in [0.0f32, -1e-3, f32::NAN, f32::INFINITY] {
            let spec = SolveSpec { tol, ..base() };
            let err = spec.validate().unwrap_err().to_string();
            assert!(err.contains("tol must be"), "tol={tol}: {err}");
        }
    }

    #[test]
    fn validate_rejects_zero_max_iter() {
        let spec = SolveSpec { max_iter: 0, ..base() };
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("max_iter must be >= 1"), "{err}");
    }

    #[test]
    fn validate_rejects_negative_lam() {
        for lam in [-1e-6f32, f32::NAN] {
            let spec = SolveSpec { lam, ..base() };
            let err = spec.validate().unwrap_err().to_string();
            assert!(err.contains("lam must be"), "lam={lam}: {err}");
        }
    }

    #[test]
    fn validate_rejects_bad_damping_and_stagnation() {
        for d in [
            Damping::Constant(0.0),
            Damping::Constant(1.5),
            Damping::Anneal { from: 0.0, to: 0.5, decay: 0.9 },
            Damping::Anneal { from: 1.0, to: 0.5, decay: 0.0 },
        ] {
            assert!(
                SolveSpec { damping: d, ..base() }.validate().is_err(),
                "{d:?} accepted"
            );
        }
        let bad_stag = SolveSpec {
            stagnation: StagnationRule { window: 0, eps: 0.0 },
            ..base()
        };
        assert!(bad_stag.validate().is_err());
    }

    #[test]
    fn builder_builds_and_rejects() {
        let spec = SolveSpec::builder(SolverKind::Hybrid)
            .window(3)
            .tol(1e-3)
            .max_iter(50)
            .max_fevals(200)
            .lam(1e-6)
            .fused_forward(false)
            .damping(Damping::Constant(0.5))
            .stagnation(StagnationRule { window: 4, eps: 0.1 })
            .restart_on_breakdown(true)
            .build()
            .unwrap();
        assert_eq!(spec.kind, SolverKind::Hybrid);
        assert_eq!(spec.window, 3);
        assert_eq!(spec.stagnation.effective_window(spec.window), 4);
        assert!(SolveSpec::builder(SolverKind::Forward).tol(-1.0).build().is_err());
        // to_builder round-trips.
        let again = spec.to_builder().build().unwrap();
        assert_eq!(again, spec);
    }

    #[test]
    fn damping_schedules() {
        assert_eq!(Damping::Full.beta(7), 1.0);
        assert_eq!(Damping::Constant(0.5).beta(3), 0.5);
        let a = Damping::Anneal { from: 0.5, to: 1.0, decay: 0.5 };
        assert!((a.beta(0) - 0.5).abs() < 1e-6);
        assert!((a.beta(1) - 0.75).abs() < 1e-6);
        assert!(a.beta(20) > 0.99);
    }

    #[test]
    fn stagnation_window_resolution() {
        assert_eq!(StagnationRule { window: 0, eps: 0.03 }.effective_window(5), 5);
        assert_eq!(StagnationRule { window: 7, eps: 0.03 }.effective_window(5), 7);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let spec = SolveSpec {
            kind: SolverKind::Hybrid,
            window: 4,
            tol: 1e-3,
            max_iter: 60,
            max_fevals: 120,
            lam: 1e-5,
            fused_forward: false,
            damping: Damping::Anneal { from: 0.5, to: 1.0, decay: 0.75 },
            stagnation: StagnationRule { window: 3, eps: 0.05 },
            restart_on_breakdown: true,
            adaptive_window: true,
            errorfactor: 1e3,
            cond_max: 1e8,
            safeguard: true,
            gram: GramMode::Sketched { dim: 48 },
        };
        let text = json::to_string(&spec.to_json());
        let back = SolveSpec::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
        // Serialize → parse → serialize is byte-stable.
        assert_eq!(json::to_string(&back.to_json()), text);
    }

    #[test]
    fn json_form_is_readable() {
        // The shortest-roundtrip float rendering keeps the wire form
        // human-readable (no f32→f64 noise).
        let text = json::to_string(&base().to_json());
        assert!(text.contains("\"tol\":0.001"), "{text}");
        assert!(text.contains("\"kind\":\"anderson\""), "{text}");
        assert!(!text.contains("00000001"), "f32 noise leaked: {text}");
    }

    #[test]
    fn validate_rejects_bad_adaptivity_knobs() {
        for ef in [1.0f32, 0.5, -3.0, f32::NAN, f32::INFINITY] {
            let spec = SolveSpec { errorfactor: ef, ..base() };
            let err = spec.validate().unwrap_err().to_string();
            assert!(err.contains("errorfactor"), "ef={ef}: {err}");
        }
        for cm in [0.5f32, -1.0, f32::NAN, f32::INFINITY] {
            let spec = SolveSpec { cond_max: cm, ..base() };
            let err = spec.validate().unwrap_err().to_string();
            assert!(err.contains("cond_max"), "cm={cm}: {err}");
        }
        // The bounds themselves apply whether or not adaptivity is
        // armed — a spec is either valid data or not.
        let armed = SolveSpec { adaptive_window: true, ..base() };
        armed.validate().unwrap();
    }

    #[test]
    fn builder_sets_adaptivity_knobs() {
        let spec = SolveSpec::builder(SolverKind::Anderson)
            .adaptive_window(true)
            .errorfactor(500.0)
            .cond_max(1e7)
            .safeguard(true)
            .build()
            .unwrap();
        assert!(spec.adaptive_window);
        assert_eq!(spec.errorfactor, 500.0);
        assert_eq!(spec.cond_max, 1e7);
        assert!(spec.safeguard);
        assert!(SolveSpec::builder(SolverKind::Anderson)
            .errorfactor(1.0)
            .build()
            .is_err());
        assert!(SolveSpec::builder(SolverKind::Anderson)
            .cond_max(0.0)
            .build()
            .is_err());
    }

    #[test]
    fn overrides_apply_adaptivity_knobs() {
        let base = base();
        let clamps = SolveClamps::default();
        let ov = SolveOverrides {
            adaptive_window: Some(true),
            errorfactor: Some(250.0),
            cond_max: Some(1e5),
            safeguard: Some(true),
            ..Default::default()
        };
        assert!(!ov.is_empty());
        let spec = ov.apply(&base, &clamps).unwrap();
        assert!(spec.adaptive_window);
        assert_eq!(spec.errorfactor, 250.0);
        assert_eq!(spec.cond_max, 1e5);
        assert!(spec.safeguard);
        // Value errors bounce at the door with descriptive messages.
        let bad = SolveOverrides { errorfactor: Some(1.0), ..Default::default() };
        assert!(bad
            .apply(&base, &clamps)
            .unwrap_err()
            .to_string()
            .contains("override errorfactor"));
        let bad = SolveOverrides { cond_max: Some(0.5), ..Default::default() };
        assert!(bad
            .apply(&base, &clamps)
            .unwrap_err()
            .to_string()
            .contains("override cond_max"));
    }

    #[test]
    fn gram_mode_json_and_dim_helpers() {
        assert_eq!(GramMode::Exact.sketch_dim(), 0);
        assert_eq!(GramMode::Sketched { dim: 32 }.sketch_dim(), 32);
        assert_eq!(GramMode::from_sketch_dim(0), GramMode::Exact);
        assert_eq!(GramMode::from_sketch_dim(9), GramMode::Sketched { dim: 9 });
        // Malformed wire forms bounce with descriptive errors.
        for bad in ["\"fast\"", "0", "-4", "2.5", "true"] {
            let v = json::parse(bad).unwrap();
            let err = GramMode::from_json(&v).unwrap_err().to_string();
            assert!(err.contains("'gram'"), "{bad}: {err}");
        }
        // Sketched{0} can only arise from struct literals; validate
        // rejects it wherever it lands.
        let spec = SolveSpec { gram: GramMode::Sketched { dim: 0 }, ..base() };
        assert!(spec.validate().unwrap_err().to_string().contains("gram"));
        let ov = SolveOverrides {
            gram: Some(GramMode::Sketched { dim: 0 }),
            ..Default::default()
        };
        assert!(!ov.is_empty());
        let err = ov
            .apply(&base(), &SolveClamps::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("override gram"), "{err}");
        // And a well-formed override lands on the spec.
        let ov = SolveOverrides {
            gram: Some(GramMode::Sketched { dim: 16 }),
            ..Default::default()
        };
        let spec = ov.apply(&base(), &SolveClamps::default()).unwrap();
        assert_eq!(spec.gram, GramMode::Sketched { dim: 16 });
    }

    #[test]
    fn pre_sketch_json_parses_to_exact_gram_and_round_trips_byte_stable() {
        // Golden: a default Anderson spec exactly as PR 5/6 serialized it
        // — no "gram" key existed on the wire.
        let old = concat!(
            "{\"adaptive_window\":false,\"cond_max\":1000000,",
            "\"damping\":{\"mode\":\"full\"},\"errorfactor\":10000,",
            "\"fused_forward\":true,\"kind\":\"anderson\",\"lam\":0.00001,",
            "\"max_fevals\":0,\"max_iter\":100,",
            "\"restart_on_breakdown\":false,\"safeguard\":false,",
            "\"stagnation\":{\"eps\":0.03,\"window\":0},",
            "\"tol\":0.001,\"window\":5}",
        );
        let spec = SolveSpec::from_json(&json::parse(old).unwrap()).unwrap();
        assert_eq!(spec, base(), "pre-sketch golden must parse to the defaults");
        assert_eq!(spec.gram, GramMode::Exact, "missing 'gram' must mean exact");
        // Re-serializing inserts only the new key, in sorted position…
        let new_text = json::to_string(&spec.to_json());
        assert_eq!(
            new_text,
            old.replace(
                "\"fused_forward\":true",
                "\"fused_forward\":true,\"gram\":\"exact\""
            ),
        );
        // …and the new form round-trips byte-stable.
        let back = SolveSpec::from_json(&json::parse(&new_text).unwrap()).unwrap();
        assert_eq!(json::to_string(&back.to_json()), new_text);
        // Sketched mode rides the wire as a bare integer.
        let sk = SolveSpec { gram: GramMode::Sketched { dim: 32 }, ..base() };
        let sk_text = json::to_string(&sk.to_json());
        assert!(sk_text.contains("\"gram\":32"), "{sk_text}");
        let sk_back = SolveSpec::from_json(&json::parse(&sk_text).unwrap()).unwrap();
        assert_eq!(sk_back, sk);
    }

    #[test]
    fn json_rejects_degenerate_spec() {
        let mut v = base().to_json();
        if let Json::Obj(map) = &mut v {
            map.insert("window".into(), Json::Num(0.0));
        }
        assert!(SolveSpec::from_json(&v).is_err());
    }

    #[test]
    fn clamps_validate_rejects_degenerate_bounds() {
        SolveClamps::default().validate().unwrap();
        for min_tol in [0.0f32, -1.0, f32::NAN, f32::INFINITY] {
            let c = SolveClamps { min_tol, ..SolveClamps::default() };
            assert!(c.validate().is_err(), "min_tol {min_tol} accepted");
        }
        let c = SolveClamps { max_iter: 0, ..SolveClamps::default() };
        assert!(c.validate().unwrap_err().to_string().contains("max_iter"));
    }

    #[test]
    fn overrides_apply_and_clamp() {
        let base = base();
        let clamps = SolveClamps { min_tol: 1e-5, max_iter: 100 };
        // Empty overrides: identity.
        let same = SolveOverrides::default().apply(&base, &clamps).unwrap();
        assert_eq!(same, base);
        // In-range overrides pass through.
        let ov = SolveOverrides {
            kind: Some(SolverKind::Forward),
            tol: Some(0.5),
            max_iter: Some(7),
            ..Default::default()
        };
        let spec = ov.apply(&base, &clamps).unwrap();
        assert_eq!(spec.kind, SolverKind::Forward);
        assert_eq!(spec.tol, 0.5);
        assert_eq!(spec.max_iter, 7);
        // Out-of-bounds requests are clamped, not rejected.
        let greedy = SolveOverrides {
            kind: None,
            tol: Some(1e-12),
            max_iter: Some(1_000_000),
            ..Default::default()
        };
        let spec = greedy.apply(&base, &clamps).unwrap();
        assert_eq!(spec.tol, 1e-5);
        assert_eq!(spec.max_iter, 100);
        // tol 0 — e.g. the f32 underflow of a tiny positive request —
        // clamps to the floor instead of bouncing as malformed.
        let underflow = SolveOverrides { tol: Some(0.0), ..Default::default() };
        assert_eq!(underflow.apply(&base, &clamps).unwrap().tol, 1e-5);
        // Nonsense values are rejected with descriptive errors.
        let bad_tol = SolveOverrides { tol: Some(-1.0), ..Default::default() };
        assert!(bad_tol
            .apply(&base, &clamps)
            .unwrap_err()
            .to_string()
            .contains("override tol"));
        let bad_iter =
            SolveOverrides { max_iter: Some(0), ..Default::default() };
        assert!(bad_iter
            .apply(&base, &clamps)
            .unwrap_err()
            .to_string()
            .contains("override max_iter"));
    }
}

//! Forward-iteration solver (the paper's baseline): z ← f(z, x).
//!
//! Two dispatch modes:
//!  * per-step: one `cell_step` artifact call per iteration — full residual
//!    trace resolution (used by the residual-vs-time experiments);
//!  * fused: `forward_solve_k` runs K cell applications inside one HLO
//!    while-loop, amortizing PJRT dispatch (the L2 perf-pass artifact);
//!    residuals are then sampled every K evaluations.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Backend, HostTensor};
use crate::solver::{max_rel_residual, SolveOptions, SolveReport, SolveStep, SolverKind};

/// Solve to tolerance with plain forward iteration.
pub fn solve(
    engine: &dyn Backend,
    params: &[HostTensor],
    x_feat: &HostTensor,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let batch = x_feat.shape[0];
    let fused_k = engine.manifest().solver.fused_steps.max(1);
    let use_fused = opts.fused_forward
        && fused_k > 1
        && engine.manifest().entry("forward_solve_k", batch).is_ok();

    let mut z = HostTensor::zeros(x_feat.shape.clone());
    let mut steps: Vec<SolveStep> = Vec::new();
    let mut converged = false;
    let mut fevals = 0usize;
    let t0 = Instant::now();

    let mut inputs: Vec<HostTensor> = params.to_vec();
    let z_slot = inputs.len();
    inputs.push(z.clone());
    inputs.push(x_feat.clone());

    while fevals < opts.max_iter {
        let (entry, evals_this_call) = if use_fused {
            ("forward_solve_k", fused_k)
        } else {
            ("cell_step", 1)
        };
        inputs[z_slot] = z;
        let out = engine.execute(entry, batch, &inputs)?;
        let f = out[0].clone();
        let rel = max_rel_residual(&out[1], &out[2], opts.lam)?;
        fevals += evals_this_call;
        steps.push(SolveStep {
            iter: steps.len(),
            rel_residual: rel,
            elapsed: t0.elapsed(),
            fevals,
            mixed: false,
        });
        z = f;
        if rel < opts.tol {
            converged = true;
            break;
        }
    }

    Ok(SolveReport { kind: SolverKind::Forward, steps, converged, z_star: z })
}

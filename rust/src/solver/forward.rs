//! Forward-iteration solver (the paper's baseline): z ← f(z, x).
//!
//! Two dispatch modes:
//!  * per-step: one `cell_step` artifact call per iteration — full residual
//!    trace resolution (used by the residual-vs-time experiments);
//!  * fused: `forward_solve_k` runs K cell applications inside one HLO
//!    while-loop, amortizing PJRT dispatch (the L2 perf-pass artifact);
//!    residuals are then sampled every K evaluations.
//!
//! Convergence is per-sample: lanes freeze the step they cross `tol`
//! (their iterate stops moving and their fevals stop counting) while the
//! rest of the batch keeps iterating.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Backend, HostTensor};
use crate::solver::{ResidualTrack, SolveOptions, SolveReport, SolveStep, SolverKind};

/// Solve to tolerance with plain forward iteration.
pub fn solve(
    engine: &dyn Backend,
    params: &[HostTensor],
    x_feat: &HostTensor,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let batch = x_feat.shape[0];
    let fused_k = engine.manifest().solver.fused_steps.max(1);
    let use_fused = opts.fused_forward
        && fused_k > 1
        && engine.manifest().entry("forward_solve_k", batch).is_ok();

    let mut steps: Vec<SolveStep> = Vec::new();
    let mut track = ResidualTrack::new(batch, opts.tol);
    let mut fevals = 0usize;
    let t0 = Instant::now();

    // The canonical iterate lives in the input slot; each step moves the
    // backend's f tensor in and recycles the previous iterate, so the
    // steady-state loop allocates nothing once the backend pool is warm.
    let mut inputs: Vec<HostTensor> = params.to_vec();
    let z_slot = inputs.len();
    inputs.push(HostTensor::zeros(x_feat.shape.clone()));
    inputs.push(x_feat.clone());

    while fevals < opts.max_iter && !track.all_converged() {
        let (entry, evals_this_call) = if use_fused {
            ("forward_solve_k", fused_k)
        } else {
            ("cell_step", 1)
        };
        let mut out = engine.execute(entry, batch, &inputs)?;
        let fnorm = out.pop().expect("cell entries return 3 outputs");
        let res = out.pop().expect("cell entries return 3 outputs");
        let f = out.pop().expect("cell entries return 3 outputs");
        let (rel, freeze) =
            track.observe_step(&res, &fnorm, opts.lam, evals_this_call)?;
        engine.recycle(vec![res, fnorm]);
        fevals += evals_this_call;
        steps.push(SolveStep {
            iter: steps.len(),
            rel_residual: track.max_rel(),
            sample_residuals: rel,
            active: track.active_count(),
            elapsed: t0.elapsed(),
            fevals,
            mixed: false,
        });
        // Lanes active this step (newly frozen included) take f; lanes
        // frozen earlier keep their converged iterate.
        let mut next = f;
        next.overwrite_rows_where(&inputs[z_slot], &freeze.frozen_before)?;
        let prev = std::mem::replace(&mut inputs[z_slot], next);
        engine.recycle(vec![prev]);
    }

    let z = inputs.swap_remove(z_slot);
    Ok(SolveReport::from_track(SolverKind::Forward, steps, z, &track))
}

//! Anderson history windows (paper Alg. 1): the ring buffers behind the
//! mixing policies.
//!
//! The coordinator owns the history window: a ring buffer of the last m
//! (iterate, image) pairs, flattened to `(batch, m, n)` tensors that feed
//! the fused L1 `anderson_update` kernel (Gram → masked solve → Eq. 5
//! mixing).  The warm-up window (k < m) is expressed through the mask
//! vector, so a single compiled artifact serves every iteration.  The
//! solve loops live elsewhere — [`crate::solver::driver`] for batch
//! solves (one [`History`] per cohort), `server::scheduler` for
//! iteration-level serving (one [`LaneHistory`] across all lanes).
//!
//! Cost anatomy per iteration (the paper's "mixing penalty", Fig. 1):
//!   cell_step:        the function evaluation f(z, x)
//!   anderson_update:  2·m·n history streaming + m² Gram + m³ solve
//! The history buffers are the "cacheable iterations": they live in
//! preallocated host ring storage and are re-packed, not re-allocated.

use anyhow::Result;

use crate::native::sketch_coords;
use crate::runtime::HostTensor;
use crate::solver::policy::WindowRule;
use crate::solver::spec::GramMode;
use crate::util::rng::Rng;

/// Outcome of one window-adaptation pass ([`History::adapt`] /
/// [`LaneHistory::adapt_lane`]): which ring slots were dropped, and by
/// which criterion.  The split matters to the property-test harness —
/// residual-bound drops must each violate the errorfactor criterion,
/// while condition drops must leave the Gram estimate at or below the
/// ceiling (or a single-entry window).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdaptOutcome {
    /// Slots still feeding the mix after adaptation (always ≥ 1).
    pub kept: usize,
    /// Slots dropped because their residual norm exceeded
    /// `errorfactor × min_i ‖f(x_i) − x_i‖`.
    pub dropped_resid: Vec<usize>,
    /// Slots dropped (largest residual first) to bring the regularized
    /// Gram condition estimate under `cond_max`.
    pub dropped_cond: Vec<usize>,
}

impl AdaptOutcome {
    /// Total slots dropped this pass.
    pub fn dropped(&self) -> usize {
        self.dropped_resid.len() + self.dropped_cond.len()
    }
}

/// Ring-buffer history for batched Anderson over flattened latents.
///
/// `m` is the *effective* window (ring size); `slots` is the artifact's
/// compiled window (tensor extent).  Slots beyond `m` stay zeroed and
/// masked out, so one compiled artifact serves every window ≤ its size.
pub struct History {
    batch: usize,
    m: usize,
    slots: usize,
    n: usize,
    /// (batch, slots, n) windows, slot-major within each sample.
    xhist: Vec<f32>,
    fhist: Vec<f32>,
    count: usize,
    /// Per (sample, slot) residual norm ‖f(z) − z‖₂ recorded at push
    /// time — the bookkeeping behind [`Self::adapt`].
    norms: Vec<f32>,
    /// Per-slot keep flags from the last [`Self::adapt`] pass.  The
    /// kernel mask punches holes where `keep` is false (the engine's
    /// masked solve accepts non-prefix masks).  All-true when adaptation
    /// never runs — the mask then degenerates to the plain valid-prefix
    /// and fixed-window traces stay bit-identical.
    keep: Vec<bool>,
}

impl History {
    pub fn new(batch: usize, m: usize, n: usize) -> Self {
        Self::with_padded_slots(batch, m, m, n)
    }

    /// Effective window `m` inside a tensor padded to `slots` ≥ m.
    pub fn with_padded_slots(batch: usize, m: usize, slots: usize, n: usize) -> Self {
        assert!(m >= 1 && m <= slots);
        Self {
            batch,
            m,
            slots,
            n,
            xhist: vec![0.0; batch * slots * n],
            fhist: vec![0.0; batch * slots * n],
            count: 0,
            norms: vec![0.0; batch * slots],
            keep: vec![true; slots],
        }
    }

    pub fn valid(&self) -> usize {
        self.count.min(self.m)
    }

    /// The ring slot holding the most recently pushed pair.  Only
    /// meaningful once something was pushed; the adaptation pass uses it
    /// to guarantee the newest iterate is never dropped.
    pub fn newest_slot(&self) -> usize {
        debug_assert!(self.count > 0);
        (self.count + self.m - 1) % self.m
    }

    /// Forget the whole window (restart-on-breakdown): zero the rings
    /// and reset the cursor, reusing the existing allocations — restarts
    /// happen mid-solve, inside the loop that must not allocate.
    pub fn reset(&mut self) {
        self.xhist.fill(0.0);
        self.fhist.fill(0.0);
        self.count = 0;
        self.norms.fill(0.0);
        self.keep.fill(true);
    }

    /// Record (z, f(z)) — both flat (batch * n).
    pub fn push(&mut self, z: &[f32], fz: &[f32]) {
        let all = vec![true; self.batch];
        self.push_where(z, fz, &all);
    }

    /// Record (z, f(z)) rows only for lanes where `active` is true.
    /// Frozen lanes keep their last window — their mixed output is
    /// discarded by the caller, so stale slots are never observed.
    pub fn push_where(&mut self, z: &[f32], fz: &[f32], active: &[bool]) {
        assert_eq!(z.len(), self.batch * self.n);
        assert_eq!(fz.len(), self.batch * self.n);
        assert_eq!(active.len(), self.batch);
        let slot = self.count % self.m;
        // A fresh push always re-arms its slot: depth truncation (see
        // [`Self::truncate`]) may have dropped it on an earlier
        // iteration, and unlike `adapt` — which rebuilds every keep flag
        // per call — truncation leaves the other flags alone.
        self.keep[slot] = true;
        for b in 0..self.batch {
            if !active[b] {
                continue;
            }
            let dst = (b * self.slots + slot) * self.n;
            let src = b * self.n;
            self.xhist[dst..dst + self.n].copy_from_slice(&z[src..src + self.n]);
            self.fhist[dst..dst + self.n]
                .copy_from_slice(&fz[src..src + self.n]);
            let mut acc = 0.0f32;
            for (zi, fi) in z[src..src + self.n].iter().zip(&fz[src..src + self.n])
            {
                let d = fi - zi;
                acc += d * d;
            }
            self.norms[b * self.slots + slot] = acc.sqrt();
        }
        self.count += 1;
    }

    /// Condition-monitored window adaptation: recompute the per-slot
    /// keep flags for the current ring from scratch —
    ///
    ///  1. drop slots whose cohort residual norm (max over the batch —
    ///     the worst lane decides) exceeds `rule.errorfactor ×` the
    ///     smallest cohort norm in the window;
    ///  2. while the regularized Gram system over the kept slots
    ///     (residual rows flattened across the cohort, `G Gᵀ + λI`) has
    ///     condition estimate above `rule.cond_max`, drop the kept slot
    ///     with the largest cohort norm.
    ///
    /// The newest slot is never dropped, so the window never empties.
    /// Call after `push_where` and before `fill_tensors`, once per mix.
    pub fn adapt(&mut self, rule: WindowRule, lam: f32) -> AdaptOutcome {
        let nv = self.valid();
        self.keep.fill(true);
        let mut out = AdaptOutcome { kept: nv, ..Default::default() };
        if nv <= 1 {
            return out;
        }
        let newest = self.newest_slot();
        // Cohort norm per slot: the worst sample in the batch decides.
        let mut sn = vec![0.0f32; nv];
        for (i, v) in sn.iter_mut().enumerate() {
            for b in 0..self.batch {
                *v = v.max(self.norms[b * self.slots + i]);
            }
        }
        let min = sn.iter().cloned().fold(f32::INFINITY, f32::min);
        for i in 0..nv {
            if i != newest && sn[i] > rule.errorfactor * min {
                self.keep[i] = false;
                out.dropped_resid.push(i);
            }
        }
        // Condition ceiling over the surviving slots.  Probe rows are the
        // full flattened cohort residuals, or — under GramMode::Sketched —
        // an unbiased coordinate subsample drawn ONCE per adapt call
        // (deterministically from the push counter, so solves replay
        // bit-identically) and reused across the whole truncation loop.
        let row = self.batch * self.n;
        let sketch = match rule.gram {
            GramMode::Exact => None,
            GramMode::Sketched { dim } => {
                let mut rng = Rng::new(0x517C ^ self.count as u64);
                sketch_coords(row, dim, &mut rng)
            }
        };
        let probe_row = sketch.as_ref().map_or(row, |(c, _)| c.len());
        let mut g: Vec<f32> = Vec::new();
        loop {
            let kept: Vec<usize> = (0..nv).filter(|&i| self.keep[i]).collect();
            out.kept = kept.len();
            if kept.len() <= 1 {
                break;
            }
            g.clear();
            g.resize(kept.len() * probe_row, 0.0);
            match &sketch {
                None => {
                    for (r, &i) in kept.iter().enumerate() {
                        for b in 0..self.batch {
                            let src = (b * self.slots + i) * self.n;
                            let dst = (r * self.batch + b) * self.n;
                            for p in 0..self.n {
                                g[dst + p] =
                                    self.fhist[src + p] - self.xhist[src + p];
                            }
                        }
                    }
                }
                Some((coords, scale)) => {
                    for (r, &i) in kept.iter().enumerate() {
                        for (t, &c) in coords.iter().enumerate() {
                            // Coordinate c of the flattened (batch, n) row.
                            let src = (c / self.n * self.slots + i) * self.n
                                + c % self.n;
                            g[r * probe_row + t] =
                                scale * (self.fhist[src] - self.xhist[src]);
                        }
                    }
                }
            }
            let cond =
                crate::native::window_cond_estimate(&g, kept.len(), probe_row, lam);
            if cond <= rule.cond_max {
                break;
            }
            let victim = kept
                .iter()
                .cloned()
                .filter(|&i| i != newest)
                .max_by(|&a, &b| sn[a].total_cmp(&sn[b]));
            match victim {
                Some(v) => {
                    self.keep[v] = false;
                    out.dropped_cond.push(v);
                }
                // Only the newest slot is left in violation — keep it;
                // a one-entry window cannot be truncated further.
                None => break,
            }
        }
        out
    }

    /// Cap the window at the `depth` newest kept slots (the
    /// auto-selection controller sizes the mixing depth from a lane's
    /// predicted remaining decades — see `solver::select`).  Runs on the
    /// keep flags left by the last [`Self::adapt`] pass (all-true when
    /// adaptation never ran), so call it after `adapt` and before
    /// `fill_tensors`.  Returns the number of slots dropped; the newest
    /// slot always survives.
    pub fn truncate(&mut self, depth: usize) -> usize {
        let depth = depth.max(1);
        let nv = self.valid();
        if nv == 0 {
            return 0;
        }
        let mut kept = 0;
        let mut dropped = 0;
        // Walk slots newest-first; beyond `depth` kept ones, drop.
        for age in 0..nv {
            let slot = (self.count - 1 - age) % self.m;
            if !self.keep[slot] {
                continue;
            }
            if kept < depth {
                kept += 1;
            } else {
                self.keep[slot] = false;
                dropped += 1;
            }
        }
        dropped
    }

    /// Mask vector over the padded slots: 1.0 for valid ring entries the
    /// last adaptation pass kept (all valid entries when adaptation
    /// never ran).
    pub fn mask(&self) -> Vec<f32> {
        let nv = self.valid();
        (0..self.slots)
            .map(|i| if i < nv && self.keep[i] { 1.0 } else { 0.0 })
            .collect()
    }

    /// Materialize the (batch, slots, n) history tensors for the kernel.
    pub fn tensors(&self) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let shape = vec![self.batch, self.slots, self.n];
        Ok((
            HostTensor::f32(shape.clone(), self.xhist.clone())?,
            HostTensor::f32(shape, self.fhist.clone())?,
            HostTensor::f32(vec![self.slots], self.mask())?,
        ))
    }

    /// Pack the window into preallocated tensors — the allocation-free
    /// twin of [`Self::tensors`] for steady-state solve loops.  Tensor
    /// element counts must match `(batch, slots, n)` / `(slots,)`.
    pub fn fill_tensors(
        &self,
        xh: &mut HostTensor,
        fh: &mut HostTensor,
        mask: &mut HostTensor,
    ) -> Result<()> {
        fill_window(
            &self.xhist,
            &self.fhist,
            self.valid(),
            self.slots,
            Some(&self.keep),
            xh,
            fh,
            mask,
        )
    }
}

/// Shared copy core of `History::fill_tensors` / `LaneHistory::fill_tensors`:
/// copy the flat windows into preallocated tensors and rewrite the mask
/// with `nv` valid slots.  `keep` (when given) punches per-slot holes
/// into the valid prefix — the adaptive-window path; `None` keeps the
/// plain prefix mask.
#[allow(clippy::too_many_arguments)]
fn fill_window(
    xhist: &[f32],
    fhist: &[f32],
    nv: usize,
    slots: usize,
    keep: Option<&[bool]>,
    xh: &mut HostTensor,
    fh: &mut HostTensor,
    mask: &mut HostTensor,
) -> Result<()> {
    let xd = xh.f32s_mut()?;
    anyhow::ensure!(
        xd.len() == xhist.len(),
        "xhist tensor holds {} elements, window has {}",
        xd.len(),
        xhist.len()
    );
    xd.copy_from_slice(xhist);
    let fd = fh.f32s_mut()?;
    anyhow::ensure!(
        fd.len() == fhist.len(),
        "fhist tensor holds {} elements, window has {}",
        fd.len(),
        fhist.len()
    );
    fd.copy_from_slice(fhist);
    let md = mask.f32s_mut()?;
    anyhow::ensure!(
        md.len() == slots,
        "mask tensor holds {} slots, window has {slots}",
        md.len()
    );
    for (i, v) in md.iter_mut().enumerate() {
        let kept = keep.map(|k| k[i]).unwrap_or(true);
        *v = if i < nv && kept { 1.0 } else { 0.0 };
    }
    Ok(())
}

/// Per-lane windowed history for iteration-level continuous batching.
///
/// Unlike [`History`], whose lanes share one warm-up (a whole batch is
/// admitted at once), every lane here fills its own ring at its own pace
/// inside one `(lanes, slots, n)` tensor — the lane scheduler admits and
/// retires lanes mid-flight, so fill levels diverge.  The shared kernel
/// mask is the full effective window: a freshly admitted lane's ring is
/// seeded by replicating its first (z, f) pair across all `m` slots,
/// which makes the masked Anderson solve return equal weights over
/// identical rows — exactly a damped forward step — until real history
/// displaces the copies.  Empty lanes hold zeros and mix to zero, which
/// the scheduler discards.
pub struct LaneHistory {
    lanes: usize,
    m: usize,
    slots: usize,
    n: usize,
    xhist: Vec<f32>,
    fhist: Vec<f32>,
    /// Per-lane push count (0 = empty ring).
    count: Vec<usize>,
    /// Per (lane, slot) residual norm ‖f(z) − z‖₂ at push time.
    norms: Vec<f32>,
    /// Per (lane, slot) liveness: true only for slots holding a
    /// *distinct* recorded pair — admission-seed replicas and
    /// adapt-dropped slots are not live.  Only live slots feed the
    /// condition monitor; the kernel mask always spans all `m` effective
    /// slots, because duplicate rows mix exactly like admission seeding
    /// (equal weight spread over copies of the newest pair = a damped
    /// step component), which is what lets per-lane adaptation coexist
    /// with the bucket's *shared* mask vector.
    live: Vec<bool>,
}

impl LaneHistory {
    /// Effective window `m` inside `slots` ≥ m compiled slots.
    pub fn new(lanes: usize, m: usize, slots: usize, n: usize) -> Self {
        assert!(m >= 1 && m <= slots);
        Self {
            lanes,
            m,
            slots,
            n,
            xhist: vec![0.0; lanes * slots * n],
            fhist: vec![0.0; lanes * slots * n],
            count: vec![0; lanes],
            norms: vec![0.0; lanes * slots],
            live: vec![false; lanes * slots],
        }
    }

    /// Valid ring entries for one lane.
    pub fn valid(&self, lane: usize) -> usize {
        self.count[lane].min(self.m)
    }

    /// Slots of one lane holding distinct (non-replica, non-dropped)
    /// pairs — what the condition monitor actually sees.
    pub fn live_slots(&self, lane: usize) -> Vec<usize> {
        let base = lane * self.slots;
        (0..self.m).filter(|&i| self.live[base + i]).collect()
    }

    /// The ring slot holding a lane's most recent pair (requires at
    /// least one push).
    pub fn newest_slot(&self, lane: usize) -> usize {
        debug_assert!(self.count[lane] > 0);
        (self.count[lane] + self.m - 1) % self.m
    }

    /// Forget a lane's window (on admit and on retire).
    pub fn clear_lane(&mut self, lane: usize) {
        self.count[lane] = 0;
        let base = lane * self.slots * self.n;
        let len = self.slots * self.n;
        self.xhist[base..base + len].fill(0.0);
        self.fhist[base..base + len].fill(0.0);
        let sb = lane * self.slots;
        self.norms[sb..sb + self.slots].fill(0.0);
        self.live[sb..sb + self.slots].fill(false);
    }

    /// Record a lane's (z, f(z)) pair.  The first push seeds every slot
    /// of the lane's window with the pair (see the type docs); later
    /// pushes overwrite the lane's own ring position.
    pub fn push_lane(&mut self, lane: usize, z: &[f32], fz: &[f32]) {
        assert_eq!(z.len(), self.n);
        assert_eq!(fz.len(), self.n);
        let mut acc = 0.0f32;
        for (zi, fi) in z.iter().zip(fz) {
            let d = fi - zi;
            acc += d * d;
        }
        let norm = acc.sqrt();
        let sb = lane * self.slots;
        if self.count[lane] == 0 {
            for slot in 0..self.m {
                let dst = (lane * self.slots + slot) * self.n;
                self.xhist[dst..dst + self.n].copy_from_slice(z);
                self.fhist[dst..dst + self.n].copy_from_slice(fz);
                self.norms[sb + slot] = norm;
                // Only the written slot is distinct; the replicas are
                // seeding artifacts the condition monitor must ignore.
                self.live[sb + slot] = slot == 0;
            }
        } else {
            let slot = self.count[lane] % self.m;
            let dst = (lane * self.slots + slot) * self.n;
            self.xhist[dst..dst + self.n].copy_from_slice(z);
            self.fhist[dst..dst + self.n].copy_from_slice(fz);
            self.norms[sb + slot] = norm;
            self.live[sb + slot] = true;
        }
        self.count[lane] += 1;
    }

    /// Per-lane condition-monitored window adaptation — the
    /// [`History::adapt`] twin for the iteration-level scheduler, where
    /// the kernel mask is *shared* across heterogeneous lanes and cannot
    /// carry per-lane holes.  Dropping a slot here therefore means
    /// overwriting it with the lane's newest pair (the admission-seeding
    /// replication idiom) and marking it not-live:
    ///
    ///  1. live slots whose residual norm exceeds `rule.errorfactor ×`
    ///     the smallest live norm are dropped;
    ///  2. while the lane's regularized Gram estimate over live slots
    ///     exceeds `rule.cond_max`, the largest-norm live slot drops.
    ///
    /// The newest slot is never dropped; a lane always keeps ≥ 1 live
    /// slot.  Call after `push_lane` and before `fill_tensors`.
    pub fn adapt_lane(
        &mut self,
        lane: usize,
        rule: WindowRule,
        lam: f32,
    ) -> AdaptOutcome {
        let base = lane * self.slots;
        let live: Vec<usize> = self.live_slots(lane);
        let mut out =
            AdaptOutcome { kept: live.len().max(1), ..Default::default() };
        if self.count[lane] == 0 || live.len() <= 1 {
            return out;
        }
        let newest = self.newest_slot(lane);
        let min = live
            .iter()
            .map(|&i| self.norms[base + i])
            .fold(f32::INFINITY, f32::min);
        for &i in &live {
            if i != newest && self.norms[base + i] > rule.errorfactor * min {
                self.drop_slot(lane, i, newest);
                out.dropped_resid.push(i);
            }
        }
        // Sketched or exact Gram probe rows, mirroring History::adapt —
        // the coordinate draw is seeded from (lane, push count) so each
        // lane sketches independently yet replays deterministically.
        let sketch = match rule.gram {
            GramMode::Exact => None,
            GramMode::Sketched { dim } => {
                let mut rng = Rng::new(
                    0x1A4E ^ ((lane as u64) << 32) ^ self.count[lane] as u64,
                );
                sketch_coords(self.n, dim, &mut rng)
            }
        };
        let probe_row = sketch.as_ref().map_or(self.n, |(c, _)| c.len());
        let mut g: Vec<f32> = Vec::new();
        loop {
            let kept = self.live_slots(lane);
            out.kept = kept.len();
            if kept.len() <= 1 {
                break;
            }
            g.clear();
            g.resize(kept.len() * probe_row, 0.0);
            match &sketch {
                None => {
                    for (r, &i) in kept.iter().enumerate() {
                        let src = (base + i) * self.n;
                        for p in 0..self.n {
                            g[r * self.n + p] =
                                self.fhist[src + p] - self.xhist[src + p];
                        }
                    }
                }
                Some((coords, scale)) => {
                    for (r, &i) in kept.iter().enumerate() {
                        let src = (base + i) * self.n;
                        for (t, &c) in coords.iter().enumerate() {
                            g[r * probe_row + t] = scale
                                * (self.fhist[src + c] - self.xhist[src + c]);
                        }
                    }
                }
            }
            let cond =
                crate::native::window_cond_estimate(&g, kept.len(), probe_row, lam);
            if cond <= rule.cond_max {
                break;
            }
            let victim = kept
                .iter()
                .cloned()
                .filter(|&i| i != newest)
                .max_by(|&a, &b| {
                    self.norms[base + a].total_cmp(&self.norms[base + b])
                });
            match victim {
                Some(v) => {
                    self.drop_slot(lane, v, newest);
                    out.dropped_cond.push(v);
                }
                None => break,
            }
        }
        out
    }

    /// Cap one lane's live window at the `depth` newest distinct pairs —
    /// the [`History::truncate`] twin for the scheduler, using the same
    /// overwrite-with-newest drop idiom as [`Self::adapt_lane`] (the
    /// shared kernel mask cannot carry per-lane holes).  Returns the
    /// number of slots dropped; the newest slot always survives.  Call
    /// after `adapt_lane` and before `fill_tensors`.
    pub fn truncate_lane(&mut self, lane: usize, depth: usize) -> usize {
        let depth = depth.max(1);
        let c = self.count[lane];
        if c == 0 {
            return 0;
        }
        let newest = self.newest_slot(lane);
        let base = lane * self.slots;
        let mut kept = 0;
        let mut dropped = 0;
        for age in 0..c.min(self.m) {
            let slot = (c - 1 - age) % self.m;
            if !self.live[base + slot] {
                continue;
            }
            if kept < depth {
                kept += 1;
            } else {
                self.drop_slot(lane, slot, newest);
                dropped += 1;
            }
        }
        dropped
    }

    /// Drop one slot of a lane: overwrite it with the lane's newest pair
    /// and mark it not-live.  The shared mask keeps covering the slot —
    /// the duplicate row just spreads mixing weight onto the newest
    /// iterate, exactly like admission seeding.
    fn drop_slot(&mut self, lane: usize, slot: usize, newest: usize) {
        debug_assert_ne!(slot, newest);
        let src = (lane * self.slots + newest) * self.n;
        let dst = (lane * self.slots + slot) * self.n;
        self.xhist.copy_within(src..src + self.n, dst);
        self.fhist.copy_within(src..src + self.n, dst);
        let base = lane * self.slots;
        self.norms[base + slot] = self.norms[base + newest];
        self.live[base + slot] = false;
    }

    /// Materialize the (lanes, slots, n) history tensors + shared mask
    /// (all `m` effective slots valid; padded slots masked out).
    pub fn tensors(&self) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let shape = vec![self.lanes, self.slots, self.n];
        let mask: Vec<f32> = (0..self.slots)
            .map(|i| if i < self.m { 1.0 } else { 0.0 })
            .collect();
        Ok((
            HostTensor::f32(shape.clone(), self.xhist.clone())?,
            HostTensor::f32(shape, self.fhist.clone())?,
            HostTensor::f32(vec![self.slots], mask)?,
        ))
    }

    /// Pack the lane windows into preallocated tensors — the
    /// allocation-free twin of [`Self::tensors`] for the scheduler's
    /// steady-state lane loop.
    pub fn fill_tensors(
        &self,
        xh: &mut HostTensor,
        fh: &mut HostTensor,
        mask: &mut HostTensor,
    ) -> Result<()> {
        fill_window(
            &self.xhist,
            &self.fhist,
            self.m,
            self.slots,
            None,
            xh,
            fh,
            mask,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_ring_and_mask() {
        let mut h = History::new(2, 3, 4);
        assert_eq!(h.valid(), 0);
        let z = vec![1.0; 8];
        let f = vec![2.0; 8];
        h.push(&z, &f);
        assert_eq!(h.valid(), 1);
        assert_eq!(h.mask(), vec![1.0, 0.0, 0.0]);
        h.push(&z, &f);
        h.push(&z, &f);
        h.push(&z, &f); // wraps
        assert_eq!(h.valid(), 3);
        assert_eq!(h.mask(), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn history_reset_clears_in_place() {
        let mut h = History::with_padded_slots(2, 2, 3, 2);
        h.push(&[1.0; 4], &[2.0; 4]);
        h.push(&[3.0; 4], &[4.0; 4]);
        assert_eq!(h.valid(), 2);
        h.reset();
        assert_eq!(h.valid(), 0);
        let (xh, fh, mask) = h.tensors().unwrap();
        assert!(xh.f32s().unwrap().iter().all(|&v| v == 0.0));
        assert!(fh.f32s().unwrap().iter().all(|&v| v == 0.0));
        assert_eq!(mask.f32s().unwrap(), &[0.0, 0.0, 0.0]);
        // The ring is usable again after reset.
        h.push(&[5.0; 4], &[6.0; 4]);
        assert_eq!(h.valid(), 1);
    }

    #[test]
    fn history_layout_is_batch_major() {
        let mut h = History::new(2, 2, 3);
        let z: Vec<f32> = (0..6).map(|v| v as f32).collect(); // sample0: 0,1,2
        let f: Vec<f32> = (10..16).map(|v| v as f32).collect();
        h.push(&z, &f);
        let (xh, fh, mask) = h.tensors().unwrap();
        assert_eq!(xh.shape, vec![2, 2, 3]);
        // sample 0, slot 0 = z[0..3]
        assert_eq!(&xh.f32s().unwrap()[0..3], &[0.0, 1.0, 2.0]);
        // sample 1, slot 0 = z[3..6] at offset (1*2+0)*3 = 6
        assert_eq!(&xh.f32s().unwrap()[6..9], &[3.0, 4.0, 5.0]);
        assert_eq!(&fh.f32s().unwrap()[0..3], &[10.0, 11.0, 12.0]);
        assert_eq!(mask.f32s().unwrap(), &[1.0, 0.0]);
    }

    #[test]
    fn padded_history_masks_unused_slots() {
        // Effective window 2 inside 5 compiled slots: ring wraps at 2,
        // slots 2..5 stay zero and masked out forever.
        let mut h = History::with_padded_slots(1, 2, 5, 3);
        for step in 0..4 {
            let v = vec![step as f32; 3];
            h.push(&v, &v);
        }
        assert_eq!(h.valid(), 2);
        let (xh, _, mask) = h.tensors().unwrap();
        assert_eq!(xh.shape, vec![1, 5, 3]);
        assert_eq!(mask.f32s().unwrap(), &[1.0, 1.0, 0.0, 0.0, 0.0]);
        let x = xh.f32s().unwrap();
        // Ring of size 2: slot 0 holds step 2, slot 1 holds step 3.
        assert_eq!(&x[0..3], &[2.0, 2.0, 2.0]);
        assert_eq!(&x[3..6], &[3.0, 3.0, 3.0]);
        assert_eq!(&x[6..15], &[0.0; 9]);
    }

    #[test]
    fn fill_tensors_matches_tensors() {
        // The in-place pack must agree exactly with the allocating one,
        // including the mask as the window fills.
        let mut h = History::with_padded_slots(2, 2, 4, 3);
        let mut xh = HostTensor::zeros(vec![2, 4, 3]);
        let mut fh = HostTensor::zeros(vec![2, 4, 3]);
        let mut mask = HostTensor::zeros(vec![4]);
        for step in 0..3 {
            let z = vec![step as f32; 6];
            let f = vec![10.0 + step as f32; 6];
            h.push(&z, &f);
            let (xw, fw, mw) = h.tensors().unwrap();
            h.fill_tensors(&mut xh, &mut fh, &mut mask).unwrap();
            assert_eq!(xh.f32s().unwrap(), xw.f32s().unwrap());
            assert_eq!(fh.f32s().unwrap(), fw.f32s().unwrap());
            assert_eq!(mask.f32s().unwrap(), mw.f32s().unwrap());
        }
        // Wrong-sized targets are rejected, not silently truncated.
        let mut small = HostTensor::zeros(vec![2, 2, 3]);
        assert!(h.fill_tensors(&mut small, &mut fh, &mut mask).is_err());

        let mut lh = LaneHistory::new(2, 2, 3, 2);
        lh.push_lane(1, &[5.0, 6.0], &[7.0, 8.0]);
        let (xw, fw, mw) = lh.tensors().unwrap();
        let mut lxh = HostTensor::zeros(vec![2, 3, 2]);
        let mut lfh = HostTensor::zeros(vec![2, 3, 2]);
        let mut lmask = HostTensor::zeros(vec![3]);
        lh.fill_tensors(&mut lxh, &mut lfh, &mut lmask).unwrap();
        assert_eq!(lxh.f32s().unwrap(), xw.f32s().unwrap());
        assert_eq!(lfh.f32s().unwrap(), fw.f32s().unwrap());
        assert_eq!(lmask.f32s().unwrap(), mw.f32s().unwrap());
    }

    #[test]
    fn masked_push_freezes_lane_window() {
        let mut h = History::new(2, 2, 2);
        h.push(&[1.0, 1.0, 9.0, 9.0], &[2.0, 2.0, 8.0, 8.0]);
        // Lane 1 frozen: its slots keep the first pair, lane 0 advances.
        h.push_where(&[3.0, 3.0, 7.0, 7.0], &[4.0, 4.0, 6.0, 6.0], &[true, false]);
        let (xh, _, _) = h.tensors().unwrap();
        let x = xh.f32s().unwrap();
        // Lane 0: slot 0 = first push, slot 1 = second push.
        assert_eq!(&x[0..4], &[1.0, 1.0, 3.0, 3.0]);
        // Lane 1: slot 0 = first push, slot 1 untouched (zeros).
        assert_eq!(&x[4..8], &[9.0, 9.0, 0.0, 0.0]);
        // The global ring cursor still advanced for the batch.
        assert_eq!(h.valid(), 2);
    }

    #[test]
    fn lane_history_seeds_fresh_lane_by_replication() {
        let mut h = LaneHistory::new(2, 3, 3, 2);
        assert_eq!(h.valid(0), 0);
        h.push_lane(0, &[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(h.valid(0), 1);
        let (xh, fh, mask) = h.tensors().unwrap();
        assert_eq!(mask.f32s().unwrap(), &[1.0, 1.0, 1.0]);
        let x = xh.f32s().unwrap();
        let f = fh.f32s().unwrap();
        // Every slot of lane 0 holds the replicated first pair.
        for slot in 0..3 {
            assert_eq!(&x[slot * 2..slot * 2 + 2], &[1.0, 2.0]);
            assert_eq!(&f[slot * 2..slot * 2 + 2], &[3.0, 4.0]);
        }
        // Lane 1 untouched (zeros).
        assert!(x[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lane_history_rings_independently_and_clears() {
        let mut h = LaneHistory::new(2, 2, 2, 1);
        h.push_lane(0, &[1.0], &[1.0]);
        h.push_lane(0, &[2.0], &[2.0]);
        h.push_lane(0, &[3.0], &[3.0]); // wraps into slot 1 of lane 0
        h.push_lane(1, &[9.0], &[9.0]); // lane 1 still replicating
        let (xh, _, _) = h.tensors().unwrap();
        let x = xh.f32s().unwrap();
        // Lane 0 ring: the seed push filled both slots, push 2 landed in
        // slot 1 (count=1), push 3 wrapped into slot 0 (count=2).
        assert_eq!(&x[0..2], &[3.0, 2.0]);
        // Lane 1: both slots replicated from its first push.
        assert_eq!(&x[2..4], &[9.0, 9.0]);
        h.clear_lane(0);
        assert_eq!(h.valid(0), 0);
        let (xh, _, _) = h.tensors().unwrap();
        assert_eq!(&xh.f32s().unwrap()[0..2], &[0.0, 0.0]);
        assert_eq!(h.valid(1), 1);
    }

    /// Push a pair whose residual f − z has the requested norm.
    fn push_with_norm(h: &mut History, norm: f32, dir: usize) {
        let n = 3;
        let mut z = vec![0.0; n];
        let mut f = vec![0.0; n];
        z[dir % n] = 1.0;
        f[dir % n] = 1.0 + norm;
        h.push(&z, &f);
    }

    #[test]
    fn history_adapt_drops_only_errorfactor_violators() {
        let rule = WindowRule {
            errorfactor: 10.0,
            cond_max: f32::INFINITY,
            gram: GramMode::Exact,
        };
        let mut h = History::new(1, 4, 3);
        // Norms 1, 100, 2, 3 in distinct directions (well conditioned).
        for (k, norm) in [1.0, 100.0, 2.0, 3.0].into_iter().enumerate() {
            push_with_norm(&mut h, norm, k);
        }
        let out = h.adapt(rule, 1e-3);
        assert_eq!(out.dropped_resid, vec![1]);
        assert!(out.dropped_cond.is_empty());
        assert_eq!(out.kept, 3);
        assert_eq!(h.mask(), vec![1.0, 0.0, 1.0, 1.0]);
        // The pass is recomputed from scratch: pushing a fresh pair into
        // the dropped slot re-validates it on the next adapt.
        push_with_norm(&mut h, 1.5, 4); // wraps into slot 0
        push_with_norm(&mut h, 1.2, 5); // slot 1 — overwrites the outlier
        let out = h.adapt(rule, 1e-3);
        assert_eq!(out.dropped(), 0);
        assert_eq!(h.mask(), vec![1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn history_adapt_cond_truncation_keeps_newest_and_never_empties() {
        // Three nearly-parallel residual rows: condition estimate blows
        // up, so the ceiling truncates — but the newest slot survives
        // and the window stays non-empty even under an impossible cap.
        let rule = WindowRule { errorfactor: 1e6, cond_max: 1.5, gram: GramMode::Exact };
        let mut h = History::new(1, 3, 2);
        for (norm, eps) in [(1.0f32, 0.0f32), (1.01, 1e-4), (0.99, 2e-4)] {
            h.push(&[0.0, 0.0], &[norm, eps]);
        }
        let out = h.adapt(rule, 1e-6);
        assert!(out.dropped_resid.is_empty());
        assert!(!out.dropped_cond.is_empty());
        assert!(out.kept >= 1);
        let newest = h.newest_slot();
        assert_eq!(newest, 2);
        assert_eq!(h.mask()[newest], 1.0);
        assert!(h.mask().iter().sum::<f32>() >= 1.0);
    }

    #[test]
    fn history_adapt_noop_matches_fixed_mask() {
        // Well-conditioned, similar-norm history: adaptation keeps
        // everything and the mask equals the fixed-window prefix.
        let rule = WindowRule { errorfactor: 1e4, cond_max: 1e6, gram: GramMode::Exact };
        let mut h = History::new(2, 3, 4);
        for k in 0..3 {
            let z = vec![0.1 * k as f32; 8];
            let f = vec![0.1 * k as f32 + 0.5; 8];
            h.push(&z, &f);
        }
        let fixed = h.mask();
        let out = h.adapt(rule, 1e-3);
        assert_eq!(out.dropped(), 0);
        assert_eq!(h.mask(), fixed);
    }

    #[test]
    fn history_truncate_keeps_newest_slots() {
        let mut h = History::new(1, 4, 3);
        for (k, norm) in [1.0, 2.0, 3.0, 4.0].into_iter().enumerate() {
            push_with_norm(&mut h, norm, k);
        }
        // Depth 2 keeps the two newest pushes (ring slots 2 and 3).
        assert_eq!(h.truncate(2), 2);
        assert_eq!(h.mask(), vec![0.0, 0.0, 1.0, 1.0]);
        // Depth 1 after adapt-reset: adapt rebuilds keep, truncate caps.
        let rule = WindowRule {
            errorfactor: 1e6,
            cond_max: f32::INFINITY,
            gram: GramMode::Exact,
        };
        h.adapt(rule, 1e-3);
        assert_eq!(h.truncate(1), 3);
        assert_eq!(h.mask(), vec![0.0, 0.0, 0.0, 1.0]);
        // Depth 0 clamps to 1: the newest slot always survives.
        h.adapt(rule, 1e-3);
        assert_eq!(h.truncate(0), 3);
        assert_eq!(h.mask().iter().sum::<f32>(), 1.0);
        // Depth beyond the window is a no-op.
        h.adapt(rule, 1e-3);
        assert_eq!(h.truncate(10), 0);
    }

    #[test]
    fn lane_truncate_drops_oldest_live_and_keeps_newest() {
        let mut h = LaneHistory::new(2, 3, 3, 2);
        h.push_lane(0, &[0.0, 0.0], &[1.0, 0.0]);
        h.push_lane(0, &[0.0, 0.0], &[0.0, 2.0]);
        h.push_lane(0, &[0.0, 0.0], &[3.0, 0.1]);
        assert_eq!(h.live_slots(0), vec![0, 1, 2]);
        assert_eq!(h.truncate_lane(0, 2), 1);
        // The oldest live slot (0) was overwritten with the newest pair
        // and marked not-live; the two newest survive.
        assert_eq!(h.live_slots(0), vec![1, 2]);
        assert_eq!(h.truncate_lane(0, 2), 0);
        // Depth 0 clamps to 1 live slot (the newest).
        assert_eq!(h.truncate_lane(0, 0), 1);
        assert_eq!(h.live_slots(0), vec![2]);
        // Untouched lane 1, and an empty lane is a no-op.
        assert!(h.live_slots(1).is_empty());
        assert_eq!(h.truncate_lane(1, 1), 0);
    }

    #[test]
    fn sketched_adapt_degrades_to_exact_when_wide_and_stays_deterministic() {
        // A sketch at least as wide as the flattened row is exactly the
        // full build (sketch_coords returns None), so the adapt outcome
        // and mask match the exact mode bit-for-bit.
        let exact = WindowRule { errorfactor: 1e6, cond_max: 1.5, gram: GramMode::Exact };
        let wide = WindowRule { gram: GramMode::Sketched { dim: 1_000 }, ..exact };
        let build = || {
            let mut h = History::new(1, 3, 2);
            for (norm, eps) in [(1.0f32, 0.0f32), (1.01, 1e-4), (0.99, 2e-4)] {
                h.push(&[0.0, 0.0], &[norm, eps]);
            }
            h
        };
        let mut he = build();
        let oe = he.adapt(exact, 1e-6);
        let mut hw = build();
        let ow = hw.adapt(wide, 1e-6);
        assert_eq!(ow, oe, "wide sketch must equal exact adapt");
        assert_eq!(hw.mask(), he.mask());

        // A genuinely narrow sketch: invariants hold (newest kept, never
        // empties) and the coordinate draw is a pure function of the push
        // counter — the same history adapts the same way every time.
        let narrow = WindowRule { gram: GramMode::Sketched { dim: 4 }, ..exact };
        let outs: Vec<AdaptOutcome> = (0..2)
            .map(|_| {
                let mut h = History::new(2, 4, 16);
                let mut rng = Rng::new(77);
                for _ in 0..6 {
                    let z = rng.normal_vec(32, 1.0);
                    let f = rng.normal_vec(32, 1.0);
                    h.push(&z, &f);
                }
                let out = h.adapt(narrow, 1e-6);
                assert!(out.kept >= 1);
                assert_eq!(h.mask()[h.newest_slot()], 1.0);
                out
            })
            .collect();
        assert_eq!(outs[0], outs[1], "sketched adapt must be deterministic");
    }

    #[test]
    fn lane_sketched_adapt_is_deterministic_and_keeps_newest() {
        let rule = WindowRule {
            errorfactor: 1e6,
            cond_max: 2.0,
            gram: GramMode::Sketched { dim: 3 },
        };
        let outs: Vec<AdaptOutcome> = (0..2)
            .map(|_| {
                let mut h = LaneHistory::new(2, 4, 4, 12);
                let mut rng = Rng::new(78);
                for _ in 0..5 {
                    let z = rng.normal_vec(12, 1.0);
                    let f = rng.normal_vec(12, 1.0);
                    h.push_lane(1, &z, &f);
                }
                let out = h.adapt_lane(1, rule, 1e-6);
                assert!(out.kept >= 1);
                assert!(h.live_slots(1).contains(&h.newest_slot(1)));
                // Lane 0 untouched by lane 1's sketch.
                assert!(h.live_slots(0).is_empty());
                out
            })
            .collect();
        assert_eq!(outs[0], outs[1], "lane sketch must be deterministic");
    }

    #[test]
    fn lane_adapt_drops_by_overwriting_with_newest() {
        let rule = WindowRule {
            errorfactor: 10.0,
            cond_max: f32::INFINITY,
            gram: GramMode::Exact,
        };
        let mut h = LaneHistory::new(2, 3, 3, 2);
        // Lane 0: norms 1 (seed), 50 (outlier), 2 (newest) in distinct
        // directions.
        h.push_lane(0, &[0.0, 0.0], &[1.0, 0.0]);
        h.push_lane(0, &[0.0, 0.0], &[0.0, 50.0]);
        h.push_lane(0, &[0.0, 0.0], &[2.0, 0.1]);
        assert_eq!(h.live_slots(0), vec![0, 1, 2]);
        let newest = h.newest_slot(0);
        assert_eq!(newest, 2);
        let out = h.adapt_lane(0, rule, 1e-3);
        assert_eq!(out.dropped_resid, vec![1]);
        assert_eq!(out.kept, 2);
        assert_eq!(h.live_slots(0), vec![0, 2]);
        // The dropped slot now replicates the newest pair, and the
        // shared mask still spans the full effective window.
        let (xh, fh, mask) = h.tensors().unwrap();
        assert_eq!(mask.f32s().unwrap(), &[1.0, 1.0, 1.0]);
        let x = xh.f32s().unwrap();
        let f = fh.f32s().unwrap();
        assert_eq!(&x[2..4], &x[4..6]);
        assert_eq!(&f[2..4], &[2.0, 0.1]);
        // Lane 1 untouched by lane 0's adaptation.
        assert_eq!(h.valid(1), 0);
        assert!(h.live_slots(1).is_empty());
        // Pushing into the dropped slot (ring wraps 3 → slot 0, 4 →
        // slot 1) revives it.
        h.push_lane(0, &[0.0, 0.0], &[1.5, 0.0]);
        h.push_lane(0, &[0.0, 0.0], &[1.4, 0.2]);
        assert_eq!(h.live_slots(0), vec![0, 1, 2]);
    }

    #[test]
    fn lane_adapt_ignores_seed_replicas_and_keeps_one_slot() {
        // A freshly seeded lane has m replicated rows — rank one, which
        // naive condition monitoring would read as catastrophic.  The
        // live-slot accounting must see exactly one distinct entry and
        // leave the lane alone.
        let rule = WindowRule {
            errorfactor: 2.0,
            cond_max: 1.0 + 1e-3,
            gram: GramMode::Exact,
        };
        let mut h = LaneHistory::new(1, 4, 4, 3);
        h.push_lane(0, &[0.0; 3], &[1.0, 2.0, 3.0]);
        assert_eq!(h.live_slots(0), vec![0]);
        let out = h.adapt_lane(0, rule, 1e-3);
        assert_eq!(out.kept, 1);
        assert_eq!(out.dropped(), 0);
        // Even with hostile knobs a lane never loses its last live slot.
        h.push_lane(0, &[0.0; 3], &[1.0 + 1e-4, 2.0, 3.0]);
        let out = h.adapt_lane(0, rule, 1e-8);
        assert!(out.kept >= 1);
        assert!(h.live_slots(0).contains(&h.newest_slot(0)));
    }
}

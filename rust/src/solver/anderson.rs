//! Anderson history windows (paper Alg. 1): the ring buffers behind the
//! mixing policies.
//!
//! The coordinator owns the history window: a ring buffer of the last m
//! (iterate, image) pairs, flattened to `(batch, m, n)` tensors that feed
//! the fused L1 `anderson_update` kernel (Gram → masked solve → Eq. 5
//! mixing).  The warm-up window (k < m) is expressed through the mask
//! vector, so a single compiled artifact serves every iteration.  The
//! solve loops live elsewhere — [`crate::solver::driver`] for batch
//! solves (one [`History`] per cohort), `server::scheduler` for
//! iteration-level serving (one [`LaneHistory`] across all lanes).
//!
//! Cost anatomy per iteration (the paper's "mixing penalty", Fig. 1):
//!   cell_step:        the function evaluation f(z, x)
//!   anderson_update:  2·m·n history streaming + m² Gram + m³ solve
//! The history buffers are the "cacheable iterations": they live in
//! preallocated host ring storage and are re-packed, not re-allocated.

use anyhow::Result;

use crate::runtime::HostTensor;

/// Ring-buffer history for batched Anderson over flattened latents.
///
/// `m` is the *effective* window (ring size); `slots` is the artifact's
/// compiled window (tensor extent).  Slots beyond `m` stay zeroed and
/// masked out, so one compiled artifact serves every window ≤ its size.
pub struct History {
    batch: usize,
    m: usize,
    slots: usize,
    n: usize,
    /// (batch, slots, n) windows, slot-major within each sample.
    xhist: Vec<f32>,
    fhist: Vec<f32>,
    count: usize,
}

impl History {
    pub fn new(batch: usize, m: usize, n: usize) -> Self {
        Self::with_padded_slots(batch, m, m, n)
    }

    /// Effective window `m` inside a tensor padded to `slots` ≥ m.
    pub fn with_padded_slots(batch: usize, m: usize, slots: usize, n: usize) -> Self {
        assert!(m >= 1 && m <= slots);
        Self {
            batch,
            m,
            slots,
            n,
            xhist: vec![0.0; batch * slots * n],
            fhist: vec![0.0; batch * slots * n],
            count: 0,
        }
    }

    pub fn valid(&self) -> usize {
        self.count.min(self.m)
    }

    /// Forget the whole window (restart-on-breakdown): zero the rings
    /// and reset the cursor, reusing the existing allocations — restarts
    /// happen mid-solve, inside the loop that must not allocate.
    pub fn reset(&mut self) {
        self.xhist.fill(0.0);
        self.fhist.fill(0.0);
        self.count = 0;
    }

    /// Record (z, f(z)) — both flat (batch * n).
    pub fn push(&mut self, z: &[f32], fz: &[f32]) {
        let all = vec![true; self.batch];
        self.push_where(z, fz, &all);
    }

    /// Record (z, f(z)) rows only for lanes where `active` is true.
    /// Frozen lanes keep their last window — their mixed output is
    /// discarded by the caller, so stale slots are never observed.
    pub fn push_where(&mut self, z: &[f32], fz: &[f32], active: &[bool]) {
        assert_eq!(z.len(), self.batch * self.n);
        assert_eq!(fz.len(), self.batch * self.n);
        assert_eq!(active.len(), self.batch);
        let slot = self.count % self.m;
        for b in 0..self.batch {
            if !active[b] {
                continue;
            }
            let dst = (b * self.slots + slot) * self.n;
            let src = b * self.n;
            self.xhist[dst..dst + self.n].copy_from_slice(&z[src..src + self.n]);
            self.fhist[dst..dst + self.n]
                .copy_from_slice(&fz[src..src + self.n]);
        }
        self.count += 1;
    }

    /// Mask vector over the padded slots: 1.0 for valid ring entries.
    pub fn mask(&self) -> Vec<f32> {
        let nv = self.valid();
        (0..self.slots)
            .map(|i| if i < nv { 1.0 } else { 0.0 })
            .collect()
    }

    /// Materialize the (batch, slots, n) history tensors for the kernel.
    pub fn tensors(&self) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let shape = vec![self.batch, self.slots, self.n];
        Ok((
            HostTensor::f32(shape.clone(), self.xhist.clone())?,
            HostTensor::f32(shape, self.fhist.clone())?,
            HostTensor::f32(vec![self.slots], self.mask())?,
        ))
    }

    /// Pack the window into preallocated tensors — the allocation-free
    /// twin of [`Self::tensors`] for steady-state solve loops.  Tensor
    /// element counts must match `(batch, slots, n)` / `(slots,)`.
    pub fn fill_tensors(
        &self,
        xh: &mut HostTensor,
        fh: &mut HostTensor,
        mask: &mut HostTensor,
    ) -> Result<()> {
        fill_window(&self.xhist, &self.fhist, self.valid(), self.slots, xh, fh, mask)
    }
}

/// Shared copy core of `History::fill_tensors` / `LaneHistory::fill_tensors`:
/// copy the flat windows into preallocated tensors and rewrite the mask
/// with `nv` valid slots.
fn fill_window(
    xhist: &[f32],
    fhist: &[f32],
    nv: usize,
    slots: usize,
    xh: &mut HostTensor,
    fh: &mut HostTensor,
    mask: &mut HostTensor,
) -> Result<()> {
    let xd = xh.f32s_mut()?;
    anyhow::ensure!(
        xd.len() == xhist.len(),
        "xhist tensor holds {} elements, window has {}",
        xd.len(),
        xhist.len()
    );
    xd.copy_from_slice(xhist);
    let fd = fh.f32s_mut()?;
    anyhow::ensure!(
        fd.len() == fhist.len(),
        "fhist tensor holds {} elements, window has {}",
        fd.len(),
        fhist.len()
    );
    fd.copy_from_slice(fhist);
    let md = mask.f32s_mut()?;
    anyhow::ensure!(
        md.len() == slots,
        "mask tensor holds {} slots, window has {slots}",
        md.len()
    );
    for (i, v) in md.iter_mut().enumerate() {
        *v = if i < nv { 1.0 } else { 0.0 };
    }
    Ok(())
}

/// Per-lane windowed history for iteration-level continuous batching.
///
/// Unlike [`History`], whose lanes share one warm-up (a whole batch is
/// admitted at once), every lane here fills its own ring at its own pace
/// inside one `(lanes, slots, n)` tensor — the lane scheduler admits and
/// retires lanes mid-flight, so fill levels diverge.  The shared kernel
/// mask is the full effective window: a freshly admitted lane's ring is
/// seeded by replicating its first (z, f) pair across all `m` slots,
/// which makes the masked Anderson solve return equal weights over
/// identical rows — exactly a damped forward step — until real history
/// displaces the copies.  Empty lanes hold zeros and mix to zero, which
/// the scheduler discards.
pub struct LaneHistory {
    lanes: usize,
    m: usize,
    slots: usize,
    n: usize,
    xhist: Vec<f32>,
    fhist: Vec<f32>,
    /// Per-lane push count (0 = empty ring).
    count: Vec<usize>,
}

impl LaneHistory {
    /// Effective window `m` inside `slots` ≥ m compiled slots.
    pub fn new(lanes: usize, m: usize, slots: usize, n: usize) -> Self {
        assert!(m >= 1 && m <= slots);
        Self {
            lanes,
            m,
            slots,
            n,
            xhist: vec![0.0; lanes * slots * n],
            fhist: vec![0.0; lanes * slots * n],
            count: vec![0; lanes],
        }
    }

    /// Valid ring entries for one lane.
    pub fn valid(&self, lane: usize) -> usize {
        self.count[lane].min(self.m)
    }

    /// Forget a lane's window (on admit and on retire).
    pub fn clear_lane(&mut self, lane: usize) {
        self.count[lane] = 0;
        let base = lane * self.slots * self.n;
        let len = self.slots * self.n;
        self.xhist[base..base + len].fill(0.0);
        self.fhist[base..base + len].fill(0.0);
    }

    /// Record a lane's (z, f(z)) pair.  The first push seeds every slot
    /// of the lane's window with the pair (see the type docs); later
    /// pushes overwrite the lane's own ring position.
    pub fn push_lane(&mut self, lane: usize, z: &[f32], fz: &[f32]) {
        assert_eq!(z.len(), self.n);
        assert_eq!(fz.len(), self.n);
        if self.count[lane] == 0 {
            for slot in 0..self.m {
                let dst = (lane * self.slots + slot) * self.n;
                self.xhist[dst..dst + self.n].copy_from_slice(z);
                self.fhist[dst..dst + self.n].copy_from_slice(fz);
            }
        } else {
            let slot = self.count[lane] % self.m;
            let dst = (lane * self.slots + slot) * self.n;
            self.xhist[dst..dst + self.n].copy_from_slice(z);
            self.fhist[dst..dst + self.n].copy_from_slice(fz);
        }
        self.count[lane] += 1;
    }

    /// Materialize the (lanes, slots, n) history tensors + shared mask
    /// (all `m` effective slots valid; padded slots masked out).
    pub fn tensors(&self) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let shape = vec![self.lanes, self.slots, self.n];
        let mask: Vec<f32> = (0..self.slots)
            .map(|i| if i < self.m { 1.0 } else { 0.0 })
            .collect();
        Ok((
            HostTensor::f32(shape.clone(), self.xhist.clone())?,
            HostTensor::f32(shape, self.fhist.clone())?,
            HostTensor::f32(vec![self.slots], mask)?,
        ))
    }

    /// Pack the lane windows into preallocated tensors — the
    /// allocation-free twin of [`Self::tensors`] for the scheduler's
    /// steady-state lane loop.
    pub fn fill_tensors(
        &self,
        xh: &mut HostTensor,
        fh: &mut HostTensor,
        mask: &mut HostTensor,
    ) -> Result<()> {
        fill_window(&self.xhist, &self.fhist, self.m, self.slots, xh, fh, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_ring_and_mask() {
        let mut h = History::new(2, 3, 4);
        assert_eq!(h.valid(), 0);
        let z = vec![1.0; 8];
        let f = vec![2.0; 8];
        h.push(&z, &f);
        assert_eq!(h.valid(), 1);
        assert_eq!(h.mask(), vec![1.0, 0.0, 0.0]);
        h.push(&z, &f);
        h.push(&z, &f);
        h.push(&z, &f); // wraps
        assert_eq!(h.valid(), 3);
        assert_eq!(h.mask(), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn history_reset_clears_in_place() {
        let mut h = History::with_padded_slots(2, 2, 3, 2);
        h.push(&[1.0; 4], &[2.0; 4]);
        h.push(&[3.0; 4], &[4.0; 4]);
        assert_eq!(h.valid(), 2);
        h.reset();
        assert_eq!(h.valid(), 0);
        let (xh, fh, mask) = h.tensors().unwrap();
        assert!(xh.f32s().unwrap().iter().all(|&v| v == 0.0));
        assert!(fh.f32s().unwrap().iter().all(|&v| v == 0.0));
        assert_eq!(mask.f32s().unwrap(), &[0.0, 0.0, 0.0]);
        // The ring is usable again after reset.
        h.push(&[5.0; 4], &[6.0; 4]);
        assert_eq!(h.valid(), 1);
    }

    #[test]
    fn history_layout_is_batch_major() {
        let mut h = History::new(2, 2, 3);
        let z: Vec<f32> = (0..6).map(|v| v as f32).collect(); // sample0: 0,1,2
        let f: Vec<f32> = (10..16).map(|v| v as f32).collect();
        h.push(&z, &f);
        let (xh, fh, mask) = h.tensors().unwrap();
        assert_eq!(xh.shape, vec![2, 2, 3]);
        // sample 0, slot 0 = z[0..3]
        assert_eq!(&xh.f32s().unwrap()[0..3], &[0.0, 1.0, 2.0]);
        // sample 1, slot 0 = z[3..6] at offset (1*2+0)*3 = 6
        assert_eq!(&xh.f32s().unwrap()[6..9], &[3.0, 4.0, 5.0]);
        assert_eq!(&fh.f32s().unwrap()[0..3], &[10.0, 11.0, 12.0]);
        assert_eq!(mask.f32s().unwrap(), &[1.0, 0.0]);
    }

    #[test]
    fn padded_history_masks_unused_slots() {
        // Effective window 2 inside 5 compiled slots: ring wraps at 2,
        // slots 2..5 stay zero and masked out forever.
        let mut h = History::with_padded_slots(1, 2, 5, 3);
        for step in 0..4 {
            let v = vec![step as f32; 3];
            h.push(&v, &v);
        }
        assert_eq!(h.valid(), 2);
        let (xh, _, mask) = h.tensors().unwrap();
        assert_eq!(xh.shape, vec![1, 5, 3]);
        assert_eq!(mask.f32s().unwrap(), &[1.0, 1.0, 0.0, 0.0, 0.0]);
        let x = xh.f32s().unwrap();
        // Ring of size 2: slot 0 holds step 2, slot 1 holds step 3.
        assert_eq!(&x[0..3], &[2.0, 2.0, 2.0]);
        assert_eq!(&x[3..6], &[3.0, 3.0, 3.0]);
        assert_eq!(&x[6..15], &[0.0; 9]);
    }

    #[test]
    fn fill_tensors_matches_tensors() {
        // The in-place pack must agree exactly with the allocating one,
        // including the mask as the window fills.
        let mut h = History::with_padded_slots(2, 2, 4, 3);
        let mut xh = HostTensor::zeros(vec![2, 4, 3]);
        let mut fh = HostTensor::zeros(vec![2, 4, 3]);
        let mut mask = HostTensor::zeros(vec![4]);
        for step in 0..3 {
            let z = vec![step as f32; 6];
            let f = vec![10.0 + step as f32; 6];
            h.push(&z, &f);
            let (xw, fw, mw) = h.tensors().unwrap();
            h.fill_tensors(&mut xh, &mut fh, &mut mask).unwrap();
            assert_eq!(xh.f32s().unwrap(), xw.f32s().unwrap());
            assert_eq!(fh.f32s().unwrap(), fw.f32s().unwrap());
            assert_eq!(mask.f32s().unwrap(), mw.f32s().unwrap());
        }
        // Wrong-sized targets are rejected, not silently truncated.
        let mut small = HostTensor::zeros(vec![2, 2, 3]);
        assert!(h.fill_tensors(&mut small, &mut fh, &mut mask).is_err());

        let mut lh = LaneHistory::new(2, 2, 3, 2);
        lh.push_lane(1, &[5.0, 6.0], &[7.0, 8.0]);
        let (xw, fw, mw) = lh.tensors().unwrap();
        let mut lxh = HostTensor::zeros(vec![2, 3, 2]);
        let mut lfh = HostTensor::zeros(vec![2, 3, 2]);
        let mut lmask = HostTensor::zeros(vec![3]);
        lh.fill_tensors(&mut lxh, &mut lfh, &mut lmask).unwrap();
        assert_eq!(lxh.f32s().unwrap(), xw.f32s().unwrap());
        assert_eq!(lfh.f32s().unwrap(), fw.f32s().unwrap());
        assert_eq!(lmask.f32s().unwrap(), mw.f32s().unwrap());
    }

    #[test]
    fn masked_push_freezes_lane_window() {
        let mut h = History::new(2, 2, 2);
        h.push(&[1.0, 1.0, 9.0, 9.0], &[2.0, 2.0, 8.0, 8.0]);
        // Lane 1 frozen: its slots keep the first pair, lane 0 advances.
        h.push_where(&[3.0, 3.0, 7.0, 7.0], &[4.0, 4.0, 6.0, 6.0], &[true, false]);
        let (xh, _, _) = h.tensors().unwrap();
        let x = xh.f32s().unwrap();
        // Lane 0: slot 0 = first push, slot 1 = second push.
        assert_eq!(&x[0..4], &[1.0, 1.0, 3.0, 3.0]);
        // Lane 1: slot 0 = first push, slot 1 untouched (zeros).
        assert_eq!(&x[4..8], &[9.0, 9.0, 0.0, 0.0]);
        // The global ring cursor still advanced for the batch.
        assert_eq!(h.valid(), 2);
    }

    #[test]
    fn lane_history_seeds_fresh_lane_by_replication() {
        let mut h = LaneHistory::new(2, 3, 3, 2);
        assert_eq!(h.valid(0), 0);
        h.push_lane(0, &[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(h.valid(0), 1);
        let (xh, fh, mask) = h.tensors().unwrap();
        assert_eq!(mask.f32s().unwrap(), &[1.0, 1.0, 1.0]);
        let x = xh.f32s().unwrap();
        let f = fh.f32s().unwrap();
        // Every slot of lane 0 holds the replicated first pair.
        for slot in 0..3 {
            assert_eq!(&x[slot * 2..slot * 2 + 2], &[1.0, 2.0]);
            assert_eq!(&f[slot * 2..slot * 2 + 2], &[3.0, 4.0]);
        }
        // Lane 1 untouched (zeros).
        assert!(x[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lane_history_rings_independently_and_clears() {
        let mut h = LaneHistory::new(2, 2, 2, 1);
        h.push_lane(0, &[1.0], &[1.0]);
        h.push_lane(0, &[2.0], &[2.0]);
        h.push_lane(0, &[3.0], &[3.0]); // wraps into slot 1 of lane 0
        h.push_lane(1, &[9.0], &[9.0]); // lane 1 still replicating
        let (xh, _, _) = h.tensors().unwrap();
        let x = xh.f32s().unwrap();
        // Lane 0 ring: the seed push filled both slots, push 2 landed in
        // slot 1 (count=1), push 3 wrapped into slot 0 (count=2).
        assert_eq!(&x[0..2], &[3.0, 2.0]);
        // Lane 1: both slots replicated from its first push.
        assert_eq!(&x[2..4], &[9.0, 9.0]);
        h.clear_lane(0);
        assert_eq!(h.valid(0), 0);
        let (xh, _, _) = h.tensors().unwrap();
        assert_eq!(&xh.f32s().unwrap()[0..2], &[0.0, 0.0]);
        assert_eq!(h.valid(1), 1);
    }
}

//! Fixed-point solver drivers over the AOT artifacts — the coordinator
//! half of the paper's contribution.
//!
//! The Python/Pallas layer owns the *math* of one step (`cell_step`,
//! `anderson_update`); this module owns the *policy*: when to evaluate,
//! when to mix, when to stop, what to record.  Three drivers:
//!
//! * [`forward`] — the paper's baseline, z ← f(z,x), optionally through
//!   the fused `forward_solve_k` artifact (K steps per PJRT dispatch).
//! * [`anderson`] — windowed Anderson extrapolation (Alg. 1): ring-buffer
//!   history management on the host, mixing via the fused L1 kernel.
//! * [`policy`] — the paper's §4 suggestion: run Anderson, watch for
//!   stagnation, fall back to damped forward steps.
//!
//! Each solve returns a [`SolveReport`] with the per-iteration residual /
//! wallclock trace — the raw series behind Figs. 1, 6 and 7.

pub mod anderson;
pub mod crossover;
pub mod forward;
pub mod policy;

use std::time::Duration;

use anyhow::Result;

use crate::runtime::{Engine, HostTensor};

/// Which solver to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Forward,
    Anderson,
    /// Anderson with stagnation fallback (paper §4).
    Hybrid,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "forward" => Some(Self::Forward),
            "anderson" => Some(Self::Anderson),
            "hybrid" => Some(Self::Hybrid),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Forward => "forward",
            Self::Anderson => "anderson",
            Self::Hybrid => "hybrid",
        }
    }
}

/// Runtime solver options (seeded from the manifest's SolverMeta).
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    pub kind: SolverKind,
    pub window: usize,
    pub tol: f32,
    pub max_iter: usize,
    pub lam: f32,
    /// Use the fused K-step artifact for forward solves when available.
    pub fused_forward: bool,
    /// Stagnation threshold for the hybrid policy: minimum relative
    /// improvement per window before switching.
    pub stagnation_eps: f32,
}

impl SolveOptions {
    pub fn from_manifest(engine: &Engine, kind: SolverKind) -> Self {
        let s = &engine.manifest().solver;
        Self {
            kind,
            window: s.window,
            tol: s.tol,
            max_iter: s.max_iter,
            lam: s.lam,
            fused_forward: true,
            stagnation_eps: 0.03,
        }
    }
}

/// One recorded solver iteration.
#[derive(Debug, Clone)]
pub struct SolveStep {
    pub iter: usize,
    /// Max-over-batch relative residual ‖f−z‖/(‖f‖+λ).
    pub rel_residual: f32,
    /// Cumulative wallclock since solve start.
    pub elapsed: Duration,
    /// Cumulative cell evaluations (per sample).
    pub fevals: usize,
    /// True if this step applied Anderson mixing (vs a plain forward step).
    pub mixed: bool,
}

/// Outcome of one equilibrium solve.
#[derive(Debug, Clone)]
pub struct SolveReport {
    pub kind: SolverKind,
    pub steps: Vec<SolveStep>,
    pub converged: bool,
    pub z_star: HostTensor,
}

impl SolveReport {
    pub fn iters(&self) -> usize {
        self.steps.len()
    }

    pub fn fevals(&self) -> usize {
        self.steps.last().map(|s| s.fevals).unwrap_or(0)
    }

    pub fn final_residual(&self) -> f32 {
        self.steps.last().map(|s| s.rel_residual).unwrap_or(f32::NAN)
    }

    pub fn total_time(&self) -> Duration {
        self.steps.last().map(|s| s.elapsed).unwrap_or(Duration::ZERO)
    }

    /// Wallclock to first residual ≤ target (None if never reached).
    pub fn time_to(&self, target: f32) -> Option<Duration> {
        self.steps
            .iter()
            .find(|s| s.rel_residual <= target)
            .map(|s| s.elapsed)
    }

    /// Best residual achieved.
    pub fn best_residual(&self) -> f32 {
        self.steps
            .iter()
            .map(|s| s.rel_residual)
            .fold(f32::INFINITY, f32::min)
    }
}

/// Dispatch a solve by kind.
pub fn solve(
    engine: &Engine,
    params: &[HostTensor],
    x_feat: &HostTensor,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    match opts.kind {
        SolverKind::Forward => forward::solve(engine, params, x_feat, opts),
        SolverKind::Anderson => anderson::solve(engine, params, x_feat, opts),
        SolverKind::Hybrid => policy::solve(engine, params, x_feat, opts),
    }
}

/// Max-over-batch relative residual from the fused cell_step outputs.
pub(crate) fn max_rel_residual(
    res_num: &HostTensor,
    f_norm: &HostTensor,
    lam: f32,
) -> Result<f32> {
    let num = res_num.f32s()?;
    let den = f_norm.f32s()?;
    Ok(num
        .iter()
        .zip(den)
        .map(|(n, d)| n / (d + lam))
        .fold(0.0f32, f32::max))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [SolverKind::Forward, SolverKind::Anderson, SolverKind::Hybrid] {
            assert_eq!(SolverKind::parse(k.name()), Some(k));
        }
        assert_eq!(SolverKind::parse("nope"), None);
    }

    #[test]
    fn max_rel_residual_takes_max() {
        let num = HostTensor::f32(vec![3], vec![1.0, 4.0, 2.0]).unwrap();
        let den = HostTensor::f32(vec![3], vec![1.0, 1.0, 1.0]).unwrap();
        let r = max_rel_residual(&num, &den, 0.0).unwrap();
        assert!((r - 4.0).abs() < 1e-6);
    }

    #[test]
    fn report_accessors_empty() {
        let r = SolveReport {
            kind: SolverKind::Forward,
            steps: vec![],
            converged: false,
            z_star: HostTensor::zeros(vec![1]),
        };
        assert_eq!(r.iters(), 0);
        assert!(r.final_residual().is_nan());
        assert_eq!(r.total_time(), Duration::ZERO);
        assert!(r.time_to(1.0).is_none());
    }
}

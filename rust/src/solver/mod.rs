//! Fixed-point equilibrium solves over any [`Backend`] — the coordinator
//! half of the paper's contribution.
//!
//! The execution backend owns the *math* of one step (`cell_step`,
//! `anderson_update`); this module owns the *policy*: when to evaluate,
//! when to mix, when to stop, what to record.  The API is composable:
//!
//! * [`SolveSpec`] ([`spec`]) — a declarative, validated, JSON-round-
//!   trippable description of one solve: kind, window, tol, iteration
//!   and feval budgets, damping schedule, stagnation rule, restart-on-
//!   breakdown.  Build one with [`SolveSpec::from_manifest`] or
//!   [`SolveSpec::builder`].
//! * [`SolvePolicy`] ([`policy`]) — the per-lane decision state machine a
//!   spec describes.  [`ForwardPolicy`] is the paper's baseline;
//!   [`AndersonPolicy`] is windowed Anderson (Alg. 1), and with its
//!   stagnation rule armed it is the paper-§4 hybrid.
//! * [`driver`] — the one generic driver loop ([`solve_spec`]) that
//!   executes any policy: ring-buffer history management on the host,
//!   mixing via the fused kernel entry, per-sample lane freezing.
//!
//! Specs also ride serving requests: [`SolveOverrides`] carries a
//! client's per-request solver/tol/max_iter, resolved against the
//! server's default spec under operator [`SolveClamps`].
//!
//! Each solve returns a [`SolveReport`] with the per-iteration residual /
//! wallclock trace — the raw series behind Figs. 1, 6 and 7.  Reports
//! round-trip through JSON (see [`SolveReport::to_json`]) so experiment
//! output formats are pinned by golden tests.
//!
//! The old flat [`SolveOptions`] + [`solve`] entry points remain as
//! deprecated shims over `SolveSpec`/[`solve_spec`].

pub mod anderson;
pub mod crossover;
pub mod driver;
pub mod policy;
pub mod select;
pub mod spec;

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::runtime::{Backend, HostTensor};
use crate::util::json::{self, Json};

pub use anderson::AdaptOutcome;
pub use driver::{drive, solve_spec};
pub use policy::{
    policy_for, AdaptiveAndersonPolicy, AndersonPolicy, ForwardPolicy,
    LaneStep, SolvePolicy, WindowRule,
};
pub use select::{
    AutoPolicy, AutoStats, ProfileStore, WorkloadPrior, WorkloadProfile,
};
pub use spec::{
    Damping, GramMode, SolveClamps, SolveOverrides, SolveSpec,
    SolveSpecBuilder, StagnationRule, DEFAULT_COND_MAX, DEFAULT_ERRORFACTOR,
};

/// Which solver to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Forward,
    Anderson,
    /// Anderson with stagnation fallback (paper §4).
    Hybrid,
    /// Online auto-selection: probe forward, fit the contraction rate,
    /// switch across the Fig. 1 crossover mid-solve (see [`select`]).
    Auto,
}

impl SolverKind {
    /// Every parseable kind, in canonical order.  The single source for
    /// CLI/wire "expected ..." error messages — see [`Self::expected`].
    pub const ALL: [Self; 4] =
        [Self::Forward, Self::Anderson, Self::Hybrid, Self::Auto];

    /// The accepted kind names, `|`-joined, for error payloads:
    /// `"forward|anderson|hybrid|auto"`.
    pub const fn expected() -> &'static str {
        "forward|anderson|hybrid|auto"
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "forward" => Some(Self::Forward),
            "anderson" => Some(Self::Anderson),
            "hybrid" => Some(Self::Hybrid),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Forward => "forward",
            Self::Anderson => "anderson",
            Self::Hybrid => "hybrid",
            Self::Auto => "auto",
        }
    }
}

/// Flat pre-[`SolveSpec`] solver options — kept as a compatibility shim
/// so external callers of the old API keep compiling; everything in-tree
/// builds a `SolveSpec` instead.
#[deprecated(
    note = "use SolveSpec (builder + validation + JSON round-trip) instead"
)]
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    pub kind: SolverKind,
    pub window: usize,
    pub tol: f32,
    pub max_iter: usize,
    pub lam: f32,
    /// Use the fused K-step entry for forward solves when available.
    pub fused_forward: bool,
    /// Stagnation threshold for the hybrid policy: minimum relative
    /// improvement per window before switching.
    pub stagnation_eps: f32,
}

#[allow(deprecated)]
impl SolveOptions {
    pub fn from_manifest(engine: &dyn Backend, kind: SolverKind) -> Self {
        let s = &engine.manifest().solver;
        Self {
            kind,
            window: s.window,
            tol: s.tol,
            max_iter: s.max_iter,
            lam: s.lam,
            fused_forward: true,
            stagnation_eps: 0.03,
        }
    }
}

#[allow(deprecated)]
impl From<SolveOptions> for SolveSpec {
    fn from(o: SolveOptions) -> Self {
        SolveSpec {
            kind: o.kind,
            window: o.window,
            tol: o.tol,
            max_iter: o.max_iter,
            max_fevals: 0,
            lam: o.lam,
            fused_forward: o.fused_forward,
            damping: Damping::Full,
            stagnation: StagnationRule { window: 0, eps: o.stagnation_eps },
            restart_on_breakdown: false,
            adaptive_window: false,
            errorfactor: spec::DEFAULT_ERRORFACTOR,
            cond_max: spec::DEFAULT_COND_MAX,
            safeguard: false,
            gram: GramMode::Exact,
        }
    }
}

/// Per-sample convergence state threaded through every solve driver.
///
/// This replaces the old max-over-batch scalar residual: each lane keeps
/// its own relative residual, feval count, iteration count and converged
/// flag, so a solve can freeze lanes the iteration they cross `tol`
/// (their fevals stop counting, their Anderson history stops updating)
/// while the rest of the batch keeps iterating.  The same machinery backs
/// iteration-level serving (see `server::scheduler`).
#[derive(Debug, Clone)]
pub struct ResidualTrack {
    tol: f32,
    rel: Vec<f32>,
    fevals: Vec<usize>,
    iters: Vec<usize>,
    converged: Vec<bool>,
    /// Quarantined lanes: a non-finite residual appeared, the lane was
    /// retired alone, and nothing about it feeds cohort decisions again.
    faulted: Vec<bool>,
}

impl ResidualTrack {
    pub fn new(batch: usize, tol: f32) -> Self {
        Self {
            tol,
            rel: vec![f32::INFINITY; batch],
            fevals: vec![0; batch],
            iters: vec![0; batch],
            converged: vec![false; batch],
            faulted: vec![false; batch],
        }
    }

    pub fn batch(&self) -> usize {
        self.converged.len()
    }

    /// Record one backend step: per-sample residuals from the fused norm
    /// outputs, charging `evals` cell evaluations to every still-active
    /// lane and freezing lanes that cross `tol`.  Frozen lanes are left
    /// untouched.  Returns the raw per-sample residual vector (all lanes,
    /// frozen included — callers record it in the step trace).
    pub fn observe(
        &mut self,
        res_num: &HostTensor,
        f_norm: &HostTensor,
        lam: f32,
        evals: usize,
    ) -> Result<Vec<f32>> {
        let rel = per_sample_rel(res_num, f_norm, lam)?;
        anyhow::ensure!(
            rel.len() == self.batch(),
            "residual batch {} != track batch {}",
            rel.len(),
            self.batch()
        );
        for (s, &r) in rel.iter().enumerate() {
            if self.converged[s] || self.faulted[s] {
                continue;
            }
            self.rel[s] = r;
            self.fevals[s] += evals;
            self.iters[s] += 1;
            if !r.is_finite() {
                // Numerical breakdown: quarantine the lane the step the
                // NaN/Inf appears, so it never reaches the cohort
                // max-residual nor another Anderson history push.
                self.faulted[s] = true;
            } else if r < self.tol {
                self.converged[s] = true;
            }
        }
        Ok(rel)
    }

    /// [`Self::observe`] plus the freeze bookkeeping every driver needs:
    /// snapshots which lanes were frozen before the step and which froze
    /// on it, so the caller can merge the next iterate with one
    /// [`FreezeTransition::apply`] instead of hand-rolled mask zips.
    pub fn observe_step(
        &mut self,
        res_num: &HostTensor,
        f_norm: &HostTensor,
        lam: f32,
        evals: usize,
    ) -> Result<(Vec<f32>, FreezeTransition)> {
        // "Frozen" for masking purposes means *settled* — converged or
        // quarantined — so a faulted lane also stops being rewritten and
        // stops feeding the history ring.
        let frozen_before: Vec<bool> = self
            .converged
            .iter()
            .zip(&self.faulted)
            .map(|(&c, &f)| c || f)
            .collect();
        let rel = self.observe(res_num, f_norm, lam, evals)?;
        let newly_frozen = frozen_before
            .iter()
            .enumerate()
            .map(|(s, &before)| {
                !before && (self.converged[s] || self.faulted[s])
            })
            .collect();
        Ok((rel, FreezeTransition { frozen_before, newly_frozen }))
    }

    /// Per-sample relative residual at each lane's last *active* step.
    pub fn rel(&self) -> &[f32] {
        &self.rel
    }

    /// Per-sample cell evaluations (frozen lanes stop accumulating).
    pub fn fevals(&self) -> &[usize] {
        &self.fevals
    }

    /// Per-sample iteration counts (frozen lanes stop accumulating).
    pub fn iters(&self) -> &[usize] {
        &self.iters
    }

    /// Per-sample converged (frozen) flags.
    pub fn converged(&self) -> &[bool] {
        &self.converged
    }

    pub fn all_converged(&self) -> bool {
        self.converged.iter().all(|&c| c)
    }

    /// Per-sample quarantine flags — lanes retired on a non-finite
    /// residual (see [`Self::observe`]).
    pub fn faulted(&self) -> &[bool] {
        &self.faulted
    }

    /// Lanes quarantined so far.
    pub fn quarantined_count(&self) -> usize {
        self.faulted.iter().filter(|&&f| f).count()
    }

    /// True when every lane is terminal — converged *or* quarantined.
    /// Drive loops exit on this; [`Self::all_converged`] stays strict so
    /// a report never claims convergence for a poisoned batch.
    pub fn all_settled(&self) -> bool {
        self.converged.iter().zip(&self.faulted).all(|(&c, &f)| c || f)
    }

    /// Lanes still iterating (neither converged nor quarantined).
    pub fn active_count(&self) -> usize {
        self.active_mask().iter().filter(|&&a| a).count()
    }

    /// Per-sample still-active mask — the lanes whose Anderson history
    /// should keep updating (neither converged nor quarantined, so a
    /// poisoned iterate never enters the history ring).
    pub fn active_mask(&self) -> Vec<bool> {
        self.converged
            .iter()
            .zip(&self.faulted)
            .map(|(&c, &f)| !c && !f)
            .collect()
    }

    /// Max residual over the non-quarantined lanes (frozen lanes hold
    /// their freeze-time value, which is below `tol` by construction).
    /// Faulted lanes are excluded explicitly: `f32::max` would ignore a
    /// NaN but keep a +Inf, and either way one poisoned sample must not
    /// drive cohort stagnation/restart decisions.
    pub fn max_rel(&self) -> f32 {
        self.rel
            .iter()
            .zip(&self.faulted)
            .filter(|&(_, &f)| !f)
            .map(|(&r, _)| r)
            .fold(0.0f32, f32::max)
    }

    /// Total cell evaluations actually charged across the batch.
    pub fn total_fevals(&self) -> usize {
        self.fevals.iter().sum()
    }
}

/// The lane-freeze bookkeeping of one observed step: which lanes were
/// already frozen before it and which froze on it.
#[derive(Debug, Clone)]
pub struct FreezeTransition {
    pub frozen_before: Vec<bool>,
    pub newly_frozen: Vec<bool>,
}

impl FreezeTransition {
    /// Merge freeze semantics into the next iterate: lanes that froze on
    /// this step take their row of `f` (the terminal step takes f
    /// directly), lanes frozen earlier keep their row of `prev`; all
    /// other rows of `next` are left as the caller computed them.
    pub fn apply(
        &self,
        next: &mut HostTensor,
        f: &HostTensor,
        prev: &HostTensor,
    ) -> Result<()> {
        next.overwrite_rows_where(f, &self.newly_frozen)?;
        next.overwrite_rows_where(prev, &self.frozen_before)
    }
}

/// One recorded solver iteration.
#[derive(Debug, Clone)]
pub struct SolveStep {
    pub iter: usize,
    /// Max-over-batch relative residual ‖f−z‖/(‖f‖+λ).
    pub rel_residual: f32,
    /// Per-sample relative residuals at this iteration (lane order).
    pub sample_residuals: Vec<f32>,
    /// Lanes still iterating after this step (unfrozen count).
    pub active: usize,
    /// Cumulative wallclock since solve start.
    pub elapsed: Duration,
    /// Cumulative cell evaluations for a lane active since the start
    /// (frozen lanes stop earlier — see `SolveReport::sample_fevals`).
    pub fevals: usize,
    /// True if Anderson mixing produced this step's *next* iterate —
    /// false for plain forward steps and for the terminal step (which
    /// takes f directly).  Note step 0's output IS mixed once its
    /// (z, f) pair is in the history window.
    pub mixed: bool,
}

impl SolveStep {
    /// JSON object form (keys sorted; `elapsed` as seconds).
    pub fn to_json(&self) -> Json {
        let samples: Vec<Json> = self
            .sample_residuals
            .iter()
            .map(|&r| json::num(r as f64))
            .collect();
        json::obj(vec![
            ("active", json::num(self.active as f64)),
            ("elapsed_s", json::num(self.elapsed.as_secs_f64())),
            ("fevals", json::num(self.fevals as f64)),
            ("iter", json::num(self.iter as f64)),
            ("mixed", Json::Bool(self.mixed)),
            ("rel_residual", json::num(self.rel_residual as f64)),
            ("sample_residuals", Json::Arr(samples)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let f64field = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("SolveStep missing '{key}'"))
        };
        // Per-sample fields entered the format with the iteration-level
        // scheduler; older traces without them parse as batch-scalar steps.
        let sample_residuals = match v.get("sample_residuals") {
            Some(arr) => arr
                .as_arr()
                .ok_or_else(|| anyhow!("'sample_residuals' is not an array"))?
                .iter()
                .map(|d| {
                    // Non-finite residuals (quarantined lanes) serialize
                    // as JSON null; read them back as NaN.
                    if matches!(d, Json::Null) {
                        return Ok(f32::NAN);
                    }
                    d.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| anyhow!("bad sample residual"))
                })
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        let active = v
            .get("active")
            .and_then(Json::as_usize)
            .unwrap_or(0);
        Ok(Self {
            iter: f64field("iter")? as usize,
            rel_residual: f64field("rel_residual")? as f32,
            sample_residuals,
            active,
            elapsed: Duration::from_secs_f64(f64field("elapsed_s")?),
            fevals: f64field("fevals")? as usize,
            mixed: v
                .get("mixed")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("SolveStep missing 'mixed'"))?,
        })
    }
}

/// Outcome of one equilibrium solve.
#[derive(Debug, Clone)]
pub struct SolveReport {
    pub kind: SolverKind,
    pub steps: Vec<SolveStep>,
    /// True only when *every* sample converged.
    pub converged: bool,
    pub z_star: HostTensor,
    /// Per-sample iterations until the lane froze (or the solve ended).
    pub sample_iters: Vec<usize>,
    /// Per-sample cell evaluations actually charged.
    pub sample_fevals: Vec<usize>,
    /// Per-sample converged flags.
    pub sample_converged: Vec<bool>,
    /// Per-sample quarantine flags (non-finite residual — the lane was
    /// retired with a numerical fault; its `z_star` row is garbage).
    pub sample_faulted: Vec<bool>,
}

impl SolveReport {
    /// Assemble a report from a finished drive and its residual track.
    pub fn from_track(
        kind: SolverKind,
        steps: Vec<SolveStep>,
        z_star: HostTensor,
        track: &ResidualTrack,
    ) -> Self {
        Self {
            kind,
            steps,
            converged: track.all_converged(),
            z_star,
            sample_iters: track.iters().to_vec(),
            sample_fevals: track.fevals().to_vec(),
            sample_converged: track.converged().to_vec(),
            sample_faulted: track.faulted().to_vec(),
        }
    }

    /// Lanes quarantined on a numerical fault.
    pub fn quarantined(&self) -> usize {
        self.sample_faulted.iter().filter(|&&f| f).count()
    }

    pub fn iters(&self) -> usize {
        self.steps.len()
    }

    pub fn fevals(&self) -> usize {
        self.steps.last().map(|s| s.fevals).unwrap_or(0)
    }

    /// Total cell evaluations actually charged across the batch (the
    /// iteration-level accounting; falls back to the lockstep count when
    /// no per-sample trace is present, e.g. on legacy JSON reports).
    pub fn fevals_total(&self) -> usize {
        if self.sample_fevals.is_empty() {
            self.fevals() * self.z_star.shape.first().copied().unwrap_or(1)
        } else {
            self.sample_fevals.iter().sum()
        }
    }

    pub fn final_residual(&self) -> f32 {
        self.steps.last().map(|s| s.rel_residual).unwrap_or(f32::NAN)
    }

    pub fn total_time(&self) -> Duration {
        self.steps.last().map(|s| s.elapsed).unwrap_or(Duration::ZERO)
    }

    /// Wallclock to first residual ≤ target (None if never reached).
    pub fn time_to(&self, target: f32) -> Option<Duration> {
        self.steps
            .iter()
            .find(|s| s.rel_residual <= target)
            .map(|s| s.elapsed)
    }

    /// Best residual achieved.
    pub fn best_residual(&self) -> f32 {
        self.steps
            .iter()
            .map(|s| s.rel_residual)
            .fold(f32::INFINITY, f32::min)
    }

    /// JSON form of the full report (the experiment trace format).
    /// `z_star` serializes as f32 data + shape — the only latent dtype.
    pub fn to_json(&self) -> Json {
        let steps = Json::Arr(self.steps.iter().map(SolveStep::to_json).collect());
        let data: Vec<Json> = self
            .z_star
            .f32s()
            .map(|d| d.iter().map(|&v| json::num(v as f64)).collect())
            .unwrap_or_default();
        let shape: Vec<Json> = self
            .z_star
            .shape
            .iter()
            .map(|&d| json::num(d as f64))
            .collect();
        let usizes = |v: &[usize]| {
            Json::Arr(v.iter().map(|&u| json::num(u as f64)).collect())
        };
        let bools = |v: &[bool]| {
            Json::Arr(v.iter().map(|&b| Json::Bool(b)).collect())
        };
        let mut fields = vec![
            ("converged", Json::Bool(self.converged)),
            ("kind", json::s(self.kind.name())),
            ("sample_converged", bools(&self.sample_converged)),
        ];
        // Quarantine flags are emitted only when a lane actually faulted,
        // so fault-free traces stay byte-identical to the pinned goldens.
        if self.sample_faulted.iter().any(|&f| f) {
            fields.push(("sample_faulted", bools(&self.sample_faulted)));
        }
        fields.extend([
            ("sample_fevals", usizes(&self.sample_fevals)),
            ("sample_iters", usizes(&self.sample_iters)),
            ("steps", steps),
            (
                "z_star",
                json::obj(vec![("data", Json::Arr(data)), ("shape", Json::Arr(shape))]),
            ),
        ]);
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let kind_name = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("SolveReport missing 'kind'"))?;
        let kind = SolverKind::parse(kind_name)
            .ok_or_else(|| anyhow!("unknown solver kind '{kind_name}'"))?;
        let steps = v
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("SolveReport missing 'steps'"))?
            .iter()
            .map(SolveStep::from_json)
            .collect::<Result<Vec<_>>>()?;
        let z = v
            .get("z_star")
            .ok_or_else(|| anyhow!("SolveReport missing 'z_star'"))?;
        let shape: Vec<usize> = z
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("z_star missing 'shape'"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad z_star dim")))
            .collect::<Result<Vec<_>>>()?;
        let data: Vec<f32> = z
            .get("data")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("z_star missing 'data'"))?
            .iter()
            .map(|d| {
                d.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| anyhow!("bad z_star value"))
            })
            .collect::<Result<Vec<_>>>()?;
        // Per-sample traces are optional so pre-scheduler reports parse.
        let sample_usizes = |key: &str| -> Result<Vec<usize>> {
            match v.get(key) {
                Some(arr) => arr
                    .as_arr()
                    .ok_or_else(|| anyhow!("'{key}' is not an array"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad '{key}' value")))
                    .collect(),
                None => Ok(Vec::new()),
            }
        };
        let sample_bools = |key: &str| -> Result<Vec<bool>> {
            match v.get(key) {
                Some(arr) => arr
                    .as_arr()
                    .ok_or_else(|| anyhow!("'{key}' is not an array"))?
                    .iter()
                    .map(|d| {
                        d.as_bool()
                            .ok_or_else(|| anyhow!("bad '{key}' value"))
                    })
                    .collect(),
                None => Ok(Vec::new()),
            }
        };
        let sample_converged = sample_bools("sample_converged")?;
        let sample_faulted = sample_bools("sample_faulted")?;
        Ok(Self {
            kind,
            steps,
            converged: v
                .get("converged")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("SolveReport missing 'converged'"))?,
            z_star: HostTensor::f32(shape, data)?,
            sample_iters: sample_usizes("sample_iters")?,
            sample_fevals: sample_usizes("sample_fevals")?,
            sample_converged,
            sample_faulted,
        })
    }
}

/// Dispatch a solve from the flat pre-[`SolveSpec`] options — a thin
/// deprecated shim over [`solve_spec`].  The converted spec carries the
/// exact pre-redesign defaults (no damping, no restart, cohort
/// stagnation on the spec window), so reports are bit-identical to the
/// old per-kind drivers.
#[deprecated(note = "use solve_spec with a SolveSpec")]
#[allow(deprecated)]
pub fn solve(
    engine: &dyn Backend,
    params: &[HostTensor],
    x_feat: &HostTensor,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    solve_spec(engine, params, x_feat, &SolveSpec::from(*opts))
}

/// Per-sample relative residuals ‖f−z‖/(‖f‖+λ) from the fused cell_step
/// norm outputs.  Lane order matches the batch axis.
pub fn per_sample_rel(
    res_num: &HostTensor,
    f_norm: &HostTensor,
    lam: f32,
) -> Result<Vec<f32>> {
    let num = res_num.f32s()?;
    let den = f_norm.f32s()?;
    anyhow::ensure!(
        num.len() == den.len(),
        "residual norm outputs disagree on batch ({} vs {})",
        num.len(),
        den.len()
    );
    Ok(num.iter().zip(den).map(|(n, d)| n / (d + lam)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[allow(deprecated)]
    fn solve_options_shim_converts_faithfully() {
        let o = SolveOptions {
            kind: SolverKind::Hybrid,
            window: 4,
            tol: 1e-3,
            max_iter: 50,
            lam: 1e-5,
            fused_forward: false,
            stagnation_eps: 0.07,
        };
        let spec = SolveSpec::from(o);
        assert_eq!(spec.kind, SolverKind::Hybrid);
        assert_eq!(spec.window, 4);
        assert_eq!(spec.tol, 1e-3);
        assert_eq!(spec.max_iter, 50);
        assert_eq!(spec.max_fevals, 0);
        assert_eq!(spec.lam, 1e-5);
        assert!(!spec.fused_forward);
        assert_eq!(spec.damping, Damping::Full);
        assert_eq!(spec.stagnation, StagnationRule { window: 0, eps: 0.07 });
        assert!(!spec.restart_on_breakdown);
        spec.validate().unwrap();
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in SolverKind::ALL {
            assert_eq!(SolverKind::parse(k.name()), Some(k));
        }
        assert_eq!(SolverKind::parse("nope"), None);
        // The "expected ..." error string is derived from the same list,
        // so the two can never drift apart.
        for k in SolverKind::ALL {
            assert!(SolverKind::expected().split('|').any(|n| n == k.name()));
        }
        assert_eq!(
            SolverKind::expected().split('|').count(),
            SolverKind::ALL.len()
        );
    }

    #[test]
    fn per_sample_rel_lane_order() {
        let num = HostTensor::f32(vec![3], vec![1.0, 4.0, 2.0]).unwrap();
        let den = HostTensor::f32(vec![3], vec![1.0, 1.0, 1.0]).unwrap();
        let r = per_sample_rel(&num, &den, 0.0).unwrap();
        assert_eq!(r.len(), 3);
        assert!((r[0] - 1.0).abs() < 1e-6);
        assert!((r[1] - 4.0).abs() < 1e-6);
        assert!((r[2] - 2.0).abs() < 1e-6);
        let short = HostTensor::f32(vec![2], vec![1.0, 1.0]).unwrap();
        assert!(per_sample_rel(&num, &short, 0.0).is_err());
    }

    #[test]
    fn residual_track_freezes_converged_lanes() {
        let mut tr = ResidualTrack::new(2, 0.5);
        let den = HostTensor::f32(vec![2], vec![1.0, 1.0]).unwrap();
        // Lane 0 converges immediately; lane 1 stays active.
        let num = HostTensor::f32(vec![2], vec![0.1, 2.0]).unwrap();
        tr.observe(&num, &den, 0.0, 1).unwrap();
        assert_eq!(tr.converged(), &[true, false]);
        assert_eq!(tr.active_count(), 1);
        assert!(!tr.all_converged());
        // A frozen lane takes no further fevals/iters even if the kernel
        // keeps reporting residuals for it.
        let num2 = HostTensor::f32(vec![2], vec![9.0, 0.2]).unwrap();
        tr.observe(&num2, &den, 0.0, 1).unwrap();
        assert_eq!(tr.fevals(), &[1, 2]);
        assert_eq!(tr.iters(), &[1, 2]);
        assert_eq!(tr.converged(), &[true, true]);
        assert!(tr.all_converged());
        assert_eq!(tr.total_fevals(), 3);
        // Frozen lane 0 holds its freeze-time residual, not 9.0.
        assert!((tr.rel()[0] - 0.1).abs() < 1e-6);
        assert!((tr.max_rel() - 0.2).abs() < 1e-6);
    }

    #[test]
    fn residual_track_quarantines_non_finite_lanes() {
        let mut tr = ResidualTrack::new(3, 0.5);
        let den = HostTensor::f32(vec![3], vec![1.0, 1.0, 1.0]).unwrap();
        // Lane 1 goes NaN on step 1; lanes 0/2 keep iterating.
        let num = HostTensor::f32(vec![3], vec![2.0, f32::NAN, 2.0]).unwrap();
        let (rel, fr) = tr.observe_step(&num, &den, 0.0, 1).unwrap();
        assert!(rel[1].is_nan());
        assert_eq!(tr.faulted(), &[false, true, false]);
        assert_eq!(tr.quarantined_count(), 1);
        assert_eq!(tr.converged(), &[false, false, false]);
        // The quarantined lane freezes like a converged one would, so
        // drivers stop rewriting its rows and history pushes skip it.
        assert_eq!(fr.newly_frozen, vec![false, true, false]);
        assert_eq!(tr.active_mask(), vec![true, false, true]);
        assert_eq!(tr.active_count(), 2);
        // Cohort max-residual excludes the poisoned lane entirely.
        assert!((tr.max_rel() - 2.0).abs() < 1e-6);
        assert!(tr.max_rel().is_finite());
        // The fault is charged its iteration (it cost a real step).
        assert_eq!(tr.iters(), &[1, 1, 1]);
        // Further steps leave the quarantined lane untouched even if the
        // kernel reports a finite value for it again.
        let num2 = HostTensor::f32(vec![3], vec![0.1, 0.1, 0.1]).unwrap();
        tr.observe(&num2, &den, 0.0, 1).unwrap();
        assert_eq!(tr.faulted(), &[false, true, false]);
        assert!(tr.rel()[1].is_nan());
        assert_eq!(tr.iters(), &[2, 1, 2]);
        assert_eq!(tr.converged(), &[true, false, true]);
        // Terminal state: settled (exit the loop) but NOT converged.
        assert!(tr.all_settled());
        assert!(!tr.all_converged());
    }

    #[test]
    fn infinite_residual_quarantines_and_stays_out_of_max_rel() {
        let mut tr = ResidualTrack::new(2, 0.5);
        let den = HostTensor::f32(vec![2], vec![1.0, 1.0]).unwrap();
        let num = HostTensor::f32(vec![2], vec![f32::INFINITY, 2.0]).unwrap();
        tr.observe(&num, &den, 0.0, 1).unwrap();
        assert_eq!(tr.faulted(), &[true, false]);
        // f32::max would have kept the +Inf; quarantine must not.
        assert!((tr.max_rel() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn observe_step_reports_freeze_transition_and_applies_it() {
        let mut tr = ResidualTrack::new(3, 0.5);
        let den = HostTensor::f32(vec![3], vec![1.0, 1.0, 1.0]).unwrap();
        // Step 1: lane 0 freezes.
        let num = HostTensor::f32(vec![3], vec![0.1, 2.0, 2.0]).unwrap();
        let (_, fr1) = tr.observe_step(&num, &den, 0.0, 1).unwrap();
        assert_eq!(fr1.frozen_before, vec![false, false, false]);
        assert_eq!(fr1.newly_frozen, vec![true, false, false]);
        // Step 2: lane 1 freezes; lane 0 already frozen.
        let num2 = HostTensor::f32(vec![3], vec![9.0, 0.2, 2.0]).unwrap();
        let (_, fr2) = tr.observe_step(&num2, &den, 0.0, 1).unwrap();
        assert_eq!(fr2.frozen_before, vec![true, false, false]);
        assert_eq!(fr2.newly_frozen, vec![false, true, false]);
        // apply(): newly frozen lane takes f, frozen lane keeps prev,
        // active lane keeps the caller's (e.g. mixed) row.
        let mut next =
            HostTensor::f32(vec![3, 1], vec![10.0, 11.0, 12.0]).unwrap();
        let f = HostTensor::f32(vec![3, 1], vec![20.0, 21.0, 22.0]).unwrap();
        let prev = HostTensor::f32(vec![3, 1], vec![30.0, 31.0, 32.0]).unwrap();
        fr2.apply(&mut next, &f, &prev).unwrap();
        assert_eq!(next.f32s().unwrap(), &[30.0, 21.0, 12.0]);
    }

    #[test]
    fn report_accessors_empty() {
        let r = SolveReport {
            kind: SolverKind::Forward,
            steps: vec![],
            converged: false,
            z_star: HostTensor::zeros(vec![1]),
            sample_iters: vec![],
            sample_fevals: vec![],
            sample_converged: vec![],
            sample_faulted: vec![],
        };
        assert_eq!(r.iters(), 0);
        assert!(r.final_residual().is_nan());
        assert_eq!(r.total_time(), Duration::ZERO);
        assert!(r.time_to(1.0).is_none());
        assert_eq!(r.fevals_total(), 0);
    }

    #[test]
    fn step_json_roundtrip() {
        let s = SolveStep {
            iter: 3,
            rel_residual: 0.25,
            sample_residuals: vec![0.25, 0.125],
            active: 1,
            elapsed: Duration::from_millis(1500),
            fevals: 4,
            mixed: true,
        };
        let back = SolveStep::from_json(&s.to_json()).unwrap();
        assert_eq!(back.iter, 3);
        assert_eq!(back.rel_residual, 0.25);
        assert_eq!(back.sample_residuals, vec![0.25, 0.125]);
        assert_eq!(back.active, 1);
        assert_eq!(back.elapsed, Duration::from_millis(1500));
        assert_eq!(back.fevals, 4);
        assert!(back.mixed);
    }

    #[test]
    fn legacy_step_json_still_parses() {
        // Pre-scheduler traces have no per-sample fields.
        let v = json::parse(
            r#"{"elapsed_s":0.5,"fevals":2,"iter":1,"mixed":false,"rel_residual":0.125}"#,
        )
        .unwrap();
        let s = SolveStep::from_json(&v).unwrap();
        assert!(s.sample_residuals.is_empty());
        assert_eq!(s.active, 0);
        assert_eq!(s.fevals, 2);
    }

    #[test]
    fn report_json_rejects_malformed() {
        let v = json::parse(r#"{"kind":"anderson"}"#).unwrap();
        assert!(SolveReport::from_json(&v).is_err());
        let v = json::parse(r#"{"kind":"warp","steps":[],"converged":true}"#).unwrap();
        assert!(SolveReport::from_json(&v).is_err());
    }
}

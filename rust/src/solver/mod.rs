//! Fixed-point solver drivers over any [`Backend`] — the coordinator
//! half of the paper's contribution.
//!
//! The execution backend owns the *math* of one step (`cell_step`,
//! `anderson_update`); this module owns the *policy*: when to evaluate,
//! when to mix, when to stop, what to record.  Three drivers:
//!
//! * [`forward`] — the paper's baseline, z ← f(z,x), optionally through
//!   the fused `forward_solve_k` entry (K steps per dispatch).
//! * [`anderson`] — windowed Anderson extrapolation (Alg. 1): ring-buffer
//!   history management on the host, mixing via the fused kernel entry.
//! * [`policy`] — the paper's §4 suggestion: run Anderson, watch for
//!   stagnation, fall back to damped forward steps.
//!
//! Each solve returns a [`SolveReport`] with the per-iteration residual /
//! wallclock trace — the raw series behind Figs. 1, 6 and 7.  Reports
//! round-trip through JSON (see [`SolveReport::to_json`]) so experiment
//! output formats are pinned by golden tests.

pub mod anderson;
pub mod crossover;
pub mod forward;
pub mod policy;

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::runtime::{Backend, HostTensor};
use crate::util::json::{self, Json};

/// Which solver to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Forward,
    Anderson,
    /// Anderson with stagnation fallback (paper §4).
    Hybrid,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "forward" => Some(Self::Forward),
            "anderson" => Some(Self::Anderson),
            "hybrid" => Some(Self::Hybrid),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Forward => "forward",
            Self::Anderson => "anderson",
            Self::Hybrid => "hybrid",
        }
    }
}

/// Runtime solver options (seeded from the manifest's SolverMeta).
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    pub kind: SolverKind,
    pub window: usize,
    pub tol: f32,
    pub max_iter: usize,
    pub lam: f32,
    /// Use the fused K-step entry for forward solves when available.
    pub fused_forward: bool,
    /// Stagnation threshold for the hybrid policy: minimum relative
    /// improvement per window before switching.
    pub stagnation_eps: f32,
}

impl SolveOptions {
    pub fn from_manifest(engine: &dyn Backend, kind: SolverKind) -> Self {
        let s = &engine.manifest().solver;
        Self {
            kind,
            window: s.window,
            tol: s.tol,
            max_iter: s.max_iter,
            lam: s.lam,
            fused_forward: true,
            stagnation_eps: 0.03,
        }
    }
}

/// One recorded solver iteration.
#[derive(Debug, Clone)]
pub struct SolveStep {
    pub iter: usize,
    /// Max-over-batch relative residual ‖f−z‖/(‖f‖+λ).
    pub rel_residual: f32,
    /// Cumulative wallclock since solve start.
    pub elapsed: Duration,
    /// Cumulative cell evaluations (per sample).
    pub fevals: usize,
    /// True if Anderson mixing produced this step's *next* iterate —
    /// false for plain forward steps and for the terminal step (which
    /// takes f directly).  Note step 0's output IS mixed once its
    /// (z, f) pair is in the history window.
    pub mixed: bool,
}

impl SolveStep {
    /// JSON object form (keys sorted; `elapsed` as seconds).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("elapsed_s", json::num(self.elapsed.as_secs_f64())),
            ("fevals", json::num(self.fevals as f64)),
            ("iter", json::num(self.iter as f64)),
            ("mixed", Json::Bool(self.mixed)),
            ("rel_residual", json::num(self.rel_residual as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let f64field = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("SolveStep missing '{key}'"))
        };
        Ok(Self {
            iter: f64field("iter")? as usize,
            rel_residual: f64field("rel_residual")? as f32,
            elapsed: Duration::from_secs_f64(f64field("elapsed_s")?),
            fevals: f64field("fevals")? as usize,
            mixed: v
                .get("mixed")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("SolveStep missing 'mixed'"))?,
        })
    }
}

/// Outcome of one equilibrium solve.
#[derive(Debug, Clone)]
pub struct SolveReport {
    pub kind: SolverKind,
    pub steps: Vec<SolveStep>,
    pub converged: bool,
    pub z_star: HostTensor,
}

impl SolveReport {
    pub fn iters(&self) -> usize {
        self.steps.len()
    }

    pub fn fevals(&self) -> usize {
        self.steps.last().map(|s| s.fevals).unwrap_or(0)
    }

    pub fn final_residual(&self) -> f32 {
        self.steps.last().map(|s| s.rel_residual).unwrap_or(f32::NAN)
    }

    pub fn total_time(&self) -> Duration {
        self.steps.last().map(|s| s.elapsed).unwrap_or(Duration::ZERO)
    }

    /// Wallclock to first residual ≤ target (None if never reached).
    pub fn time_to(&self, target: f32) -> Option<Duration> {
        self.steps
            .iter()
            .find(|s| s.rel_residual <= target)
            .map(|s| s.elapsed)
    }

    /// Best residual achieved.
    pub fn best_residual(&self) -> f32 {
        self.steps
            .iter()
            .map(|s| s.rel_residual)
            .fold(f32::INFINITY, f32::min)
    }

    /// JSON form of the full report (the experiment trace format).
    /// `z_star` serializes as f32 data + shape — the only latent dtype.
    pub fn to_json(&self) -> Json {
        let steps = Json::Arr(self.steps.iter().map(SolveStep::to_json).collect());
        let data: Vec<Json> = self
            .z_star
            .f32s()
            .map(|d| d.iter().map(|&v| json::num(v as f64)).collect())
            .unwrap_or_default();
        let shape: Vec<Json> = self
            .z_star
            .shape
            .iter()
            .map(|&d| json::num(d as f64))
            .collect();
        json::obj(vec![
            ("converged", Json::Bool(self.converged)),
            ("kind", json::s(self.kind.name())),
            ("steps", steps),
            (
                "z_star",
                json::obj(vec![("data", Json::Arr(data)), ("shape", Json::Arr(shape))]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let kind_name = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("SolveReport missing 'kind'"))?;
        let kind = SolverKind::parse(kind_name)
            .ok_or_else(|| anyhow!("unknown solver kind '{kind_name}'"))?;
        let steps = v
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("SolveReport missing 'steps'"))?
            .iter()
            .map(SolveStep::from_json)
            .collect::<Result<Vec<_>>>()?;
        let z = v
            .get("z_star")
            .ok_or_else(|| anyhow!("SolveReport missing 'z_star'"))?;
        let shape: Vec<usize> = z
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("z_star missing 'shape'"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad z_star dim")))
            .collect::<Result<Vec<_>>>()?;
        let data: Vec<f32> = z
            .get("data")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("z_star missing 'data'"))?
            .iter()
            .map(|d| {
                d.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| anyhow!("bad z_star value"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            kind,
            steps,
            converged: v
                .get("converged")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("SolveReport missing 'converged'"))?,
            z_star: HostTensor::f32(shape, data)?,
        })
    }
}

/// Dispatch a solve by kind.
pub fn solve(
    engine: &dyn Backend,
    params: &[HostTensor],
    x_feat: &HostTensor,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    match opts.kind {
        SolverKind::Forward => forward::solve(engine, params, x_feat, opts),
        SolverKind::Anderson => anderson::solve(engine, params, x_feat, opts),
        SolverKind::Hybrid => policy::solve(engine, params, x_feat, opts),
    }
}

/// Max-over-batch relative residual from the fused cell_step outputs.
pub(crate) fn max_rel_residual(
    res_num: &HostTensor,
    f_norm: &HostTensor,
    lam: f32,
) -> Result<f32> {
    let num = res_num.f32s()?;
    let den = f_norm.f32s()?;
    Ok(num
        .iter()
        .zip(den)
        .map(|(n, d)| n / (d + lam))
        .fold(0.0f32, f32::max))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [SolverKind::Forward, SolverKind::Anderson, SolverKind::Hybrid] {
            assert_eq!(SolverKind::parse(k.name()), Some(k));
        }
        assert_eq!(SolverKind::parse("nope"), None);
    }

    #[test]
    fn max_rel_residual_takes_max() {
        let num = HostTensor::f32(vec![3], vec![1.0, 4.0, 2.0]).unwrap();
        let den = HostTensor::f32(vec![3], vec![1.0, 1.0, 1.0]).unwrap();
        let r = max_rel_residual(&num, &den, 0.0).unwrap();
        assert!((r - 4.0).abs() < 1e-6);
    }

    #[test]
    fn report_accessors_empty() {
        let r = SolveReport {
            kind: SolverKind::Forward,
            steps: vec![],
            converged: false,
            z_star: HostTensor::zeros(vec![1]),
        };
        assert_eq!(r.iters(), 0);
        assert!(r.final_residual().is_nan());
        assert_eq!(r.total_time(), Duration::ZERO);
        assert!(r.time_to(1.0).is_none());
    }

    #[test]
    fn step_json_roundtrip() {
        let s = SolveStep {
            iter: 3,
            rel_residual: 0.25,
            elapsed: Duration::from_millis(1500),
            fevals: 4,
            mixed: true,
        };
        let back = SolveStep::from_json(&s.to_json()).unwrap();
        assert_eq!(back.iter, 3);
        assert_eq!(back.rel_residual, 0.25);
        assert_eq!(back.elapsed, Duration::from_millis(1500));
        assert_eq!(back.fevals, 4);
        assert!(back.mixed);
    }

    #[test]
    fn report_json_rejects_malformed() {
        let v = json::parse(r#"{"kind":"anderson"}"#).unwrap();
        assert!(SolveReport::from_json(&v).is_err());
        let v = json::parse(r#"{"kind":"warp","steps":[],"converged":true}"#).unwrap();
        assert!(SolveReport::from_json(&v).is_err());
    }
}

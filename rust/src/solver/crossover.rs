//! Crossover-point and mixing-penalty analysis (paper Fig. 1 & §4).
//!
//! Given residual-vs-time traces from two solvers, locate:
//!  * the **crossover point**: the residual level below which Anderson's
//!    wallclock beats forward iteration (above it, the per-iteration
//!    mixing penalty dominates and forward is cheaper);
//!  * the **mixing penalty**: the per-iteration cost ratio
//!    anderson/forward (>1 by construction).

use std::time::Duration;

use crate::solver::SolveReport;

/// A point on a residual-vs-time curve.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    pub t: Duration,
    pub residual: f32,
}

pub fn trace(report: &SolveReport) -> Vec<TracePoint> {
    report
        .steps
        .iter()
        .map(|s| TracePoint { t: s.elapsed, residual: s.rel_residual })
        .collect()
}

/// Time for a trace to first reach `target` (linear scan; traces are short).
pub fn time_to_target(trace: &[TracePoint], target: f32) -> Option<Duration> {
    trace.iter().find(|p| p.residual <= target).map(|p| p.t)
}

/// Result of comparing two solvers' traces.
#[derive(Debug, Clone)]
pub struct CrossoverReport {
    /// Residual targets swept (log-spaced between the traces' extremes).
    pub targets: Vec<f32>,
    /// time-to-target for (anderson, forward); None = never reached.
    pub times: Vec<(Option<Duration>, Option<Duration>)>,
    /// First target where Anderson is strictly faster (the crossover).
    pub crossover_residual: Option<f32>,
    /// Mean per-iteration cost ratio anderson/forward (the mixing penalty).
    pub mixing_penalty: f32,
}

/// Compare solver traces across log-spaced residual targets.
pub fn analyze(anderson: &SolveReport, forward: &SolveReport) -> CrossoverReport {
    let ta = trace(anderson);
    let tf = trace(forward);

    // Sweep targets from the max starting residual down to the best
    // residual either solver achieved.
    let hi = ta
        .first()
        .map(|p| p.residual)
        .unwrap_or(1.0)
        .max(tf.first().map(|p| p.residual).unwrap_or(1.0));
    let lo = anderson
        .best_residual()
        .min(forward.best_residual())
        .max(1e-9);
    let steps = 24usize;
    let (lh, ll) = (hi.ln(), lo.ln());
    let targets: Vec<f32> = (0..=steps)
        .map(|i| (lh + (ll - lh) * i as f32 / steps as f32).exp())
        .collect();

    let times: Vec<(Option<Duration>, Option<Duration>)> = targets
        .iter()
        .map(|&tg| (time_to_target(&ta, tg), time_to_target(&tf, tg)))
        .collect();

    let crossover_residual = targets
        .iter()
        .zip(&times)
        .find(|(_, (a, f))| match (a, f) {
            (Some(a), Some(f)) => a < f,
            (Some(_), None) => true,
            _ => false,
        })
        .map(|(t, _)| *t);

    let per_iter = |r: &SolveReport| -> f32 {
        if r.steps.is_empty() {
            return f32::NAN;
        }
        r.total_time().as_secs_f32() / r.steps.len() as f32
    };
    let mixing_penalty = per_iter(anderson) / per_iter(forward);

    CrossoverReport { targets, times, crossover_residual, mixing_penalty }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;
    use crate::solver::{SolveStep, SolverKind};

    fn fake_report(kind: SolverKind, per_iter_us: u64, rate: f32, n: usize) -> SolveReport {
        let steps = (0..n)
            .map(|k| SolveStep {
                iter: k,
                rel_residual: rate.powi(k as i32),
                sample_residuals: vec![rate.powi(k as i32)],
                active: 1,
                elapsed: Duration::from_micros(per_iter_us * (k as u64 + 1)),
                fevals: k + 1,
                mixed: kind == SolverKind::Anderson,
            })
            .collect();
        SolveReport {
            kind,
            steps,
            converged: true,
            z_star: HostTensor::zeros(vec![1]),
            sample_iters: vec![n],
            sample_fevals: vec![n],
            sample_converged: vec![true],
            sample_faulted: vec![false],
        }
    }

    #[test]
    fn crossover_detected_when_anderson_converges_faster() {
        // Anderson: 3x cost per iter but rate 0.5 vs forward rate 0.9.
        let a = fake_report(SolverKind::Anderson, 300, 0.5, 30);
        let f = fake_report(SolverKind::Forward, 100, 0.9, 200);
        let rep = analyze(&a, &f);
        assert!(rep.mixing_penalty > 2.5 && rep.mixing_penalty < 3.5);
        let x = rep.crossover_residual.expect("crossover exists");
        // Deep targets favor anderson; the crossover is below 1.0.
        assert!(x < 1.0);
        // At the deepest target BOTH solvers reach, anderson must be faster.
        let (ta, tf) = rep
            .times
            .iter()
            .rev()
            .find(|(a, f)| a.is_some() && f.is_some())
            .unwrap();
        assert!(ta.unwrap() < tf.unwrap());
    }

    #[test]
    fn no_crossover_when_anderson_slower_everywhere() {
        // Same rate, higher cost: anderson never wins.
        let a = fake_report(SolverKind::Anderson, 300, 0.9, 50);
        let f = fake_report(SolverKind::Forward, 100, 0.9, 50);
        let rep = analyze(&a, &f);
        assert!(rep.crossover_residual.is_none());
    }

    #[test]
    fn time_to_target_monotone() {
        let r = fake_report(SolverKind::Forward, 10, 0.8, 40);
        let tr = trace(&r);
        let t1 = time_to_target(&tr, 0.5).unwrap();
        let t2 = time_to_target(&tr, 0.1).unwrap();
        assert!(t1 <= t2);
        assert!(time_to_target(&tr, 0.0).is_none());
    }

    #[test]
    fn crossover_when_only_anderson_reaches_deep_targets() {
        // Forward stalls shallow (few iterations, slow rate); Anderson
        // alone reaches the deep targets.  The (Some, None) arm of the
        // detector must still report a crossover.
        let a = fake_report(SolverKind::Anderson, 300, 0.3, 40);
        let f = fake_report(SolverKind::Forward, 100, 0.95, 5);
        let rep = analyze(&a, &f);
        let x = rep.crossover_residual.expect("anderson-only depth");
        // The crossover is at or below the deepest residual forward saw.
        assert!(x <= f.best_residual() * 1.001);
        // Every swept target at/below the crossover keeps anderson ahead.
        let mut past = false;
        for (t, (ta, tf)) in rep.targets.iter().zip(&rep.times) {
            if *t <= x {
                past = true;
                match (ta, tf) {
                    (Some(ta), Some(tf)) => assert!(ta <= tf),
                    (Some(_), None) => {}
                    other => panic!("target {t}: anderson lost it ({other:?})"),
                }
            }
        }
        assert!(past, "no swept target at/below the crossover");
    }

    #[test]
    fn targets_sweep_is_monotone_decreasing_and_spans_traces() {
        let a = fake_report(SolverKind::Anderson, 300, 0.5, 30);
        let f = fake_report(SolverKind::Forward, 100, 0.9, 200);
        let rep = analyze(&a, &f);
        assert_eq!(rep.targets.len(), rep.times.len());
        assert!(rep.targets.len() >= 2);
        for w in rep.targets.windows(2) {
            assert!(w[0] >= w[1], "targets not decreasing: {} < {}", w[0], w[1]);
        }
        // The sweep starts at the worst starting residual and ends at the
        // best residual either solver achieved.
        assert!((rep.targets[0] - 1.0).abs() < 1e-3);
        // (floored at 1e-9, as the sweep is).
        let deepest = a.best_residual().min(f.best_residual()).max(1e-9);
        let last = *rep.targets.last().unwrap();
        assert!((last / deepest).ln().abs() < 1e-2);
    }

    #[test]
    fn empty_traces_degrade_without_panicking() {
        let empty = |kind| SolveReport {
            kind,
            steps: vec![],
            converged: false,
            z_star: HostTensor::zeros(vec![1]),
            sample_iters: vec![],
            sample_fevals: vec![],
            sample_converged: vec![],
            sample_faulted: vec![],
        };
        let rep = analyze(&empty(SolverKind::Anderson), &empty(SolverKind::Forward));
        assert!(rep.crossover_residual.is_none());
        assert!(rep.mixing_penalty.is_nan());
        assert!(rep.times.iter().all(|(a, f)| a.is_none() && f.is_none()));
    }

    #[test]
    fn mixing_penalty_matches_per_iteration_cost_ratio() {
        // 300µs vs 100µs per iteration → penalty 3 exactly (equal counts).
        let a = fake_report(SolverKind::Anderson, 300, 0.5, 20);
        let f = fake_report(SolverKind::Forward, 100, 0.5, 20);
        let rep = analyze(&a, &f);
        assert!((rep.mixing_penalty - 3.0).abs() < 1e-3);
    }

    #[test]
    fn time_to_target_pins_the_first_crossing_of_a_non_monotone_trace() {
        // Residuals are not monotone in general (restarts, safeguarded
        // steps): 1.0 → 0.05 (transient dip) → 0.5 → 0.01.  The contract
        // is *first* crossing, so the dip at t=2µs is the answer for
        // target 0.1 even though the trace rises above it afterwards.
        let tr: Vec<TracePoint> = [1.0f32, 0.05, 0.5, 0.01]
            .iter()
            .enumerate()
            .map(|(k, &r)| TracePoint {
                t: Duration::from_micros(k as u64 + 1),
                residual: r,
            })
            .collect();
        assert_eq!(
            time_to_target(&tr, 0.1),
            Some(Duration::from_micros(2)),
            "must take the transient dip, not the later stable crossing"
        );
        // A target below the dip but above the tail resolves to the tail.
        assert_eq!(time_to_target(&tr, 0.02), Some(Duration::from_micros(4)));
        assert_eq!(time_to_target(&tr, 1e-3), None);
    }

    #[test]
    fn single_point_traces_analyze_without_panicking() {
        let a = fake_report(SolverKind::Anderson, 300, 0.5, 1);
        let f = fake_report(SolverKind::Forward, 100, 0.9, 1);
        let rep = analyze(&a, &f);
        // Both one-point traces sit at residual 1.0 (rate^0): every
        // target is reached immediately by both, anderson is never
        // *strictly* faster, and the penalty is the plain cost ratio.
        assert_eq!(rep.targets.len(), rep.times.len());
        assert!(rep.crossover_residual.is_none());
        assert!((rep.mixing_penalty - 3.0).abs() < 1e-3);
        let tr = trace(&a);
        assert_eq!(time_to_target(&tr, 1.0), Some(Duration::from_micros(300)));
        assert!(time_to_target(&tr, 0.5).is_none());
    }

    #[test]
    fn no_crossover_when_anderson_never_reaches_any_deep_target() {
        // Anderson stalls flat at its starting residual (rate 1.0) while
        // forward descends: the (None, Some) and (None, None) detector
        // arms must never claim a crossover.
        let a = fake_report(SolverKind::Anderson, 300, 1.0, 10);
        let f = fake_report(SolverKind::Forward, 100, 0.8, 40);
        let rep = analyze(&a, &f);
        assert!(rep.crossover_residual.is_none());
        // Below anderson's flatline only forward ever arrives.
        assert!(rep
            .times
            .iter()
            .zip(&rep.targets)
            .filter(|(_, &tg)| tg < 0.99)
            .all(|((ta, tf), _)| ta.is_none() && tf.is_some()));
    }
}

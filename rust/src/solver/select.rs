//! Online forward↔Anderson auto-selection — the Fig. 1 crossover closed
//! as a live, per-lane control loop.
//!
//! The paper's offline analysis ([`crate::solver::crossover`]) shows one
//! crossover per workload: above a residual threshold the per-iteration
//! mixing penalty makes plain forward iteration cheaper per wallclock,
//! below it Anderson's iteration savings win.  This module makes that
//! decision *during* a solve, per lane, with two layers:
//!
//!  * [`AutoPolicy`] — a [`SolvePolicy`] for [`SolverKind::Auto`].  It
//!    runs a short forward probe, fits the lane's residual contraction
//!    rate `ρ` from the early `observe(rel)` trace (the geometric-mean
//!    rate estimate from Saad's fixed-point acceleration survey),
//!    predicts which side of the crossover the lane sits on from the
//!    remaining decades to `tol` and a mixing-penalty estimate, and
//!    switches forward↔Anderson mid-solve.  The window depth it mixes
//!    with is chosen from the predicted remaining decades, and every
//!    mixed step is safeguarded exactly like
//!    [`AdaptiveAndersonPolicy`](crate::solver::policy::AdaptiveAndersonPolicy):
//!    a post-mix residual rise falls back to one plain damped step with
//!    the window kept.
//!  * [`ProfileStore`] / [`WorkloadProfile`] — the router-side learning
//!    layer: per-bucket EWMAs of retired-lane decay rates, chosen kinds,
//!    iters/fevals to converge, measured Anderson-vs-forward iteration
//!    cost (the live mixing penalty, same semantics as the
//!    `mixing_penalty` of
//!    [`analyze`](crate::solver::crossover::analyze)), and switch
//!    outcomes.  The store seeds each new
//!    Auto lane's [`WorkloadPrior`] and is surfaced through the TCP
//!    `stats` command.
//!
//! The crossover prediction compares expected remaining wallclock in
//! forward-iteration units.  With `d` decades left to `tol`, a fitted
//! forward rate `ρ_f` (so `d_f = −log₁₀ ρ_f` decades per forward step),
//! a learned Anderson speedup `s` (decades per iteration, relative to
//! forward) and mixing penalty `p` (Anderson-iteration cost over
//! forward-iteration cost):
//!
//! ```text
//! cost_forward  = d / d_f
//! cost_anderson = p · (w + d / (s · d_f))      w = window warmup
//! ```
//!
//! Anderson wins exactly when the lane is far enough from `tol` that the
//! iteration savings amortize the per-iteration penalty — the Fig. 1
//! threshold, evaluated live per lane.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use crate::solver::policy::{LaneStep, SolvePolicy, WindowRule};
use crate::solver::spec::{Damping, SolveSpec};
use crate::solver::SolverKind;

/// Forward probe length: residual observations collected before the
/// first crossover decision (3 successive ratios).
pub const PROBE_ITERS: usize = 4;

/// Hard cap on forward↔Anderson switches per lane — the controller must
/// not ping-pong on a noisy trajectory.
pub const MAX_SWITCHES: u64 = 6;

/// A fitted contraction rate at or above this is treated as
/// non-contracting: forward iteration alone will not converge, so the
/// crossover decision short-circuits to Anderson.
const DIVERGENCE_RHO: f32 = 0.9995;

/// Fit a residual contraction rate from a trace: the clamped geometric
/// mean of successive ratios `r_{k+1}/r_k` (Saad's per-iteration decay
/// estimate).  Non-finite and non-positive points are skipped; `None`
/// when no usable ratio exists (fewer than two valid points).
pub fn fit_rate(trace: &[f32]) -> Option<f32> {
    let mut sum = 0.0f64;
    let mut n = 0u32;
    for w in trace.windows(2) {
        let (a, b) = (w[0], w[1]);
        if a.is_finite() && b.is_finite() && a > 0.0 && b > 0.0 {
            sum += f64::from((b / a).clamp(1e-3, 1e3)).ln();
            n += 1;
        }
    }
    (n > 0).then(|| ((sum / f64::from(n)).exp() as f32).clamp(1e-2, 1e3))
}

/// The prior an Auto lane starts from — either the library defaults or a
/// bucket's learned [`WorkloadProfile`] (see [`ProfileStore::prior`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadPrior {
    /// Expected forward contraction rate ρ_f (residual multiplier per
    /// forward iteration).
    pub decay_rate: f32,
    /// Anderson-iteration cost over forward-iteration cost (> 1; the
    /// Fig. 1 mixing penalty, with the semantics of
    /// [`analyze`](crate::solver::crossover::analyze)).
    pub mixing_penalty: f32,
    /// Decades-per-iteration multiplier Anderson achieves over forward.
    pub anderson_speedup: f32,
}

impl Default for WorkloadPrior {
    fn default() -> Self {
        // Conservative seeds: a moderately stiff lane, the typical
        // measured window-5 mixing penalty, and the several-fold
        // iteration saving the paper's Fig. 1 regime exhibits.
        Self { decay_rate: 0.9, mixing_penalty: 1.5, anderson_speedup: 4.0 }
    }
}

/// Live introspection of one Auto lane, harvested by the scheduler at
/// retirement (and by tests mid-solve).  Static policies report `None`
/// from [`SolvePolicy::auto_stats`].
#[derive(Debug, Clone, Copy)]
pub struct AutoStats {
    /// Forward↔Anderson switch decisions taken so far.
    pub switches: u64,
    /// The side of the crossover the lane currently iterates on.
    pub active: SolverKind,
    /// Fitted forward contraction rate ρ_f (None until the probe fit).
    pub decay_rate: Option<f32>,
    /// Observed Anderson speedup (decades/iter over forward) while the
    /// lane mixed; None before enough mixed steps.
    pub anderson_speedup: Option<f32>,
    /// The window depth chosen at the last switch to Anderson.
    pub window_depth: Option<usize>,
}

/// The in-solve half of the auto-selection subsystem (see the module
/// docs for the decision rule).  One instance owns one lane's (or one
/// batch cohort's) controller state.
#[derive(Debug, Clone)]
pub struct AutoPolicy {
    tol: f32,
    max_window: usize,
    damping: Damping,
    /// Condition-monitored window rule, armed only when the spec armed
    /// `adaptive_window` (mirroring the adaptive Anderson policy).
    rule: Option<WindowRule>,
    prior: WorkloadPrior,
    /// Residual trajectory of the *current* phase (cleared on switch).
    trace: Vec<f32>,
    /// True while the lane Anderson-mixes.
    mixing: bool,
    /// Fitted forward contraction rate, EWMA-refreshed while forward.
    rho_f: Option<f32>,
    /// Observed Anderson speedup (decades/iter over forward).
    speedup_obs: Option<f32>,
    prev: Option<f32>,
    /// True while the last emitted step was a mix — the safeguard judges
    /// only mixed steps, never its own fallback step.
    last_mixed: bool,
    fwd_steps: usize,
    safeguard_steps: u64,
    switches: u64,
    /// Iterations to wait before the next crossover (re)evaluation.
    cooldown: usize,
    /// Window depth chosen at the last switch to Anderson.
    depth: usize,
}

impl AutoPolicy {
    /// Auto controller with the library-default prior.
    pub fn new(spec: &SolveSpec) -> Self {
        Self::with_prior(spec, WorkloadPrior::default())
    }

    /// Auto controller seeded from a learned per-bucket prior (the
    /// scheduler's admission path — see [`ProfileStore::prior`]).
    pub fn with_prior(spec: &SolveSpec, prior: WorkloadPrior) -> Self {
        Self {
            tol: spec.tol,
            max_window: spec.window.max(1),
            damping: spec.damping,
            rule: spec.adaptive_window.then(|| WindowRule::from_spec(spec)),
            prior,
            trace: Vec::new(),
            mixing: false,
            rho_f: None,
            speedup_obs: None,
            prev: None,
            last_mixed: false,
            fwd_steps: 0,
            safeguard_steps: 0,
            switches: 0,
            cooldown: PROBE_ITERS,
            depth: spec.window.max(2),
        }
    }

    /// Switch decisions taken so far.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// True while the lane Anderson-mixes.
    pub fn is_mixing(&self) -> bool {
        self.mixing
    }

    /// Safeguarded (post-mix fallback) steps taken so far.
    pub fn safeguard_steps(&self) -> u64 {
        self.safeguard_steps
    }

    /// Crossover prediction at residual `rel` given the fitted forward
    /// rate: `Some(depth)` when the lane should mix (with the window
    /// depth to mix at), `None` when forward is the cheaper side.
    fn crossover(&self, rel: f32, rho: f32) -> Option<usize> {
        let d_rem = (rel / self.tol).max(1.0).log10();
        // Decades left ≘ the deepest useful window: each slot roughly
        // buys one order of residual structure, so a lane two decades
        // from tol has no use for a 10-deep window.
        let depth =
            (d_rem.ceil() as usize).clamp(2, self.max_window.max(2));
        if rho >= DIVERGENCE_RHO {
            // Forward iteration is not contracting — mixing is the only
            // side of the crossover that terminates.
            return Some(depth);
        }
        if d_rem <= 0.0 {
            return None;
        }
        let df = -rho.max(1e-2).log10();
        let s = self.prior.anderson_speedup.max(1.01);
        let p = self.prior.mixing_penalty.max(1.0);
        let cost_f = d_rem / df;
        let cost_a = p * (depth as f32 + d_rem / (s * df));
        (cost_a < cost_f).then_some(depth)
    }

    /// A plain damped forward step on the spec's schedule.
    fn forward_step(&mut self) -> LaneStep {
        let beta = self.damping.beta(self.fwd_steps);
        self.fwd_steps += 1;
        LaneStep::Forward { beta }
    }
}

impl SolvePolicy for AutoPolicy {
    fn kind(&self) -> SolverKind {
        SolverKind::Auto
    }

    fn uses_history(&self) -> bool {
        true
    }

    fn reset(&mut self) {
        self.trace.clear();
        self.mixing = false;
        self.rho_f = None;
        self.speedup_obs = None;
        self.prev = None;
        self.last_mixed = false;
        self.fwd_steps = 0;
        self.safeguard_steps = 0;
        self.switches = 0;
        self.cooldown = PROBE_ITERS;
        self.depth = self.max_window.max(2);
    }

    fn observe(&mut self, rel: f32) -> LaneStep {
        let prev = self.prev.replace(rel);
        let rose = prev.map(|p| rel > p).unwrap_or(false);
        if self.mixing && self.last_mixed && rose {
            // Safeguard (Stable Anderson Acceleration): the mixed step
            // regressed — one plain damped step from the newest iterate,
            // window kept, then mixing resumes.
            self.trace.push(rel);
            self.last_mixed = false;
            self.safeguard_steps += 1;
            return self.forward_step();
        }
        self.trace.push(rel);
        self.cooldown = self.cooldown.saturating_sub(1);
        if self.mixing {
            // Judge the mixed regime once the window is warm: the
            // observed speedup must beat the mixing penalty, or the lane
            // crosses back to forward steps.
            if self.cooldown == 0 && self.trace.len() >= PROBE_ITERS {
                let tail = &self.trace[self.trace.len() - PROBE_ITERS..];
                if let (Some(rho_a), Some(rho_f)) =
                    (fit_rate(tail), self.rho_f)
                {
                    let da = -rho_a.min(0.9999).log10();
                    let df = -rho_f.clamp(1e-2, 0.9999).log10();
                    let s_obs = (da / df).max(0.0);
                    self.speedup_obs = Some(s_obs);
                    if s_obs < self.prior.mixing_penalty.max(1.0)
                        && self.switches < MAX_SWITCHES
                    {
                        self.mixing = false;
                        self.last_mixed = false;
                        self.switches += 1;
                        self.trace.clear();
                        self.trace.push(rel);
                        self.cooldown = PROBE_ITERS;
                        return self.forward_step();
                    }
                    self.cooldown = PROBE_ITERS;
                }
            }
            self.last_mixed = true;
            return LaneStep::Mix;
        }
        // Forward side (probe or post-switch-back): keep the rate fit
        // fresh and re-evaluate the crossover once per cooldown window.
        if self.trace.len() >= 2 {
            if let Some(fit) =
                fit_rate(&self.trace[self.trace.len().saturating_sub(PROBE_ITERS)..])
            {
                self.rho_f = Some(match self.rho_f {
                    // EWMA refresh: early fits are noisy, late fits see
                    // the asymptotic rate.
                    Some(r) => r + 0.5 * (fit - r),
                    None => fit,
                });
            }
        }
        if self.cooldown == 0 && self.switches < MAX_SWITCHES {
            if let Some(rho) = self.rho_f {
                if let Some(depth) = self.crossover(rel, rho) {
                    self.mixing = true;
                    self.last_mixed = true;
                    self.switches += 1;
                    self.depth = depth;
                    self.trace.clear();
                    self.trace.push(rel);
                    // Hold judgment until the chosen window is warm.
                    self.cooldown = depth + 1;
                    return LaneStep::Mix;
                }
            }
            self.cooldown = 1;
        }
        self.forward_step()
    }

    fn window_rule(&self) -> Option<WindowRule> {
        if self.mixing {
            self.rule
        } else {
            None
        }
    }

    fn window_depth(&self) -> Option<usize> {
        self.mixing.then_some(self.depth)
    }

    fn auto_stats(&self) -> Option<AutoStats> {
        Some(AutoStats {
            switches: self.switches,
            active: if self.mixing {
                SolverKind::Anderson
            } else {
                SolverKind::Forward
            },
            decay_rate: self.rho_f,
            anderson_speedup: self.speedup_obs,
            window_depth: self.mixing.then_some(self.depth),
        })
    }
}

/// One EWMA gauge: first observation seeds, later ones blend at a fixed
/// rate.  Non-finite observations are dropped.
#[derive(Debug, Clone, Copy, Default)]
struct Ewma {
    v: f32,
    n: u64,
}

impl Ewma {
    const ALPHA: f32 = 0.2;

    fn push(&mut self, x: f32) {
        if !x.is_finite() {
            return;
        }
        self.n += 1;
        if self.n == 1 {
            self.v = x;
        } else {
            self.v += Self::ALPHA * (x - self.v);
        }
    }

    fn get(&self) -> Option<f32> {
        (self.n > 0).then_some(self.v)
    }
}

/// What the router has learned about one bucket's workload: EWMAs over
/// retired lanes plus per-kind retirement counts.  Snapshot-visible via
/// TCP `stats`; prior-visible via [`ProfileStore::prior`].
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkloadProfile {
    decay: Ewma,
    speedup: Ewma,
    iters: Ewma,
    fevals: Ewma,
    /// EWMA wallclock per lane-iteration of forward-only iterations.
    cost_fwd: Ewma,
    /// EWMA wallclock per lane-iteration of iterations that mixed.
    cost_mix: Ewma,
    /// Retired lanes observed.
    pub lanes: u64,
    /// Switch decisions accumulated from retired Auto lanes.
    pub switches: u64,
    /// Per-kind retirement counts, [`SolverKind::ALL`] order.
    pub retired: [u64; 4],
    /// Auto lanes that retired on the Anderson side of the crossover.
    pub auto_on_anderson: u64,
}

impl WorkloadProfile {
    /// Learned forward contraction rate, if any Auto lane reported one.
    pub fn decay_rate(&self) -> Option<f32> {
        self.decay.get()
    }

    /// Learned Anderson speedup (decades/iter over forward).
    pub fn anderson_speedup(&self) -> Option<f32> {
        self.speedup.get()
    }

    /// Live mixing penalty: measured mixed-iteration cost over
    /// forward-only iteration cost — the `mixing_penalty` of
    /// [`analyze`](crate::solver::crossover::analyze), measured on the
    /// serving loop instead of offline traces.
    pub fn mixing_penalty(&self) -> Option<f32> {
        match (self.cost_mix.get(), self.cost_fwd.get()) {
            (Some(m), Some(f)) if f > 0.0 => Some(m / f),
            _ => None,
        }
    }

    /// Mean iterations to retire a lane.
    pub fn mean_iters(&self) -> Option<f32> {
        self.iters.get()
    }

    /// Mean cell evaluations to retire a lane.
    pub fn mean_fevals(&self) -> Option<f32> {
        self.fevals.get()
    }

    /// The prior this profile seeds new Auto lanes with: learned values
    /// where available, library defaults elsewhere.  The penalty is
    /// floored at 1 — a measurement below 1 means timing noise, not a
    /// free Anderson step.
    pub fn prior(&self) -> WorkloadPrior {
        let d = WorkloadPrior::default();
        WorkloadPrior {
            decay_rate: self.decay.get().unwrap_or(d.decay_rate),
            mixing_penalty: self
                .mixing_penalty()
                .map(|p| p.max(1.0))
                .unwrap_or(d.mixing_penalty),
            anderson_speedup: self
                .speedup
                .get()
                .map(|s| s.max(1.01))
                .unwrap_or(d.anderson_speedup),
        }
    }
}

fn kind_index(kind: SolverKind) -> usize {
    SolverKind::ALL
        .iter()
        .position(|&k| k == kind)
        .expect("SolverKind::ALL covers every kind")
}

/// The router-side learning layer: per-bucket [`WorkloadProfile`]s
/// behind one mutex, shared (via `Arc`) between the replica schedulers
/// (writers) and the TCP `stats` path (readers).
#[derive(Debug, Default)]
pub struct ProfileStore {
    buckets: Mutex<BTreeMap<usize, WorkloadProfile>>,
}

impl ProfileStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> MutexGuard<'_, BTreeMap<usize, WorkloadProfile>> {
        // A poisoned profile map only ever holds finished EWMA updates —
        // recover the data rather than cascading the panic.
        self.buckets.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The prior a new Auto lane in `bucket` should start from (library
    /// defaults until the bucket has retired lanes).
    pub fn prior(&self, bucket: usize) -> WorkloadPrior {
        self.lock()
            .get(&bucket)
            .map(WorkloadProfile::prior)
            .unwrap_or_default()
    }

    /// Record one retired lane: kind histogram, iters/fevals EWMAs, and
    /// (for Auto lanes) the controller's fitted rate, observed speedup
    /// and switch outcomes.
    pub fn record_retirement(
        &self,
        bucket: usize,
        kind: SolverKind,
        iters: usize,
        fevals: usize,
        auto: Option<AutoStats>,
    ) {
        let mut map = self.lock();
        let p = map.entry(bucket).or_default();
        p.lanes += 1;
        p.retired[kind_index(kind)] += 1;
        p.iters.push(iters as f32);
        p.fevals.push(fevals as f32);
        if let Some(a) = auto {
            p.switches += a.switches;
            if a.active == SolverKind::Anderson {
                p.auto_on_anderson += 1;
            }
            if let Some(r) = a.decay_rate {
                p.decay.push(r);
            }
            if let Some(s) = a.anderson_speedup {
                p.speedup.push(s);
            }
        }
    }

    /// Record one scheduler iteration's measured cost: `secs_per_lane`
    /// wallclock divided by occupied lanes, attributed to the mixed or
    /// forward-only cost EWMA.  The ratio of the two is the bucket's
    /// live mixing penalty.
    pub fn record_iteration_cost(
        &self,
        bucket: usize,
        mixed: bool,
        secs_per_lane: f64,
    ) {
        let mut map = self.lock();
        let p = map.entry(bucket).or_default();
        let cost = secs_per_lane as f32;
        if mixed {
            p.cost_mix.push(cost);
        } else {
            p.cost_fwd.push(cost);
        }
    }

    /// Snapshot every bucket's profile (bucket-ascending) for stats.
    pub fn snapshot(&self) -> Vec<(usize, WorkloadProfile)> {
        self.lock().iter().map(|(&b, &p)| (b, p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn auto_spec(tol: f32, window: usize) -> SolveSpec {
        SolveSpec { tol, window, ..SolveSpec::new(SolverKind::Auto) }
    }

    #[test]
    fn fit_rate_recovers_geometric_decay() {
        let trace: Vec<f32> = (0..6).map(|k| 0.5f32.powi(k)).collect();
        let rho = fit_rate(&trace).unwrap();
        assert!((rho - 0.5).abs() < 1e-3, "rho = {rho}");
        assert!(fit_rate(&[1.0]).is_none());
        assert!(fit_rate(&[]).is_none());
        // Non-finite and non-positive points are skipped, not fatal.
        let rho = fit_rate(&[1.0, f32::NAN, 0.0, 4.0, 2.0]).unwrap();
        assert!((rho - 0.5).abs() < 1e-3);
    }

    #[test]
    fn probe_steps_are_forward() {
        // Easy decay close to tol: the whole probe (and beyond) stays on
        // the forward side, and the probe leaves a fitted rate behind.
        let mut p = AutoPolicy::new(&auto_spec(1e-1, 5));
        assert_eq!(p.kind(), SolverKind::Auto);
        assert!(p.uses_history());
        for k in 0..PROBE_ITERS {
            let step = p.observe(0.5f32.powi(k as i32));
            assert_eq!(step, LaneStep::Forward { beta: 1.0 }, "probe {k}");
        }
        let rho = p.auto_stats().unwrap().decay_rate.unwrap();
        assert!((rho - 0.5).abs() < 0.05, "fitted rho = {rho}");
    }

    #[test]
    fn easy_lane_stays_forward() {
        // Fast decay, one decade to tol: the penalty never amortizes.
        let mut p = AutoPolicy::new(&auto_spec(1e-1, 5));
        for k in 0..12 {
            let step = p.observe(0.5f32.powi(k));
            assert!(
                matches!(step, LaneStep::Forward { .. }),
                "iter {k} switched: {step:?}"
            );
        }
        assert_eq!(p.switches(), 0);
        assert!(p.auto_stats().unwrap().window_depth.is_none());
    }

    #[test]
    fn stiff_lane_crosses_to_anderson_with_bounded_depth() {
        // Slow decay, six decades to tol: Anderson side of Fig. 1.
        let mut p = AutoPolicy::new(&auto_spec(1e-6, 5));
        let mut mixed_at = None;
        for k in 0..20 {
            if p.observe(0.99f32.powi(k)).mixes() {
                mixed_at = Some(k);
                break;
            }
        }
        let k = mixed_at.expect("stiff lane never crossed to Anderson");
        // The first crossover decision lands on observation PROBE_ITERS
        // (index PROBE_ITERS − 1): never earlier.
        assert!(k as usize >= PROBE_ITERS - 1, "switched inside the probe");
        assert_eq!(p.switches(), 1);
        let stats = p.auto_stats().unwrap();
        assert_eq!(stats.active, SolverKind::Anderson);
        let depth = stats.window_depth.unwrap();
        assert!((2..=5).contains(&depth), "depth {depth} out of range");
    }

    #[test]
    fn diverging_probe_forces_anderson() {
        let mut p = AutoPolicy::new(&auto_spec(1e-3, 4));
        let mut mixed = false;
        for k in 0..10 {
            // Residual growing: forward will never converge.
            if p.observe(1.0 + 0.1 * k as f32).mixes() {
                mixed = true;
                break;
            }
        }
        assert!(mixed, "non-contracting lane never switched to Anderson");
    }

    #[test]
    fn post_mix_rise_takes_safeguarded_step_and_resumes() {
        let mut p = AutoPolicy::new(&auto_spec(1e-6, 5));
        let mut rel = 1.0f32;
        // Drive to the Anderson side.
        while !p.observe(rel).mixes() {
            rel *= 0.99;
        }
        // A mixed step that regresses: plain damped step, window kept.
        assert_eq!(p.observe(rel * 1.5), LaneStep::Forward { beta: 1.0 });
        assert_eq!(p.safeguard_steps(), 1);
        assert!(p.is_mixing(), "safeguard must not leave the mixed phase");
        // The safeguard never judges its own step.
        assert!(p.observe(rel * 1.6).mixes());
    }

    #[test]
    fn unproductive_mixing_switches_back_to_forward() {
        let mut p = AutoPolicy::new(&auto_spec(1e-6, 4));
        let mut rel = 1.0f32;
        while !p.observe(rel).mixes() {
            rel *= 0.99;
        }
        // Anderson delivers no speedup at all: a slowly *decaying* flat
        // trajectory (never rising, so the safeguard stays out of the
        // way) whose rate matches plain forward.
        let mut back = false;
        for _ in 0..3 * PROBE_ITERS + p.depth {
            rel *= 0.995;
            if !p.observe(rel).mixes() {
                back = true;
                break;
            }
        }
        assert!(back, "unproductive mixing never crossed back");
        assert!(!p.is_mixing());
        assert_eq!(p.switches(), 2);
    }

    #[test]
    fn switch_count_is_capped() {
        let mut p = AutoPolicy::new(&auto_spec(1e-6, 4));
        // An adversarial trajectory that keeps re-crossing: slow decay
        // everywhere, so forward always wants Anderson and mixing never
        // delivers speedup.
        let mut rel = 1.0f32;
        for _ in 0..400 {
            rel *= 0.999;
            p.observe(rel);
        }
        assert!(p.switches() <= MAX_SWITCHES);
    }

    #[test]
    fn reset_rearms_the_probe_and_keeps_the_prior() {
        let prior = WorkloadPrior {
            decay_rate: 0.95,
            mixing_penalty: 2.0,
            anderson_speedup: 6.0,
        };
        let mut p = AutoPolicy::with_prior(&auto_spec(1e-6, 5), prior);
        let mut rel = 1.0f32;
        while !p.observe(rel).mixes() {
            rel *= 0.99;
        }
        assert!(p.switches() > 0);
        p.reset();
        assert_eq!(p.switches(), 0);
        assert!(!p.is_mixing());
        assert_eq!(p.prior, prior);
        assert_eq!(p.observe(1.0), LaneStep::Forward { beta: 1.0 });
    }

    #[test]
    fn profile_store_learns_and_seeds_priors() {
        let store = ProfileStore::new();
        // Unseen bucket: library defaults.
        assert_eq!(store.prior(8), WorkloadPrior::default());
        // Iteration costs: mixed iterations cost 2x forward ones.
        for _ in 0..8 {
            store.record_iteration_cost(8, false, 1e-4);
            store.record_iteration_cost(8, true, 2e-4);
        }
        let auto = AutoStats {
            switches: 1,
            active: SolverKind::Anderson,
            decay_rate: Some(0.97),
            anderson_speedup: Some(5.0),
            window_depth: Some(3),
        };
        store.record_retirement(8, SolverKind::Auto, 30, 31, Some(auto));
        store.record_retirement(8, SolverKind::Anderson, 12, 13, None);
        let prior = store.prior(8);
        assert!((prior.decay_rate - 0.97).abs() < 1e-6);
        assert!((prior.mixing_penalty - 2.0).abs() < 1e-2);
        assert!((prior.anderson_speedup - 5.0).abs() < 1e-6);
        let snap = store.snapshot();
        assert_eq!(snap.len(), 1);
        let (bucket, profile) = snap[0];
        assert_eq!(bucket, 8);
        assert_eq!(profile.lanes, 2);
        assert_eq!(profile.switches, 1);
        assert_eq!(profile.auto_on_anderson, 1);
        assert_eq!(profile.retired[kind_index(SolverKind::Auto)], 1);
        assert_eq!(profile.retired[kind_index(SolverKind::Anderson)], 1);
        assert_eq!(profile.retired[kind_index(SolverKind::Forward)], 0);
        assert!(profile.mean_iters().unwrap() > 0.0);
        assert!(profile.mean_fevals().unwrap() > 0.0);
    }

    #[test]
    fn profile_penalty_is_floored_at_one_in_the_prior() {
        let store = ProfileStore::new();
        // Timing noise put the mixed cost *below* forward: the prior
        // must not report a sub-1 penalty (a free Anderson step).
        store.record_iteration_cost(0, false, 2e-4);
        store.record_iteration_cost(0, true, 1e-4);
        assert!(store.snapshot()[0].1.mixing_penalty().unwrap() < 1.0);
        assert!(store.prior(0).mixing_penalty >= 1.0);
    }
}

//! The one generic equilibrium-solve loop, parameterized by
//! [`SolvePolicy`] — the collapse of the old `forward.rs` / `anderson.rs`
//! / `policy.rs` driver triplet.
//!
//! The loop owns everything the three drivers shared: the cell-input
//! slots (canonical iterate + features), the per-sample residual track
//! with lane freezing, the step trace, the feval budget, and the
//! recycle discipline that keeps steady-state iterations allocation-free.
//! The policy owns only the *decision*: after each evaluation it returns
//! a [`LaneStep`] — mix through the history window, take a (possibly
//! damped) forward step, or restart the window.
//!
//! Trace compatibility: with the default spec knobs (no damping, no
//! restart) the loop performs exactly the pre-redesign drivers' backend
//! calls in the same order, so forward/anderson/hybrid reports are
//! bit-identical to the old per-kind drivers.  For hybrid batch solves
//! the policy observes the *cohort max* residual — the whole batch
//! crosses over together, as before; per-lane crossover lives in the
//! iteration-level scheduler, where each lane owns a policy instance.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Backend, HostTensor};
use crate::solver::anderson::History;
use crate::solver::policy::{policy_for, LaneStep, SolvePolicy};
use crate::solver::spec::SolveSpec;
use crate::solver::{ResidualTrack, SolveReport, SolveStep};

/// Solve the equilibrium described by `spec`: validates, builds the
/// spec's policy, and runs the generic driver loop.
pub fn solve_spec(
    engine: &dyn Backend,
    params: &[HostTensor],
    x_feat: &HostTensor,
    spec: &SolveSpec,
) -> Result<SolveReport> {
    spec.validate()?;
    let mut policy = policy_for(spec);
    drive(engine, params, x_feat, spec, &mut *policy)
}

/// The damped forward update z ← z + β·(f − z), in place over one flat
/// row: `f_row` holds f(z) on entry and the damped iterate on exit.
/// The single numeric definition shared by the batch driver (masked
/// whole-tensor blends) and the scheduler (per-lane row blends).
pub fn damp_in_place(f_row: &mut [f32], z_row: &[f32], beta: f32) {
    debug_assert_eq!(f_row.len(), z_row.len());
    for (fv, &zv) in f_row.iter_mut().zip(z_row) {
        *fv = zv + beta * (*fv - zv);
    }
}

/// [`damp_in_place`] over selected rows: `next` holds f(z) on entry and
/// z + β·(f − z) for each selected row on exit.
fn damped_rows(
    next: &mut HostTensor,
    z: &HostTensor,
    beta: f32,
    rows: &[bool],
) -> Result<()> {
    let rw = next.row_len();
    let zs = z.f32s()?;
    let nf = next.f32s_mut()?;
    anyhow::ensure!(
        zs.len() == nf.len(),
        "damped blend over mismatched tensors ({} vs {})",
        zs.len(),
        nf.len()
    );
    for (i, &sel) in rows.iter().enumerate() {
        if !sel {
            continue;
        }
        damp_in_place(&mut nf[i * rw..(i + 1) * rw], &zs[i * rw..(i + 1) * rw], beta);
    }
    Ok(())
}

/// The generic driver loop over an explicit policy instance.  Most
/// callers want [`solve_spec`]; this entry exists so custom
/// [`SolvePolicy`] implementations can ride the same loop.
pub fn drive<P: SolvePolicy + ?Sized>(
    engine: &dyn Backend,
    params: &[HostTensor],
    x_feat: &HostTensor,
    spec: &SolveSpec,
    policy: &mut P,
) -> Result<SolveReport> {
    let batch = x_feat.shape[0];
    let meta = engine.manifest().model.clone();
    let n = meta.latent_dim();
    let m = spec.window;
    let compiled_m = engine.manifest().solver.window;
    let uses_history = policy.uses_history();
    if uses_history {
        // The anderson_update artifact is compiled for the manifest
        // window; smaller runtime windows ride the same artifact through
        // the mask (the kernel zeroes masked slots exactly), enabling
        // window ablations without recompiling.
        anyhow::ensure!(
            m <= compiled_m,
            "anderson window {m} > compiled window {compiled_m} \
             (rebuild artifacts with a larger SolverConfig.window)"
        );
    }

    // The canonical iterate lives in the cell-input slot; each step moves
    // the next iterate in and recycles the previous one, and the
    // anderson_update inputs are preallocated and refilled in place, so
    // the steady-state loop performs no bucket-sized allocation (the
    // backend pool absorbs the rest — see tests/native_kernels.rs).
    let mut cell_inputs: Vec<HostTensor> = params.to_vec();
    let z_slot = cell_inputs.len();
    cell_inputs.push(HostTensor::zeros(x_feat.shape.clone()));
    cell_inputs.push(x_feat.clone());
    let mut hist = uses_history
        .then(|| History::with_padded_slots(batch, m, compiled_m, n));
    let mut and_inputs: Option<[HostTensor; 3]> = uses_history.then(|| {
        [
            HostTensor::zeros(vec![batch, compiled_m, n]),
            HostTensor::zeros(vec![batch, compiled_m, n]),
            HostTensor::zeros(vec![compiled_m]),
        ]
    });

    let mut steps: Vec<SolveStep> = Vec::new();
    let mut track = ResidualTrack::new(batch, spec.tol);
    let mut fevals = 0usize;
    // The dispatch entry is fixed for the whole solve (engine, batch and
    // spec don't change mid-drive), so resolve it once, not per
    // iteration of the hot loop.
    let (step_entry, step_evals) = policy.step_entry(engine, batch);
    let t0 = Instant::now();

    // `all_settled` — converged OR quarantined — so one lane going
    // non-finite cannot keep the whole cohort iterating forever (nor
    // stall it: its NaN never reaches the cohort max-residual).
    while fevals < spec.max_iter
        && (spec.max_fevals == 0 || fevals < spec.max_fevals)
        && !track.all_settled()
    {
        // --- one cell evaluation (possibly fused) + fused norms ---
        // `max_fevals` is a *hard* budget: a fused dispatch that would
        // overshoot it downgrades to single steps.  (`max_iter` keeps
        // the historical checked-between-dispatches semantics, which
        // fused forward solves may overshoot by up to K−1.)
        let (entry, evals) =
            if spec.max_fevals > 0 && fevals + step_evals > spec.max_fevals {
                ("cell_step", 1)
            } else {
                (step_entry, step_evals)
            };
        let mut out = engine.execute(entry, batch, &cell_inputs)?;
        let fnorm = out.pop().expect("cell entries return 3 outputs");
        let res = out.pop().expect("cell entries return 3 outputs");
        let f = out.pop().expect("cell entries return 3 outputs");
        let (rel, freeze) = track.observe_step(&res, &fnorm, spec.lam, evals)?;
        engine.recycle(vec![res, fnorm]);
        fevals += evals;
        // `mixed` is back-filled below once mixing actually runs, so the
        // flag describes the update that produced THIS step's next
        // iterate: the terminal (converged) step takes f directly and
        // stays unmixed, while step 0 is mixed as soon as its (z, f)
        // pair enters the window.
        steps.push(SolveStep {
            iter: steps.len(),
            rel_residual: track.max_rel(),
            sample_residuals: rel,
            active: track.active_count(),
            elapsed: t0.elapsed(),
            fevals,
            mixed: false,
        });
        if track.all_settled() {
            // Lanes that converged (or faulted) this step take f as
            // their terminal iterate; lanes frozen earlier already hold
            // theirs.  A faulted lane's row is garbage either way — the
            // report flags it via `sample_faulted`.
            cell_inputs[z_slot].overwrite_rows_where(&f, &freeze.newly_frozen)?;
            engine.recycle(vec![f]);
            break;
        }

        // --- policy decision on the cohort's max residual ---
        let action = policy.observe(track.max_rel());
        match action {
            LaneStep::Forward { beta } => {
                // Lanes active this step (newly frozen included) take f —
                // damped toward z for still-active lanes when β < 1 —
                // and lanes frozen earlier keep their converged iterate.
                let mut next = f;
                if beta < 1.0 {
                    let still_active: Vec<bool> = freeze
                        .frozen_before
                        .iter()
                        .zip(&freeze.newly_frozen)
                        .map(|(a, b)| !a && !b)
                        .collect();
                    damped_rows(
                        &mut next,
                        &cell_inputs[z_slot],
                        beta,
                        &still_active,
                    )?;
                }
                next.overwrite_rows_where(
                    &cell_inputs[z_slot],
                    &freeze.frozen_before,
                )?;
                let prev = std::mem::replace(&mut cell_inputs[z_slot], next);
                engine.recycle(vec![prev]);
            }
            LaneStep::Mix | LaneStep::Restart => {
                let hist = hist.as_mut().ok_or_else(|| {
                    anyhow::anyhow!(
                        "policy requested mixing but declared uses_history() == false"
                    )
                })?;
                let and_inputs = and_inputs
                    .as_mut()
                    .expect("history and mix inputs are allocated together");
                if action == LaneStep::Restart {
                    hist.reset();
                }
                // Window update + Anderson mixing for still-active lanes
                // only: frozen lanes' history stops updating and their
                // rows of the mixed output are discarded below.
                hist.push_where(
                    cell_inputs[z_slot].f32s()?,
                    f.f32s()?,
                    &track.active_mask(),
                );
                // Adaptive policies prune the window before the mix:
                // the keep-mask holes reach the kernel through the mask
                // tensor.  Fixed-window policies return None and the
                // packed mask stays the plain valid-prefix, keeping
                // their traces bit-identical.
                if let Some(rule) = policy.window_rule() {
                    hist.adapt(rule, spec.lam);
                }
                // The auto-selection controller additionally caps the
                // mixing depth at the window it sized from the predicted
                // remaining decades; static policies return None and the
                // mask is untouched.
                if let Some(depth) = policy.window_depth() {
                    hist.truncate(depth);
                }
                {
                    let [xh, fh, mask] = &mut *and_inputs;
                    hist.fill_tensors(xh, fh, mask)?;
                }
                let mut update =
                    engine.execute("anderson_update", batch, &and_inputs[..])?;
                let alpha =
                    update.pop().expect("anderson_update returns 2 outputs");
                let zmix =
                    update.pop().expect("anderson_update returns 2 outputs");
                engine.recycle(vec![alpha]);
                let mut next = zmix.reshaped(meta.latent_shape(batch))?;
                freeze.apply(&mut next, &f, &cell_inputs[z_slot])?;
                let prev = std::mem::replace(&mut cell_inputs[z_slot], next);
                engine.recycle(vec![prev, f]);
            }
        }
        if action.mixes() {
            steps.last_mut().expect("step recorded above").mixed = true;
        }
    }

    let z = cell_inputs.swap_remove(z_slot);
    Ok(SolveReport::from_track(policy.kind(), steps, z, &track))
}
